"""Model configurations shared by the compile path and (via manifest.json)
the Rust coordinator.

The paper deploys BitNet 0.73B on the KV260; we AOT-compile functional
artifacts for three smaller configs (CPU-PJRT is the execution substrate)
and keep ``bitnet-0.73b`` as a simulator-only workload description — its
timing behaviour is modeled analytically in ``rust/src/engines`` exactly as
the paper's Eqs. 3–5 do.
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A BitNet-style ternary transformer configuration."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    max_seq: int              # decode KV-cache capacity
    prefill_buckets: List[int]  # compiled prefill lengths (ascending)
    attn_block: int           # Pallas attention block size (bq = bk)
    tlmm_block_m: int = 128
    tlmm_block_n: int = 128
    rope_base: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Approximate parameter count (ternary linears + fp embeddings)."""
        attn = 4 * self.d_model * self.d_model
        ffn = 3 * self.d_model * self.d_ff
        return self.n_layers * (attn + ffn) + self.vocab * self.d_model

    def validate(self) -> None:
        assert self.d_model % 4 == 0 and self.d_ff % 4 == 0, "TLMM pack=4"
        assert self.head_dim % 2 == 0, "RoPE needs even head_dim"
        for b in self.prefill_buckets:
            assert b % self.attn_block == 0, (b, self.attn_block)
            assert b <= self.max_seq
        assert self.max_seq % self.attn_block == 0
        assert self.prefill_buckets == sorted(self.prefill_buckets)


# AOT-compiled configs (functional artifacts exist for these).
CONFIGS = {
    # 2-layer toy used by pytest and cargo-test integration tests.
    "test": ModelConfig(
        name="test", n_layers=2, d_model=128, n_heads=4, d_ff=384,
        vocab=256, max_seq=32, prefill_buckets=[8, 16], attn_block=8,
        tlmm_block_m=8, tlmm_block_n=64,
    ),
    # Quickstart-scale model (~3.3M ternary + embeddings).
    "tiny": ModelConfig(
        name="tiny", n_layers=4, d_model=256, n_heads=4, d_ff=768,
        vocab=2048, max_seq=128, prefill_buckets=[32, 64], attn_block=16,
        tlmm_block_m=32, tlmm_block_n=128,
    ),
    # ~103M-parameter model for the end-to-end serving driver.
    "e2e-100m": ModelConfig(
        name="e2e-100m", n_layers=10, d_model=768, n_heads=12, d_ff=3072,
        vocab=8192, max_seq=640, prefill_buckets=[128, 256, 512],
        attn_block=64, tlmm_block_m=64, tlmm_block_n=128,
    ),
    # Paper model — simulator workload only (no PJRT artifact by default;
    # `aot.py --config bitnet-0.73b` will happily compile it if you have
    # the patience and RAM).
    "bitnet-0.73b": ModelConfig(
        name="bitnet-0.73b", n_layers=24, d_model=1536, n_heads=24,
        d_ff=4096, vocab=32000, max_seq=2048,
        prefill_buckets=[128, 256, 512, 1024, 2048], attn_block=64,
    ),
}

# Configs `make artifacts` builds by default.
DEFAULT_AOT = ["test", "tiny", "e2e-100m"]

for _c in CONFIGS.values():
    _c.validate()
