"""Synthetic weight generation + the ``weights.bin`` serialization format.

The paper loads real BitNet 0.73B checkpoints; we have no weights (and the
accelerator's performance does not depend on their values — DESIGN.md §2),
so ``aot.py`` generates seeded synthetic ternary weights here and dumps them
in a simple binary format the Rust runtime reads directly. Keeping
generation + packing on the Python side means the base-3 pack logic exists
in exactly one place (``kernels/ref.py``) and Rust never re-implements it.

``weights.bin`` layout (all little-endian):

    bytes 0..8    magic b"PDSWAP01"
    bytes 8..16   u64 header_len
    bytes 16..16+header_len   JSON header (utf-8):
        {"config": "<name>", "tensors": [
            {"name", "shape", "dtype" ("f32"|"u8"|"i32"), "offset", "nbytes"},
            ...  # in model.WEIGHT_ORDER
        ]}
    then raw tensor data; each tensor starts at `offset` bytes past the end
    of the header, offsets 64-byte aligned, row-major (C) order.
"""

from __future__ import annotations

import json
from typing import Dict

import numpy as np

from .configs import ModelConfig
from .model import WEIGHT_ORDER, weight_specs

MAGIC = b"PDSWAP01"
ALIGN = 64

_DTYPE_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.uint8): "u8",
                np.dtype(np.int32): "i32"}


def _pack_ternary_np(w_t: np.ndarray) -> np.ndarray:
    """numpy mirror of kernels.ref.pack_ternary (asserted equal in pytest)."""
    n, k = w_t.shape
    assert k % 4 == 0
    digits = (w_t.astype(np.int32) + 1).reshape(n, k // 4, 4)
    weights = 3 ** np.arange(4, dtype=np.int32)
    return np.sum(digits * weights, axis=-1).astype(np.uint8)


def _ternarize_np(w_f: np.ndarray):
    """numpy mirror of kernels.ref.ternarize."""
    sw = max(float(np.mean(np.abs(w_f))), 1e-8)
    w_t = np.clip(np.round(w_f / sw), -1, 1).astype(np.int8)
    return w_t, np.float32(sw)


def generate(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Seeded synthetic weights for every WEIGHT_ORDER entry.

    Linear weights are gaussians scaled 1/sqrt(fan_in) then BitNet
    absmean-ternarized; norms start at 1; embeddings are small gaussians.
    """
    rng = np.random.RandomState(seed)
    specs = weight_specs(cfg)
    out: Dict[str, np.ndarray] = {}

    out["tok_emb"] = (rng.randn(*specs["tok_emb"][0]) * 0.05).astype(np.float32)
    out["final_norm_g"] = np.ones(specs["final_norm_g"][0], np.float32)
    out["attn_norm_g"] = np.ones(specs["attn_norm_g"][0], np.float32)
    out["ffn_norm_g"] = np.ones(specs["ffn_norm_g"][0], np.float32)

    for base in ("wq", "wk", "wv", "wo", "w1", "w3", "w2"):
        codes_name, scale_name = f"{base}_codes", f"{base}_scale"
        nl, n, kp = specs[codes_name][0]
        k = kp * 4
        codes = np.empty((nl, n, kp), np.uint8)
        scales = np.empty((nl,), np.float32)
        for layer in range(nl):
            w_f = rng.randn(n, k).astype(np.float32) / np.sqrt(k)
            w_t, sw = _ternarize_np(w_f)
            codes[layer] = _pack_ternary_np(w_t)
            scales[layer] = sw
        out[codes_name] = codes
        out[scale_name] = scales

    # Shape/dtype sanity against the model's declared specs.
    for name in WEIGHT_ORDER:
        shape, dtype = specs[name]
        assert out[name].shape == tuple(shape), name
        assert out[name].dtype == np.dtype(dtype), name
    return out


def save(path: str, cfg: ModelConfig, weights: Dict[str, np.ndarray]) -> None:
    """Serialize weights in WEIGHT_ORDER to ``path`` (format above)."""
    tensors = []
    offset = 0
    for name in WEIGHT_ORDER:
        arr = np.ascontiguousarray(weights[name])
        offset = (offset + ALIGN - 1) // ALIGN * ALIGN
        tensors.append({
            "name": name,
            "shape": list(arr.shape),
            "dtype": _DTYPE_NAMES[arr.dtype],
            "offset": offset,
            "nbytes": arr.nbytes,
        })
        offset += arr.nbytes
    header = json.dumps({"config": cfg.name, "tensors": tensors}).encode()

    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(len(header).to_bytes(8, "little"))
        f.write(header)
        data_start = f.tell()
        for name, meta in zip(WEIGHT_ORDER, tensors):
            f.seek(data_start + meta["offset"])
            f.write(np.ascontiguousarray(weights[name]).tobytes())


def load(path: str) -> Dict[str, np.ndarray]:
    """Read a ``weights.bin`` back (used by pytest round-trip checks)."""
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        header_len = int.from_bytes(f.read(8), "little")
        header = json.loads(f.read(header_len))
        data_start = f.tell()
        out = {}
        np_dtypes = {"f32": np.float32, "u8": np.uint8, "i32": np.int32}
        for meta in header["tensors"]:
            f.seek(data_start + meta["offset"])
            raw = f.read(meta["nbytes"])
            out[meta["name"]] = np.frombuffer(
                raw, dtype=np_dtypes[meta["dtype"]]
            ).reshape(meta["shape"])
    return out
