"""AOT compile path: lower the L2 model to HLO **text** artifacts.

Run once by ``make artifacts``; Python never appears on the request path.

Interchange is HLO text, NOT ``lowered.compile()`` / ``.serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the Rust
side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md
and gen_hlo.py there).

Per config this emits into ``<out>/<config>/``:

* ``prefill_L{bucket}.hlo.txt`` — one shape-specialized prefill executable
  per bucket length (the Rust coordinator picks the smallest bucket that
  fits the prompt and right-pads).
* ``decode.hlo.txt`` — the single-token autoregressive step.
* ``weights.bin`` — seeded synthetic ternary weights (weights.py format).
* ``manifest.json`` — everything the Rust side needs: weight order, IO
  specs, bucket table, file names.
* ``golden.json`` (``--golden``) — greedy generation trace computed here
  with the same jitted functions, asserted bit-for-bit-ish by the Rust
  integration tests (cross-layer correctness signal).

Usage: ``python -m compile.aot --out ../artifacts [--config test ...]``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import weights as weights_mod
from .configs import CONFIGS, DEFAULT_AOT, ModelConfig
from .model import WEIGHT_ORDER, make_decode_fn, make_prefill_fn, weight_specs

_DT = {"f32": jnp.float32, "u8": jnp.uint8, "i32": jnp.int32}
_DT_NAMES = {jnp.float32: "f32", jnp.uint8: "u8", jnp.int32: "i32"}


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _weight_arg_specs(cfg: ModelConfig):
    specs = weight_specs(cfg)
    return [jax.ShapeDtypeStruct(*specs[n]) for n in WEIGHT_ORDER]


def _cache_spec(cfg: ModelConfig):
    return jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32
    )


def lower_prefill(cfg: ModelConfig, bucket: int) -> str:
    fn = make_prefill_fn(cfg, bucket)
    args = _weight_arg_specs(cfg) + [
        jax.ShapeDtypeStruct((bucket,), jnp.int32),   # tokens
        jax.ShapeDtypeStruct((), jnp.int32),          # prompt_len
    ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_decode(cfg: ModelConfig) -> str:
    fn = make_decode_fn(cfg)
    args = _weight_arg_specs(cfg) + [
        jax.ShapeDtypeStruct((), jnp.int32),          # token
        jax.ShapeDtypeStruct((), jnp.int32),          # pos
        _cache_spec(cfg),                             # k_cache
        _cache_spec(cfg),                             # v_cache
    ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def make_manifest(cfg: ModelConfig, golden: bool) -> dict:
    cache_shape = [cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim]
    specs = weight_specs(cfg)
    return {
        "format_version": 1,
        "config": dataclasses.asdict(cfg),
        "head_dim": cfg.head_dim,
        "n_params": cfg.n_params,
        "weights_file": "weights.bin",
        "weight_order": [
            {
                "name": n,
                "shape": list(specs[n][0]),
                "dtype": _DT_NAMES[specs[n][1]],
            }
            for n in WEIGHT_ORDER
        ],
        "entrypoints": {
            "prefill": [
                {"bucket": b, "file": f"prefill_L{b}.hlo.txt"}
                for b in cfg.prefill_buckets
            ],
            "decode": "decode.hlo.txt",
        },
        "io": {
            "prefill_inputs": ["<weights...>", "tokens i32[bucket]",
                               "prompt_len i32[]"],
            "prefill_outputs": [
                f"logits f32[{cfg.vocab}]",
                f"k_cache f32{cache_shape}",
                f"v_cache f32{cache_shape}",
            ],
            "decode_inputs": ["<weights...>", "token i32[]", "pos i32[]",
                              f"k_cache f32{cache_shape}",
                              f"v_cache f32{cache_shape}"],
            "decode_outputs": [
                f"logits f32[{cfg.vocab}]",
                f"k_cache f32{cache_shape}",
                f"v_cache f32{cache_shape}",
            ],
            "cache_shape": cache_shape,
            "vocab": cfg.vocab,
        },
        "golden": "golden.json" if golden else None,
    }


def make_golden(cfg: ModelConfig, weights: dict, n_gen: int = 8,
                prompt=None) -> dict:
    """Greedy-generate with the jitted (Pallas) functions as ground truth."""
    w = [jnp.asarray(weights[n]) for n in WEIGHT_ORDER]
    prompt = prompt if prompt is not None else [1, 2, 3, 4, 5]
    bucket = next(b for b in cfg.prefill_buckets if b >= len(prompt))
    toks = np.zeros(bucket, np.int32)
    toks[: len(prompt)] = prompt

    prefill_fn = jax.jit(make_prefill_fn(cfg, bucket))
    decode_fn = jax.jit(make_decode_fn(cfg))

    logits, kc, vc = prefill_fn(*w, jnp.asarray(toks),
                                jnp.int32(len(prompt)))
    first_logits = np.asarray(logits[:8], np.float32)
    generated = []
    tok = int(jnp.argmax(logits))
    pos = len(prompt)
    for _ in range(n_gen):
        generated.append(tok)
        if pos >= cfg.max_seq:
            break
        logits, kc, vc = decode_fn(*w, jnp.int32(tok), jnp.int32(pos), kc, vc)
        tok = int(jnp.argmax(logits))
        pos += 1
    return {
        "prompt": list(map(int, prompt)),
        "bucket": bucket,
        "generated": generated,
        "first_logits_prefix": [float(x) for x in first_logits],
        "n_gen": len(generated),
    }


def build_config(cfg: ModelConfig, out_dir: str, seed: int,
                 golden: bool) -> None:
    cdir = os.path.join(out_dir, cfg.name)
    os.makedirs(cdir, exist_ok=True)
    print(f"[aot] {cfg.name}: generating weights (seed={seed}) ...",
          flush=True)
    w = weights_mod.generate(cfg, seed=seed)
    weights_mod.save(os.path.join(cdir, "weights.bin"), cfg, w)

    for b in cfg.prefill_buckets:
        print(f"[aot] {cfg.name}: lowering prefill L={b} ...", flush=True)
        text = lower_prefill(cfg, b)
        with open(os.path.join(cdir, f"prefill_L{b}.hlo.txt"), "w") as f:
            f.write(text)
    print(f"[aot] {cfg.name}: lowering decode ...", flush=True)
    with open(os.path.join(cdir, "decode.hlo.txt"), "w") as f:
        f.write(lower_decode(cfg))

    if golden:
        print(f"[aot] {cfg.name}: computing golden trace ...", flush=True)
        g = make_golden(cfg, w)
        with open(os.path.join(cdir, "golden.json"), "w") as f:
            json.dump(g, f, indent=1)

    with open(os.path.join(cdir, "manifest.json"), "w") as f:
        json.dump(make_manifest(cfg, golden), f, indent=1)
    print(f"[aot] {cfg.name}: done -> {cdir}", flush=True)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--config", action="append",
                   help=f"one of {sorted(CONFIGS)} (repeatable); "
                        f"default {DEFAULT_AOT}")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--no-golden", action="store_true",
                   help="skip golden traces (they run the interpret-mode "
                        "model in python, which is slow for big configs)")
    args = p.parse_args()

    names = args.config or DEFAULT_AOT
    for name in names:
        cfg = CONFIGS[name]
        # Golden only for configs where interpret-mode generation is cheap.
        golden = (not args.no_golden) and name in ("test", "tiny")
        build_config(cfg, args.out, args.seed, golden)


if __name__ == "__main__":
    main()
