"""Layer-2 JAX model: BitNet-style ternary transformer, prefill + decode
graphs, calling the Layer-1 Pallas kernels.

Build-time only. ``aot.py`` lowers :func:`make_prefill_fn` (once per prefill
bucket length) and :func:`make_decode_fn` (once) to HLO text; the Rust
coordinator executes those artifacts via PJRT and never sees Python.

Graph contracts (positional HLO parameters — order is WEIGHT_ORDER then the
per-call inputs; recorded in manifest.json for the Rust side):

* prefill(W..., tokens i32[L], prompt_len i32[]) ->
      (logits f32[vocab], k_cache f32[nl,H,max_seq,dh], v_cache same)
  The prompt is right-padded to the bucket length L; causal masking keeps
  the logits at ``prompt_len-1`` exact, and cache rows >= prompt_len are
  garbage that the decode kernel masks away by ``length``.
* decode(W..., token i32[], pos i32[], k_cache, v_cache) ->
      (logits f32[vocab], k_cache', v_cache')
  One autoregressive step: inserts the token's K/V at ``pos`` and attends
  to positions ``0..pos``.

Layers are folded with ``lax.scan`` over stacked per-layer weights so the
HLO size is independent of depth (24-layer BitNet lowers as cheaply as the
2-layer test config).
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.decode_attention import decode_attention
from .kernels.prefill_attention import prefill_attention
from .kernels.rmsnorm import rmsnorm_quant
from .kernels.tlmm import tlmm

# Flat positional parameter order of the HLO artifacts. Entries with a
# leading ``nl`` axis are per-layer stacks consumed by lax.scan.
WEIGHT_ORDER: List[str] = [
    "tok_emb",        # [vocab, d] f32 (tied embedding / lm head)
    "final_norm_g",   # [d] f32
    "attn_norm_g",    # [nl, d] f32
    "wq_codes",       # [nl, d, d//4] u8
    "wq_scale",       # [nl] f32
    "wk_codes", "wk_scale",
    "wv_codes", "wv_scale",
    "wo_codes", "wo_scale",
    "ffn_norm_g",     # [nl, d] f32
    "w1_codes",       # [nl, d_ff, d//4] u8  (SwiGLU gate)
    "w1_scale",
    "w3_codes",       # [nl, d_ff, d//4] u8  (SwiGLU up)
    "w3_scale",
    "w2_codes",       # [nl, d, d_ff//4] u8  (SwiGLU down)
    "w2_scale",
]

PER_LAYER = [n for n in WEIGHT_ORDER if n not in ("tok_emb", "final_norm_g")]


def weight_specs(cfg: ModelConfig) -> Dict[str, tuple]:
    """name -> (shape, dtype) for every entry of WEIGHT_ORDER."""
    nl, d, dff, v = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    u8, f32 = jnp.uint8, jnp.float32
    return {
        "tok_emb": ((v, d), f32),
        "final_norm_g": ((d,), f32),
        "attn_norm_g": ((nl, d), f32),
        "wq_codes": ((nl, d, d // 4), u8), "wq_scale": ((nl,), f32),
        "wk_codes": ((nl, d, d // 4), u8), "wk_scale": ((nl,), f32),
        "wv_codes": ((nl, d, d // 4), u8), "wv_scale": ((nl,), f32),
        "wo_codes": ((nl, d, d // 4), u8), "wo_scale": ((nl,), f32),
        "ffn_norm_g": ((nl, d), f32),
        "w1_codes": ((nl, dff, d // 4), u8), "w1_scale": ((nl,), f32),
        "w3_codes": ((nl, dff, d // 4), u8), "w3_scale": ((nl,), f32),
        "w2_codes": ((nl, d, dff // 4), u8), "w2_scale": ((nl,), f32),
    }


def _split_heads(x, n_heads, head_dim):
    """[L, d] -> [H, L, dh]."""
    l = x.shape[0]
    return x.reshape(l, n_heads, head_dim).transpose(1, 0, 2)


def _merge_heads(x):
    """[H, L, dh] -> [L, d]."""
    h, l, dh = x.shape
    return x.transpose(1, 0, 2).reshape(l, h * dh)


def _linear(cfg: ModelConfig, x_q, sx, codes, sw):
    """TLMM linear with the config's block sizes."""
    return tlmm(
        x_q, sx, codes, sw,
        block_m=cfg.tlmm_block_m, block_n=cfg.tlmm_block_n,
    )


def _attn_block_prefill(cfg: ModelConfig, x, lw, positions):
    """Attention sub-block for a full sequence. Returns (x', k_rope, v)."""
    h_q, sx = rmsnorm_quant(x, lw["attn_norm_g"], block_m=cfg.tlmm_block_m)
    q = _linear(cfg, h_q, sx, lw["wq_codes"], lw["wq_scale"])
    k = _linear(cfg, h_q, sx, lw["wk_codes"], lw["wk_scale"])
    v = _linear(cfg, h_q, sx, lw["wv_codes"], lw["wv_scale"])
    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_heads, cfg.head_dim)
    q = ref.rope_ref(q, positions, cfg.rope_base)
    k = ref.rope_ref(k, positions, cfg.rope_base)
    o = prefill_attention(
        q, k, v, block_q=cfg.attn_block, block_k=cfg.attn_block
    )
    o = _merge_heads(o)
    o_q, o_sx = ref.quantize_i8(o)
    out = _linear(cfg, o_q, o_sx, lw["wo_codes"], lw["wo_scale"])
    return x + out, k, v


def _ffn_block(cfg: ModelConfig, x, lw, block_m=None):
    """SwiGLU FFN sub-block (shared by prefill and decode)."""
    bm = block_m if block_m is not None else cfg.tlmm_block_m
    h_q, sx = rmsnorm_quant(x, lw["ffn_norm_g"], block_m=bm)
    gate = _linear(cfg, h_q, sx, lw["w1_codes"], lw["w1_scale"])
    up = _linear(cfg, h_q, sx, lw["w3_codes"], lw["w3_scale"])
    a = ref.swiglu_ref(gate, up)
    a_q, a_sx = ref.quantize_i8(a)
    out = _linear(cfg, a_q, a_sx, lw["w2_codes"], lw["w2_scale"])
    return x + out


def _layer_weights(weights: Dict[str, jax.Array]):
    """Stacked per-layer weights as scan xs."""
    return {n: weights[n] for n in PER_LAYER}


def prefill(cfg: ModelConfig, weights: Dict[str, jax.Array], tokens, prompt_len):
    """Process a (padded) prompt; see module docstring for the contract."""
    l = tokens.shape[0]
    positions = jnp.arange(l, dtype=jnp.int32)
    x = jnp.take(weights["tok_emb"], tokens, axis=0)  # [L, d]

    def step(x, lw):
        x, k, v = _attn_block_prefill(cfg, x, lw, positions)
        x = _ffn_block(cfg, x, lw)
        # Pad the bucket-length cache out to max_seq in-graph so the decode
        # executable gets full-capacity caches without a host-side copy.
        kc = jnp.zeros((cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0))
        return x, (kc, vc)

    x, (k_cache, v_cache) = jax.lax.scan(step, x, _layer_weights(weights))

    # Logits for the last *valid* prompt position only.
    last = jax.lax.dynamic_slice(x, (prompt_len - 1, 0), (1, cfg.d_model))
    normed = ref.rmsnorm_ref(last, weights["final_norm_g"])
    logits = (normed @ weights["tok_emb"].T)[0]  # [vocab]
    return logits, k_cache, v_cache


def _attn_block_decode(cfg: ModelConfig, x, lw, kc, vc, pos):
    """Attention sub-block for one token. Returns (x', kc', vc')."""
    h_q, sx = rmsnorm_quant(x, lw["attn_norm_g"], block_m=1)
    q = _linear(cfg, h_q, sx, lw["wq_codes"], lw["wq_scale"])  # [1, d]
    k = _linear(cfg, h_q, sx, lw["wk_codes"], lw["wk_scale"])
    v = _linear(cfg, h_q, sx, lw["wv_codes"], lw["wv_scale"])
    pos_arr = pos.reshape(1).astype(jnp.int32)
    q = ref.rope_ref(_split_heads(q, cfg.n_heads, cfg.head_dim), pos_arr,
                     cfg.rope_base)  # [H, 1, dh]
    k = ref.rope_ref(_split_heads(k, cfg.n_heads, cfg.head_dim), pos_arr,
                     cfg.rope_base)
    v = _split_heads(v, cfg.n_heads, cfg.head_dim)
    # Insert this token's K/V at pos, then attend to 0..pos inclusive.
    kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0))
    o = decode_attention(
        q[:, 0, :], kc, vc, pos + 1, block_k=cfg.attn_block
    )  # [H, dh]
    o = o.reshape(1, cfg.d_model)
    o_q, o_sx = ref.quantize_i8(o)
    out = _linear(cfg, o_q, o_sx, lw["wo_codes"], lw["wo_scale"])
    return x + out, kc, vc


def decode_step(cfg: ModelConfig, weights: Dict[str, jax.Array],
                token, pos, k_cache, v_cache):
    """One autoregressive step; see module docstring for the contract."""
    x = jnp.take(weights["tok_emb"], token[None], axis=0)  # [1, d]

    def step(x, xs):
        lw, kc, vc = xs
        x, kc, vc = _attn_block_decode(cfg, x, lw, kc, vc, pos)
        x = _ffn_block(cfg, x, lw, block_m=1)
        return x, (kc, vc)

    x, (k_cache, v_cache) = jax.lax.scan(
        step, x, (_layer_weights(weights), k_cache, v_cache)
    )
    normed = ref.rmsnorm_ref(x, weights["final_norm_g"])
    logits = (normed @ weights["tok_emb"].T)[0]
    return logits, k_cache, v_cache


# ---------------------------------------------------------------------------
# jit-able entry points with flat positional weights (the AOT interface)
# ---------------------------------------------------------------------------

def make_prefill_fn(cfg: ModelConfig, bucket_len: int):
    """Returns f(*weights, tokens[i32 L], prompt_len[i32]) -> 3-tuple."""
    del bucket_len  # shape comes from the example args at lowering time

    def fn(*args):
        weights = dict(zip(WEIGHT_ORDER, args[: len(WEIGHT_ORDER)]))
        tokens, prompt_len = args[len(WEIGHT_ORDER):]
        return prefill(cfg, weights, tokens, prompt_len)

    return fn


def make_decode_fn(cfg: ModelConfig):
    """Returns f(*weights, token, pos, k_cache, v_cache) -> 3-tuple."""

    def fn(*args):
        weights = dict(zip(WEIGHT_ORDER, args[: len(WEIGHT_ORDER)]))
        token, pos, k_cache, v_cache = args[len(WEIGHT_ORDER):]
        return decode_step(cfg, weights, token, pos, k_cache, v_cache)

    return fn


# ---------------------------------------------------------------------------
# Pure-jnp reference model (oracle for the whole graph, used by pytest and
# to generate golden outputs for the Rust integration tests)
# ---------------------------------------------------------------------------

def reference_forward(cfg: ModelConfig, weights: Dict[str, jax.Array], tokens):
    """Dense full-sequence forward pass with no Pallas, no KV cache.

    ``tokens`` i32 ``[T]`` (no padding) -> logits f32 ``[T, vocab]``.
    """
    positions = jnp.arange(tokens.shape[0], dtype=jnp.int32)
    x = jnp.take(weights["tok_emb"], tokens, axis=0)
    for i in range(cfg.n_layers):
        lw = {n: weights[n][i] for n in PER_LAYER}
        xq, sx = ref.rmsnorm_quant_ref(x, lw["attn_norm_g"])
        q = ref.tlmm_ref(xq, sx, lw["wq_codes"], lw["wq_scale"])
        k = ref.tlmm_ref(xq, sx, lw["wk_codes"], lw["wk_scale"])
        v = ref.tlmm_ref(xq, sx, lw["wv_codes"], lw["wv_scale"])
        q = ref.rope_ref(_split_heads(q, cfg.n_heads, cfg.head_dim), positions,
                         cfg.rope_base)
        k = ref.rope_ref(_split_heads(k, cfg.n_heads, cfg.head_dim), positions,
                         cfg.rope_base)
        v = _split_heads(v, cfg.n_heads, cfg.head_dim)
        o = _merge_heads(ref.attention_ref(q, k, v, causal=True))
        oq, osx = ref.quantize_i8(o)
        x = x + ref.tlmm_ref(oq, osx, lw["wo_codes"], lw["wo_scale"])
        xq, sx = ref.rmsnorm_quant_ref(x, lw["ffn_norm_g"])
        gate = ref.tlmm_ref(xq, sx, lw["w1_codes"], lw["w1_scale"])
        up = ref.tlmm_ref(xq, sx, lw["w3_codes"], lw["w3_scale"])
        aq, asx = ref.quantize_i8(ref.swiglu_ref(gate, up))
        x = x + ref.tlmm_ref(aq, asx, lw["w2_codes"], lw["w2_scale"])
    normed = ref.rmsnorm_ref(x, weights["final_norm_g"])
    return normed @ weights["tok_emb"].T
