"""PD-Swap compile path: JAX/Pallas model definition + AOT lowering.

Build-time only — the Rust coordinator consumes the emitted
``artifacts/<config>/*.hlo.txt`` and never imports this package.
"""
