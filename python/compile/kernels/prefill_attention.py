"""Pallas prefill attention kernel — the compute-heavy reconfigurable
module (Fig. 3b), a blocked FlashAttention with the paper's *reverse*
causal scheduling.

FPGA formulation: the prefill RM keeps a Q tile resident (BRAM/registers)
and streams K/V blocks from DDR, maintaining the FlashAttention running
(max, sum, output) statistics (Eq. 1). Causal masking is handled by a
reverse block schedule: for Q block *i*, K blocks are visited
``j = i, i-1, ..., 0`` so the *first* block visited is the only partially
masked (diagonal) one and every later block is dense — the PE array never
stalls on mask logic after the first iteration, and the diagonal block
seeds the running max with the row's own (largest-position) scores.

TPU adaptation: Q tile ``[bq, dh]`` lives in VMEM for the whole inner loop
(paper: registers/BRAM); K/V for the head are pinned by the BlockSpec and
sliced block-by-block with ``pl.ds`` (paper: DDR bursts over HP ports);
running statistics are loop carries. The reverse schedule is kept verbatim.

Grid: ``(heads, L // block_q)``. interpret=True (see tlmm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
NEG_INF = -1e30  # avoid actual -inf: exp(-inf - -inf) = nan in the rescale


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, dh, scale):
    """One (head, q-block) step: reverse-scheduled online softmax.

    q_ref: [bq, dh]   resident Q tile
    k_ref: [L, dh]    full K for this head (sliced per block)
    v_ref: [L, dh]    full V for this head
    o_ref: [bq, dh]
    """
    iq = pl.program_id(1)
    q = q_ref[...] * scale  # [bq, dh]

    # Absolute row positions of this Q tile (for the diagonal mask).
    row_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    def body(step, carry):
        o, m, l = carry
        # Reverse schedule: step 0 visits the diagonal block j = iq.
        j = iq - step
        k_blk = pl.load(k_ref, (pl.ds(j * bk, bk), slice(None)))  # [bk, dh]
        v_blk = pl.load(v_ref, (pl.ds(j * bk, bk), slice(None)))  # [bk, dh]
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, bk]
        # Causal mask — only the diagonal block (step 0) is ever partial.
        col_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(col_pos <= row_pos, s, NEG_INF)

        # FlashAttention running update (Eq. 1 of the paper).
        m_blk = jnp.max(s, axis=-1)  # rmax(L^(j))
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[:, None])  # e^{L^(j) - m^(j)}
        alpha = jnp.exp(m - m_new)  # e^{m^(j-1) - m^(j)}
        l_new = alpha * l + jnp.sum(p, axis=-1)
        o_new = alpha[:, None] * o + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return o_new, m_new, l_new

    o0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    # Q block iq attends to K blocks 0..iq — (iq + 1) blocks, reversed.
    o, m, l = jax.lax.fori_loop(0, iq + 1, body, (o0, m0, l0))
    o_ref[...] = o / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def prefill_attention(q, k, v, *, block_q=64, block_k=64):
    """Causal FlashAttention with reverse block scheduling.

    ``q, k, v`` f32 ``[H, L, dh]`` (RoPE already applied to q, k) ->
    ``[H, L, dh]``. L must divide by the (clamped) block sizes.
    """
    h, l, dh = q.shape
    bq = min(block_q, l)
    bk = min(block_k, l)
    assert l % bq == 0 and l % bk == 0, (l, bq, bk)
    assert bq == bk, "reverse diagonal scheduling assumes square blocks"
    scale = 1.0 / (dh ** 0.5)

    grid = (h, l // bq)
    return pl.pallas_call(
        functools.partial(_prefill_kernel, bq=bq, bk=bk, dh=dh, scale=scale),
        grid=grid,
        in_specs=[
            # None squeezes the head dim: refs arrive as [bq/l, dh].
            pl.BlockSpec((None, bq, dh), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((None, l, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((None, l, dh), lambda ih, iq: (ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, l, dh), jnp.float32),
        interpret=INTERPRET,
    )(q, k, v)


def vmem_bytes(l, dh, block_q=64, block_k=64):
    """Estimated per-step VMEM footprint: Q tile + one K/V block + stats.

    The full-head K/V pin in the BlockSpec is an interpret-mode convenience;
    the real schedule streams one [bk, dh] block at a time, which is what
    the perf model should charge.
    """
    bq, bk = block_q, block_k
    return 4 * (bq * dh + 2 * bk * dh + bq * bk + bq * dh + 3 * bq)
