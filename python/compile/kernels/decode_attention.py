"""Pallas decode attention kernel — the bandwidth-optimized reconfigurable
module (Fig. 3d).

FPGA formulation: in decode, L = 1, so there is no Q reuse at all; the
operation degenerates to ``q_t · K_<t^T -> softmax -> · V_<t -> o_t``, a
memory-bound streaming pass over the growing KV cache. The paper's decode
RM therefore trades PE count for bandwidth: 2 HP ports stream K and 2
stream V (vs. the prefill/baseline QKVO port mapping), the single Q token
is pre-staged into on-chip buffers, and the output token is held locally
until the KV transfers finish (§3.2.3) — roughly doubling effective KV
bandwidth.

TPU adaptation: the single query vector is VMEM-resident (paper: Q buffer),
the KV cache is streamed block-by-block through VMEM with a running-softmax
carry — the BlockSpec/ds schedule is the VMEM analogue of the 2K+2V burst
schedule. The cache is padded to ``Lmax``; a scalar ``length`` input masks
the tail, which is how the Rust coordinator reuses one compiled executable
for every decode position.

Grid: ``(heads,)``. interpret=True (see tlmm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, lmax, bk, dh, scale):
    """One head: stream KV blocks, running softmax against the live length.

    len_ref: [1]        int32  valid cache length t (attend to 0..t-1)
    q_ref:   [1, dh]    f32    the single query vector
    k_ref:   [lmax, dh] f32    padded K cache for this head
    v_ref:   [lmax, dh] f32    padded V cache for this head
    o_ref:   [1, dh]    f32
    """
    length = len_ref[0]
    q = q_ref[...] * scale  # [1, dh]

    def body(j, carry):
        o, m, l = carry
        k_blk = pl.load(k_ref, (pl.ds(j * bk, bk), slice(None)))  # [bk, dh]
        v_blk = pl.load(v_ref, (pl.ds(j * bk, bk), slice(None)))
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [1, bk]
        pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        o_new = alpha[:, None] * o + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return o_new, m_new, l_new

    o0 = jnp.zeros((1, dh), jnp.float32)
    m0 = jnp.full((1,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    # Only visit blocks that contain live positions: ceil(length / bk).
    nblocks = (length + bk - 1) // bk
    o, m, l = jax.lax.fori_loop(0, nblocks, body, (o0, m0, l0))
    o_ref[...] = o / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, length, *, block_k=64):
    """Single-token attention against a padded KV cache.

    Args:
      q:       f32 ``[H, dh]`` query for the new token (RoPE applied).
      k_cache: f32 ``[H, Lmax, dh]`` padded key cache (RoPE applied).
      v_cache: f32 ``[H, Lmax, dh]`` padded value cache.
      length:  int32 scalar — number of valid positions (includes the
               current token, whose K/V must already be in the cache).
      block_k: KV streaming block size (clamped to Lmax).

    Returns f32 ``[H, dh]``.
    """
    h, lmax, dh = k_cache.shape
    bk = min(block_k, lmax)
    assert lmax % bk == 0, (lmax, bk)
    scale = 1.0 / (dh ** 0.5)
    len_arr = jnp.asarray(length, jnp.int32).reshape(1)

    grid = (h,)
    return pl.pallas_call(
        functools.partial(
            _decode_kernel, lmax=lmax, bk=bk, dh=dh, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ih: (0,)),
            pl.BlockSpec((1, dh), lambda ih: (ih, 0)),  # [1, dh] per head
            pl.BlockSpec((None, lmax, dh), lambda ih: (ih, 0, 0)),
            pl.BlockSpec((None, lmax, dh), lambda ih: (ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda ih: (ih, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), jnp.float32),
        interpret=INTERPRET,
    )(len_arr, q, k_cache, v_cache)


def hbm_bytes(length, dh, n_heads):
    """KV bytes streamed per decode step (perf model input): the kernel is
    bandwidth-bound, so this IS the roofline numerator."""
    return 2 * n_heads * length * dh * 4


def vmem_bytes(dh, block_k=64):
    """Per-step VMEM footprint: q + one K/V block + running stats."""
    return 4 * (dh + 2 * block_k * dh + block_k + dh + 3)
