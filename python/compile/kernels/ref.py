"""Pure-jnp reference oracles for every PD-Swap kernel.

These are the CORE correctness signal: each Pallas kernel in this package is
checked against the corresponding function here via pytest + hypothesis
(``python/tests/``). Keep these as boring and obviously-correct as possible —
no blocking, no running softmax, no packing tricks.

Conventions (shared with the kernels and with ``model.py``):

* Linear layers compute ``y = (x_q @ W_t.T) * (sx * sw)`` where
  ``x_q`` is the per-token int8 quantized activation, ``W_t`` is the ternary
  weight matrix with entries in {-1, 0, +1} stored output-major ``[N, K]``,
  ``sx`` is the per-token activation scale and ``sw`` the per-tensor weight
  scale (BitNet beta = mean |W|).
* Attention uses softmax scale ``1/sqrt(head_dim)`` and causal masking.
* RMSNorm uses ``x * g / sqrt(mean(x^2) + eps)``.
"""

from __future__ import annotations

import jax.numpy as jnp

# Activation quantization clamp (int8, symmetric).
QMAX = 127.0
RMS_EPS = 1e-5


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

def quantize_i8(x):
    """Per-token (last-axis) symmetric absmax int8 quantization.

    Returns ``(x_q, sx)`` with ``x_q`` int8 of x.shape and ``sx`` float32 of
    ``x.shape[:-1] + (1,)`` such that ``x ≈ x_q * sx``.
    """
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    sx = jnp.maximum(absmax, 1e-8) / QMAX
    x_q = jnp.clip(jnp.round(x / sx), -QMAX, QMAX).astype(jnp.int8)
    return x_q, sx.astype(jnp.float32)


def ternarize(w_f):
    """BitNet absmean ternarization of a float weight matrix.

    Returns ``(w_t, sw)`` where ``w_t`` is int8 in {-1, 0, +1} and ``sw`` is
    the scalar absmean scale, such that ``w_f ≈ w_t * sw``.
    """
    sw = jnp.mean(jnp.abs(w_f))
    sw = jnp.maximum(sw, 1e-8)
    w_t = jnp.clip(jnp.round(w_f / sw), -1, 1).astype(jnp.int8)
    return w_t, sw.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Ternary weight packing (the TLMM storage format)
# ---------------------------------------------------------------------------

# Weights are packed in groups of 4 along the K (input) axis, one uint8 code
# per group, base-3 digits: code = sum_j (w[4k+j] + 1) * 3^j, code in [0, 81).
# This is the on-URAM format of the paper's table-lookup matmul engine:
# the code doubles as the index into the per-group precomputed partial-sum
# table (see tlmm_lut.py for the faithful lookup formulation).
PACK_GROUP = 4
PACK_BASE = 3
PACK_CODES = PACK_BASE ** PACK_GROUP  # 81


def pack_ternary(w_t):
    """Pack ternary int8 matrix ``[N, K]`` (K % 4 == 0) to uint8 ``[N, K//4]``."""
    n, k = w_t.shape
    assert k % PACK_GROUP == 0, f"K={k} not a multiple of {PACK_GROUP}"
    digits = (w_t.astype(jnp.int32) + 1).reshape(n, k // PACK_GROUP, PACK_GROUP)
    weights = PACK_BASE ** jnp.arange(PACK_GROUP, dtype=jnp.int32)
    codes = jnp.sum(digits * weights, axis=-1)
    return codes.astype(jnp.uint8)


def unpack_ternary(codes, k):
    """Inverse of :func:`pack_ternary`: uint8 ``[N, K//4]`` -> int8 ``[N, K]``."""
    n = codes.shape[0]
    c = codes.astype(jnp.int32)[:, :, None]
    shifts = PACK_BASE ** jnp.arange(PACK_GROUP, dtype=jnp.int32)
    digits = (c // shifts) % PACK_BASE - 1
    return digits.reshape(n, k).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Reference kernels
# ---------------------------------------------------------------------------

def tlmm_ref(x_q, sx, codes, sw):
    """Reference ternary table-lookup matmul.

    ``x_q`` int8 ``[M, K]``, ``sx`` f32 ``[M, 1]``, ``codes`` uint8
    ``[N, K//4]``, ``sw`` f32 scalar -> f32 ``[M, N]``.
    """
    k = x_q.shape[-1]
    w_t = unpack_ternary(codes, k)  # [N, K]
    acc = jnp.dot(x_q.astype(jnp.int32), w_t.astype(jnp.int32).T)  # [M, N]
    return acc.astype(jnp.float32) * sx * sw


def linear_ref(x, w_f):
    """Full float path: quantize activations, ternarize weights, matmul."""
    x_q, sx = quantize_i8(x)
    w_t, sw = ternarize(w_f)
    return tlmm_ref(x_q, sx, pack_ternary(w_t), sw)


def rmsnorm_ref(x, g, eps=RMS_EPS):
    """RMSNorm over the last axis. ``x`` ``[M, D]``, ``g`` ``[D]``."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * g


def rmsnorm_quant_ref(x, g, eps=RMS_EPS):
    """Fused RMSNorm + find-max + int8 quant (the paper's 'RMSNorm & Find
    Max Unit'). Returns ``(x_q, sx)``."""
    return quantize_i8(rmsnorm_ref(x, g, eps))


def attention_ref(q, k, v, causal=True):
    """Dense causal attention. ``q,k,v`` ``[H, L, dh]`` -> ``[H, L, dh]``."""
    h, l, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((l, l), dtype=bool))
        s = jnp.where(mask[None, :, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def decode_attention_ref(q, k_cache, v_cache, length):
    """Single-token attention against a padded KV cache.

    ``q`` ``[H, dh]``, ``k_cache/v_cache`` ``[H, Lmax, dh]``, ``length``
    int32 (number of valid cache positions) -> ``[H, dh]``.
    """
    h, lmax, dh = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("hd,hkd->hk", q, k_cache) * scale
    valid = jnp.arange(lmax) < length
    s = jnp.where(valid[None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hk,hkd->hd", p, v_cache)


def rope_ref(x, positions, base=10000.0):
    """Rotary position embedding (half-split convention).

    ``x`` ``[H, L, dh]``, ``positions`` ``[L]`` int32 -> ``[H, L, dh]``.
    """
    h, l, dh = x.shape
    half = dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [L, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def silu_ref(x):
    """SiLU (swish) activation."""
    return x / (1.0 + jnp.exp(-x))


def swiglu_ref(gate, up):
    """SwiGLU activation: silu(gate) * up."""
    return silu_ref(gate) * up
