"""Faithful table-lookup formulation of the TLMM engine (Fig. 3a).

This module implements the paper's *actual* FPGA algorithm, not the
MXU-adapted one in ``tlmm.py``: for every token and every group of 4
activations, precompute all ``3^4 = 81`` signed add/subtract combinations
into a table, then use each packed weight code as an index to *look up* the
group's partial sum and accumulate.

On the KV260 the table lives in LUTs/BRAM and the codes in URAM, so the
inner loop has no multipliers at all. On TPU this formulation is gather
bound and strictly worse than the decode+dot form, so it is used only as a
**semantic cross-check**: ``python/tests/test_tlmm.py`` asserts
``tlmm_lut == tlmm == tlmm_ref`` exactly (all-integer accumulation), which
is the equivalence the paper's engine relies on.

Kept in plain jnp (not Pallas) intentionally — it is an executable
specification, and the gather patterns it needs are the part that does NOT
survive the hardware translation (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import PACK_BASE, PACK_CODES, PACK_GROUP


def build_group_tables(x_q):
    """Precompute the 81-entry partial-sum table for every activation group.

    ``x_q`` int8 ``[M, K]`` -> int32 ``[M, K//4, 81]`` where entry
    ``[m, g, c]`` is ``sum_j digit_j(c) * x_q[m, 4g+j]`` with
    ``digit_j(c) = (c // 3^j) % 3 - 1``.

    This mirrors the paper's "for every value group, add/subtract
    combinations are pre-computed" step; the FPGA builds it once per token
    as the activations stream in, reusing it across all N output channels.
    """
    m, k = x_q.shape
    assert k % PACK_GROUP == 0
    groups = x_q.astype(jnp.int32).reshape(m, k // PACK_GROUP, PACK_GROUP)
    codes = jnp.arange(PACK_CODES, dtype=jnp.int32)  # [81]
    shifts = PACK_BASE ** jnp.arange(PACK_GROUP, dtype=jnp.int32)  # [4]
    digits = (codes[:, None] // shifts[None, :]) % PACK_BASE - 1  # [81, 4]
    # [M, G, 81] = sum_j groups[m, g, j] * digits[c, j]
    return jnp.einsum("mgj,cj->mgc", groups, digits)


def tlmm_lut(x_q, sx, codes, sw):
    """Table-lookup matmul: index -> lookup -> accumulate.

    Same contract as :func:`tlmm.tlmm`. ``codes`` uint8 ``[N, K//4]``.
    """
    tables = build_group_tables(x_q)  # [M, G, 81]
    idx = codes.astype(jnp.int32)  # [N, G]
    # The lookup: partial[m, n, g] = tables[m, g, idx[n, g]].
    # vmap over output channels n; each channel gathers its G partial sums.
    def one_channel(ch_idx):
        # tables: [M, G, 81], ch_idx: [G] -> [M, G]
        return jnp.take_along_axis(
            tables, ch_idx[None, :, None], axis=2
        )[..., 0]

    partial = jax.vmap(one_channel, in_axes=0, out_axes=2)(idx)  # [M, G, N]
    acc = jnp.sum(partial, axis=1)  # [M, N] int32
    return acc.astype(jnp.float32) * sx * jnp.asarray(sw, jnp.float32)
