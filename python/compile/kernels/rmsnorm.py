"""Pallas fused RMSNorm + find-max + int8 quantization kernel — the paper's
static-region "RMSNorm & Find Max Unit" (Table 2 row 2).

Every TLMM linear is fed by this unit: normalize the residual stream, find
the per-token absmax (the "Find Max" half), and emit int8 activations plus
the per-token scale. Fusing the three passes means the activation vector is
read once from the stream instead of three times — on the FPGA this is one
pipeline; on TPU it is one VMEM-resident row block per grid step.

Grid: ``(M // block_m,)``. interpret=True (see tlmm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import QMAX, RMS_EPS

INTERPRET = True


def _rmsnorm_quant_kernel(x_ref, g_ref, q_ref, s_ref, *, eps):
    """x_ref [bm, D] f32, g_ref [1, D] f32 -> q_ref [bm, D] i8, s_ref [bm, 1] f32."""
    x = x_ref[...]
    g = g_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(ms + eps) * g
    absmax = jnp.max(jnp.abs(normed), axis=-1, keepdims=True)  # find-max
    sx = jnp.maximum(absmax, 1e-8) / QMAX
    q_ref[...] = jnp.clip(jnp.round(normed / sx), -QMAX, QMAX).astype(jnp.int8)
    s_ref[...] = sx.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m",))
def rmsnorm_quant(x, g, *, block_m=128, eps=RMS_EPS):
    """Fused RMSNorm -> absmax -> int8 quant over the last axis.

    ``x`` f32 ``[M, D]``, ``g`` f32 ``[D]`` -> ``(x_q int8 [M, D],
    sx f32 [M, 1])``.
    """
    m, d = x.shape
    bm = min(block_m, m)
    assert m % bm == 0, (m, bm)
    g2 = g.reshape(1, d)

    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_quant_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, d), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, g2)
