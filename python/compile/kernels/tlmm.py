"""Pallas ternary table-lookup matmul (TLMM) kernel — the paper's static
region workhorse (Fig. 3a).

Paper formulation (KV260): ternary weights are packed 4-per-URAM-word as
base-3 codes; for each group of 4 int8 activations all 81 add/subtract
combinations are precomputed into a LUT-resident table, and the weight code
is the *index* used to fetch the partial sum. Runtime matmul becomes
index -> lookup -> accumulate, eliminating both multipliers (DSPs) and DDR
weight traffic (weights live on-chip).

TPU adaptation (DESIGN.md §Hardware-Adaptation): there is no LUT fabric, so
the surviving insight is *weights resident in fast memory + multiplication-
free accumulation*. The kernel keeps the paper's packed base-3 storage
format (2 bits/weight asymptotically, 1 byte per 4 weights here), decodes
the codes to {-1, 0, +1} **inside VMEM** — the decode stands in for the
table lookup — and feeds an integer dot-product. The BlockSpec pins the
whole K (reduction) extent of both operands per grid step, expressing the
paper's "weights never leave URAM" residency: the weight tile is read from
HBM once per (i, j) output tile and never re-streamed per token.

A faithful lookup formulation (actual 81-entry tables, used to validate the
equivalence claim) lives in ``tlmm_lut.py``; it is tested against this
kernel but not used in the AOT model because the MXU prefers the dot form.

All kernels in this package run with ``interpret=True``: CPU PJRT cannot
execute Mosaic custom-calls, so we lower to plain HLO (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PACK_BASE, PACK_GROUP

INTERPRET = True  # CPU PJRT path; real-TPU perf is estimated analytically.


def _decode_codes(codes_i32, bn, bk):
    """Decode packed base-3 codes ``[bn, bk//4]`` int32 -> ternary ``[bn, bk]``.

    This is the in-VMEM stand-in for the paper's partial-sum table lookup:
    one divmod chain per group instead of one URAM read per group.
    """
    c = codes_i32[:, :, None]
    shifts = PACK_BASE ** jnp.arange(PACK_GROUP, dtype=jnp.int32)
    digits = (c // shifts) % PACK_BASE - 1  # [bn, bk//4, 4]
    return digits.reshape(bn, bk)


def _tlmm_kernel(x_ref, sx_ref, codes_ref, sw_ref, o_ref, *, bm, bn, bk):
    """One (i, j) output tile: int8 activations x ternary weights.

    x_ref:     [bm, K]      int8   (quantized activations, full K resident)
    sx_ref:    [bm, 1]      f32    (per-token activation scales)
    codes_ref: [bn, K//4]   uint8  (packed ternary weights, full K resident)
    sw_ref:    [1, 1]       f32    (per-tensor weight scale)
    o_ref:     [bm, bn]     f32
    """
    x = x_ref[...].astype(jnp.int32)  # [bm, K]
    codes = codes_ref[...].astype(jnp.int32)  # [bn, K//4]
    w = _decode_codes(codes, bn, bk)  # [bn, K] in {-1,0,+1}
    # Integer accumulate: on real TPU this is a bf16 MXU matmul of the
    # decoded ternary tile; int32 keeps the interpret path exact.
    acc = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # [bm, bn]
    o_ref[...] = acc.astype(jnp.float32) * sx_ref[...] * sw_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def tlmm(x_q, sx, codes, sw, *, block_m=128, block_n=128):
    """Ternary table-lookup matmul: ``y = (x_q @ W.T) * sx * sw``.

    Args:
      x_q:   int8  ``[M, K]`` quantized activations (K % 4 == 0).
      sx:    f32   ``[M, 1]`` per-token activation scale.
      codes: uint8 ``[N, K//4]`` packed ternary weights (output-major).
      sw:    f32   scalar (or ``[]``) weight scale.
      block_m/block_n: output tile sizes (clamped to M, N).

    Returns f32 ``[M, N]``.
    """
    m, k = x_q.shape
    n, kp = codes.shape
    assert kp * PACK_GROUP == k, (k, kp)
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    sw2 = jnp.asarray(sw, jnp.float32).reshape(1, 1)

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_tlmm_kernel, bm=bm, bn=bn, bk=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kp), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x_q, sx, codes, sw2)


def vmem_bytes(m, k, n, block_m=128, block_n=128):
    """Estimated VMEM footprint of one grid step (perf model input).

    int8 activations + packed codes + decoded i32 tile + f32 output tile.
    """
    bm, bn = min(block_m, m), min(block_n, n)
    return (
        bm * k  # x int8
        + bm * 4  # sx f32
        + bn * (k // PACK_GROUP)  # codes u8
        + bn * k * 4  # decoded weight tile i32
        + bm * bn * 4  # output f32
    )
