"""PD-Swap Layer-1 Pallas kernels (build-time only; lowered into the L2
HLO artifacts, never imported at runtime).

* :mod:`.tlmm` — ternary table-lookup matmul (static region, Fig. 3a)
* :mod:`.tlmm_lut` — faithful 81-entry lookup formulation (spec/cross-check)
* :mod:`.prefill_attention` — reverse-scheduled FlashAttention RM (Fig. 3b)
* :mod:`.decode_attention` — KV-cache-streaming decode RM (Fig. 3d)
* :mod:`.rmsnorm` — fused RMSNorm + find-max + int8 quant (static region)
* :mod:`.ref` — pure-jnp oracles for all of the above
"""

from . import decode_attention, prefill_attention, ref, rmsnorm, tlmm, tlmm_lut  # noqa: F401
