"""Whole-model consistency: the AOT prefill/decode graphs (Pallas kernels,
scan over layers, KV caches) against the dense no-cache reference model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import CONFIGS
from compile.model import (
    WEIGHT_ORDER,
    make_decode_fn,
    make_prefill_fn,
    reference_forward,
    weight_specs,
)

RTOL = 2e-3
ATOL = 2e-3


@pytest.fixture(scope="module")
def jitted(test_cfg, test_weights):
    wl = [jnp.asarray(test_weights[n]) for n in WEIGHT_ORDER]
    return {
        "w": wl,
        "wd": {n: jnp.asarray(test_weights[n]) for n in WEIGHT_ORDER},
        "prefill8": jax.jit(make_prefill_fn(test_cfg, 8)),
        "prefill16": jax.jit(make_prefill_fn(test_cfg, 16)),
        "decode": jax.jit(make_decode_fn(test_cfg)),
    }


def pad_prompt(prompt, bucket):
    t = np.zeros(bucket, np.int32)
    t[: len(prompt)] = prompt
    return jnp.asarray(t)


def test_prefill_matches_reference(test_cfg, jitted):
    prompt = [1, 2, 3, 4, 5]
    logits, _, _ = jitted["prefill8"](
        *jitted["w"], pad_prompt(prompt, 8), jnp.int32(len(prompt))
    )
    want = reference_forward(test_cfg, jitted["wd"], jnp.asarray(prompt, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(want[-1]), rtol=RTOL, atol=ATOL
    )


def test_prefill_bucket_invariance(test_cfg, jitted):
    """Same prompt through the 8- and 16-token buckets -> same logits."""
    prompt = [3, 1, 4, 1, 5]
    l8, k8, v8 = jitted["prefill8"](
        *jitted["w"], pad_prompt(prompt, 8), jnp.int32(len(prompt))
    )
    l16, k16, v16 = jitted["prefill16"](
        *jitted["w"], pad_prompt(prompt, 16), jnp.int32(len(prompt))
    )
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l16), rtol=RTOL, atol=ATOL)
    # The *valid* cache region must agree too.
    n = len(prompt)
    np.testing.assert_allclose(
        np.asarray(k8)[:, :, :n], np.asarray(k16)[:, :, :n], rtol=RTOL, atol=ATOL
    )
    np.testing.assert_allclose(
        np.asarray(v8)[:, :, :n], np.asarray(v16)[:, :, :n], rtol=RTOL, atol=ATOL
    )


def test_decode_chain_matches_reference(test_cfg, jitted):
    """Prefill + t decode steps == dense forward of the whole sequence at
    every step — the fundamental prefill/decode consistency invariant."""
    prompt = [1, 2, 3, 4, 5]
    seq = list(prompt)
    _, kc, vc = jitted["prefill8"](
        *jitted["w"], pad_prompt(prompt, 8), jnp.int32(len(prompt))
    )
    next_tokens = [7, 11, 200, 5]
    for step, tok in enumerate(next_tokens):
        pos = len(seq)
        logits, kc, vc = jitted["decode"](
            *jitted["w"], jnp.int32(tok), jnp.int32(pos), kc, vc
        )
        seq.append(tok)
        want = reference_forward(test_cfg, jitted["wd"], jnp.asarray(seq, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(want[-1]),
            rtol=RTOL,
            atol=ATOL,
            err_msg=f"decode step {step} (pos {pos}) diverged",
        )


def test_prompt_len_one(test_cfg, jitted):
    """Minimal prompt exercises the dynamic_slice at prompt_len-1 == 0."""
    logits, _, _ = jitted["prefill8"](
        *jitted["w"], pad_prompt([9], 8), jnp.int32(1)
    )
    want = reference_forward(test_cfg, jitted["wd"], jnp.asarray([9], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(want[-1]), rtol=RTOL, atol=ATOL
    )


def test_padding_tokens_do_not_leak(test_cfg, jitted):
    """Changing the *padding* region of the bucket must not change logits."""
    prompt = [1, 2, 3]
    a = jitted["prefill8"](*jitted["w"], pad_prompt(prompt, 8), jnp.int32(3))[0]
    padded = np.full(8, 77, np.int32)
    padded[:3] = prompt
    b = jitted["prefill8"](*jitted["w"], jnp.asarray(padded), jnp.int32(3))[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_weight_specs_cover_order(test_cfg):
    specs = weight_specs(test_cfg)
    assert set(specs) == set(WEIGHT_ORDER)
    # Pack axis: every codes tensor's last dim is K//4 for its matmul.
    d, dff = test_cfg.d_model, test_cfg.d_ff
    assert specs["wq_codes"][0][-1] == d // 4
    assert specs["w2_codes"][0][-1] == dff // 4
    assert specs["w1_codes"][0][1] == dff


def test_full_cache_decode(test_cfg, jitted):
    """Decode at the last cache slot (pos = max_seq - 1) works."""
    prompt = list(range(1, 9))
    _, kc, vc = jitted["prefill8"](
        *jitted["w"], pad_prompt(prompt, 8), jnp.int32(8)
    )
    pos = 8
    tok = 1
    # walk the cache to the end
    while pos < test_cfg.max_seq:
        logits, kc, vc = jitted["decode"](
            *jitted["w"], jnp.int32(tok), jnp.int32(pos), kc, vc
        )
        tok = int(jnp.argmax(logits))
        pos += 1
    assert np.isfinite(np.asarray(logits)).all()
