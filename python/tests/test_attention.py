"""Attention kernels: reverse-scheduled FlashAttention prefill and the
KV-streaming decode kernel vs the dense oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.decode_attention import decode_attention, hbm_bytes
from compile.kernels.prefill_attention import prefill_attention

ATOL = 2e-5


def make_qkv(rng, h, l, dh):
    return tuple(
        jnp.asarray(rng.randn(h, l, dh), jnp.float32) for _ in range(3)
    )


@pytest.mark.parametrize(
    "h,l,dh,blk",
    [
        (1, 8, 8, 4),     # 2 blocks
        (2, 16, 8, 4),    # 4 blocks, multi-head
        (4, 8, 32, 8),    # single block (degenerate loop)
        (2, 32, 16, 8),   # deeper block chain
        (3, 24, 8, 8),    # non-power-of-two length
    ],
)
def test_prefill_matches_dense_causal(rng, h, l, dh, blk):
    q, k, v = make_qkv(rng, h, l, dh)
    got = prefill_attention(q, k, v, block_q=blk, block_k=blk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_prefill_is_causal(rng):
    """Mutating future K/V must not change earlier outputs."""
    h, l, dh, blk = 2, 16, 8, 4
    q, k, v = make_qkv(rng, h, l, dh)
    base = np.asarray(prefill_attention(q, k, v, block_q=blk, block_k=blk))
    k2 = k.at[:, l // 2:, :].set(99.0)
    v2 = v.at[:, l // 2:, :].set(-99.0)
    pert = np.asarray(prefill_attention(q, k2, v2, block_q=blk, block_k=blk))
    np.testing.assert_allclose(
        base[:, : l // 2], pert[:, : l // 2], atol=ATOL,
        err_msg="future tokens leaked into past outputs",
    )
    assert not np.allclose(base[:, l // 2:], pert[:, l // 2:]), \
        "sanity: the perturbed region must actually change"


def test_prefill_first_token_attends_only_itself(rng):
    """Row 0 output == V[0] (softmax over a single unmasked score)."""
    q, k, v = make_qkv(rng, 2, 8, 8)
    out = np.asarray(prefill_attention(q, k, v, block_q=4, block_k=4))
    np.testing.assert_allclose(out[:, 0, :], np.asarray(v)[:, 0, :], atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 4),
    nblk=st.integers(1, 4),
    blk=st.sampled_from([4, 8]),
    dh=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2 ** 16),
)
def test_prefill_hypothesis(h, nblk, blk, dh, seed):
    r = np.random.RandomState(seed)
    l = nblk * blk
    q, k, v = make_qkv(r, h, l, dh)
    got = prefill_attention(q, k, v, block_q=blk, block_k=blk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length", [1, 3, 8, 11, 16])
def test_decode_matches_dense(rng, length):
    h, lmax, dh, blk = 2, 16, 8, 4
    kc = jnp.asarray(rng.randn(h, lmax, dh), jnp.float32)
    vc = jnp.asarray(rng.randn(h, lmax, dh), jnp.float32)
    q = jnp.asarray(rng.randn(h, dh), jnp.float32)
    got = decode_attention(q, kc, vc, length, block_k=blk)
    want = ref.decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_decode_ignores_padding_garbage(rng):
    """Cache rows beyond `length` may hold anything (stale requests,
    prefill bucket padding) without affecting the output."""
    h, lmax, dh = 2, 16, 8
    kc = jnp.asarray(rng.randn(h, lmax, dh), jnp.float32)
    vc = jnp.asarray(rng.randn(h, lmax, dh), jnp.float32)
    q = jnp.asarray(rng.randn(h, dh), jnp.float32)
    length = 5
    base = np.asarray(decode_attention(q, kc, vc, length, block_k=4))
    kc2 = kc.at[:, length:, :].set(1e6)
    vc2 = vc.at[:, length:, :].set(-1e6)
    pert = np.asarray(decode_attention(q, kc2, vc2, length, block_k=4))
    np.testing.assert_allclose(base, pert, atol=ATOL)


def test_decode_length_one_returns_v0(rng):
    h, lmax, dh = 3, 8, 8
    kc = jnp.asarray(rng.randn(h, lmax, dh), jnp.float32)
    vc = jnp.asarray(rng.randn(h, lmax, dh), jnp.float32)
    q = jnp.asarray(rng.randn(h, dh), jnp.float32)
    out = np.asarray(decode_attention(q, kc, vc, 1, block_k=4))
    np.testing.assert_allclose(out, np.asarray(vc)[:, 0, :], atol=ATOL)


def test_decode_agrees_with_prefill_last_row(rng):
    """Decode at position t-1 == last row of prefill over t tokens."""
    h, t, dh, blk = 2, 12, 8, 4
    lmax = 16
    q, k, v = make_qkv(rng, h, t, dh)
    pre = np.asarray(prefill_attention(q, k, v, block_q=4, block_k=4))

    kc = jnp.zeros((h, lmax, dh), jnp.float32).at[:, :t, :].set(k)
    vc = jnp.zeros((h, lmax, dh), jnp.float32).at[:, :t, :].set(v)
    dec = np.asarray(decode_attention(q[:, t - 1, :], kc, vc, t, block_k=blk))
    np.testing.assert_allclose(dec, pre[:, t - 1, :], atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 4),
    lmax_blk=st.integers(1, 4),
    blk=st.sampled_from([4, 8]),
    dh=st.sampled_from([4, 8]),
    seed=st.integers(0, 2 ** 16),
)
def test_decode_hypothesis(h, lmax_blk, blk, dh, seed):
    r = np.random.RandomState(seed)
    lmax = lmax_blk * blk
    length = r.randint(1, lmax + 1)
    kc = jnp.asarray(r.randn(h, lmax, dh), jnp.float32)
    vc = jnp.asarray(r.randn(h, lmax, dh), jnp.float32)
    q = jnp.asarray(r.randn(h, dh), jnp.float32)
    got = decode_attention(q, kc, vc, length, block_k=blk)
    want = ref.decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_hbm_traffic_model_is_linear():
    """The perf-model numerator: KV bytes scale linearly with context."""
    b1 = hbm_bytes(length=64, dh=64, n_heads=24)
    b2 = hbm_bytes(length=2048, dh=64, n_heads=24)
    assert b2 == 32 * b1
    # BitNet 0.73B at L=2048: 2 * 24 heads * 2048 * 64 * 4B = 24 MiB/step/layer.
    assert b2 == 2 * 24 * 2048 * 64 * 4
