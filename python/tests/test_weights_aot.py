"""Weight generation/serialization and the AOT artifact contract."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import weights as wm
from compile.aot import lower_prefill, make_manifest
from compile.configs import CONFIGS
from compile.kernels import ref
from compile.model import WEIGHT_ORDER, weight_specs


def test_numpy_pack_matches_jax_pack(rng):
    w = (rng.randint(0, 3, size=(12, 40)) - 1).astype(np.int8)
    np.testing.assert_array_equal(
        wm._pack_ternary_np(w), np.asarray(ref.pack_ternary(jnp.asarray(w)))
    )


def test_numpy_ternarize_matches_jax(rng):
    w = rng.randn(16, 32).astype(np.float32)
    wt_np, sw_np = wm._ternarize_np(w)
    wt_j, sw_j = ref.ternarize(jnp.asarray(w))
    np.testing.assert_array_equal(wt_np, np.asarray(wt_j))
    assert abs(sw_np - float(sw_j)) < 1e-6


def test_generate_is_deterministic(test_cfg):
    a = wm.generate(test_cfg, seed=7)
    b = wm.generate(test_cfg, seed=7)
    c = wm.generate(test_cfg, seed=8)
    for n in WEIGHT_ORDER:
        np.testing.assert_array_equal(a[n], b[n])
    assert any(not np.array_equal(a[n], c[n]) for n in WEIGHT_ORDER)


def test_generate_matches_specs(test_cfg, test_weights):
    specs = weight_specs(test_cfg)
    for n in WEIGHT_ORDER:
        shape, dtype = specs[n]
        assert test_weights[n].shape == tuple(shape)
        assert test_weights[n].dtype == np.dtype(dtype)
    # codes are valid base-3 packs
    assert test_weights["wq_codes"].max() < 81


def test_save_load_roundtrip(tmp_path, test_cfg, test_weights):
    path = str(tmp_path / "weights.bin")
    wm.save(path, test_cfg, test_weights)
    loaded = wm.load(path)
    assert list(loaded) == WEIGHT_ORDER  # order preserved
    for n in WEIGHT_ORDER:
        np.testing.assert_array_equal(loaded[n], test_weights[n])
    # alignment contract
    with open(path, "rb") as f:
        assert f.read(8) == wm.MAGIC
        hlen = int.from_bytes(f.read(8), "little")
        header = json.loads(f.read(hlen))
    for t in header["tensors"]:
        assert t["offset"] % wm.ALIGN == 0


def test_manifest_contents(test_cfg):
    m = make_manifest(test_cfg, golden=True)
    assert m["format_version"] == 1
    assert [t["name"] for t in m["weight_order"]] == WEIGHT_ORDER
    assert m["entrypoints"]["decode"] == "decode.hlo.txt"
    assert [e["bucket"] for e in m["entrypoints"]["prefill"]] == \
        test_cfg.prefill_buckets
    assert m["io"]["cache_shape"] == [
        test_cfg.n_layers, test_cfg.n_heads, test_cfg.max_seq,
        test_cfg.head_dim,
    ]
    assert m["golden"] == "golden.json"
    assert make_manifest(test_cfg, golden=False)["golden"] is None


def test_lowering_produces_hlo_text(test_cfg):
    text = lower_prefill(test_cfg, test_cfg.prefill_buckets[0])
    assert text.startswith("HloModule")
    # All weights + tokens + prompt_len appear as ENTRY parameters (nested
    # computations have their own parameter() lists, so scope the count).
    entry = text[text.index("ENTRY "):]
    n_params = entry.count("parameter(")
    assert n_params == len(WEIGHT_ORDER) + 2, f"got {n_params} parameters"
    # Tuple-rooted (the Rust side unwraps a 3-tuple).
    assert "tuple(" in text


def test_emitted_artifacts_if_present():
    """Validate the on-disk artifacts when `make artifacts` already ran."""
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "test")
    if not os.path.isdir(adir):
        pytest.skip("artifacts/test not built")
    with open(os.path.join(adir, "manifest.json")) as f:
        m = json.load(f)
    assert m["config"]["name"] == "test"
    for e in m["entrypoints"]["prefill"]:
        assert os.path.exists(os.path.join(adir, e["file"]))
    assert os.path.exists(os.path.join(adir, m["entrypoints"]["decode"]))
    loaded = wm.load(os.path.join(adir, m["weights_file"]))
    assert list(loaded) == WEIGHT_ORDER
    if m["golden"]:
        with open(os.path.join(adir, m["golden"])) as f:
            g = json.load(f)
        assert len(g["generated"]) == g["n_gen"] > 0
