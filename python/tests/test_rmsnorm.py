"""Fused RMSNorm+quant kernel vs oracle, plus RoPE/SwiGLU element-wise
properties (they live in the static region of the paper's design)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rmsnorm import rmsnorm_quant


@pytest.mark.parametrize("m,d,bm", [(4, 32, 4), (16, 128, 8), (1, 64, 1), (12, 32, 4)])
def test_rmsnorm_quant_matches_ref(rng, m, d, bm):
    x = jnp.asarray(rng.randn(m, d) * 3.0, jnp.float32)
    g = jnp.asarray(rng.randn(d), jnp.float32)
    q_got, s_got = rmsnorm_quant(x, g, block_m=bm)
    q_want, s_want = ref.rmsnorm_quant_ref(x, g)
    np.testing.assert_array_equal(np.asarray(q_got), np.asarray(q_want))
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want), rtol=1e-6)


def test_rmsnorm_output_is_unit_rms(rng):
    x = jnp.asarray(rng.randn(8, 256) * 5.0, jnp.float32)
    g = jnp.ones(256, jnp.float32)
    normed = ref.rmsnorm_ref(x, g)
    rms = np.sqrt(np.mean(np.square(np.asarray(normed)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_quant_range_and_reconstruction(rng):
    x = jnp.asarray(rng.randn(16, 64) * 10.0, jnp.float32)
    q, s = ref.quantize_i8(x)
    qa = np.asarray(q, np.int32)
    assert qa.max() <= 127 and qa.min() >= -127
    # per-token absmax hits full scale
    assert (np.abs(qa).max(axis=1) == 127).all()
    recon = qa * np.asarray(s)
    err = np.abs(recon - np.asarray(x)).max()
    scale = np.abs(np.asarray(x)).max()
    assert err <= scale / 127.0 + 1e-6


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([8, 32, 128]),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2 ** 16),
)
def test_rmsnorm_hypothesis(m, d, scale, seed):
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(m, d) * scale, jnp.float32)
    g = jnp.asarray(r.randn(d), jnp.float32)
    q_got, s_got = rmsnorm_quant(x, g, block_m=max(1, m // 2))
    q_want, s_want = ref.rmsnorm_quant_ref(x, g)
    # int8 rounding at the exact .5 boundary can differ by 1 ulp between
    # the fused and the reference path after rsqrt reassociation.
    diff = np.abs(
        np.asarray(q_got, np.int32) - np.asarray(q_want, np.int32)
    ).max()
    assert diff <= 1
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want), rtol=1e-5)


# ---------------------------------------------------------------------------
# RoPE + SwiGLU properties
# ---------------------------------------------------------------------------

def test_rope_preserves_pair_norms(rng):
    """RoPE is a rotation in each (x1, x2) plane: per-pair norms invariant."""
    h, l, dh = 2, 8, 16
    x = jnp.asarray(rng.randn(h, l, dh), jnp.float32)
    pos = jnp.arange(l, dtype=jnp.int32)
    y = np.asarray(ref.rope_ref(x, pos))
    xa = np.asarray(x)
    half = dh // 2
    n_x = xa[..., :half] ** 2 + xa[..., half:] ** 2
    n_y = y[..., :half] ** 2 + y[..., half:] ** 2
    np.testing.assert_allclose(n_x, n_y, rtol=1e-4)


def test_rope_position_zero_is_identity(rng):
    x = jnp.asarray(rng.randn(2, 1, 8), jnp.float32)
    y = ref.rope_ref(x, jnp.zeros(1, jnp.int32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_rope_relative_phase(rng):
    """Dot products under RoPE depend only on relative position:
    <rope(q,m), rope(k,n)> == <rope(q,m+d), rope(k,n+d)>."""
    dh = 16
    q = jnp.asarray(rng.randn(1, 1, dh), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, dh), jnp.float32)

    def dot(m, n):
        qm = ref.rope_ref(q, jnp.asarray([m], jnp.int32))
        kn = ref.rope_ref(k, jnp.asarray([n], jnp.int32))
        return float(jnp.sum(qm * kn))

    assert abs(dot(3, 7) - dot(13, 17)) < 1e-3
    assert abs(dot(0, 5) - dot(20, 25)) < 1e-3


def test_swiglu_properties(rng):
    gate = jnp.asarray(rng.randn(8, 16), jnp.float32)
    up = jnp.asarray(rng.randn(8, 16), jnp.float32)
    y = np.asarray(ref.swiglu_ref(gate, up))
    # silu(0) = 0 -> zero gate kills the output
    y0 = np.asarray(ref.swiglu_ref(jnp.zeros_like(gate), up))
    np.testing.assert_allclose(y0, 0.0, atol=1e-7)
    # large positive gate ~ identity * up
    yb = np.asarray(ref.swiglu_ref(jnp.full_like(gate, 20.0), up))
    np.testing.assert_allclose(yb, 20.0 * np.asarray(up), rtol=1e-4)
    # silu is bounded below by ~ -0.2785
    s = np.asarray(ref.silu_ref(jnp.linspace(-50, 50, 1001)))
    assert s.min() > -0.2786
    assert y.shape == (8, 16)
