"""Shared pytest fixtures for the PD-Swap compile-path tests.

Run from the ``python/`` directory: ``cd python && pytest tests/ -q``.
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def test_cfg():
    from compile.configs import CONFIGS

    return CONFIGS["test"]


@pytest.fixture(scope="session")
def test_weights(test_cfg):
    from compile import weights as wm

    return wm.generate(test_cfg, seed=0)
