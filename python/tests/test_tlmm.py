"""TLMM kernel correctness: Pallas vs pure-jnp oracle vs faithful LUT.

The accumulation is all-integer, so the Pallas kernel, the reference, and
the 81-entry table-lookup formulation must agree *exactly* (zero ulp) —
this is the equivalence the paper's FPGA engine relies on.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.tlmm import tlmm, vmem_bytes
from compile.kernels.tlmm_lut import build_group_tables, tlmm_lut


def make_case(rng, m, k, n):
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    x_q, sx = ref.quantize_i8(x)
    w_t, sw = ref.ternarize(jnp.asarray(rng.randn(n, k), jnp.float32))
    return x_q, sx, ref.pack_ternary(w_t), sw, w_t


@pytest.mark.parametrize(
    "m,k,n,bm,bn",
    [
        (8, 16, 8, 8, 8),      # single tile
        (16, 32, 24, 8, 8),    # multi-tile both dims
        (1, 128, 64, 8, 64),   # decode shape (M=1)
        (32, 64, 16, 64, 64),  # blocks larger than dims (clamped)
        (8, 4, 8, 4, 4),       # minimal K (one pack group)
    ],
)
def test_tlmm_matches_ref_exactly(rng, m, k, n, bm, bn):
    x_q, sx, codes, sw, _ = make_case(rng, m, k, n)
    got = tlmm(x_q, sx, codes, sw, block_m=bm, block_n=bn)
    want = ref.tlmm_ref(x_q, sx, codes, sw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tlmm_lut_matches_ref_exactly(rng):
    x_q, sx, codes, sw, _ = make_case(rng, 8, 32, 16)
    got = tlmm_lut(x_q, sx, codes, sw)
    want = ref.tlmm_ref(x_q, sx, codes, sw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_unpack_roundtrip(rng):
    w = (rng.randint(0, 3, size=(16, 32)) - 1).astype(np.int8)
    codes = ref.pack_ternary(jnp.asarray(w))
    back = ref.unpack_ternary(codes, 32)
    np.testing.assert_array_equal(np.asarray(back), w)
    assert codes.dtype == jnp.uint8
    assert int(jnp.max(codes)) < ref.PACK_CODES


def test_group_tables_definition(rng):
    """table[m, g, c] must equal the dot of group activations with the
    decoded digits of c — spot-check against a brute-force build."""
    x = (rng.randint(-127, 128, size=(3, 8))).astype(np.int8)
    tables = np.asarray(build_group_tables(jnp.asarray(x)))
    assert tables.shape == (3, 2, 81)
    for m in range(3):
        for g in range(2):
            grp = x[m, 4 * g: 4 * g + 4].astype(np.int64)
            for c in (0, 1, 40, 80):
                digits = [(c // 3 ** j) % 3 - 1 for j in range(4)]
                assert tables[m, g, c] == int(np.dot(grp, digits))


def test_weight_residency_footprint():
    """The BlockSpec pins full-K operand rows; the VMEM estimate must stay
    under a TPU core's ~16 MiB VMEM for the paper-scale layer shapes."""
    # BitNet 0.73B largest linear: d_ff=4096 rows over K=1536.
    assert vmem_bytes(m=128, k=1536, n=4096) < 16 * 2 ** 20
    # e2e-100m shapes with the config's blocks.
    assert vmem_bytes(m=512, k=768, n=3072, block_m=64, block_n=128) < 16 * 2 ** 20


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 2, 4, 8]),
    kg=st.integers(1, 16),
    n=st.sampled_from([4, 8, 12, 32]),
    seed=st.integers(0, 2 ** 16),
)
def test_tlmm_hypothesis_shapes(m, kg, n, seed):
    """Random shapes (K any multiple of 4) and random int8/ternary data:
    kernel == ref exactly."""
    r = np.random.RandomState(seed)
    k = 4 * kg
    x_q = jnp.asarray(r.randint(-127, 128, size=(m, k)), jnp.int8)
    sx = jnp.asarray(np.abs(r.randn(m, 1)) + 0.01, jnp.float32)
    w_t = jnp.asarray(r.randint(-1, 2, size=(n, k)), jnp.int8)
    codes = ref.pack_ternary(w_t)
    sw = jnp.float32(abs(r.randn()) + 0.01)
    got = tlmm(x_q, sx, codes, sw, block_m=max(1, m // 2), block_n=max(1, n // 2))
    want = ref.tlmm_ref(x_q, sx, codes, sw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_three_formulations_agree(seed):
    """tlmm (decode+dot), tlmm_lut (table lookup), tlmm_ref (unpack+dot)
    are the same function."""
    r = np.random.RandomState(seed)
    m, k, n = 4, 24, 8
    x_q = jnp.asarray(r.randint(-127, 128, size=(m, k)), jnp.int8)
    sx = jnp.asarray(np.abs(r.randn(m, 1)) + 0.01, jnp.float32)
    w_t = jnp.asarray(r.randint(-1, 2, size=(n, k)), jnp.int8)
    codes = ref.pack_ternary(w_t)
    sw = jnp.float32(1.0)
    a = np.asarray(tlmm(x_q, sx, codes, sw, block_m=4, block_n=8))
    b = np.asarray(tlmm_lut(x_q, sx, codes, sw))
    c = np.asarray(ref.tlmm_ref(x_q, sx, codes, sw))
    np.testing.assert_array_equal(a, c)
    np.testing.assert_array_equal(b, c)


def test_dequant_approximates_float_matmul(rng):
    """End-to-end quantized linear ~ float matmul within quantization noise."""
    x = jnp.asarray(rng.randn(16, 64), jnp.float32)
    w_f = jnp.asarray(rng.randn(32, 64), jnp.float32) / 8.0
    y_q = ref.linear_ref(x, w_f)
    y_f = x @ w_f.T
    # Ternary + int8 quantization is lossy; correlation must be high.
    a, b = np.asarray(y_q).ravel(), np.asarray(y_f).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    # ~0.88 is the expected fidelity of absmean ternarization on gaussian
    # weights (information-theoretic, not a bug) — guard against regressions.
    assert corr > 0.85, f"dequantized output decorrelated: r={corr:.3f}"
