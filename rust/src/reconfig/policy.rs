//! Swap-scheduling policies for continuous serving under DPR.
//!
//! **What is the paper's and what is ours:** the paper's controller
//! (§3.2.1/§3.4) serves one request at a time, so its only policy is
//! [`SwapPolicy::Eager`] — trigger the decode swap the moment the final
//! layer's prefill attention finishes (the early trigger of Fig. 5) and
//! swap back to prefill as soon as the next request wants the fabric.
//! Under *continuous mixed traffic* that eagerness thrashes the PCAP:
//! every arrival interrupts decode for a full swap pair (~2×45 ms plus
//! the latency of the interposed prefill). [`SwapPolicy::Hysteresis`] and
//! [`SwapPolicy::Lookahead`] are our serving extensions — they decide
//! *when a swap is worth it*, not how it is overlapped; all three use the
//! paper's §3.4 early-trigger overlap for the prefill→decode direction
//! whenever the engine enables it.
//!
//! The engine ([`crate::coordinator::events::EventServer`]) consults a
//! policy at exactly two decision points, passing a [`SwapOutlook`]
//! snapshot of both phases' backlogs:
//!
//! 1. **At the prefill trigger point** (final-layer attention done):
//!    commit to the decode swap now, or keep the prefill RM and serve
//!    more queued prompts first?
//! 2. **Between decode steps**: interrupt decoding to go prefill the
//!    waiting prompts, or keep generating?
//!
//! The engine itself handles the forced cases (nothing to decode → stay
//! in prefill; nothing to prefill → stay in decode), so policies only
//! ever arbitrate genuine contention.
//!
//! When the engine runs multi-stream decode
//! ([`EventServerConfig::decode_batch`](crate::coordinator::EventServerConfig) > 1),
//! [`SwapOutlook::est_decode_step`] is the *amortized per-token* batched
//! step — resident streams share one weight-stream pass — so the same
//! policy arithmetic automatically values decode backlog higher when
//! batching makes it cheaper to drain.
//!
//! ```
//! use pd_swap::reconfig::{SwapOutlook, SwapPolicy};
//!
//! // Three prompts wait; the decode side still owes 512 tokens.
//! let outlook = SwapOutlook {
//!     pending_prefill: 3,
//!     pending_prefill_tokens: 768,
//!     est_prefill_time: 5.2,
//!     decode_ready: 2,
//!     decode_pending_tokens: 512,
//!     est_decode_step: 0.036,
//!     reconfig_latency: 0.045,
//!     est_round_trip_exposed: 0.06,
//! };
//! // The paper's eager flow yields the fabric to any waiting prompt;
//! // hysteresis demands a deeper backlog before paying the swap pair.
//! assert!(SwapPolicy::Eager.swap_to_prefill_mid_decode(&outlook));
//! assert!(SwapPolicy::hysteresis_default().swap_to_prefill_mid_decode(&outlook));
//! let shallow = SwapOutlook { pending_prefill: 1, ..outlook };
//! assert!(!SwapPolicy::hysteresis_default().swap_to_prefill_mid_decode(&shallow));
//! ```

use crate::engines::PhaseModel;
use crate::model::ModelShape;

use super::OverlapScheduler;

/// Snapshot of both phases' pending work at a policy decision point.
/// All times are estimates from the analytic phase model — the policy is
/// deciding the future, so exactness is impossible by construction.
///
/// The event engine assembles this snapshot in O(1): the backlog counts
/// and token sums are maintained incrementally (updated at arrival,
/// extraction, eviction-requeue, and per applied token — never by
/// re-scanning the queue or the decode set per decision), and the decode
/// estimate comes from the uniform-context closed form
/// ([`crate::engines::LatencySurface::decode_step_uniform_paged`]), so a
/// policy consultation allocates nothing and costs a handful of
/// floating-point operations.
#[derive(Debug, Clone, Copy)]
pub struct SwapOutlook {
    /// Arrived-but-not-prefilled requests (admissible or not).
    pub pending_prefill: usize,
    /// Sum of their prompt lengths.
    pub pending_prefill_tokens: usize,
    /// Estimated time to prefill all of them, seconds.
    pub est_prefill_time: f64,
    /// Residents with generation budget left (decode-side backlog).
    pub decode_ready: usize,
    /// Sum of their remaining generation tokens.
    pub decode_pending_tokens: usize,
    /// Current per-token decode latency estimate, seconds. With
    /// multi-stream decode ([`EventServerConfig::decode_batch`] > 1 on
    /// the engine) this is the *amortized* batched step
    /// (`batched total / batch`), so policies price decode work at what
    /// it actually costs under the configured residency — batching is
    /// folded in here rather than carried as a separate field no policy
    /// would read.
    ///
    /// [`EventServerConfig::decode_batch`]: crate::coordinator::EventServerConfig
    pub est_decode_step: f64,
    /// Full PCAP load latency, seconds.
    pub reconfig_latency: f64,
    /// Estimated *exposed* reconfiguration cost of a prefill round trip
    /// (decode→prefill swap is fully exposed; the return swap hides
    /// behind the §3.4 tail of whatever would be prefilled).
    pub est_round_trip_exposed: f64,
}

/// The two contention points a policy arbitrates — used by telemetry to
/// label decision records ([`SwapPolicy::decision_costs`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionPoint {
    /// §3.4 prefill trigger: commit the decode swap, or keep the prefill
    /// RM and serve more queued prompts first?
    AtTrigger,
    /// Between decode steps: interrupt decoding and yield the fabric to
    /// waiting prompts?
    MidDecode,
}

impl DecisionPoint {
    pub fn name(&self) -> &'static str {
        match self {
            DecisionPoint::AtTrigger => "at-trigger",
            DecisionPoint::MidDecode => "mid-decode",
        }
    }
}

/// When to move the reconfigurable attention slot between phases.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SwapPolicy {
    /// The paper's baseline: swap at the final-layer attention trigger
    /// after every prefill, and yield the fabric to a waiting prompt
    /// after the very next decode step. One swap pair per request —
    /// optimal at the paper's single-request workload, pathological
    /// under continuous arrivals.
    Eager,
    /// Phase stickiness: stay in the current phase until the *other*
    /// phase's backlog crosses a threshold. Avoids bitstream thrash by
    /// batching phase changes; the thresholds trade TTFT (prompts wait
    /// longer) for decode throughput (fewer exposed swaps).
    Hysteresis {
        /// Swap decode→prefill only once this many prompts wait.
        prefill_backlog: usize,
        /// While prefilling, keep the prefill RM until the decode side
        /// has at least this many tokens pending (then switch).
        decode_backlog_tokens: usize,
    },
    /// Amortization arithmetic: swap decode→prefill only when the
    /// waiting prefill work is at least `amortize` times the exposed
    /// round-trip reconfiguration cost (computed with the
    /// [`OverlapScheduler`]'s §3.4 overlap arithmetic), so the PCAP tax
    /// is always a bounded fraction of useful work.
    Lookahead {
        /// Required ratio of useful prefill work to exposed swap cost.
        amortize: f64,
    },
}

impl SwapPolicy {
    /// Parse a CLI/bench name.
    pub fn from_name(name: &str) -> Option<SwapPolicy> {
        match name {
            "eager" => Some(SwapPolicy::Eager),
            "hysteresis" => Some(SwapPolicy::hysteresis_default()),
            "lookahead" => Some(SwapPolicy::lookahead_default()),
            _ => None,
        }
    }

    /// Hysteresis tuned for edge mixed traffic: leave decode once three
    /// prompts wait; once prefilling, drain the queue unless the decode
    /// backlog turns critical (a high valve — returning early would pay
    /// a whole extra round trip per remaining prompt).
    pub fn hysteresis_default() -> SwapPolicy {
        SwapPolicy::Hysteresis { prefill_backlog: 3, decode_backlog_tokens: 4096 }
    }

    /// Lookahead requiring 8× useful work per exposed swap-second.
    pub fn lookahead_default() -> SwapPolicy {
        SwapPolicy::Lookahead { amortize: 8.0 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SwapPolicy::Eager => "eager",
            SwapPolicy::Hysteresis { .. } => "hysteresis",
            SwapPolicy::Lookahead { .. } => "lookahead",
        }
    }

    /// Decision point 1 — prefill trigger: commit to the decode swap now?
    /// (`false` = keep the prefill RM and serve more prompts first.)
    /// Only called when decode-side work exists; the engine stays in
    /// prefill unconditionally when there is nothing to decode.
    pub fn swap_to_decode_at_trigger(&self, o: &SwapOutlook) -> bool {
        if o.pending_prefill == 0 {
            return true; // nothing more to prefill: always go decode
        }
        match *self {
            // Paper flow: one prompt, one swap pair.
            SwapPolicy::Eager => true,
            // Keep prefilling until the decode side has real backlog.
            SwapPolicy::Hysteresis { decode_backlog_tokens, .. } => {
                o.decode_pending_tokens >= decode_backlog_tokens.max(1)
            }
            // Prefilling the next queued prompt now costs only its
            // prefill; returning for it later costs that prefill PLUS a
            // swap round trip. So keep draining unless the decode
            // backlog dwarfs the remaining prefill investment.
            SwapPolicy::Lookahead { amortize } => {
                o.decode_pending_tokens as f64 * o.est_decode_step
                    >= amortize * (o.est_prefill_time + o.est_round_trip_exposed.max(1e-9))
            }
        }
    }

    /// Decision point 2 — between decode steps: interrupt decoding and
    /// swap to prefill? Only called when prefill-side work exists *and*
    /// decode work remains; the engine swaps unconditionally when the
    /// decode set drains.
    pub fn swap_to_prefill_mid_decode(&self, o: &SwapOutlook) -> bool {
        match *self {
            // Any waiting prompt grabs the fabric immediately.
            SwapPolicy::Eager => o.pending_prefill > 0,
            SwapPolicy::Hysteresis { prefill_backlog, .. } => {
                o.pending_prefill >= prefill_backlog.max(1)
            }
            SwapPolicy::Lookahead { amortize } => {
                o.est_prefill_time >= amortize * o.est_round_trip_exposed.max(1e-9)
            }
        }
    }

    /// The operands behind the two decision methods, exposed for
    /// swap-decision telemetry attribution: `(in_favor, threshold)` such
    /// that the policy swaps iff `in_favor >= threshold`. This replays
    /// the exact arithmetic of [`Self::swap_to_decode_at_trigger`] /
    /// [`Self::swap_to_prefill_mid_decode`] (the forced
    /// nothing-left-to-prefill case included) without changing them —
    /// consistency is pinned by the `decision_costs_match_decisions`
    /// test. Units differ by policy (counts for Eager/Hysteresis,
    /// seconds for Lookahead); the telemetry record carries the policy
    /// name so consumers can interpret them.
    pub fn decision_costs(&self, point: DecisionPoint, o: &SwapOutlook) -> (f64, f64) {
        match point {
            DecisionPoint::AtTrigger => {
                if o.pending_prefill == 0 {
                    return (1.0, 0.0); // forced: nothing more to prefill
                }
                match *self {
                    SwapPolicy::Eager => (1.0, 0.0),
                    SwapPolicy::Hysteresis { decode_backlog_tokens, .. } => (
                        o.decode_pending_tokens as f64,
                        decode_backlog_tokens.max(1) as f64,
                    ),
                    SwapPolicy::Lookahead { amortize } => (
                        o.decode_pending_tokens as f64 * o.est_decode_step,
                        amortize
                            * (o.est_prefill_time + o.est_round_trip_exposed.max(1e-9)),
                    ),
                }
            }
            DecisionPoint::MidDecode => match *self {
                SwapPolicy::Eager => (o.pending_prefill as f64, 1.0),
                SwapPolicy::Hysteresis { prefill_backlog, .. } => {
                    (o.pending_prefill as f64, prefill_backlog.max(1) as f64)
                }
                SwapPolicy::Lookahead { amortize } => {
                    (o.est_prefill_time, amortize * o.est_round_trip_exposed.max(1e-9))
                }
            },
        }
    }
}

/// Estimate the exposed cost of a decode→prefill→decode round trip for
/// [`SwapOutlook::est_round_trip_exposed`]: the outbound swap is fully
/// exposed (decode work is stalled for the whole PCAP load), while the
/// return swap overlaps with the §3.4 tail of a representative pending
/// prompt.
pub fn round_trip_exposed(
    ov: &OverlapScheduler,
    shape: &ModelShape,
    representative_prompt: usize,
) -> f64 {
    let back = ov.overlapped(shape, representative_prompt.max(1)).exposed;
    ov.reconfig_latency + back
}

/// Estimated time to prefill `prompt_tokens` spread over `n` prompts
/// (used for [`SwapOutlook::est_prefill_time`]): models each prompt at
/// the mean length rather than summing per-prompt calls, so the engine
/// can compute it in O(1) per decision.
pub fn est_prefill_time(
    model: &PhaseModel,
    shape: &ModelShape,
    n: usize,
    prompt_tokens: usize,
) -> f64 {
    est_prefill_time_with(|l| model.prefill(shape, l).total, n, prompt_tokens)
}

/// [`est_prefill_time`] over any prefill-latency oracle — the serving
/// engines pass a [`crate::engines::LatencySurface`] closure here so the
/// estimate costs O(1) with no phase-model re-derivation. The arithmetic
/// is shared with the model-backed path, so both are bit-identical.
pub fn est_prefill_time_with(
    prefill_total: impl Fn(usize) -> f64,
    n: usize,
    prompt_tokens: usize,
) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let mean = (prompt_tokens / n).max(1);
    prefill_total(mean) * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outlook() -> SwapOutlook {
        SwapOutlook {
            pending_prefill: 2,
            pending_prefill_tokens: 512,
            est_prefill_time: 3.0,
            decode_ready: 2,
            decode_pending_tokens: 64,
            est_decode_step: 0.05,
            reconfig_latency: 0.045,
            est_round_trip_exposed: 0.06,
        }
    }

    #[test]
    fn eager_always_swaps() {
        let o = outlook();
        assert!(SwapPolicy::Eager.swap_to_decode_at_trigger(&o));
        assert!(SwapPolicy::Eager.swap_to_prefill_mid_decode(&o));
        let idle = SwapOutlook { pending_prefill: 0, ..o };
        assert!(!SwapPolicy::Eager.swap_to_prefill_mid_decode(&idle));
    }

    #[test]
    fn hysteresis_sticks_until_backlog() {
        let p = SwapPolicy::Hysteresis { prefill_backlog: 3, decode_backlog_tokens: 96 };
        let o = outlook();
        // 2 waiting prompts < 3: keep decoding.
        assert!(!p.swap_to_prefill_mid_decode(&o));
        let deep = SwapOutlook { pending_prefill: 3, ..o };
        assert!(p.swap_to_prefill_mid_decode(&deep));
        // Decode backlog 64 < 96: keep prefilling at the trigger.
        assert!(!p.swap_to_decode_at_trigger(&o));
        let heavy = SwapOutlook { decode_pending_tokens: 200, ..o };
        assert!(p.swap_to_decode_at_trigger(&heavy));
        // Nothing left to prefill: always go decode.
        let drained = SwapOutlook { pending_prefill: 0, ..o };
        assert!(p.swap_to_decode_at_trigger(&drained));
    }

    #[test]
    fn lookahead_amortizes_swap_cost() {
        let p = SwapPolicy::Lookahead { amortize: 8.0 };
        let o = outlook();
        // 3.0 s of prefill work vs 8 × 0.06 s = 0.48 s: worth leaving
        // decode for.
        assert!(p.swap_to_prefill_mid_decode(&o));
        let tiny = SwapOutlook { est_prefill_time: 0.3, ..o };
        assert!(!p.swap_to_prefill_mid_decode(&tiny));
        // At the trigger with 3.0 s of prompts still queued: decode
        // backlog 64 × 0.05 = 3.2 s < 8 × (3.0 + 0.06) s — keep
        // draining the queue.
        assert!(!p.swap_to_decode_at_trigger(&o));
        // Once the remaining prefill investment is tiny, decode wins:
        // 3.2 s ≥ 8 × (0.1 + 0.06) s.
        let drained = SwapOutlook { est_prefill_time: 0.1, pending_prefill: 1, ..o };
        assert!(p.swap_to_decode_at_trigger(&drained));
        // And an empty queue always goes to decode.
        let empty = SwapOutlook { pending_prefill: 0, ..o };
        assert!(p.swap_to_decode_at_trigger(&empty));
    }

    #[test]
    fn decision_costs_match_decisions() {
        // The telemetry operands must agree with the live decisions
        // (`swap ⟺ in_favor >= threshold`) on every policy at both
        // decision points, across a grid that crosses every comparison's
        // boundary in both directions.
        let policies = [
            SwapPolicy::Eager,
            SwapPolicy::hysteresis_default(),
            SwapPolicy::Hysteresis { prefill_backlog: 1, decode_backlog_tokens: 1 },
            SwapPolicy::lookahead_default(),
            SwapPolicy::Lookahead { amortize: 0.5 },
        ];
        let base = outlook();
        for p in policies {
            for pending_prefill in [0usize, 1, 2, 3, 5] {
                for decode_pending_tokens in [0usize, 64, 4096, 9000] {
                    for est_prefill_time in [0.01, 0.3, 3.0, 30.0] {
                        let o = SwapOutlook {
                            pending_prefill,
                            decode_pending_tokens,
                            est_prefill_time,
                            ..base
                        };
                        let (lhs, rhs) = p.decision_costs(DecisionPoint::AtTrigger, &o);
                        assert_eq!(
                            lhs >= rhs,
                            p.swap_to_decode_at_trigger(&o),
                            "{p:?} at-trigger {o:?}"
                        );
                        let (lhs, rhs) = p.decision_costs(DecisionPoint::MidDecode, &o);
                        assert_eq!(
                            lhs >= rhs,
                            p.swap_to_prefill_mid_decode(&o),
                            "{p:?} mid-decode {o:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn decision_point_names() {
        assert_eq!(DecisionPoint::AtTrigger.name(), "at-trigger");
        assert_eq!(DecisionPoint::MidDecode.name(), "mid-decode");
    }

    #[test]
    fn names_round_trip() {
        for n in ["eager", "hysteresis", "lookahead"] {
            assert_eq!(SwapPolicy::from_name(n).unwrap().name(), n);
        }
        assert!(SwapPolicy::from_name("nope").is_none());
    }
}
