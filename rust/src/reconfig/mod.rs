//! Latency-overlapped runtime reconfiguration (§3.4, Fig. 5).
//!
//! A request needs exactly one swap (prefill-attention → decode-attention)
//! but the ~45 ms PCAP load would still be visible on short generations —
//! so the paper's controller starts the swap the moment the *final
//! layer's* prefill attention finishes, overlapping the load with the
//! remaining output-projection + FFN tail (~31 ms at L=128) and exposing
//! only the difference (~75% of the overhead hidden).
//!
//! [`OverlapScheduler`] computes that arithmetic for any (design, model,
//! L); [`SwapController`] drives an [`FpgaDevice`] through the swap with
//! the correctness rule the paper states: decode never starts before the
//! decode-attention bitstream is fully loaded.

use anyhow::Result;

use crate::engines::PhaseModel;
use crate::fpga::FpgaDevice;
use crate::model::ModelShape;

pub mod policy;

pub use policy::{round_trip_exposed, DecisionPoint, SwapOutlook, SwapPolicy};

/// What to do when a PCAP partial reconfiguration fails (fault
/// injection, `docs/ARCHITECTURE.md` extension #10): retry with capped
/// exponential backoff in *virtual* time, then fall back.
///
/// Fallback semantics at exhaustion:
/// - **degraded** (default): keep whatever engine is resident and serve
///   the other phase through the modeled static-unified penalty
///   (TeLLMe-v2-style single engine) until a scheduled repair swap
///   succeeds — availability over latency.
/// - **fail-stop** (`fail_stop = true`): shed everything outstanding and
///   every later arrival — the naive comparator the `fault_tolerance`
///   bench prices the degraded mode against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwapRetryPolicy {
    /// PCAP attempts per logical swap before fallback (≥ 1; the first
    /// attempt counts).
    pub max_attempts: u32,
    /// Backoff before the first retry, seconds of virtual time.
    pub backoff_base_s: f64,
    /// Backoff ceiling; also the cadence of degraded-mode repair swaps.
    pub backoff_cap_s: f64,
    /// Exhaustion sheds instead of degrading (naive baseline).
    pub fail_stop: bool,
}

impl Default for SwapRetryPolicy {
    fn default() -> Self {
        // Base ≈ a quarter PCAP load, cap ≈ 7 loads: retries stay cheap
        // next to the ~45 ms reconfiguration they are retrying, and the
        // repair cadence doesn't busy-spin the degraded timeline.
        Self { max_attempts: 3, backoff_base_s: 0.010, backoff_cap_s: 0.320, fail_stop: false }
    }
}

impl SwapRetryPolicy {
    /// The naive fail-stop comparator (same retry budget, no fallback).
    pub fn fail_stop() -> Self {
        Self { fail_stop: true, ..Self::default() }
    }

    /// Virtual-time delay before retry number `attempt` (1-based):
    /// `base · 2^(attempt−1)`, capped. Pure float arithmetic with an
    /// early cap return, so the schedule is bit-deterministic.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let mut d = self.backoff_base_s.max(0.0);
        for _ in 1..attempt {
            d *= 2.0;
            if d >= self.backoff_cap_s {
                return self.backoff_cap_s;
            }
        }
        d.min(self.backoff_cap_s)
    }
}

/// Names of the two attention RMs (shared with `AcceleratorDesign`).
pub const RM_PREFILL: &str = "attn-prefill";
pub const RM_DECODE: &str = "attn-decode";

/// The Fig. 5 timeline for one prefill→decode transition.
#[derive(Debug, Clone, Copy)]
pub struct OverlapTimeline {
    /// Total prefill latency (t=0 .. prefill_end).
    pub prefill_end: f64,
    /// When the final layer's attention completes = swap trigger point.
    pub trigger: f64,
    /// The prefill tail available for overlap (prefill_end - trigger).
    pub tail: f64,
    /// PCAP load latency.
    pub reconfig: f64,
    /// When the decode RM is live.
    pub decode_ready: f64,
    /// Reconfiguration latency NOT hidden by the tail.
    pub exposed: f64,
    /// Fraction of the reconfig latency hidden (the paper's ~75%).
    pub hidden_fraction: f64,
}

/// Computes overlap timelines from the phase model.
#[derive(Debug, Clone)]
pub struct OverlapScheduler {
    pub model: PhaseModel,
    pub reconfig_latency: f64,
}

impl OverlapScheduler {
    pub fn new(model: PhaseModel, reconfig_latency: f64) -> Self {
        Self { model, reconfig_latency }
    }

    /// Timeline with early-trigger overlap (the paper's mechanism).
    pub fn overlapped(&self, shape: &ModelShape, l: usize) -> OverlapTimeline {
        let prefill_end = self.model.prefill(shape, l).total;
        let tail = self.model.prefill_tail_after_last_attention(shape, l);
        let trigger = prefill_end - tail;
        let decode_ready = (trigger + self.reconfig_latency).max(prefill_end);
        let exposed = decode_ready - prefill_end;
        OverlapTimeline {
            prefill_end,
            trigger,
            tail,
            reconfig: self.reconfig_latency,
            decode_ready,
            exposed,
            hidden_fraction: 1.0 - exposed / self.reconfig_latency,
        }
    }

    /// Timeline without overlap (swap starts only after prefill ends) —
    /// the naive baseline Fig. 5 compares against.
    pub fn sequential(&self, shape: &ModelShape, l: usize) -> OverlapTimeline {
        let prefill_end = self.model.prefill(shape, l).total;
        OverlapTimeline {
            prefill_end,
            trigger: prefill_end,
            tail: 0.0,
            reconfig: self.reconfig_latency,
            decode_ready: prefill_end + self.reconfig_latency,
            exposed: self.reconfig_latency,
            hidden_fraction: 0.0,
        }
    }
}

/// Drives the simulated device through phase swaps with the §3.4 safety
/// rule: decode work is only admitted once the decode RM is live.
#[derive(Debug)]
pub struct SwapController {
    pub device: FpgaDevice,
}

impl SwapController {
    pub fn new(device: FpgaDevice) -> Self {
        Self { device }
    }

    /// Ensure the prefill RM is (or becomes) live; returns when it's ready.
    pub fn ensure_prefill(&mut self, now: f64) -> Result<f64> {
        if self.device.is_live(RM_PREFILL, now) {
            return Ok(now);
        }
        self.device.start_reconfig(RM_PREFILL, now)
    }

    /// Early-trigger the decode swap at the §3.4 trigger point.
    pub fn trigger_decode_swap(&mut self, trigger_time: f64) -> Result<f64> {
        if self.device.is_live(RM_DECODE, trigger_time) {
            return Ok(trigger_time);
        }
        self.device.start_reconfig(RM_DECODE, trigger_time)
    }

    /// The §3.4 conservative rule: decode may start at
    /// `max(prefill_end, decode_ready)`.
    pub fn decode_admissible_at(&mut self, prefill_end: f64, decode_ready: f64) -> f64 {
        self.device.settle(decode_ready);
        prefill_end.max(decode_ready)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::AcceleratorDesign;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn scheduler() -> OverlapScheduler {
        let design = AcceleratorDesign::pd_swap();
        let device = design.program(&KV260).unwrap();
        let lat = device.reconfig_latency();
        OverlapScheduler::new(PhaseModel::new(design, KV260.clone()), lat)
    }

    #[test]
    fn fig5_numbers_at_l128() {
        // Paper: reconfig ~45 ms, tail ~31 ms at L=128, ~75% hidden.
        let s = scheduler();
        let t = s.overlapped(&BITNET_0_73B, 128);
        assert!((0.035..0.055).contains(&t.reconfig), "reconfig {:.1} ms", t.reconfig * 1e3);
        assert!((0.022..0.042).contains(&t.tail), "tail {:.1} ms", t.tail * 1e3);
        // Paper: "reduce the effective reconfiguration overhead by about
        // 75%"; our tail estimate is slightly more conservative (the tail
        // fraction of the last layer depends on how much of the output
        // projection is really left), so accept a 50-90% band — the
        // mechanism and order of magnitude are what's pinned here.
        assert!(
            (0.50..0.90).contains(&t.hidden_fraction),
            "hidden {:.0}%",
            t.hidden_fraction * 100.0
        );
    }

    #[test]
    fn overlap_strictly_beats_sequential() {
        let s = scheduler();
        for l in [64, 128, 256, 512] {
            let o = s.overlapped(&BITNET_0_73B, l);
            let q = s.sequential(&BITNET_0_73B, l);
            assert!(o.decode_ready < q.decode_ready, "L={l}");
            assert!(o.exposed < q.exposed, "L={l}");
            assert!(o.exposed >= 0.0, "exposed latency can never be negative");
        }
    }

    #[test]
    fn long_prefill_hides_everything() {
        // At long L the tail alone exceeds 45 ms: zero exposure.
        let s = scheduler();
        let t = s.overlapped(&BITNET_0_73B, 2048);
        assert!(t.exposed == 0.0, "exposed {:.1} ms", t.exposed * 1e3);
        assert!((t.hidden_fraction - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tail_longer_than_reconfig_is_fully_hidden() {
        // Edge case: once the §3.4 tail alone exceeds the PCAP load, the
        // swap must be *entirely* free — decode-ready co-incides with
        // prefill end and the exposed term is exactly zero, not merely
        // small (downstream accounting records it in histograms, so a
        // tiny negative or epsilon value would poison means).
        let s = scheduler();
        for l in [1024, 2048] {
            let t = s.overlapped(&BITNET_0_73B, l);
            assert!(t.tail > t.reconfig, "L={l}: tail {:.1} ms", t.tail * 1e3);
            assert_eq!(t.exposed, 0.0, "L={l}");
            assert_eq!(t.decode_ready, t.prefill_end, "L={l}");
            assert!((t.hidden_fraction - 1.0).abs() < 1e-12, "L={l}");
        }
    }

    #[test]
    fn single_layer_model_keeps_trigger_before_prefill_end() {
        // Degenerate 1-layer shape: the "final layer" is the only layer,
        // so the trigger is the whole prefill minus that one layer's
        // post-attention tail. The timeline invariants must survive:
        // 0 ≤ trigger ≤ prefill_end and exposed ∈ [0, reconfig].
        let mut shape = BITNET_0_73B;
        shape.n_layers = 1;
        let s = scheduler();
        for l in [1, 16, 128, 2048] {
            let t = s.overlapped(&shape, l);
            assert!(t.trigger >= 0.0, "L={l}: trigger {:.4}", t.trigger);
            assert!(t.trigger <= t.prefill_end + 1e-12, "L={l}");
            assert!((0.0..=t.reconfig + 1e-12).contains(&t.exposed), "L={l}");
            assert!(t.decode_ready >= t.prefill_end, "L={l}");
            // The sequential baseline's trigger IS prefill end.
            let q = s.sequential(&shape, l);
            assert_eq!(q.trigger, q.prefill_end, "L={l}");
            assert!(t.decode_ready <= q.decode_ready + 1e-12, "L={l}");
        }
    }

    #[test]
    fn tiny_prompt_exposes_most_of_the_reconfig() {
        // L=1 prefill has an almost-zero tail: the overlap mechanism
        // degrades gracefully toward the sequential cost instead of
        // underflowing.
        let s = scheduler();
        let t = s.overlapped(&BITNET_0_73B, 1);
        assert!(t.tail < t.reconfig);
        assert!(t.exposed > 0.0 && t.exposed <= t.reconfig + 1e-12);
        assert!((t.exposed - (t.reconfig - t.tail)).abs() < 1e-12);
    }

    #[test]
    fn zero_token_decode_pays_the_swap_but_no_steps() {
        // A request with max_new_tokens = 0 still triggers the decode
        // swap under the paper's flow (the controller cannot know the
        // generation is empty before sampling); the timeline is valid
        // and the decode span contributes nothing.
        let design = AcceleratorDesign::pd_swap();
        let device = design.program(&KV260).unwrap();
        let lat = device.reconfig_latency();
        let model = PhaseModel::new(design, KV260.clone());
        assert_eq!(model.decode_span(&BITNET_0_73B, 64, 0), 0.0);
        let s = OverlapScheduler::new(model, lat);
        let t = s.overlapped(&BITNET_0_73B, 64);
        let mut ctl = SwapController::new(device);
        let t0 = ctl.ensure_prefill(0.0).unwrap();
        let ready = ctl.trigger_decode_swap(t0 + t.trigger).unwrap();
        let admit = ctl.decode_admissible_at(t0 + t.prefill_end, ready);
        assert!(admit >= t0 + t.prefill_end);
        // e2e for the zero-token request = prefill + exposed swap only.
        assert!((admit - t0 - t.prefill_end - t.exposed).abs() < 1e-9);
    }

    #[test]
    fn controller_enforces_decode_safety() {
        let design = AcceleratorDesign::pd_swap();
        let device = design.program(&KV260).unwrap();
        let mut ctl = SwapController::new(device);

        let t0 = ctl.ensure_prefill(0.0).unwrap();
        assert!(t0 > 0.0, "first prefill load takes PCAP time");
        // Prefill runs; trigger the decode swap early (§3.4).
        let trigger = t0 + 1.0;
        let ready = ctl.trigger_decode_swap(trigger).unwrap();
        assert!(ready > trigger);
        // Decode admission: not before the bitstream is in.
        let prefill_end = trigger + 0.010; // tail shorter than reconfig
        let admit = ctl.decode_admissible_at(prefill_end, ready);
        assert_eq!(admit, ready.max(prefill_end));
        assert!(ctl.device.is_live(super::RM_DECODE, admit));
    }

    #[test]
    fn retry_backoff_is_capped_exponential() {
        let p = SwapRetryPolicy::default();
        assert_eq!(p.backoff(1).to_bits(), 0.010f64.to_bits());
        assert_eq!(p.backoff(2).to_bits(), 0.020f64.to_bits());
        assert_eq!(p.backoff(3).to_bits(), 0.040f64.to_bits());
        // Monotone, and pinned at the cap from attempt 6 on.
        let mut last = 0.0;
        for a in 1..=12 {
            let d = p.backoff(a);
            assert!(d >= last, "attempt {a}");
            assert!(d <= p.backoff_cap_s);
            last = d;
        }
        assert_eq!(p.backoff(6).to_bits(), p.backoff_cap_s.to_bits());
        assert_eq!(p.backoff(32).to_bits(), p.backoff_cap_s.to_bits());
        assert!(SwapRetryPolicy::fail_stop().fail_stop);
        assert_eq!(SwapRetryPolicy::fail_stop().max_attempts, p.max_attempts);
    }

    #[test]
    fn failed_trigger_swap_retried_through_controller_stays_safe() {
        // Satellite: a decode swap triggered mid-prefill fails at its
        // completion point; the retried load must pay full PCAP time
        // from the retry instant and the §3.4 admission rule must hold
        // against the *retried* ready time, never the failed one.
        let design = AcceleratorDesign::pd_swap();
        let device = design.program(&KV260).unwrap();
        let mut ctl = SwapController::new(device);
        let t0 = ctl.ensure_prefill(0.0).unwrap();
        let trigger = t0 + 1.0;
        let ready1 = ctl.trigger_decode_swap(trigger).unwrap();
        // The load fails exactly when it would have completed.
        ctl.device.fail_reconfig(ready1).unwrap();
        assert!(!ctl.device.is_live(RM_DECODE, ready1));
        // Retry after backoff: a full PCAP load from the retry time, via
        // the same trigger path (the RP is Empty, so this is a real load,
        // not the already-live no-op).
        let retry_at = ready1 + SwapRetryPolicy::default().backoff(1);
        let ready2 = ctl.trigger_decode_swap(retry_at).unwrap();
        assert!((ready2 - retry_at - ctl.device.reconfig_latency()).abs() < 1e-12);
        assert!(ready2 > ready1, "retried ready time strictly later");
        // Admission: decode still gated on the retried ready time.
        let prefill_end = trigger + 0.010;
        let admit = ctl.decode_admissible_at(prefill_end, ready2);
        assert_eq!(admit, ready2);
        assert!(ctl.device.is_live(RM_DECODE, admit));
        assert!(!ctl.device.is_live(RM_DECODE, ready2 - 1e-6));
    }

    #[test]
    fn repeat_swaps_accumulate_telemetry() {
        let design = AcceleratorDesign::pd_swap();
        let device = design.program(&KV260).unwrap();
        let mut ctl = SwapController::new(device);
        let mut now = 0.0;
        for _ in 0..3 {
            now = ctl.ensure_prefill(now).unwrap();
            now = ctl.trigger_decode_swap(now).unwrap();
        }
        assert_eq!(ctl.device.reconfig_count, 6);
        assert!(ctl.device.reconfig_seconds_total > 0.2);
    }
}
