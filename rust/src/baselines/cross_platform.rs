//! Table 1 rows: cross-platform + FPGA baselines.
//!
//! Literature rows carry the paper's published numbers; the PD-Swap and
//! TeLLMe rows are *computed* from our simulator so the table is a live
//! output, not a transcription (the test pins computed-vs-paper agreement).

use crate::engines::PhaseModel;
use crate::fpga::{ResourceVec, KV260};
use crate::model::BITNET_0_73B;

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct PlatformRow {
    pub work: &'static str,
    pub platform: &'static str,
    pub processor: &'static str,
    pub model: &'static str,
    pub bitwidth: &'static str,
    /// FPGA resource utilization (None for non-FPGA platforms).
    pub resources: Option<ResourceVec>,
    pub power_w: f64,
    /// WikiText-2 perplexity (model quality; unchanged by the accelerator).
    pub wt2_ppl: f64,
    /// Prefill throughput (tokens/s).
    pub prefill_tks: f64,
    /// Decode throughput (tokens/s).
    pub decode_tks: f64,
}

impl PlatformRow {
    /// Energy efficiency in tokens/J.
    pub fn prefill_tkj(&self) -> f64 {
        self.prefill_tks / self.power_w
    }
    pub fn decode_tkj(&self) -> f64 {
        self.decode_tks / self.power_w
    }
}

/// Literature rows of Table 1 (published numbers, reproduced verbatim).
pub const TABLE1_ROWS: &[PlatformRow] = &[
    PlatformRow {
        work: "Raspberry Pi 5 [19]",
        platform: "SoC",
        processor: "4x Cortex-A76",
        model: "Qwen 0.6B",
        bitwidth: "W4-A16",
        resources: None,
        power_w: 7.8,
        wt2_ppl: 24.00,
        prefill_tks: 61.8,
        decode_tks: 16.6,
    },
    PlatformRow {
        work: "Jetson Orin Nano [20]",
        platform: "GPU SoC",
        processor: "8x GPU SM",
        model: "TinyLLaMA 1.1B",
        bitwidth: "W4-A16",
        resources: None,
        power_w: 25.0,
        wt2_ppl: 12.42,
        prefill_tks: 324.9,
        decode_tks: 67.6,
    },
    PlatformRow {
        work: "LLaMAF [21]",
        platform: "FPGA SoC",
        processor: "ZCU102",
        model: "TinyLLaMA 1.1B",
        bitwidth: "W8-A8",
        resources: Some(ResourceVec {
            lut: 150_000.0,
            ff: 171_000.0,
            bram36: 223.0,
            uram: 0.0,
            dsp: 528.0,
        }),
        power_w: 5.1,
        wt2_ppl: 8.89,
        prefill_tks: 100.0,
        decode_tks: 1.5,
    },
    PlatformRow {
        work: "MEADOW [1]",
        platform: "FPGA SoC",
        processor: "ZCU102",
        model: "OPT 1.3B",
        bitwidth: "W8-A8",
        resources: Some(ResourceVec {
            lut: 0.0, // not reported
            ff: 0.0,
            bram36: 2034.0 / 2.0, // paper reports BRAM18 count
            uram: 0.0,
            dsp: 845.0,
        }),
        power_w: 10.0,
        wt2_ppl: 15.41,
        prefill_tks: 143.0,
        decode_tks: 2.0,
    },
];

/// The paper's expected PD-Swap row (for agreement checks in tests).
pub const PAPER_PDSWAP: (f64, f64, f64) = (4.9, 148.0, 27.8); // (W, prefill, decode)
/// The paper's TeLLMe row.
pub const PAPER_TELLME: (f64, f64, f64) = (4.8, 143.0, 25.0);

/// Short-context decode length used for the Table 1 throughput column
/// (Table 1 reports best-case/short-context decode).
pub const TABLE1_DECODE_CTX: usize = 64;
/// Prefill length for the prefill-throughput column.
pub const TABLE1_PREFILL_CTX: usize = 128;

fn computed_row(
    work: &'static str,
    model: PhaseModel,
    power_w: f64,
    resources: ResourceVec,
) -> PlatformRow {
    let shape = BITNET_0_73B;
    let prefill = model.prefill(&shape, TABLE1_PREFILL_CTX);
    let prefill_tks = TABLE1_PREFILL_CTX as f64 / prefill.total;
    let decode_tks = model.decode_throughput(&shape, TABLE1_DECODE_CTX);
    PlatformRow {
        work,
        platform: "FPGA SoC",
        processor: "KV260",
        model: "BitNet 0.73B",
        bitwidth: "W1.58-A8",
        resources: Some(resources),
        power_w,
        wt2_ppl: 12.79, // property of the BitNet checkpoint, not the system
        prefill_tks,
        decode_tks,
    }
}

/// PD-Swap row, computed live from the simulator.
pub fn pd_swap_row() -> PlatformRow {
    let design = crate::engines::AcceleratorDesign::pd_swap();
    let plan = design.region_plan().expect("pd-swap floorplans");
    let total = plan.static_region.total() + plan.rp.pblock;
    computed_row(
        "PD-Swap (ours, simulated)",
        PhaseModel::new(design, KV260.clone()),
        4.9,
        total,
    )
}

/// TeLLMe row, computed from the same engine family statically hosted.
pub fn tellme_row() -> PlatformRow {
    let design = crate::engines::AcceleratorDesign::tellme_static();
    let total = design.static_region().total();
    computed_row(
        "TeLLMe [10] (simulated)",
        PhaseModel::new(design, KV260.clone()),
        4.8,
        total,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computed_rows_match_paper() {
        let pd = pd_swap_row();
        let (w, pre, dec) = PAPER_PDSWAP;
        assert_eq!(pd.power_w, w);
        // Short-context decode: 27.8 tok/s claimed.
        assert!(
            (dec * 0.93..=dec * 1.07).contains(&pd.decode_tks),
            "decode {:.1} vs paper {dec}",
            pd.decode_tks
        );
        // Prefill throughput at L=128 lands under the projection-rate
        // asymptote (148): allow a wide band because TTFT includes the
        // attention+weights terms at short L.
        assert!(
            (0.5 * pre..=1.1 * pre).contains(&pd.prefill_tks),
            "prefill {:.1} vs paper {pre}",
            pd.prefill_tks
        );

        let te = tellme_row();
        let (_, _, dec_te) = PAPER_TELLME;
        assert!(
            (dec_te * 0.93..=dec_te * 1.07).contains(&te.decode_tks),
            "tellme decode {:.1} vs paper {dec_te}",
            te.decode_tks
        );
    }

    #[test]
    fn energy_efficiency_ordering() {
        // The FPGA designs beat the Jetson/Pi on decode tokens/J (Table 1's
        // qualitative claim).
        let pd = pd_swap_row();
        for row in TABLE1_ROWS {
            if row.platform != "FPGA SoC" {
                assert!(
                    pd.decode_tkj() > row.decode_tkj(),
                    "PD-Swap {:.2} TK/J should beat {} {:.2}",
                    pd.decode_tkj(),
                    row.work,
                    row.decode_tkj()
                );
            }
        }
    }

    #[test]
    fn pd_beats_tellme_on_both_axes() {
        let pd = pd_swap_row();
        let te = tellme_row();
        assert!(pd.decode_tks > te.decode_tks);
        assert!(pd.prefill_tks > te.prefill_tks);
        assert!(pd.decode_tkj() > te.decode_tkj());
    }

    #[test]
    fn literature_rows_expose_published_values() {
        assert_eq!(TABLE1_ROWS.len(), 4);
        let jetson = &TABLE1_ROWS[1];
        assert!((jetson.decode_tkj() - 2.70).abs() < 0.05);
        let pi = &TABLE1_ROWS[0];
        assert!((pi.decode_tkj() - 2.13).abs() < 0.05);
    }
}
