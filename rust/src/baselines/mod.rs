//! Baseline systems for the paper's comparisons (Table 1, Fig. 6).
//!
//! Two kinds:
//!
//! * **TeLLMe (static)** — the head-to-head baseline: same board, same
//!   model, same engine family, but both attention engines resident and
//!   compromised. Built from our own engine models
//!   ([`crate::engines::AcceleratorDesign::tellme_static`]) so the Fig. 6
//!   comparison is a true ablation of DPR, not a curve transplant.
//! * **Cross-platform rows** ([`cross_platform`]) — Raspberry Pi 5, Jetson
//!   Orin Nano, LLaMAF, MEADOW: published numbers from Table 1 plus simple
//!   analytic throughput/energy models used for sanity checks (these
//!   platforms are not simulated at the microarchitecture level; the
//!   rows are reproduced, not re-derived — EXPERIMENTS.md flags this).

pub mod cross_platform;

pub use cross_platform::{PlatformRow, TABLE1_ROWS, pd_swap_row, tellme_row};
