//! Admission and eviction policy for the paged KV pool.
//!
//! Policies are *declarative* here; the mechanics (page accounting, LRU
//! ordering, release) live in [`super::pool`], and the serving loop in
//! [`crate::coordinator::sim_server`] executes the decisions.

/// How the pool judges whether a new request fits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionControl {
    /// Reserve the worst case up front: `ceil(min(prompt + max_new,
    /// max_seq) / page_tokens)` pages. Admitted requests can never run
    /// out of pages mid-decode; the cost is lower occupancy (pages held
    /// for tokens that may never be generated).
    WorstCase,
    /// Reserve only the prompt's pages at admission and grow one page at
    /// a time during decode. Higher occupancy, but the pool can exhaust
    /// mid-decode — then [`EvictionPolicy`] decides who pays.
    Optimistic,
}

impl AdmissionControl {
    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionControl::WorstCase => "worst-case",
            AdmissionControl::Optimistic => "optimistic",
        }
    }

    /// Parse a CLI name (see [`Self::name`]).
    pub fn from_name(name: &str) -> Option<AdmissionControl> {
        match name {
            "worst-case" | "worstcase" | "worst" => Some(AdmissionControl::WorstCase),
            "optimistic" => Some(AdmissionControl::Optimistic),
            _ => None,
        }
    }
}

/// What happens when an optimistically admitted request needs a page the
/// pool no longer has.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Evict the least-recently-touched co-resident request: its pages
    /// are freed immediately and the victim is requeued to re-prefill
    /// from scratch later (its recomputation time is charged to
    /// `ServerMetrics::recompute_overhead`). This trades compute for
    /// capacity — the right call on edge parts where DDR capacity, not
    /// prefill compute, is the scarce resource at long context.
    EvictAndRecompute,
    /// Never evict: the request that cannot grow simply stops generating
    /// (capacity-capped), and every resident keeps its pages until it
    /// completes. Predictable, starvation-free, but long-context
    /// requests get truncated generations under pressure.
    KeepResident,
}

impl EvictionPolicy {
    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::EvictAndRecompute => "evict",
            EvictionPolicy::KeepResident => "keep",
        }
    }

    /// Parse a CLI name (see [`Self::name`]).
    pub fn from_name(name: &str) -> Option<EvictionPolicy> {
        match name {
            "evict" | "evict-recompute" | "evict-and-recompute" => {
                Some(EvictionPolicy::EvictAndRecompute)
            }
            "keep" | "keep-resident" => Some(EvictionPolicy::KeepResident),
            _ => None,
        }
    }
}

/// The pool's verdict on an admission query (see
/// [`super::KvPool::admission_plan`]). The pool never mutates state while
/// planning — the caller executes the decision (reserve, evict, defer).
#[derive(Debug, Clone, PartialEq)]
pub enum AdmissionDecision {
    /// The reservation fits free pages as-is.
    Fits {
        /// Pages to reserve at admission.
        reserved_pages: usize,
        /// Tokens this reservation may grow to.
        token_capacity: usize,
    },
    /// The request alone exceeds the whole pool (or the free pool with no
    /// co-residents to evict): admit it with a clamped reservation and a
    /// correspondingly capped token budget rather than deadlocking.
    Capped {
        reserved_pages: usize,
        token_capacity: usize,
    },
    /// Doesn't fit now, but evicting these residents (LRU-first) would
    /// free enough pages. Only produced under
    /// [`EvictionPolicy::EvictAndRecompute`].
    EvictThenFit {
        victims: Vec<u64>,
        reserved_pages: usize,
        token_capacity: usize,
    },
    /// Doesn't fit while the current residents hold the pool; retry once
    /// some of them complete. Never produced on an empty pool.
    Defer,
}

impl AdmissionDecision {
    /// Pages the decision would reserve if executed (0 for `Defer`).
    pub fn reserved_pages(&self) -> usize {
        match self {
            AdmissionDecision::Fits { reserved_pages, .. }
            | AdmissionDecision::Capped { reserved_pages, .. }
            | AdmissionDecision::EvictThenFit { reserved_pages, .. } => *reserved_pages,
            AdmissionDecision::Defer => 0,
        }
    }

    /// True if the request can be admitted right now without touching any
    /// co-resident (i.e. `Fits` or `Capped`).
    pub fn admits_immediately(&self) -> bool {
        matches!(
            self,
            AdmissionDecision::Fits { .. } | AdmissionDecision::Capped { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_accessors() {
        let f = AdmissionDecision::Fits { reserved_pages: 4, token_capacity: 128 };
        assert_eq!(f.reserved_pages(), 4);
        assert!(f.admits_immediately());

        let c = AdmissionDecision::Capped { reserved_pages: 8, token_capacity: 256 };
        assert!(c.admits_immediately());

        let e = AdmissionDecision::EvictThenFit {
            victims: vec![1, 2],
            reserved_pages: 6,
            token_capacity: 192,
        };
        assert_eq!(e.reserved_pages(), 6);
        assert!(!e.admits_immediately());

        assert_eq!(AdmissionDecision::Defer.reserved_pages(), 0);
        assert!(!AdmissionDecision::Defer.admits_immediately());
    }
}
