//! The page allocator: reservations, growth, eviction, conservation.

use std::collections::BTreeMap;

use crate::fpga::DeviceConfig;
use crate::model::ModelShape;

use super::policy::{AdmissionControl, AdmissionDecision, EvictionPolicy};

/// Default tokens per KV page. 32 tokens × head_dim 64 × fp16 = 4 KiB of
/// contiguous K (and V) per head per page — comfortably past the 64-beat
/// AXI burst knee, so paging costs no DDR efficiency at this size (see
/// [`crate::memory::traffic::paged_kv_burst`]).
pub const PAGE_TOKENS_DEFAULT: usize = 32;

/// DDR bytes held back from the KV budget for activation spill, DMA
/// descriptors, and the PS-side runtime (the PS and PL share the same
/// DDR on the KV260).
pub const ACTIVATION_RESERVE_BYTES: f64 = 256e6;

fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Pool sizing + policy configuration.
#[derive(Debug, Clone)]
pub struct KvPoolConfig {
    /// Tokens per page (all layers of one token share a page slot:
    /// a page holds `page_tokens` tokens' worth of K+V across the model).
    pub page_tokens: usize,
    /// KV bytes per token of context (all layers, K+V) — from
    /// [`ModelShape::kv_bytes_per_token`].
    pub bytes_per_token: f64,
    /// Total pages in the pool (the modeled DDR KV budget).
    pub total_pages: usize,
    /// A single request's KV can never exceed this many tokens (the
    /// compiled graph's `max_seq`); worst-case reservations clamp here.
    pub max_tokens_per_request: usize,
    pub admission: AdmissionControl,
    pub eviction: EvictionPolicy,
}

impl KvPoolConfig {
    /// Derive the pool from the device's DDR capacity and the model:
    /// `budget = ddr − packed ternary weights − activation reserve`.
    pub fn for_device(shape: &ModelShape, device: &DeviceConfig) -> Self {
        let budget =
            (device.ddr_bytes - shape.ternary_weight_bytes() - ACTIVATION_RESERVE_BYTES).max(0.0);
        let bytes_per_token = shape.kv_bytes_per_token();
        let page_bytes = bytes_per_token * PAGE_TOKENS_DEFAULT as f64;
        let total_pages = ((budget / page_bytes).floor() as usize).max(1);
        Self {
            page_tokens: PAGE_TOKENS_DEFAULT,
            bytes_per_token,
            total_pages,
            max_tokens_per_request: shape.max_seq,
            admission: AdmissionControl::WorstCase,
            eviction: EvictionPolicy::KeepResident,
        }
    }

    /// Override the pool size (tests / what-if studies).
    pub fn with_total_pages(mut self, total_pages: usize) -> Self {
        self.total_pages = total_pages.max(1);
        self
    }

    /// Re-page the pool at a different tokens-per-page granularity while
    /// preserving the DDR byte budget: the page count is re-derived so
    /// `budget_bytes()` stays (floor-rounded) constant. This is the
    /// codesign sweep's page-size axis — smaller pages cut internal
    /// fragmentation but shorten DDR bursts
    /// ([`crate::memory::traffic::paged_kv_burst`]), larger pages the
    /// reverse, so the sweet spot is workload-dependent.
    pub fn with_page_tokens(mut self, page_tokens: usize) -> Self {
        let budget = self.budget_bytes();
        self.page_tokens = page_tokens.max(1);
        self.total_pages = ((budget / self.page_bytes()).floor() as usize).max(1);
        self
    }

    pub fn with_policies(mut self, admission: AdmissionControl, eviction: EvictionPolicy) -> Self {
        self.admission = admission;
        self.eviction = eviction;
        self
    }

    /// Bytes of one page.
    pub fn page_bytes(&self) -> f64 {
        self.bytes_per_token * self.page_tokens as f64
    }

    /// The modeled KV byte budget.
    pub fn budget_bytes(&self) -> f64 {
        self.page_bytes() * self.total_pages as f64
    }

    /// Pages needed to hold `tokens` tokens of context.
    pub fn pages_for_tokens(&self, tokens: usize) -> usize {
        ceil_div(tokens, self.page_tokens.max(1))
    }

    /// Worst-case pages for a request: prompt plus full generation,
    /// clamped to the per-request sequence ceiling.
    pub fn worst_case_pages(&self, prompt_len: usize, max_new_tokens: usize) -> usize {
        let tokens = (prompt_len + max_new_tokens).min(self.max_tokens_per_request);
        self.pages_for_tokens(tokens.max(1))
    }
}

/// One resident request's slice of the pool.
#[derive(Debug, Clone)]
struct Reservation {
    /// Pages committed to this request (free pool excludes them).
    reserved: usize,
    /// Pages actually backing written tokens (`ceil(tokens/page)`).
    used: usize,
    /// Tokens currently in the cache.
    tokens: usize,
    /// Tokens this reservation may grow to (admission-capped).
    token_cap: usize,
    /// Last simulation time this request's cache was read or written
    /// (LRU key for victim selection).
    last_touch: f64,
}

/// Conservation counters + occupancy telemetry.
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub admitted: u64,
    pub evicted: u64,
    pub completed: u64,
    /// Admissions that had to clamp their reservation (request alone
    /// bigger than the free pool with nobody to evict).
    pub capped_admissions: u64,
    /// Decode-time page grabs denied because the pool was exhausted.
    pub grow_denied: u64,
    /// Peak committed pages over the pool's lifetime.
    pub high_water_pages: usize,
}

/// Allocation failures.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum PoolError {
    #[error("request {0} is already resident in the KV pool")]
    AlreadyResident(u64),
    #[error("request {0} is not resident in the KV pool")]
    NotResident(u64),
    #[error("reservation of {requested} pages exceeds {free} free (of {total})")]
    OutOfPages { requested: usize, free: usize, total: usize },
    #[error("request {id} would exceed its token capacity ({cap} tokens)")]
    TokenCapExceeded { id: u64, cap: usize },
    #[error("KV pool exhausted growing request {id} to {tokens} tokens")]
    Exhausted { id: u64, tokens: usize },
}

/// Bound on the timestamped eviction log (diagnostics, not accounting).
const EVICTION_LOG_CAP: usize = 4096;

/// The paged KV-cache pool.
#[derive(Debug, Clone)]
pub struct KvPool {
    cfg: KvPoolConfig,
    residents: BTreeMap<u64, Reservation>,
    reserved_total: usize,
    pub stats: PoolStats,
    /// Timestamped `(when, victim)` eviction records, bounded at
    /// `EVICTION_LOG_CAP` — the serving simulators surface these as
    /// timeline events.
    pub eviction_log: Vec<(f64, u64)>,
}

impl KvPool {
    pub fn new(cfg: KvPoolConfig) -> Self {
        Self {
            cfg,
            residents: BTreeMap::new(),
            reserved_total: 0,
            stats: PoolStats::default(),
            eviction_log: Vec::new(),
        }
    }

    pub fn config(&self) -> &KvPoolConfig {
        &self.cfg
    }

    pub fn total_pages(&self) -> usize {
        self.cfg.total_pages
    }

    /// Pages not committed to any reservation.
    pub fn free_pages(&self) -> usize {
        self.cfg.total_pages - self.reserved_total
    }

    /// Pages committed across all residents.
    pub fn reserved_pages(&self) -> usize {
        self.reserved_total
    }

    /// Pages actually backing written tokens.
    pub fn used_pages(&self) -> usize {
        self.residents.values().map(|r| r.used).sum()
    }

    pub fn resident_count(&self) -> usize {
        self.residents.len()
    }

    pub fn is_resident(&self, id: u64) -> bool {
        self.residents.contains_key(&id)
    }

    /// Committed fraction of the pool.
    pub fn occupancy(&self) -> f64 {
        self.reserved_total as f64 / self.cfg.total_pages.max(1) as f64
    }

    /// Internal fragmentation: fraction of *committed* page capacity not
    /// backing real tokens (worst-case reservations + last-page slack).
    pub fn fragmentation(&self) -> f64 {
        if self.reserved_total == 0 {
            return 0.0;
        }
        let capacity_tokens = self.reserved_total * self.cfg.page_tokens;
        let live_tokens: usize = self.residents.values().map(|r| r.tokens).sum();
        1.0 - live_tokens as f64 / capacity_tokens.max(1) as f64
    }

    /// Tokens a resident may still grow to (admission cap).
    /// Pages currently committed to resident `id` (None if not
    /// resident). Lets callers dry-run [`Self::ensure_tokens`] growth —
    /// the event core's decode fast-forward checks every folded step's
    /// page demand against the real reservations before committing, so
    /// pool-exhaustion steps (partial growth + eviction) always run
    /// through the stepped path.
    pub fn reserved_pages_of(&self, id: u64) -> Option<usize> {
        self.residents.get(&id).map(|r| r.reserved)
    }

    pub fn token_cap(&self, id: u64) -> Option<usize> {
        self.residents.get(&id).map(|r| r.token_cap)
    }

    /// Plan an admission without mutating the pool. The caller executes
    /// the decision ([`Self::admit`], plus [`Self::evict`] for
    /// `EvictThenFit` victims).
    pub fn admission_plan(&self, prompt_len: usize, max_new_tokens: usize) -> AdmissionDecision {
        let worst = self.cfg.worst_case_pages(prompt_len, max_new_tokens);
        let token_capacity = (prompt_len + max_new_tokens).min(self.cfg.max_tokens_per_request);
        let need = match self.cfg.admission {
            AdmissionControl::WorstCase => worst,
            AdmissionControl::Optimistic => {
                self.cfg.pages_for_tokens(prompt_len.min(self.cfg.max_tokens_per_request).max(1))
            }
        };
        let free = self.free_pages();
        if need <= free {
            return AdmissionDecision::Fits { reserved_pages: need, token_capacity };
        }
        if self.cfg.eviction == EvictionPolicy::EvictAndRecompute {
            if let Some(victims) = self.eviction_plan(need - free) {
                return AdmissionDecision::EvictThenFit {
                    victims,
                    reserved_pages: need,
                    token_capacity,
                };
            }
        }
        if self.residents.is_empty() {
            // Whole pool free and still not enough: clamp rather than
            // deadlock. The token capacity shrinks with the reservation.
            let reserved_pages = self.cfg.total_pages.min(need);
            let token_capacity = (reserved_pages * self.cfg.page_tokens).min(token_capacity);
            return AdmissionDecision::Capped { reserved_pages, token_capacity };
        }
        AdmissionDecision::Defer
    }

    /// Allocation-free twin of
    /// `admission_plan(..).admits_immediately()`: would this request be
    /// admitted right now without evicting anyone? Exactly equivalent
    /// (pinned by `admits_now_matches_admission_plan`) — `Fits` is
    /// `need ≤ free`, and `Capped` is only reachable with an empty pool
    /// (an empty pool makes `eviction_plan` fail for any positive
    /// deficit, so `EvictThenFit` never preempts it). The full plan
    /// materializes an eviction victim list on the `EvictThenFit` path;
    /// this predicate is for the per-event hot paths that only need the
    /// yes/no — the pump's candidate probe and the fast-forward
    /// dormancy checks, which run once per queue event.
    pub fn admits_now(&self, prompt_len: usize, max_new_tokens: usize) -> bool {
        let need = match self.cfg.admission {
            AdmissionControl::WorstCase => {
                self.cfg.worst_case_pages(prompt_len, max_new_tokens)
            }
            AdmissionControl::Optimistic => {
                self.cfg.pages_for_tokens(prompt_len.min(self.cfg.max_tokens_per_request).max(1))
            }
        };
        need <= self.free_pages() || self.residents.is_empty()
    }

    /// LRU-first set of residents whose eviction frees at least
    /// `deficit` pages, or `None` if even evicting everyone falls short.
    pub fn eviction_plan(&self, deficit: usize) -> Option<Vec<u64>> {
        let mut by_lru: Vec<(&u64, &Reservation)> = self.residents.iter().collect();
        by_lru.sort_by(|a, b| a.1.last_touch.partial_cmp(&b.1.last_touch).unwrap());
        let mut victims = Vec::new();
        let mut freed = 0usize;
        for (&id, r) in by_lru {
            if freed >= deficit {
                break;
            }
            victims.push(id);
            freed += r.reserved;
        }
        (freed >= deficit).then_some(victims)
    }

    /// The least-recently-touched resident among those `eligible` allows.
    pub fn lru_victim<F: Fn(u64) -> bool>(&self, eligible: F) -> Option<u64> {
        self.residents
            .iter()
            .filter(|(&id, _)| eligible(id))
            .min_by(|a, b| a.1.last_touch.partial_cmp(&b.1.last_touch).unwrap())
            .map(|(&id, _)| id)
    }

    /// Commit a reservation. `tokens_now` is the context already written
    /// (the prompt after prefill; 0 when reserving ahead of prefill).
    pub fn admit(
        &mut self,
        id: u64,
        tokens_now: usize,
        reserved_pages: usize,
        token_cap: usize,
        now: f64,
    ) -> Result<(), PoolError> {
        if self.residents.contains_key(&id) {
            return Err(PoolError::AlreadyResident(id));
        }
        let free = self.free_pages();
        if reserved_pages > free {
            return Err(PoolError::OutOfPages {
                requested: reserved_pages,
                free,
                total: self.cfg.total_pages,
            });
        }
        // KV beyond the reservation's capacity is not retained (the
        // Capped-admission case: a prompt larger than the whole pool).
        let tokens = tokens_now.min(token_cap).min(reserved_pages * self.cfg.page_tokens);
        let used = self.cfg.pages_for_tokens(tokens).min(reserved_pages);
        self.residents.insert(
            id,
            Reservation { reserved: reserved_pages, used, tokens, token_cap, last_touch: now },
        );
        self.reserved_total += reserved_pages;
        self.stats.admitted += 1;
        self.stats.high_water_pages = self.stats.high_water_pages.max(self.reserved_total);
        Ok(())
    }

    /// Execute an [`AdmissionDecision`] for `id`: `Fits`/`Capped` reserve
    /// (`Capped` also bumps `stats.capped_admissions`), `EvictThenFit`
    /// evicts its victims then reserves, `Defer` is a no-op. Returns
    /// whether the request is now resident.
    pub fn execute_admission(
        &mut self,
        id: u64,
        tokens_now: usize,
        decision: AdmissionDecision,
        now: f64,
    ) -> Result<bool, PoolError> {
        match decision {
            AdmissionDecision::Fits { reserved_pages, token_capacity } => {
                self.admit(id, tokens_now, reserved_pages, token_capacity, now)?;
                Ok(true)
            }
            AdmissionDecision::Capped { reserved_pages, token_capacity } => {
                self.admit(id, tokens_now, reserved_pages, token_capacity, now)?;
                self.stats.capped_admissions += 1;
                Ok(true)
            }
            AdmissionDecision::EvictThenFit { victims, reserved_pages, token_capacity } => {
                for v in victims {
                    self.evict(v)?;
                }
                self.admit(id, tokens_now, reserved_pages, token_capacity, now)?;
                Ok(true)
            }
            AdmissionDecision::Defer => Ok(false),
        }
    }

    /// Record that `id`'s cache now holds `tokens` tokens, growing the
    /// reservation page-by-page if the admission mode allows. Errors with
    /// [`PoolError::Exhausted`] when a needed page does not exist — the
    /// caller then evicts (per policy) or caps the request.
    pub fn ensure_tokens(&mut self, id: u64, tokens: usize, now: f64) -> Result<(), PoolError> {
        let page_tokens = self.cfg.page_tokens;
        let r = self.residents.get_mut(&id).ok_or(PoolError::NotResident(id))?;
        if tokens > r.token_cap {
            return Err(PoolError::TokenCapExceeded { id, cap: r.token_cap });
        }
        let need_pages = ceil_div(tokens.max(1), page_tokens.max(1));
        if need_pages > r.reserved {
            let extra = need_pages - r.reserved;
            if self.cfg.total_pages - self.reserved_total < extra {
                self.stats.grow_denied += 1;
                return Err(PoolError::Exhausted { id, tokens });
            }
            r.reserved += extra;
            self.reserved_total += extra;
            self.stats.high_water_pages = self.stats.high_water_pages.max(self.reserved_total);
        }
        r.tokens = tokens.max(r.tokens);
        r.used = need_pages.max(r.used);
        r.last_touch = now;
        Ok(())
    }

    /// Mark `id`'s cache as accessed (decode reads it every step).
    pub fn touch(&mut self, id: u64, now: f64) {
        if let Some(r) = self.residents.get_mut(&id) {
            r.last_touch = r.last_touch.max(now);
        }
    }

    fn release(&mut self, id: u64) -> Result<usize, PoolError> {
        let r = self.residents.remove(&id).ok_or(PoolError::NotResident(id))?;
        self.reserved_total -= r.reserved;
        Ok(r.reserved)
    }

    /// Release a completed request's pages.
    pub fn complete(&mut self, id: u64) -> Result<usize, PoolError> {
        let freed = self.release(id)?;
        self.stats.completed += 1;
        Ok(freed)
    }

    /// Evict a resident (pages freed immediately; its KV must be
    /// recomputed if the request runs again).
    pub fn evict(&mut self, id: u64) -> Result<usize, PoolError> {
        let freed = self.release(id)?;
        self.stats.evicted += 1;
        Ok(freed)
    }

    /// [`Self::evict`] with a simulation timestamp: additionally records
    /// `(now, id)` on [`Self::eviction_log`] so the serving simulators
    /// can surface the preemption as a timeline event.
    pub fn evict_at(&mut self, id: u64, now: f64) -> Result<usize, PoolError> {
        let freed = self.evict(id)?;
        if self.eviction_log.len() < EVICTION_LOG_CAP {
            self.eviction_log.push((now, id));
        }
        Ok(freed)
    }

    /// Verify the pool's conservation invariants (property-test hook).
    pub fn check_invariants(&self) -> Result<(), String> {
        let sum_reserved: usize = self.residents.values().map(|r| r.reserved).sum();
        if sum_reserved != self.reserved_total {
            return Err(format!(
                "reserved_total {} != sum of reservations {}",
                self.reserved_total, sum_reserved
            ));
        }
        if self.reserved_total > self.cfg.total_pages {
            return Err(format!(
                "over-committed: {} reserved of {} total",
                self.reserved_total, self.cfg.total_pages
            ));
        }
        for (id, r) in &self.residents {
            if r.used > r.reserved {
                return Err(format!("request {id}: used {} > reserved {}", r.used, r.reserved));
            }
            if r.tokens > r.token_cap {
                return Err(format!("request {id}: tokens {} > cap {}", r.tokens, r.token_cap));
            }
            if self.cfg.pages_for_tokens(r.tokens.max(1)) > r.used.max(1) {
                return Err(format!(
                    "request {id}: {} tokens not covered by {} used pages",
                    r.tokens, r.used
                ));
            }
        }
        let resident = self.residents.len() as u64;
        if self.stats.admitted < self.stats.evicted + self.stats.completed {
            return Err("more departures than admissions".into());
        }
        if self.stats.admitted - self.stats.evicted - self.stats.completed != resident {
            return Err(format!(
                "conservation broken: admitted {} - evicted {} - completed {} != resident {}",
                self.stats.admitted, self.stats.evicted, self.stats.completed, resident
            ));
        }
        if self.stats.high_water_pages > self.cfg.total_pages {
            return Err("high-water above pool size".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn cfg(pages: usize) -> KvPoolConfig {
        KvPoolConfig::for_device(&BITNET_0_73B, &KV260).with_total_pages(pages)
    }

    #[test]
    fn kv260_budget_is_sane() {
        let c = KvPoolConfig::for_device(&BITNET_0_73B, &KV260);
        // 4 GB DDR − ~170 MB weights − 256 MB reserve ≈ 3.8 GB of KV →
        // room for roughly a dozen full 2048-token contexts.
        let full_contexts = c.budget_bytes() / BITNET_0_73B.kv_bytes(2048);
        assert!((8.0..20.0).contains(&full_contexts), "contexts {full_contexts:.1}");
        assert_eq!(c.page_tokens, PAGE_TOKENS_DEFAULT);
        // Worst case clamps at max_seq.
        assert_eq!(
            c.worst_case_pages(2040, 100),
            c.pages_for_tokens(BITNET_0_73B.max_seq)
        );
    }

    #[test]
    fn admit_grow_complete_balances() {
        let mut p = KvPool::new(cfg(10));
        p.admit(1, 32, 2, 96, 0.0).unwrap();
        assert_eq!(p.free_pages(), 8);
        assert_eq!(p.used_pages(), 1);
        p.ensure_tokens(1, 64, 1.0).unwrap(); // fills page 2
        p.ensure_tokens(1, 65, 2.0).unwrap(); // grows to page 3
        assert_eq!(p.reserved_pages(), 3);
        p.check_invariants().unwrap();
        assert_eq!(p.complete(1).unwrap(), 3);
        assert_eq!(p.free_pages(), 10);
        assert_eq!(p.resident_count(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn repaging_preserves_the_byte_budget() {
        let base = KvPoolConfig::for_device(&BITNET_0_73B, &KV260);
        let budget = base.budget_bytes();
        for pt in [1, 8, 16, 64, 128] {
            let repaged = base.clone().with_page_tokens(pt);
            assert_eq!(repaged.page_tokens, pt);
            // Floor rounding loses at most one page of budget.
            assert!(repaged.budget_bytes() <= budget + 1e-6, "pt={pt}");
            assert!(
                repaged.budget_bytes() >= budget - repaged.page_bytes() - 1e-6,
                "pt={pt}: budget {:.0} vs base {budget:.0}",
                repaged.budget_bytes()
            );
        }
        // Same page size round-trips to (almost exactly) the same pool —
        // floor rounding of the float budget may shave one page.
        let same = base.clone().with_page_tokens(base.page_tokens);
        assert!(
            same.total_pages == base.total_pages || same.total_pages + 1 == base.total_pages,
            "{} vs {}",
            same.total_pages,
            base.total_pages
        );
    }

    #[test]
    fn token_cap_is_enforced() {
        let mut p = KvPool::new(cfg(10));
        p.admit(1, 10, 1, 40, 0.0).unwrap();
        assert!(matches!(
            p.ensure_tokens(1, 41, 1.0),
            Err(PoolError::TokenCapExceeded { .. })
        ));
    }

    #[test]
    fn exhaustion_is_reported_not_granted() {
        let mut p = KvPool::new(cfg(3));
        p.admit(1, 32, 1, 1024, 0.0).unwrap();
        p.admit(2, 64, 2, 1024, 0.0).unwrap();
        let err = p.ensure_tokens(1, 33, 1.0).unwrap_err();
        assert!(matches!(err, PoolError::Exhausted { .. }));
        assert_eq!(p.stats.grow_denied, 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn worst_case_admission_never_exhausts() {
        let c = cfg(100).with_policies(AdmissionControl::WorstCase, EvictionPolicy::KeepResident);
        let mut p = KvPool::new(c);
        let plan = p.admission_plan(64, 64);
        let AdmissionDecision::Fits { reserved_pages, token_capacity } = plan else {
            panic!("expected Fits, got {plan:?}");
        };
        p.admit(1, 64, reserved_pages, token_capacity, 0.0).unwrap();
        for t in 65..=token_capacity {
            p.ensure_tokens(1, t, t as f64).unwrap();
        }
        p.check_invariants().unwrap();
    }

    #[test]
    fn oversized_request_is_capped_on_empty_pool() {
        let p = KvPool::new(cfg(4));
        match p.admission_plan(1024, 512) {
            AdmissionDecision::Capped { reserved_pages, token_capacity } => {
                assert_eq!(reserved_pages, 4);
                assert_eq!(token_capacity, 4 * PAGE_TOKENS_DEFAULT);
            }
            other => panic!("expected Capped, got {other:?}"),
        }
    }

    #[test]
    fn optimistic_defers_when_residents_hold_pool() {
        let c = cfg(4).with_policies(AdmissionControl::Optimistic, EvictionPolicy::KeepResident);
        let mut p = KvPool::new(c);
        p.admit(1, 96, 3, 256, 0.0).unwrap();
        assert_eq!(p.admission_plan(64, 64), AdmissionDecision::Defer);
    }

    #[test]
    fn eviction_plan_prefers_lru() {
        let c = cfg(6).with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut p = KvPool::new(c);
        p.admit(1, 64, 2, 256, 0.0).unwrap();
        p.admit(2, 64, 2, 256, 1.0).unwrap();
        p.touch(1, 5.0); // request 2 is now LRU
        match p.admission_plan(96, 32) {
            AdmissionDecision::EvictThenFit { victims, .. } => assert_eq!(victims, vec![2]),
            other => panic!("expected EvictThenFit, got {other:?}"),
        }
        assert_eq!(p.lru_victim(|_| true), Some(2));
        assert_eq!(p.lru_victim(|id| id != 2), Some(1));
        p.evict_at(2, 6.0).unwrap();
        assert_eq!(p.stats.evicted, 1);
        assert_eq!(p.eviction_log, vec![(6.0, 2)]);
        p.check_invariants().unwrap();
    }

    #[test]
    fn fragmentation_and_occupancy() {
        let mut p = KvPool::new(cfg(10));
        assert_eq!(p.fragmentation(), 0.0);
        // Reserve 4 pages (128-token capacity) holding only 40 tokens.
        p.admit(1, 40, 4, 128, 0.0).unwrap();
        assert!((p.occupancy() - 0.4).abs() < 1e-12);
        let frag = p.fragmentation();
        assert!((frag - (1.0 - 40.0 / 128.0)).abs() < 1e-12, "frag {frag}");
    }

    #[test]
    fn double_admit_and_unknown_release_rejected() {
        let mut p = KvPool::new(cfg(10));
        p.admit(1, 10, 1, 64, 0.0).unwrap();
        assert!(matches!(p.admit(1, 10, 1, 64, 0.0), Err(PoolError::AlreadyResident(1))));
        assert!(matches!(p.complete(9), Err(PoolError::NotResident(9))));
        assert!(matches!(p.evict(9), Err(PoolError::NotResident(9))));
    }

    #[test]
    fn admits_now_matches_admission_plan() {
        // Equivalence across admission modes, eviction policies, pool
        // fill levels, and request sizes — including the empty-pool
        // Capped corner and the EvictThenFit (plan says no-immediate)
        // region the fast-forward dormancy check leans on.
        for admission in [AdmissionControl::WorstCase, AdmissionControl::Optimistic] {
            for eviction in [EvictionPolicy::KeepResident, EvictionPolicy::EvictAndRecompute] {
                for residents in 0..4usize {
                    let mut c = cfg(12);
                    c.admission = admission;
                    c.eviction = eviction;
                    let mut p = KvPool::new(c);
                    for id in 0..residents as u64 {
                        p.admit(id, 32, 3, 96, id as f64).unwrap();
                    }
                    for (prompt, gen) in
                        [(1, 1), (16, 16), (64, 64), (200, 400), (2048, 2048), (4000, 4000)]
                    {
                        let plan = p.admission_plan(prompt, gen).admits_immediately();
                        let fast = p.admits_now(prompt, gen);
                        assert_eq!(
                            plan, fast,
                            "admits_now diverged: {admission:?}/{eviction:?} \
                             residents={residents} prompt={prompt} gen={gen}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn admits_now_flips_exactly_at_zero_free_pages() {
        // The 1 → 0 free-page boundary: one free page still admits a
        // one-page request; zero free pages with residents denies even
        // the smallest one. Pins the `need <= free || empty` predicate
        // the pump's candidate probe and fast-forward dormancy rely on.
        let c = cfg(32).with_policies(AdmissionControl::WorstCase, EvictionPolicy::KeepResident);
        let mut p = KvPool::new(c);
        p.admit(0, 0, 31, 31 * PAGE_TOKENS_DEFAULT, 0.0).unwrap();
        assert_eq!(p.free_pages(), 1);
        assert!(p.admits_now(1, 1), "one free page admits a one-page worst case");
        p.admit(1, 0, 1, PAGE_TOKENS_DEFAULT, 0.0).unwrap();
        assert_eq!(p.free_pages(), 0);
        assert!(!p.admits_now(1, 1), "zero free + residents must deny");

        // Optimistic admission needs at least one page too (the .max(1)
        // clamp), so it denies at exactly-zero free just the same.
        let c = cfg(4).with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut q = KvPool::new(c);
        q.admit(7, 0, 4, 4 * PAGE_TOKENS_DEFAULT, 0.0).unwrap();
        assert_eq!(q.free_pages(), 0);
        assert!(!q.admits_now(1, 1), "optimistic at zero free must deny");

        // Draining restores the empty-pool escape hatch: with no
        // residents the predicate is true even for oversized requests
        // (the Capped-admission clamp handles the sizing).
        q.complete(7).unwrap();
        assert_eq!(q.resident_count(), 0);
        assert!(q.admits_now(10_000, 10_000));
        p.complete(0).unwrap();
        p.complete(1).unwrap();
        assert!(p.admits_now(10_000, 10_000), "empty pool admits via the Capped clamp");
        p.check_invariants().unwrap();
        q.check_invariants().unwrap();
    }
}
