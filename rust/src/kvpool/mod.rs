//! Paged KV-cache pool — DDR capacity management for multi-request serving.
//!
//! The paper serves one request at a time, so its KV cache is a single
//! monolithic `[n_layers, n_heads, max_seq, head_dim]` allocation and DDR
//! capacity never binds. The moment the coordinator admits *concurrent*
//! requests (§3.4's "multiple short-token requests in edge scenarios"),
//! the KV260's 4 GB of DDR — shared with the packed ternary weights and
//! the activation spill space — becomes a first-class resource. This
//! module owns that budget:
//!
//! * [`pool::KvPoolConfig`] derives the KV byte budget from a
//!   [`crate::fpga::DeviceConfig`] (DDR capacity minus weights minus an
//!   activation/runtime reserve) and splits it into fixed-size *token
//!   pages* (vLLM-style paged attention, sized so page-granular DDR
//!   bursts stay long enough not to hurt AXI efficiency — see
//!   [`crate::memory::traffic::paged_kv_burst`]).
//! * [`pool::KvPool`] is the allocator: per-request page reservations,
//!   growth during decode, release on completion, and LRU bookkeeping.
//! * [`policy::AdmissionControl`] decides what "fits" means at admission
//!   (pessimistic worst-case vs. optimistic prompt-only), and
//!   [`policy::EvictionPolicy`] what happens when an optimistically
//!   admitted request exhausts the pool mid-decode (evict-and-recompute
//!   vs. keep-resident-and-cap).
//! * [`pool::PoolStats`] exposes the occupancy high-water mark,
//!   admission/eviction/completion conservation counters, and internal
//!   fragmentation — surfaced through [`crate::metrics::ServerMetrics`].
//!
//! Invariants (enforced by [`pool::KvPool::check_invariants`] and the
//! property tests in `rust/tests/prop_invariants.rs`):
//!
//! 1. **Pages conserved** — `free + reserved == total` at all times.
//! 2. **Reservation bound** — no request's used pages exceed its
//!    reservation, and no request's tokens exceed its token capacity.
//! 3. **Request conservation** — `admitted − evicted − completed ==
//!    resident`.
//!
//! This is an extension beyond the paper (which never multi-tenants the
//! KV DDR); EXPERIMENTS.md/CHANGES.md label it as such.
//!
//! ```
//! use pd_swap::fpga::KV260;
//! use pd_swap::kvpool::{KvPool, KvPoolConfig};
//! use pd_swap::model::BITNET_0_73B;
//!
//! // Pool sized from the KV260's DDR minus weights and the reserve.
//! let cfg = KvPoolConfig::for_device(&BITNET_0_73B, &KV260);
//! let mut pool = KvPool::new(cfg);
//!
//! // Admit a request (256-token prompt, up to 64 generated), write its
//! // prompt KV, grow one decode token, then release everything.
//! let plan = pool.admission_plan(256, 64);
//! assert!(plan.admits_immediately());
//! pool.execute_admission(7, 256, plan, 0.0).unwrap();
//! pool.ensure_tokens(7, 257, 1.0).unwrap();
//! pool.complete(7).unwrap();
//! assert_eq!(pool.resident_count(), 0);
//! pool.check_invariants().unwrap();
//! ```

pub mod policy;
pub mod pool;

pub use policy::{AdmissionControl, AdmissionDecision, EvictionPolicy};
pub use pool::{KvPool, KvPoolConfig, PoolError, PoolStats, PAGE_TOKENS_DEFAULT};
