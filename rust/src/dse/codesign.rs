//! Design × policy co-exploration: the §4.3 DSE grid joined with the
//! serving-policy space, end to end through the event-driven simulator.
//!
//! The paper picks hardware (§3.3/§4.3) assuming its one-request-at-a-time
//! flow; PR 2's serving extension showed the *swap policy* dominates
//! delivered throughput under continuous mixed traffic. Those two choices
//! interact — a design with a bigger prefill RM changes how expensive a
//! decode→prefill round trip is, which changes which policy wins — so the
//! right question is joint: **which (design, policy) pair serves this
//! traffic best?** Answering it means running the full DSE grid through
//! the [`EventServer`] once per policy per trace, which was computationally
//! out of reach before the [`crate::engines::surface`] kernel made both
//! the grid evaluation and the per-token simulation O(1) in the analytic
//! model.
//!
//! The sweep has a fourth axis beyond (design × policy × trace): the
//! **decode batch** ([`CodesignConfig::decode_batches`], CLI
//! `--decode-batch 1,4`). Batch-1 is the paper's single-stream decode
//! engine; larger batches step several pool-resident streams through one
//! shared weight-stream pass
//! ([`crate::engines::LatencySurface::decode_step_batched_paged`]), which
//! lifts decode throughput for *every* design but not uniformly — the
//! weight-stream floor it amortizes is design-independent while the
//! per-stream KV and compute terms are not — so the winning design or
//! policy can flip as B grows. [`CodesignReport::batch_flips`] reports
//! exactly that, per trace. The [`SurfaceCache`] stays keyed per design:
//! the per-B closed forms are evaluated from batch-independent cached
//! coefficients, so a (design, B) key would memoize nothing extra.
//!
//! The fifth axis is the **KV pool** ([`CodesignConfig::pools`], CLI
//! `--admission/--eviction/--page-size`): decode is KV-bandwidth-bound
//! (PD-Swap §3), so admission control × eviction policy × page size can
//! flip the per-trace winner just like the decode batch does — optimistic
//! admission packs more residents (more batching headroom) at the cost of
//! mid-decode evictions, and the page size trades internal fragmentation
//! against DDR burst efficiency. [`CodesignReport::pool_flips`] reports
//! the per-trace verdict. Two warm-start mechanisms make the enlarged
//! grid affordable ([`CodesignConfig::warm_start`]): one
//! [`SurfaceFactory`] per distinct page size plus the shared
//! [`SurfaceCache`] means every (design, page) pair pays surface
//! construction once across all its (policy × batch × admission ×
//! eviction × trace) cells, and the DSE pass's floorplan-feasibility
//! verdict is reused (`EventServerConfig::assume_feasible`) instead of
//! revalidating per cell. The decode-batch axis is additionally clamped
//! per design by [`crate::engines::AcceleratorDesign::max_decode_batch`]
//! (activation-buffer BRAM/URAM pressure), and clamped cells are flagged
//! (`SweepCell::batch_capped`) in the ranking output.
//!
//! Everything is deterministic: traces are seeded, simulations run on the
//! virtual clock, designs are swept in grid order, and ranking ties break
//! by (grid order, policy order, batch order, pool order) — so
//! `pd-swap codesign` prints identical winners on every run and machine.
//!
//! ```
//! use pd_swap::dse::{run_codesign, CodesignConfig, TracePreset};
//! use pd_swap::fpga::KV260;
//! use pd_swap::model::BITNET_0_73B;
//!
//! let mut sweep = CodesignConfig::paper_default(BITNET_0_73B, KV260.clone());
//! // Tiny grid + one short trace so the example runs in milliseconds.
//! sweep.dse.tlmm_grid = vec![320];
//! sweep.dse.prefill_grid = vec![300];
//! sweep.dse.decode_grid = vec![250];
//! sweep.traces = vec![TracePreset::by_name("mixed", 4, 0.05, 2048, 7).unwrap()];
//! sweep.decode_batches = vec![1, 4];
//! let report = run_codesign(&sweep).unwrap();
//! assert_eq!(report.sims_run, 3 * 2); // 3 policies x 2 decode batches
//! let winner = report.traces[0].winner();
//! assert!(winner.decode_tps > 0.0);
//! ```

use std::sync::Mutex;

use anyhow::{anyhow, bail};

use crate::coordinator::{requests_from_trace, EventServer, EventServerConfig, Request};
use crate::engines::{AttentionHosting, SurfaceCache, SurfaceFactory};
use crate::fpga::DeviceConfig;
use crate::kvpool::{AdmissionControl, EvictionPolicy, PAGE_TOKENS_DEFAULT};
use crate::model::{ModelShape, TraceSpec};
use crate::reconfig::SwapPolicy;
use crate::telemetry::TraceRecorder;
use crate::util::json::Value;
use crate::util::par::{default_threads, par_map};
use crate::Result;

use super::{DseConfig, DseKernel, DsePoint};

/// A named, seeded arrival trace for the sweep.
#[derive(Debug, Clone)]
pub struct TracePreset {
    pub name: String,
    pub spec: TraceSpec,
}

impl TracePreset {
    /// Resolve a CLI preset name (`interactive` | `mixed` | `bursty` |
    /// `long` — the sparse long-generation trace where the event core's
    /// decode fast-forward pays off most — | `million`, the decode-heavy
    /// underloaded preset sized for million-request streaming runs).
    pub fn by_name(
        name: &str,
        n_requests: usize,
        rate: f64,
        long_ctx: usize,
        seed: u64,
    ) -> Option<TracePreset> {
        let spec = match name {
            "interactive" => TraceSpec::interactive(n_requests, rate, seed),
            "mixed" => TraceSpec::mixed_long_context(n_requests, rate, long_ctx, seed),
            "bursty" => TraceSpec::bursty(n_requests, seed),
            "long" => TraceSpec::long_decode(n_requests, seed),
            "million" => TraceSpec::million(n_requests, seed),
            _ => return None,
        };
        Some(TracePreset { name: name.to_string(), spec })
    }

    /// The default sweep pair: the mixed long-context trace (where policy
    /// choice matters most) and the bursty short-prompt trace (the §3.4
    /// arrival-storm scenario).
    pub fn defaults(n_requests: usize, rate: f64, long_ctx: usize, seed: u64) -> Vec<TracePreset> {
        ["mixed", "bursty"]
            .iter()
            .map(|n| Self::by_name(n, n_requests, rate, long_ctx, seed).unwrap())
            .collect()
    }
}

/// One point on the sweep's KV-pool axis: how the pool admits, evicts,
/// and pages. The DDR byte budget stays fixed (derived from the device);
/// only its management changes per variant.
#[derive(Debug, Clone)]
pub struct PoolVariant {
    pub admission: AdmissionControl,
    pub eviction: EvictionPolicy,
    /// Tokens per KV page (budget-preserving re-page via
    /// [`crate::kvpool::KvPoolConfig::with_page_tokens`]).
    pub page_tokens: usize,
}

impl PoolVariant {
    /// The PR 1 default pool: worst-case admission, keep-resident, the
    /// burst-knee page size.
    pub fn paper_default() -> Self {
        Self {
            admission: AdmissionControl::WorstCase,
            eviction: EvictionPolicy::KeepResident,
            page_tokens: PAGE_TOKENS_DEFAULT,
        }
    }

    /// Stable report/ranking label, e.g. `worst-case+keep@pg32`.
    pub fn label(&self) -> String {
        format!("{}+{}@pg{}", self.admission.name(), self.eviction.name(), self.page_tokens)
    }
}

/// Joint-sweep configuration.
#[derive(Debug, Clone)]
pub struct CodesignConfig {
    /// The design grid (must use DPR hosting — the event core schedules
    /// swaps, which static designs do not have).
    pub dse: DseConfig,
    /// Swap policies to cross with every design.
    pub policies: Vec<SwapPolicy>,
    /// Traffic mixes to evaluate each (design, policy) pair under.
    pub traces: Vec<TracePreset>,
    /// Decode batch sizes to cross with every (design, policy, trace)
    /// cell (1 = the paper's single-stream decode flow). Clamped per
    /// design by [`crate::engines::AcceleratorDesign::max_decode_batch`].
    pub decode_batches: Vec<usize>,
    /// KV-pool variants (admission × eviction × page size) to cross with
    /// every cell. Default: the single PR 1 pool.
    pub pools: Vec<PoolVariant>,
    /// Cap on feasible designs swept, best Eq. 6 objective first
    /// (0 = sweep every feasible grid point).
    pub max_designs: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Share surface construction (one [`SurfaceFactory`] per page size +
    /// the sweep-wide [`SurfaceCache`]) and the DSE pass's
    /// floorplan-feasibility verdicts across cells. `false` forces cold
    /// per-cell construction — the `hotpath_kernel` bench's baseline for
    /// the warm-start speedup gate; results are bit-identical either way.
    pub warm_start: bool,
}

impl CodesignConfig {
    /// The full paper grid × all three policies × the default trace pair.
    pub fn paper_default(shape: ModelShape, device: DeviceConfig) -> Self {
        let dse =
            DseConfig::paper_default(shape, device, AttentionHosting::Reconfigurable);
        Self {
            dse,
            policies: vec![
                SwapPolicy::Eager,
                SwapPolicy::hysteresis_default(),
                SwapPolicy::lookahead_default(),
            ],
            traces: TracePreset::defaults(24, 0.05, shape.max_seq, 0),
            decode_batches: vec![1],
            pools: vec![PoolVariant::paper_default()],
            max_designs: 0,
            threads: 0,
            warm_start: true,
        }
    }
}

/// One (design, policy, trace) simulation outcome.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub design: String,
    /// Grid index of the design (the determinism anchor for ties).
    pub design_seq: usize,
    /// The design's Eq. 6 objective from the DSE pass.
    pub objective: f64,
    pub policy: &'static str,
    /// Position of the policy in the sweep's policy list.
    pub policy_seq: usize,
    /// Streams stepped per decode token-step event (1 = paper flow) —
    /// the EFFECTIVE batch after the per-design activation-buffer clamp.
    pub decode_batch: usize,
    /// The batch the sweep axis requested before clamping.
    pub requested_batch: usize,
    /// True when the design's [`max_decode_batch`] cap clamped
    /// `requested_batch` down to `decode_batch`.
    ///
    /// [`max_decode_batch`]: crate::engines::AcceleratorDesign::max_decode_batch
    pub batch_capped: bool,
    /// Position of the batch in the sweep's decode-batch list.
    pub batch_seq: usize,
    /// KV-pool variant label ([`PoolVariant::label`]).
    pub pool: String,
    /// Position of the pool variant in the sweep's pool list.
    pub pool_seq: usize,
    /// 1 / mean wall inter-token gap — the policy-sensitive metric.
    pub decode_tps: f64,
    pub makespan_s: f64,
    pub makespan_tps: f64,
    pub swaps: u64,
    pub exposed_s: f64,
    pub ttft_p95_s: f64,
    /// SLO-weighted goodput: `makespan_tps × slo_attainment` — useful
    /// tokens per second, discounted by the completed fraction. Equal to
    /// `makespan_tps` in fault-free sweeps (attainment 1.0); separates
    /// from it under fault injection (extension #10), where shed
    /// requests generate no counted tokens.
    pub slo_goodput_tps: f64,
    /// Full [`MetricsRegistry`] snapshot of the cell's run
    /// ([`crate::metrics::ServerMetrics::summary_json`]) — every named
    /// counter/gauge/histogram, carried into `codesign --out`.
    ///
    /// [`MetricsRegistry`]: crate::metrics::MetricsRegistry
    pub metrics: Value,
}

/// All cells for one trace, ranked best first.
#[derive(Debug)]
pub struct TraceOutcome {
    pub trace: String,
    pub offered_tokens_per_sec: f64,
    /// Ranking: decode throughput desc, then makespan asc, then
    /// (design grid order, policy order, batch order, pool order) — a
    /// total order, so the winner is unique and run-independent.
    pub ranked: Vec<SweepCell>,
}

impl TraceOutcome {
    pub fn winner(&self) -> &SweepCell {
        &self.ranked[0]
    }

    /// Best cell restricted to one *requested* decode batch (the per-B
    /// winner the flip analysis compares; a design whose activation
    /// headroom clamps the batch still competes in its requested column).
    /// `None` if the batch was not swept.
    pub fn winner_for_batch(&self, decode_batch: usize) -> Option<&SweepCell> {
        self.ranked.iter().find(|c| c.requested_batch == decode_batch)
    }

    /// Best cell restricted to one KV-pool variant (by sweep position).
    /// `None` if the variant was not swept.
    pub fn winner_for_pool(&self, pool_seq: usize) -> Option<&SweepCell> {
        self.ranked.iter().find(|c| c.pool_seq == pool_seq)
    }
}

/// Per-trace verdict of the decode-batch axis: does multi-stream decode
/// change which (design, policy) pair should ship?
#[derive(Debug)]
pub struct BatchFlip {
    pub trace: String,
    /// `(decode_batch, design, policy)` winner per swept batch, in sweep
    /// order.
    pub winners: Vec<(usize, String, &'static str)>,
    /// True if any two batches disagree on the winning design or policy.
    pub flips: bool,
}

/// Per-trace verdict of the KV-pool axis: does the pool's management
/// (admission × eviction × page size) change which (design, policy)
/// pair should ship?
#[derive(Debug)]
pub struct PoolFlip {
    pub trace: String,
    /// `(pool label, design, policy)` winner per swept variant, in sweep
    /// order.
    pub winners: Vec<(String, String, &'static str)>,
    /// True if any two variants disagree on the winning design or policy.
    pub flips: bool,
}

/// The joint sweep's result.
#[derive(Debug)]
pub struct CodesignReport {
    pub explored: usize,
    pub feasible: usize,
    pub designs_swept: usize,
    /// Ranked cells produced across all traces. Requested-batch columns
    /// that clamp to an already-simulated effective batch reuse that
    /// simulation's result (re-labeled), so the number of event-server
    /// runs actually executed can be lower than this.
    pub sims_run: usize,
    /// The decode-batch axis the sweep crossed in (sweep order).
    pub decode_batches: Vec<usize>,
    /// The KV-pool axis the sweep crossed in (sweep order, labels).
    pub pools: Vec<String>,
    pub traces: Vec<TraceOutcome>,
}

impl CodesignReport {
    /// Per-trace decode-batch flip analysis: the winner restricted to
    /// each swept batch, and whether multi-stream decode changes the
    /// (design, policy) that should ship. Deterministic — derived from
    /// the already-total ranking order.
    pub fn batch_flips(&self) -> Vec<BatchFlip> {
        self.traces
            .iter()
            .map(|t| {
                let winners: Vec<(usize, String, &'static str)> = self
                    .decode_batches
                    .iter()
                    .filter_map(|&b| {
                        t.winner_for_batch(b)
                            .map(|c| (b, c.design.clone(), c.policy))
                    })
                    .collect();
                let flips = winners
                    .windows(2)
                    .any(|w| w[0].1 != w[1].1 || w[0].2 != w[1].2);
                BatchFlip { trace: t.trace.clone(), winners, flips }
            })
            .collect()
    }

    /// Per-trace KV-pool flip analysis: the winner restricted to each
    /// swept pool variant, and whether pool management changes the
    /// (design, policy) that should ship. Deterministic — derived from
    /// the already-total ranking order.
    pub fn pool_flips(&self) -> Vec<PoolFlip> {
        self.traces
            .iter()
            .map(|t| {
                let winners: Vec<(String, String, &'static str)> = self
                    .pools
                    .iter()
                    .enumerate()
                    .filter_map(|(seq, label)| {
                        t.winner_for_pool(seq)
                            .map(|c| (label.clone(), c.design.clone(), c.policy))
                    })
                    .collect();
                let flips = winners
                    .windows(2)
                    .any(|w| w[0].1 != w[1].1 || w[0].2 != w[1].2);
                PoolFlip { trace: t.trace.clone(), winners, flips }
            })
            .collect()
    }

    /// Machine-readable summary (per-trace winner + top ranks).
    pub fn to_json(&self, top: usize) -> Value {
        let traces = self
            .traces
            .iter()
            .map(|t| {
                let cell = |c: &SweepCell| {
                    Value::Obj(vec![
                        ("design".into(), Value::Str(c.design.clone())),
                        ("policy".into(), Value::Str(c.policy.into())),
                        ("decode_batch".into(), Value::Num(c.decode_batch as f64)),
                        ("requested_decode_batch".into(), Value::Num(c.requested_batch as f64)),
                        ("batch_capped".into(), Value::Bool(c.batch_capped)),
                        ("pool".into(), Value::Str(c.pool.clone())),
                        ("decode_tokens_per_sec".into(), Value::Num(c.decode_tps)),
                        ("makespan_s".into(), Value::Num(c.makespan_s)),
                        ("makespan_tokens_per_sec".into(), Value::Num(c.makespan_tps)),
                        ("swaps".into(), Value::Num(c.swaps as f64)),
                        ("reconfig_exposed_total_s".into(), Value::Num(c.exposed_s)),
                        ("ttft_p95_s".into(), Value::Num(c.ttft_p95_s)),
                        ("slo_goodput_tokens_per_sec".into(), Value::Num(c.slo_goodput_tps)),
                        ("dse_objective".into(), Value::Num(c.objective)),
                        ("metrics".into(), c.metrics.clone()),
                    ])
                };
                let ranked: Vec<Value> = t.ranked.iter().take(top).map(cell).collect();
                let by_batch: Vec<(String, Value)> = self
                    .decode_batches
                    .iter()
                    .filter_map(|&b| {
                        t.winner_for_batch(b).map(|c| (format!("b{b}"), cell(c)))
                    })
                    .collect();
                let by_pool: Vec<(String, Value)> = self
                    .pools
                    .iter()
                    .enumerate()
                    .filter_map(|(seq, label)| {
                        t.winner_for_pool(seq).map(|c| (label.clone(), cell(c)))
                    })
                    .collect();
                (
                    t.trace.clone(),
                    Value::Obj(vec![
                        ("offered_tokens_per_sec".into(), Value::Num(t.offered_tokens_per_sec)),
                        ("winner".into(), cell(t.winner())),
                        ("winner_by_decode_batch".into(), Value::Obj(by_batch)),
                        ("winner_by_pool".into(), Value::Obj(by_pool)),
                        ("top".into(), Value::Arr(ranked)),
                    ]),
                )
            })
            .collect();
        let flips: Vec<Value> = self
            .batch_flips()
            .into_iter()
            .map(|f| {
                Value::Obj(vec![
                    ("trace".into(), Value::Str(f.trace)),
                    ("flips".into(), Value::Bool(f.flips)),
                ])
            })
            .collect();
        let pflips: Vec<Value> = self
            .pool_flips()
            .into_iter()
            .map(|f| {
                Value::Obj(vec![
                    ("trace".into(), Value::Str(f.trace)),
                    ("flips".into(), Value::Bool(f.flips)),
                ])
            })
            .collect();
        Value::Obj(vec![
            ("bench".into(), Value::Str("codesign".into())),
            ("explored".into(), Value::Num(self.explored as f64)),
            ("feasible".into(), Value::Num(self.feasible as f64)),
            ("designs_swept".into(), Value::Num(self.designs_swept as f64)),
            ("sims_run".into(), Value::Num(self.sims_run as f64)),
            (
                "decode_batches".into(),
                Value::Arr(
                    self.decode_batches.iter().map(|&b| Value::Num(b as f64)).collect(),
                ),
            ),
            (
                "pools".into(),
                Value::Arr(self.pools.iter().map(|p| Value::Str(p.clone())).collect()),
            ),
            ("decode_batch_flips".into(), Value::Arr(flips)),
            ("pool_flips".into(), Value::Arr(pflips)),
            ("traces".into(), Value::Obj(traces)),
        ])
    }
}

/// Everything that locates one sweep cell besides the design and trace:
/// the policy, the (requested) decode batch with the design's activation
/// cap, and the KV-pool variant.
struct CellCoord<'a> {
    design_seq: usize,
    policy: SwapPolicy,
    policy_seq: usize,
    requested_batch: usize,
    batch_seq: usize,
    batch_cap: usize,
    pool: &'a PoolVariant,
    pool_seq: usize,
}

/// Run one (design, policy, batch, pool) cell over a workload on the
/// event core. Warm-started sweeps pull the latency surface out of the
/// shared [`SurfaceCache`] via the per-page-size [`SurfaceFactory`] —
/// every server of one (design, page) pair shares one construction, and
/// a cache miss is pure arithmetic (the lock is held for nanoseconds,
/// not a memory-model evaluation) — and reuse the DSE pass's
/// floorplan-feasibility verdict instead of revalidating per server.
fn simulate_cell(
    sweep: &CodesignConfig,
    factory: &SurfaceFactory,
    surfaces: &Mutex<SurfaceCache>,
    point: &DsePoint,
    coord: &CellCoord<'_>,
    workload: Vec<Request>,
) -> Result<SweepCell> {
    let policy = coord.policy;
    let mut cfg = EventServerConfig::pd_swap(
        sweep.dse.shape,
        sweep.dse.device.clone(),
        policy,
    );
    cfg.design = point.design.clone();
    // `pd_swap()` defaults keep the analytic decode fast-forward ON, so
    // every sweep cell (and `trace_winners` below) inherits the event
    // reduction — bit-identical clocks/metrics either way.
    // Clamp the requested batch by the design's activation headroom.
    let decode_batch = coord.requested_batch.min(coord.batch_cap).max(1);
    cfg.decode_batch = decode_batch;
    cfg.pool = cfg
        .pool
        .clone()
        .with_page_tokens(coord.pool.page_tokens)
        .with_policies(coord.pool.admission, coord.pool.eviction);
    if sweep.warm_start {
        // Surfaces are batch- and policy-independent (the per-B closed
        // forms reuse the cached coefficients), so all cells of a
        // (design, page size) pair share one cache entry; the DSE pass
        // already ran the floorplan rule on this design.
        cfg.assume_feasible = true;
        cfg.surface = Some(
            surfaces
                .lock()
                .expect("surface cache poisoned")
                .get_with(factory, &cfg.design),
        );
    }
    let mut srv = EventServer::new(cfg)
        .map_err(|e| anyhow!("{}/{}: {e}", point.design.name, policy.name()))?;
    srv.run(workload)
        .map_err(|e| anyhow!("{}/{}: {e}", point.design.name, policy.name()))?;
    let m = &srv.metrics;
    Ok(SweepCell {
        design: point.design.name.clone(),
        design_seq: coord.design_seq,
        objective: point.objective,
        policy: policy.name(),
        policy_seq: coord.policy_seq,
        decode_batch,
        requested_batch: coord.requested_batch,
        batch_capped: decode_batch < coord.requested_batch,
        batch_seq: coord.batch_seq,
        pool: coord.pool.label(),
        pool_seq: coord.pool_seq,
        decode_tps: m.decode_throughput(),
        makespan_s: srv.clock(),
        makespan_tps: m.tokens_generated.get() as f64 / srv.clock().max(1e-12),
        swaps: m.reconfigurations.get(),
        exposed_s: m.reconfig_exposed.mean() * m.reconfig_exposed.count() as f64,
        ttft_p95_s: m.ttft.quantile(0.95),
        slo_goodput_tps: (m.tokens_generated.get() as f64 / srv.clock().max(1e-12))
            * m.slo_attainment(),
        metrics: m.summary_json(),
    })
}

/// Execute the joint sweep: DSE grid pass (fast kernel, parallel), then
/// (feasible designs × policies × traces) through the event simulator,
/// then deterministic per-trace ranking.
pub fn run_codesign(sweep: &CodesignConfig) -> Result<CodesignReport> {
    if sweep.dse.hosting != AttentionHosting::Reconfigurable {
        bail!("codesign sweeps swap policies, which need DPR hosting (drop --static)");
    }
    if sweep.policies.is_empty() || sweep.traces.is_empty() {
        bail!("codesign needs at least one policy and one trace");
    }
    if sweep.decode_batches.is_empty() || sweep.decode_batches.iter().any(|&b| b == 0) {
        bail!("codesign needs at least one decode batch, all >= 1");
    }
    if sweep.pools.is_empty() || sweep.pools.iter().any(|p| p.page_tokens == 0) {
        bail!("codesign needs at least one KV-pool variant, all with page size >= 1");
    }
    let threads = if sweep.threads == 0 { default_threads() } else { sweep.threads };

    // -- DSE pass: evaluate the grid, keep feasible designs in grid order.
    let kernel = DseKernel::new(&sweep.dse);
    let grid = sweep.dse.grid();
    let points = par_map(&grid, threads, |&(t, p, d)| kernel.evaluate(t, p, d));
    let explored = points.len();
    let mut candidates: Vec<(usize, DsePoint)> = points
        .into_iter()
        .enumerate()
        .filter(|(_, p)| p.feasible)
        .collect();
    let feasible = candidates.len();
    if candidates.is_empty() {
        bail!("no feasible design among {explored} grid points — widen the search");
    }
    // Best objective first; grid order within exact ties.
    candidates.sort_by(|(sa, a), (sb, b)| {
        a.objective
            .partial_cmp(&b.objective)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(sa.cmp(sb))
    });
    if sweep.max_designs > 0 {
        candidates.truncate(sweep.max_designs);
    }

    // -- Serving pass: every (design × policy × trace) cell, parallel over
    // designs, deterministic inner order.
    let workloads: Vec<(String, Vec<Request>, f64)> = sweep
        .traces
        .iter()
        .map(|t| {
            let entries = t.spec.generate();
            let offered = TraceSpec::offered_tokens_per_sec(&entries);
            (t.name.clone(), requests_from_trace(&entries), offered)
        })
        .collect();
    // Warm start, part 1: one factory per DISTINCT page size on the pool
    // axis — the design-independent analytic work (memory system, weight
    // stream, paged KV bandwidths) is paid once per page size for the
    // whole sweep, and the shared cache memoizes the finished surface per
    // (design, page) so every (policy × batch × admission × eviction ×
    // trace) cell of that pair reuses one construction.
    let mut page_sizes: Vec<usize> = sweep.pools.iter().map(|p| p.page_tokens).collect();
    page_sizes.sort_unstable();
    page_sizes.dedup();
    let factories: Vec<(usize, SurfaceFactory)> = page_sizes
        .iter()
        .map(|&pt| (pt, SurfaceFactory::new(&sweep.dse.device, &sweep.dse.shape, pt)))
        .collect();
    let factory_for = |pt: usize| -> &SurfaceFactory {
        &factories
            .iter()
            .find(|(p, _)| *p == pt)
            .expect("factory exists for every swept page size")
            .1
    };
    let surfaces = Mutex::new(SurfaceCache::new());
    let per_design: Vec<Result<Vec<(usize, SweepCell)>>> =
        par_map(&candidates, threads, |(design_seq, point)| {
            // Warm start, part 2: the activation-headroom batch cap (and,
            // inside `simulate_cell`, the floorplan verdict) is computed
            // once per design, not once per cell.
            let batch_cap =
                point.design.max_decode_batch(&sweep.dse.device, &sweep.dse.shape);
            let mut cells = Vec::with_capacity(
                workloads.len()
                    * sweep.policies.len()
                    * sweep.decode_batches.len()
                    * sweep.pools.len(),
            );
            // Requested batches that clamp to the SAME effective batch
            // (e.g. `--decode-batch 64,512` on a design whose cap is 13)
            // would run bit-identical simulations — memoize per
            // (trace, policy, effective batch, pool) and re-label the
            // cached cell for the duplicate requested column instead.
            let mut effective_memo: Vec<((usize, usize, usize, usize), SweepCell)> =
                Vec::new();
            for (trace_idx, (_, workload, _)) in workloads.iter().enumerate() {
                for (policy_seq, &policy) in sweep.policies.iter().enumerate() {
                    for (batch_seq, &requested_batch) in
                        sweep.decode_batches.iter().enumerate()
                    {
                        for (pool_seq, pool) in sweep.pools.iter().enumerate() {
                            let effective = requested_batch.min(batch_cap).max(1);
                            let key = (trace_idx, policy_seq, effective, pool_seq);
                            if let Some((_, cached)) =
                                effective_memo.iter().find(|(k, _)| *k == key)
                            {
                                let mut cell = cached.clone();
                                cell.requested_batch = requested_batch;
                                cell.batch_seq = batch_seq;
                                cell.batch_capped = effective < requested_batch;
                                cells.push((trace_idx, cell));
                                continue;
                            }
                            let coord = CellCoord {
                                design_seq: *design_seq,
                                policy,
                                policy_seq,
                                requested_batch,
                                batch_seq,
                                batch_cap,
                                pool,
                                pool_seq,
                            };
                            let cell = simulate_cell(
                                sweep,
                                factory_for(pool.page_tokens),
                                &surfaces,
                                point,
                                &coord,
                                workload.clone(),
                            )?;
                            effective_memo.push((key, cell.clone()));
                            cells.push((trace_idx, cell));
                        }
                    }
                }
            }
            Ok(cells)
        });

    let mut by_trace: Vec<Vec<SweepCell>> = workloads.iter().map(|_| Vec::new()).collect();
    let mut sims_run = 0usize;
    for design_cells in per_design {
        for (trace_idx, cell) in design_cells? {
            sims_run += 1;
            by_trace[trace_idx].push(cell);
        }
    }

    // -- Rank per trace (total order: throughput, makespan, grid, policy,
    // batch, pool).
    let traces = workloads
        .iter()
        .zip(by_trace)
        .map(|((name, _, offered), mut cells)| {
            cells.sort_by(|a, b| {
                b.decode_tps
                    .partial_cmp(&a.decode_tps)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(
                        a.makespan_s
                            .partial_cmp(&b.makespan_s)
                            .unwrap_or(std::cmp::Ordering::Equal),
                    )
                    .then(a.design_seq.cmp(&b.design_seq))
                    .then(a.policy_seq.cmp(&b.policy_seq))
                    .then(a.batch_seq.cmp(&b.batch_seq))
                    .then(a.pool_seq.cmp(&b.pool_seq))
            });
            TraceOutcome {
                trace: name.clone(),
                offered_tokens_per_sec: *offered,
                ranked: cells,
            }
        })
        .collect();

    Ok(CodesignReport {
        explored,
        feasible,
        designs_swept: candidates.len(),
        sims_run,
        decode_batches: sweep.decode_batches.clone(),
        pools: sweep.pools.iter().map(PoolVariant::label).collect(),
        traces,
    })
}

/// Re-run each trace's winning cell with the telemetry recorder enabled
/// and return one Chrome-trace recorder per trace (`pd-swap codesign
/// --trace-winners`). The replay is serial and derived purely from the
/// report's (already thread-count-independent) ranking, so the emitted
/// traces are byte-identical across runs and thread counts.
pub fn trace_winners(
    sweep: &CodesignConfig,
    report: &CodesignReport,
) -> Result<Vec<(String, TraceRecorder)>> {
    let kernel = DseKernel::new(&sweep.dse);
    let mut out = Vec::with_capacity(report.traces.len());
    for (preset, outcome) in sweep.traces.iter().zip(&report.traces) {
        let w = outcome.winner();
        // SweepCells carry labels, not objects: resolve the winner's
        // design / policy / pool back through the sweep's own axes.
        let point = sweep
            .dse
            .grid()
            .into_iter()
            .map(|(t, p, d)| kernel.evaluate(t, p, d))
            .find(|p| p.feasible && p.design.name == w.design)
            .ok_or_else(|| anyhow!("winner design '{}' not on the sweep grid", w.design))?;
        let policy = sweep
            .policies
            .iter()
            .copied()
            .find(|p| p.name() == w.policy)
            .ok_or_else(|| anyhow!("winner policy '{}' not in the sweep", w.policy))?;
        let pool = sweep
            .pools
            .iter()
            .find(|p| p.label() == w.pool)
            .ok_or_else(|| anyhow!("winner pool '{}' not in the sweep", w.pool))?;
        let mut cfg = EventServerConfig::pd_swap(
            sweep.dse.shape,
            sweep.dse.device.clone(),
            policy,
        );
        cfg.design = point.design;
        // The winner's effective (already activation-clamped) batch.
        cfg.decode_batch = w.decode_batch;
        cfg.pool = cfg
            .pool
            .clone()
            .with_page_tokens(pool.page_tokens)
            .with_policies(pool.admission, pool.eviction);
        cfg.trace = true;
        let mut srv = EventServer::new(cfg)
            .map_err(|e| anyhow!("{}/{}: {e}", w.design, w.policy))?;
        srv.run(requests_from_trace(&preset.spec.generate()))
            .map_err(|e| anyhow!("{}/{}: {e}", w.design, w.policy))?;
        out.push((outcome.trace.clone(), srv.recorder));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn small_sweep() -> CodesignConfig {
        let mut sweep = CodesignConfig::paper_default(BITNET_0_73B, KV260.clone());
        sweep.dse.tlmm_grid = vec![320];
        sweep.dse.prefill_grid = vec![250, 300];
        sweep.dse.decode_grid = vec![150, 250];
        sweep.traces = vec![TracePreset::by_name("mixed", 6, 0.05, 2048, 7).unwrap()];
        sweep
    }

    #[test]
    fn sweep_covers_grid_times_policies_times_traces() {
        let sweep = small_sweep();
        let report = run_codesign(&sweep).unwrap();
        assert_eq!(report.explored, 4);
        assert!(report.feasible >= 2, "trimmed grid should mostly fit");
        assert_eq!(report.designs_swept, report.feasible);
        assert_eq!(
            report.sims_run,
            report.designs_swept * sweep.policies.len() * sweep.traces.len()
        );
        let t = &report.traces[0];
        assert_eq!(t.ranked.len(), report.sims_run);
        // Ranking is by decode throughput, best first.
        for w in t.ranked.windows(2) {
            assert!(w[0].decode_tps >= w[1].decode_tps);
        }
        assert!(t.winner().decode_tps > 0.0);
    }

    #[test]
    fn sweep_is_deterministic_across_runs_and_threads() {
        let mut a_cfg = small_sweep();
        a_cfg.threads = 1;
        let mut b_cfg = small_sweep();
        b_cfg.threads = 4;
        let a = run_codesign(&a_cfg).unwrap();
        let b = run_codesign(&b_cfg).unwrap();
        for (ta, tb) in a.traces.iter().zip(&b.traces) {
            assert_eq!(ta.winner().design, tb.winner().design);
            assert_eq!(ta.winner().policy, tb.winner().policy);
            assert_eq!(
                ta.winner().decode_tps.to_bits(),
                tb.winner().decode_tps.to_bits(),
                "virtual-clock metrics must be bit-stable"
            );
            for (ca, cb) in ta.ranked.iter().zip(&tb.ranked) {
                assert_eq!(ca.design, cb.design);
                assert_eq!(ca.policy, cb.policy);
            }
        }
    }

    #[test]
    fn max_designs_caps_the_sweep() {
        let mut sweep = small_sweep();
        sweep.max_designs = 1;
        let report = run_codesign(&sweep).unwrap();
        assert_eq!(report.designs_swept, 1);
        assert_eq!(report.sims_run, sweep.policies.len());
    }

    #[test]
    fn decode_batch_axis_multiplies_cells_and_ranks_deterministically() {
        let mut sweep = small_sweep();
        sweep.max_designs = 1;
        sweep.decode_batches = vec![1, 4];
        let report = run_codesign(&sweep).unwrap();
        assert_eq!(report.sims_run, sweep.policies.len() * 2);
        let t = &report.traces[0];
        assert_eq!(t.ranked.len(), report.sims_run);
        // Both batch restrictions have a winner, and the per-B winners
        // agree with the global ranking's first hit.
        let w1 = t.winner_for_batch(1).expect("batch-1 cells exist");
        let w4 = t.winner_for_batch(4).expect("batch-4 cells exist");
        assert_eq!(w1.decode_batch, 1);
        assert_eq!(w4.decode_batch, 4);
        // Multi-stream decode amortizes the shared weight stream: for the
        // backlog-insensitive policies the batch-4 cell of a design can
        // never decode slower than its batch-1 cell (identical swap
        // decisions, pointwise-smaller token gaps).
        for p in ["eager", "hysteresis"] {
            let cell = |b: usize| {
                t.ranked
                    .iter()
                    .find(|c| c.policy == p && c.decode_batch == b)
                    .unwrap()
            };
            assert!(
                cell(4).decode_tps >= cell(1).decode_tps,
                "{p}: batch-4 {:.2} tok/s vs batch-1 {:.2} tok/s",
                cell(4).decode_tps,
                cell(1).decode_tps
            );
        }
        // Flip analysis is consistent with the per-B winners.
        let flips = report.batch_flips();
        assert_eq!(flips.len(), 1);
        assert_eq!(flips[0].winners.len(), 2);
        let expect_flip = w1.design != w4.design || w1.policy != w4.policy;
        assert_eq!(flips[0].flips, expect_flip);
        // Determinism across thread counts, including the batch column.
        let mut again = small_sweep();
        again.max_designs = 1;
        again.decode_batches = vec![1, 4];
        again.threads = 4;
        let b = run_codesign(&again).unwrap();
        for (ca, cb) in report.traces[0].ranked.iter().zip(&b.traces[0].ranked) {
            assert_eq!(ca.design, cb.design);
            assert_eq!(ca.policy, cb.policy);
            assert_eq!(ca.decode_batch, cb.decode_batch);
            assert_eq!(ca.decode_tps.to_bits(), cb.decode_tps.to_bits());
        }
    }

    fn pool_axis() -> Vec<PoolVariant> {
        vec![
            PoolVariant::paper_default(),
            PoolVariant {
                admission: AdmissionControl::Optimistic,
                eviction: EvictionPolicy::EvictAndRecompute,
                page_tokens: PAGE_TOKENS_DEFAULT,
            },
            PoolVariant {
                admission: AdmissionControl::WorstCase,
                eviction: EvictionPolicy::KeepResident,
                page_tokens: 64,
            },
        ]
    }

    #[test]
    fn pool_axis_multiplies_cells_and_reports_flips() {
        let mut sweep = small_sweep();
        sweep.max_designs = 1;
        sweep.pools = pool_axis();
        let report = run_codesign(&sweep).unwrap();
        assert_eq!(report.sims_run, sweep.policies.len() * 3);
        assert_eq!(report.pools.len(), 3);
        let t = &report.traces[0];
        assert_eq!(t.ranked.len(), report.sims_run);
        // Every pool variant has a restricted winner, and the flip
        // verdict is consistent with them.
        let flips = report.pool_flips();
        assert_eq!(flips.len(), 1);
        assert_eq!(flips[0].winners.len(), 3);
        let expect = flips[0]
            .winners
            .windows(2)
            .any(|w| w[0].1 != w[1].1 || w[0].2 != w[1].2);
        assert_eq!(flips[0].flips, expect);
        // The JSON artifact carries the axis and the verdicts.
        let v = report.to_json(5);
        assert_eq!(v.get("pools").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("pool_flips").unwrap().as_arr().unwrap().len(), 1);
        let mixed = v.get("traces").unwrap().get("mixed").unwrap();
        let by_pool = mixed.get("winner_by_pool").unwrap();
        for p in &report.pools {
            assert!(by_pool.get(p).is_some(), "missing winner for pool '{p}'");
        }
        // Determinism across thread counts, including the pool column.
        let mut again = small_sweep();
        again.max_designs = 1;
        again.pools = pool_axis();
        again.threads = 4;
        let b = run_codesign(&again).unwrap();
        for (ca, cb) in report.traces[0].ranked.iter().zip(&b.traces[0].ranked) {
            assert_eq!(ca.design, cb.design);
            assert_eq!(ca.policy, cb.policy);
            assert_eq!(ca.pool, cb.pool);
            assert_eq!(ca.decode_tps.to_bits(), cb.decode_tps.to_bits());
        }
    }

    #[test]
    fn warm_and_cold_sweeps_are_bit_identical() {
        // Warm start shares surface construction and reuses the DSE
        // pass's floorplan verdicts; it must be a pure performance
        // optimization — every ranked cell identical to the bit.
        let mut warm = small_sweep();
        warm.pools = pool_axis();
        warm.decode_batches = vec![1, 4];
        let mut cold = warm.clone();
        cold.warm_start = false;
        let a = run_codesign(&warm).unwrap();
        let b = run_codesign(&cold).unwrap();
        assert_eq!(a.sims_run, b.sims_run);
        for (ta, tb) in a.traces.iter().zip(&b.traces) {
            for (ca, cb) in ta.ranked.iter().zip(&tb.ranked) {
                assert_eq!(ca.design, cb.design);
                assert_eq!(ca.policy, cb.policy);
                assert_eq!(ca.pool, cb.pool);
                assert_eq!(ca.decode_batch, cb.decode_batch);
                assert_eq!(ca.decode_tps.to_bits(), cb.decode_tps.to_bits());
                assert_eq!(ca.makespan_s.to_bits(), cb.makespan_s.to_bits());
            }
        }
    }

    #[test]
    fn oversized_batch_requests_are_clamped_and_flagged() {
        // Request a decode batch far beyond any KV260 design's
        // activation-buffer headroom: the sweep must clamp it, flag the
        // cells, and still rank the requested column.
        let mut sweep = small_sweep();
        sweep.max_designs = 1;
        sweep.decode_batches = vec![1, 64, 512];
        let report = run_codesign(&sweep).unwrap();
        let t = &report.traces[0];
        let w = t.winner_for_batch(512).expect("requested column still ranked");
        assert!(w.batch_capped, "512 streams cannot fit the activation headroom");
        assert!(w.decode_batch < 512);
        assert_eq!(w.requested_batch, 512);
        // Batch-1 cells are never capped.
        let w1 = t.winner_for_batch(1).unwrap();
        assert!(!w1.batch_capped);
        assert_eq!(w1.decode_batch, 1);
        // 64 and 512 clamp to the same effective batch: the duplicate
        // column reuses the memoized simulation, so the two cells are
        // bit-identical apart from their requested-batch label.
        let w64 = t.winner_for_batch(64).unwrap();
        assert_eq!(w64.decode_batch, w.decode_batch);
        assert_eq!(w64.decode_tps.to_bits(), w.decode_tps.to_bits());
        assert_eq!(w64.makespan_s.to_bits(), w.makespan_s.to_bits());
    }

    #[test]
    fn empty_pool_axis_is_rejected() {
        let mut sweep = small_sweep();
        sweep.pools = vec![];
        assert!(run_codesign(&sweep).is_err());
        let mut sweep = small_sweep();
        sweep.pools = vec![PoolVariant {
            admission: AdmissionControl::WorstCase,
            eviction: EvictionPolicy::KeepResident,
            page_tokens: 0,
        }];
        assert!(run_codesign(&sweep).is_err());
    }

    #[test]
    fn zero_decode_batch_is_rejected() {
        let mut sweep = small_sweep();
        sweep.decode_batches = vec![1, 0];
        assert!(run_codesign(&sweep).is_err());
        sweep.decode_batches = vec![];
        assert!(run_codesign(&sweep).is_err());
    }

    #[test]
    fn static_hosting_is_rejected() {
        let mut sweep = small_sweep();
        sweep.dse.hosting = AttentionHosting::StaticBoth;
        assert!(run_codesign(&sweep).is_err());
    }

    #[test]
    fn report_json_has_winners() {
        let report = run_codesign(&small_sweep()).unwrap();
        let v = report.to_json(3);
        let mixed = v.get("traces").unwrap().get("mixed").unwrap();
        assert!(mixed.get("winner").unwrap().get("design").is_some());
        assert!(mixed.get("top").unwrap().as_arr().unwrap().len() <= 3);
    }

    #[test]
    fn report_cells_carry_metric_snapshots() {
        // Every ranked cell ships its full MetricsRegistry snapshot into
        // `codesign --out` — named counters, gauges, and histograms.
        let report = run_codesign(&small_sweep()).unwrap();
        let v = report.to_json(3);
        let winner =
            v.get("traces").unwrap().get("mixed").unwrap().get("winner").unwrap();
        let m = winner.get("metrics").unwrap();
        assert!(m.get("counters").unwrap().get("tokens_generated").is_some());
        assert!(m.get("counters").unwrap().get("swaps_to_decode").is_some());
        assert!(m.get("gauges").unwrap().get("reconfig_hidden_fraction").is_some());
        assert!(m.get("histograms").unwrap().get("ttft").is_some());
    }

    #[test]
    fn winner_traces_are_byte_identical_across_thread_counts() {
        let mut a_cfg = small_sweep();
        a_cfg.threads = 1;
        let mut b_cfg = small_sweep();
        b_cfg.threads = 4;
        let a = run_codesign(&a_cfg).unwrap();
        let b = run_codesign(&b_cfg).unwrap();
        let ta = trace_winners(&a_cfg, &a).unwrap();
        let tb = trace_winners(&b_cfg, &b).unwrap();
        assert_eq!(ta.len(), 1);
        for ((na, ra), (nb, rb)) in ta.iter().zip(&tb) {
            assert_eq!(na, nb);
            assert!(!ra.is_empty(), "winner replay must record spans");
            assert!(ra.decision_count() >= 1, "policy decisions must be attributed");
            let ja = ra.to_chrome_json();
            crate::telemetry::validate_chrome_trace(&ja).unwrap();
            assert_eq!(
                ja.to_string(),
                rb.to_chrome_json().to_string(),
                "winner trace must not depend on sweep thread count"
            );
        }
    }
}
