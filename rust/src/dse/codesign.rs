//! Design × policy co-exploration: the §4.3 DSE grid joined with the
//! serving-policy space, end to end through the event-driven simulator.
//!
//! The paper picks hardware (§3.3/§4.3) assuming its one-request-at-a-time
//! flow; PR 2's serving extension showed the *swap policy* dominates
//! delivered throughput under continuous mixed traffic. Those two choices
//! interact — a design with a bigger prefill RM changes how expensive a
//! decode→prefill round trip is, which changes which policy wins — so the
//! right question is joint: **which (design, policy) pair serves this
//! traffic best?** Answering it means running the full DSE grid through
//! the [`EventServer`] once per policy per trace, which was computationally
//! out of reach before the [`crate::engines::surface`] kernel made both
//! the grid evaluation and the per-token simulation O(1) in the analytic
//! model.
//!
//! Everything is deterministic: traces are seeded, simulations run on the
//! virtual clock, designs are swept in grid order, and ranking ties break
//! by (grid order, policy order) — so `pd-swap codesign` prints identical
//! winners on every run and machine.

use std::sync::Mutex;

use anyhow::{anyhow, bail};

use crate::coordinator::{requests_from_trace, EventServer, EventServerConfig, Request};
use crate::engines::{AttentionHosting, SurfaceCache, SurfaceFactory};
use crate::fpga::DeviceConfig;
use crate::kvpool::KvPoolConfig;
use crate::model::{ModelShape, TraceSpec};
use crate::reconfig::SwapPolicy;
use crate::util::json::Value;
use crate::util::par::{default_threads, par_map};
use crate::Result;

use super::{DseConfig, DseKernel, DsePoint};

/// A named, seeded arrival trace for the sweep.
#[derive(Debug, Clone)]
pub struct TracePreset {
    pub name: String,
    pub spec: TraceSpec,
}

impl TracePreset {
    /// Resolve a CLI preset name (`interactive` | `mixed` | `bursty`).
    pub fn by_name(
        name: &str,
        n_requests: usize,
        rate: f64,
        long_ctx: usize,
        seed: u64,
    ) -> Option<TracePreset> {
        let spec = match name {
            "interactive" => TraceSpec::interactive(n_requests, rate, seed),
            "mixed" => TraceSpec::mixed_long_context(n_requests, rate, long_ctx, seed),
            "bursty" => TraceSpec::bursty(n_requests, seed),
            _ => return None,
        };
        Some(TracePreset { name: name.to_string(), spec })
    }

    /// The default sweep pair: the mixed long-context trace (where policy
    /// choice matters most) and the bursty short-prompt trace (the §3.4
    /// arrival-storm scenario).
    pub fn defaults(n_requests: usize, rate: f64, long_ctx: usize, seed: u64) -> Vec<TracePreset> {
        ["mixed", "bursty"]
            .iter()
            .map(|n| Self::by_name(n, n_requests, rate, long_ctx, seed).unwrap())
            .collect()
    }
}

/// Joint-sweep configuration.
#[derive(Debug, Clone)]
pub struct CodesignConfig {
    /// The design grid (must use DPR hosting — the event core schedules
    /// swaps, which static designs do not have).
    pub dse: DseConfig,
    /// Swap policies to cross with every design.
    pub policies: Vec<SwapPolicy>,
    /// Traffic mixes to evaluate each (design, policy) pair under.
    pub traces: Vec<TracePreset>,
    /// Cap on feasible designs swept, best Eq. 6 objective first
    /// (0 = sweep every feasible grid point).
    pub max_designs: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl CodesignConfig {
    /// The full paper grid × all three policies × the default trace pair.
    pub fn paper_default(shape: ModelShape, device: DeviceConfig) -> Self {
        let dse =
            DseConfig::paper_default(shape, device, AttentionHosting::Reconfigurable);
        Self {
            dse,
            policies: vec![
                SwapPolicy::Eager,
                SwapPolicy::hysteresis_default(),
                SwapPolicy::lookahead_default(),
            ],
            traces: TracePreset::defaults(24, 0.05, shape.max_seq, 0),
            max_designs: 0,
            threads: 0,
        }
    }
}

/// One (design, policy, trace) simulation outcome.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub design: String,
    /// Grid index of the design (the determinism anchor for ties).
    pub design_seq: usize,
    /// The design's Eq. 6 objective from the DSE pass.
    pub objective: f64,
    pub policy: &'static str,
    /// Position of the policy in the sweep's policy list.
    pub policy_seq: usize,
    /// 1 / mean wall inter-token gap — the policy-sensitive metric.
    pub decode_tps: f64,
    pub makespan_s: f64,
    pub makespan_tps: f64,
    pub swaps: u64,
    pub exposed_s: f64,
    pub ttft_p95_s: f64,
}

/// All cells for one trace, ranked best first.
#[derive(Debug)]
pub struct TraceOutcome {
    pub trace: String,
    pub offered_tokens_per_sec: f64,
    /// Ranking: decode throughput desc, then makespan asc, then
    /// (design grid order, policy order) — a total order, so the winner
    /// is unique and run-independent.
    pub ranked: Vec<SweepCell>,
}

impl TraceOutcome {
    pub fn winner(&self) -> &SweepCell {
        &self.ranked[0]
    }
}

/// The joint sweep's result.
#[derive(Debug)]
pub struct CodesignReport {
    pub explored: usize,
    pub feasible: usize,
    pub designs_swept: usize,
    pub sims_run: usize,
    pub traces: Vec<TraceOutcome>,
}

impl CodesignReport {
    /// Machine-readable summary (per-trace winner + top ranks).
    pub fn to_json(&self, top: usize) -> Value {
        let traces = self
            .traces
            .iter()
            .map(|t| {
                let cell = |c: &SweepCell| {
                    Value::Obj(vec![
                        ("design".into(), Value::Str(c.design.clone())),
                        ("policy".into(), Value::Str(c.policy.into())),
                        ("decode_tokens_per_sec".into(), Value::Num(c.decode_tps)),
                        ("makespan_s".into(), Value::Num(c.makespan_s)),
                        ("makespan_tokens_per_sec".into(), Value::Num(c.makespan_tps)),
                        ("swaps".into(), Value::Num(c.swaps as f64)),
                        ("reconfig_exposed_total_s".into(), Value::Num(c.exposed_s)),
                        ("ttft_p95_s".into(), Value::Num(c.ttft_p95_s)),
                        ("dse_objective".into(), Value::Num(c.objective)),
                    ])
                };
                let ranked: Vec<Value> = t.ranked.iter().take(top).map(cell).collect();
                (
                    t.trace.clone(),
                    Value::Obj(vec![
                        ("offered_tokens_per_sec".into(), Value::Num(t.offered_tokens_per_sec)),
                        ("winner".into(), cell(t.winner())),
                        ("top".into(), Value::Arr(ranked)),
                    ]),
                )
            })
            .collect();
        Value::Obj(vec![
            ("bench".into(), Value::Str("codesign".into())),
            ("explored".into(), Value::Num(self.explored as f64)),
            ("feasible".into(), Value::Num(self.feasible as f64)),
            ("designs_swept".into(), Value::Num(self.designs_swept as f64)),
            ("sims_run".into(), Value::Num(self.sims_run as f64)),
            ("traces".into(), Value::Obj(traces)),
        ])
    }
}

/// Run one (design, policy) pair over a workload on the event core. The
/// latency surface comes out of the shared [`SurfaceCache`] via the
/// sweep-wide [`SurfaceFactory`], so the six (policy × trace) servers of
/// one design share one construction and a cache miss is pure arithmetic
/// (the lock is held for nanoseconds, not a memory-model evaluation).
#[allow(clippy::too_many_arguments)]
fn simulate_cell(
    sweep: &CodesignConfig,
    factory: &SurfaceFactory,
    surfaces: &Mutex<SurfaceCache>,
    point: &DsePoint,
    design_seq: usize,
    policy: SwapPolicy,
    policy_seq: usize,
    workload: Vec<Request>,
) -> Result<SweepCell> {
    let mut cfg = EventServerConfig::pd_swap(
        sweep.dse.shape,
        sweep.dse.device.clone(),
        policy,
    );
    cfg.design = point.design.clone();
    cfg.surface = Some(
        surfaces
            .lock()
            .expect("surface cache poisoned")
            .get_with(factory, &cfg.design),
    );
    let mut srv = EventServer::new(cfg)
        .map_err(|e| anyhow!("{}/{}: {e}", point.design.name, policy.name()))?;
    srv.run(workload)
        .map_err(|e| anyhow!("{}/{}: {e}", point.design.name, policy.name()))?;
    let m = &srv.metrics;
    Ok(SweepCell {
        design: point.design.name.clone(),
        design_seq,
        objective: point.objective,
        policy: policy.name(),
        policy_seq,
        decode_tps: m.decode_throughput(),
        makespan_s: srv.clock(),
        makespan_tps: m.tokens_generated.get() as f64 / srv.clock().max(1e-12),
        swaps: m.reconfigurations.get(),
        exposed_s: m.reconfig_exposed.mean() * m.reconfig_exposed.count() as f64,
        ttft_p95_s: m.ttft.quantile(0.95),
    })
}

/// Execute the joint sweep: DSE grid pass (fast kernel, parallel), then
/// (feasible designs × policies × traces) through the event simulator,
/// then deterministic per-trace ranking.
pub fn run_codesign(sweep: &CodesignConfig) -> Result<CodesignReport> {
    if sweep.dse.hosting != AttentionHosting::Reconfigurable {
        bail!("codesign sweeps swap policies, which need DPR hosting (drop --static)");
    }
    if sweep.policies.is_empty() || sweep.traces.is_empty() {
        bail!("codesign needs at least one policy and one trace");
    }
    let threads = if sweep.threads == 0 { default_threads() } else { sweep.threads };

    // -- DSE pass: evaluate the grid, keep feasible designs in grid order.
    let kernel = DseKernel::new(&sweep.dse);
    let grid = sweep.dse.grid();
    let points = par_map(&grid, threads, |&(t, p, d)| kernel.evaluate(t, p, d));
    let explored = points.len();
    let mut candidates: Vec<(usize, DsePoint)> = points
        .into_iter()
        .enumerate()
        .filter(|(_, p)| p.feasible)
        .collect();
    let feasible = candidates.len();
    if candidates.is_empty() {
        bail!("no feasible design among {explored} grid points — widen the search");
    }
    // Best objective first; grid order within exact ties.
    candidates.sort_by(|(sa, a), (sb, b)| {
        a.objective
            .partial_cmp(&b.objective)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(sa.cmp(sb))
    });
    if sweep.max_designs > 0 {
        candidates.truncate(sweep.max_designs);
    }

    // -- Serving pass: every (design × policy × trace) cell, parallel over
    // designs, deterministic inner order.
    let workloads: Vec<(String, Vec<Request>, f64)> = sweep
        .traces
        .iter()
        .map(|t| {
            let entries = t.spec.generate();
            let offered = TraceSpec::offered_tokens_per_sec(&entries);
            (t.name.clone(), requests_from_trace(&entries), offered)
        })
        .collect();
    // One factory for the whole serving pass (page size = what
    // `EventServerConfig::pd_swap` will configure), memoized per design
    // through the shared cache.
    let page_tokens =
        KvPoolConfig::for_device(&sweep.dse.shape, &sweep.dse.device).page_tokens;
    let factory = SurfaceFactory::new(&sweep.dse.device, &sweep.dse.shape, page_tokens);
    let surfaces = Mutex::new(SurfaceCache::new());
    let per_design: Vec<Result<Vec<(usize, SweepCell)>>> =
        par_map(&candidates, threads, |(design_seq, point)| {
            let mut cells = Vec::with_capacity(workloads.len() * sweep.policies.len());
            for (trace_idx, (_, workload, _)) in workloads.iter().enumerate() {
                for (policy_seq, &policy) in sweep.policies.iter().enumerate() {
                    let cell = simulate_cell(
                        sweep,
                        &factory,
                        &surfaces,
                        point,
                        *design_seq,
                        policy,
                        policy_seq,
                        workload.clone(),
                    )?;
                    cells.push((trace_idx, cell));
                }
            }
            Ok(cells)
        });

    let mut by_trace: Vec<Vec<SweepCell>> = workloads.iter().map(|_| Vec::new()).collect();
    let mut sims_run = 0usize;
    for design_cells in per_design {
        for (trace_idx, cell) in design_cells? {
            sims_run += 1;
            by_trace[trace_idx].push(cell);
        }
    }

    // -- Rank per trace (total order: throughput, makespan, grid, policy).
    let traces = workloads
        .iter()
        .zip(by_trace)
        .map(|((name, _, offered), mut cells)| {
            cells.sort_by(|a, b| {
                b.decode_tps
                    .partial_cmp(&a.decode_tps)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(
                        a.makespan_s
                            .partial_cmp(&b.makespan_s)
                            .unwrap_or(std::cmp::Ordering::Equal),
                    )
                    .then(a.design_seq.cmp(&b.design_seq))
                    .then(a.policy_seq.cmp(&b.policy_seq))
            });
            TraceOutcome {
                trace: name.clone(),
                offered_tokens_per_sec: *offered,
                ranked: cells,
            }
        })
        .collect();

    Ok(CodesignReport {
        explored,
        feasible,
        designs_swept: candidates.len(),
        sims_run,
        traces,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn small_sweep() -> CodesignConfig {
        let mut sweep = CodesignConfig::paper_default(BITNET_0_73B, KV260.clone());
        sweep.dse.tlmm_grid = vec![320];
        sweep.dse.prefill_grid = vec![250, 300];
        sweep.dse.decode_grid = vec![150, 250];
        sweep.traces = vec![TracePreset::by_name("mixed", 6, 0.05, 2048, 7).unwrap()];
        sweep
    }

    #[test]
    fn sweep_covers_grid_times_policies_times_traces() {
        let sweep = small_sweep();
        let report = run_codesign(&sweep).unwrap();
        assert_eq!(report.explored, 4);
        assert!(report.feasible >= 2, "trimmed grid should mostly fit");
        assert_eq!(report.designs_swept, report.feasible);
        assert_eq!(
            report.sims_run,
            report.designs_swept * sweep.policies.len() * sweep.traces.len()
        );
        let t = &report.traces[0];
        assert_eq!(t.ranked.len(), report.sims_run);
        // Ranking is by decode throughput, best first.
        for w in t.ranked.windows(2) {
            assert!(w[0].decode_tps >= w[1].decode_tps);
        }
        assert!(t.winner().decode_tps > 0.0);
    }

    #[test]
    fn sweep_is_deterministic_across_runs_and_threads() {
        let mut a_cfg = small_sweep();
        a_cfg.threads = 1;
        let mut b_cfg = small_sweep();
        b_cfg.threads = 4;
        let a = run_codesign(&a_cfg).unwrap();
        let b = run_codesign(&b_cfg).unwrap();
        for (ta, tb) in a.traces.iter().zip(&b.traces) {
            assert_eq!(ta.winner().design, tb.winner().design);
            assert_eq!(ta.winner().policy, tb.winner().policy);
            assert_eq!(
                ta.winner().decode_tps.to_bits(),
                tb.winner().decode_tps.to_bits(),
                "virtual-clock metrics must be bit-stable"
            );
            for (ca, cb) in ta.ranked.iter().zip(&tb.ranked) {
                assert_eq!(ca.design, cb.design);
                assert_eq!(ca.policy, cb.policy);
            }
        }
    }

    #[test]
    fn max_designs_caps_the_sweep() {
        let mut sweep = small_sweep();
        sweep.max_designs = 1;
        let report = run_codesign(&sweep).unwrap();
        assert_eq!(report.designs_swept, 1);
        assert_eq!(report.sims_run, sweep.policies.len());
    }

    #[test]
    fn static_hosting_is_rejected() {
        let mut sweep = small_sweep();
        sweep.dse.hosting = AttentionHosting::StaticBoth;
        assert!(run_codesign(&sweep).is_err());
    }

    #[test]
    fn report_json_has_winners() {
        let report = run_codesign(&small_sweep()).unwrap();
        let v = report.to_json(3);
        let mixed = v.get("traces").unwrap().get("mixed").unwrap();
        assert!(mixed.get("winner").unwrap().get("design").is_some());
        assert!(mixed.get("top").unwrap().as_arr().unwrap().len() <= 3);
    }
}
