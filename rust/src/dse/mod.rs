//! Design space exploration + automated implementation flow (§3.3, Fig. 4b).
//!
//! Searches the engine-parallelism space under the Eq. 2 resource
//! constraint and minimizes the paper's Eq. 6 objective:
//!
//! ```text
//! min  T_pre(L_pre) + α·T_dec(L_long) + (1-α)·T_dec(L_short)
//! s.t. T_pre <= T_pre_max
//!      r_proj + max(r_pre, r_dec) <= R_total        (DPR hosting)
//!      r_proj + r_pre + r_dec     <= R_total        (static hosting)
//! ```
//!
//! with α = 0.7 weighting long-context decode. The same explorer runs for
//! both hostings, which *is* the paper's headline ablation: the best
//! static design is the TeLLMe-class baseline, the best DPR design is
//! PD-Swap.
//!
//! ## Hot path
//!
//! Grid evaluation runs through [`DseKernel`]: per-grid the
//! design-independent quantities (memory system, weight-stream time, the
//! KV-bandwidth variants) are computed once in a
//! [`crate::engines::SurfaceFactory`], the Eq. 2 / routability check is
//! replayed as pure [`ResourceVec`] arithmetic (no floorplan objects
//! allocated), and per-candidate latencies come from an O(1)
//! [`crate::engines::LatencySurface`] — bit-identical to the uncached
//! [`evaluate`] reference, which is retained for tests and the
//! `hotpath_kernel` bench. [`explore`] fans the grid out over scoped
//! threads ([`crate::util::par`]) and reduces serially in grid order, so
//! the result is identical for any thread count; the runner-up list is a
//! bounded top-k heap ([`TopK`]) instead of a clone-everything vector.
//!
//! [`implement_with_feedback`] models the Fig. 4b build loop: validate the
//! floorplan, and on a routability failure shrink the dynamic-region
//! parallelism and retry ("if overall timing closure still fails ...
//! iteratively reduce resource utilization in the dynamic partition").

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::{Arc, Mutex};

use anyhow::bail;

use crate::engines::{
    AcceleratorDesign, AttentionHosting, DecodeAttentionEngine, NormEngine,
    PhaseModel, PrefillAttentionEngine, ScheduleQuality, SurfaceCache, SurfaceFactory,
    TlmmEngine,
};
use crate::fpga::region::{validate_budget, PBLOCK_FILL_CEILING};
use crate::fpga::{DeviceConfig, ResourceVec};
use crate::model::ModelShape;
use crate::util::par::{default_threads, par_map};
use crate::Result;

pub mod codesign;

pub use codesign::{
    run_codesign, trace_winners, BatchFlip, CodesignConfig, CodesignReport, PoolFlip,
    PoolVariant, SweepCell, TraceOutcome, TracePreset,
};

/// Runner-up list size carried in a [`DseResult`].
pub const TOP_K: usize = 10;

/// Page size the DSE pass keys its [`SurfaceFactory`] on. The DSE
/// objective queries monolithic decode steps only; the paged bandwidth
/// slot just needs *a* page size (32 = the KV-pool default).
pub const DSE_PAGE_TOKENS: usize = 32;

/// Exploration parameters (defaults = the paper's setup).
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub shape: ModelShape,
    pub device: DeviceConfig,
    pub hosting: AttentionHosting,
    /// Prefill length for the T_pre term (and the TTFT constraint).
    pub l_prefill: usize,
    /// Long/short decode contexts of Eq. 6.
    pub l_long: usize,
    pub l_short: usize,
    /// Long-context weight α.
    pub alpha: f64,
    /// Responsiveness constraint T_pre^max (seconds).
    pub t_pre_max: f64,
    /// Search grids (DSP counts / PE counts).
    pub tlmm_grid: Vec<usize>,
    pub prefill_grid: Vec<usize>,
    pub decode_grid: Vec<usize>,
}

impl DseConfig {
    pub fn paper_default(shape: ModelShape, device: DeviceConfig, hosting: AttentionHosting) -> Self {
        Self {
            shape,
            device,
            hosting,
            l_prefill: 768,
            l_long: 2048,
            l_short: 128,
            alpha: 0.7,
            t_pre_max: 12.0,
            tlmm_grid: vec![160, 240, 320, 400],
            prefill_grid: (2..=18).map(|i| i * 25).collect(),
            decode_grid: (1..=12).map(|i| i * 25).collect(),
        }
    }

    /// Grid points in canonical order (tlmm, then prefill, then decode) —
    /// the order every reduction and determinism contract is defined on.
    pub fn grid(&self) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::with_capacity(
            self.tlmm_grid.len() * self.prefill_grid.len() * self.decode_grid.len(),
        );
        for &tlmm_pe in &self.tlmm_grid {
            for &pre_dsp in &self.prefill_grid {
                for &dec_dsp in &self.decode_grid {
                    out.push((tlmm_pe, pre_dsp, dec_dsp));
                }
            }
        }
        out
    }
}

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub design: AcceleratorDesign,
    pub feasible: bool,
    pub reject_reason: Option<String>,
    pub t_pre: f64,
    pub t_dec_long: f64,
    pub t_dec_short: f64,
    pub objective: f64,
}

/// Exploration outcome.
#[derive(Debug)]
pub struct DseResult {
    pub best: DsePoint,
    pub explored: usize,
    pub feasible: usize,
    /// Top candidates by objective (for the explorer example's report).
    pub top: Vec<DsePoint>,
}

fn candidate(
    cfg: &DseConfig,
    tlmm_pe: usize,
    pre_dsp: usize,
    dec_dsp: usize,
) -> AcceleratorDesign {
    let (sched_pre, sched_dec, kv_opt) = match cfg.hosting {
        // A dedicated RM per phase: tailored dataflow + the §3.2.3 remap.
        AttentionHosting::Reconfigurable => {
            (ScheduleQuality::Tailored, ScheduleQuality::Tailored, true)
        }
        // One static datapath compromises both phases.
        AttentionHosting::StaticBoth => {
            (ScheduleQuality::Generic, ScheduleQuality::Generic, false)
        }
    };
    AcceleratorDesign {
        name: format!(
            "{}(tlmm={tlmm_pe},pre={pre_dsp},dec={dec_dsp})",
            match cfg.hosting {
                AttentionHosting::Reconfigurable => "dpr",
                AttentionHosting::StaticBoth => "static",
            }
        ),
        tlmm: TlmmEngine { n_pe: tlmm_pe },
        norm: NormEngine::PAPER,
        prefill_attn: PrefillAttentionEngine { n_dsp: pre_dsp, schedule: sched_pre },
        decode_attn: DecodeAttentionEngine {
            n_dsp: dec_dsp,
            schedule: sched_dec,
            kv_optimized_ports: kv_opt,
        },
        hosting: cfg.hosting,
    }
}

/// Evaluate one candidate against constraints + objective — the uncached
/// reference path: builds the floorplan objects and a full [`PhaseModel`]
/// per call. The grid explorer uses the bit-identical [`DseKernel`] fast
/// path instead; this stays as the oracle for tests and the
/// `hotpath_kernel` bench.
pub fn evaluate(cfg: &DseConfig, design: AcceleratorDesign) -> DsePoint {
    // Constraint: Eq. 2 / static fit + routability, via the floorplanner.
    let plan = match design.region_plan() {
        Ok(p) => p,
        Err(e) => {
            return DsePoint {
                design,
                feasible: false,
                reject_reason: Some(e.to_string()),
                t_pre: f64::INFINITY,
                t_dec_long: f64::INFINITY,
                t_dec_short: f64::INFINITY,
                objective: f64::INFINITY,
            }
        }
    };
    if let Err(e) = plan.validate(&cfg.device) {
        return DsePoint {
            design,
            feasible: false,
            reject_reason: Some(e),
            t_pre: f64::INFINITY,
            t_dec_long: f64::INFINITY,
            t_dec_short: f64::INFINITY,
            objective: f64::INFINITY,
        };
    }

    let model = PhaseModel::new(design.clone(), cfg.device.clone());
    let t_pre = model.prefill(&cfg.shape, cfg.l_prefill).total;
    let t_dec_long = model.decode_step(&cfg.shape, cfg.l_long).total;
    let t_dec_short = model.decode_step(&cfg.shape, cfg.l_short).total;
    finish_point(cfg, design, t_pre, t_dec_long, t_dec_short)
}

/// Shared tail of both evaluation paths: the Eq. 4 responsiveness check
/// and the Eq. 6 objective.
fn finish_point(
    cfg: &DseConfig,
    design: AcceleratorDesign,
    t_pre: f64,
    t_dec_long: f64,
    t_dec_short: f64,
) -> DsePoint {
    // Constraint: user-perceived responsiveness (Eq. 4).
    if t_pre > cfg.t_pre_max {
        return DsePoint {
            design,
            feasible: false,
            reject_reason: Some(format!(
                "T_pre {:.2}s exceeds T_pre_max {:.2}s",
                t_pre, cfg.t_pre_max
            )),
            t_pre,
            t_dec_long,
            t_dec_short,
            objective: f64::INFINITY,
        };
    }

    // Eq. 6. The decode terms are per-token latencies; the paper weights
    // them directly (α on the long-context term). We scale the decode
    // terms to a representative 256-token generation so the units match
    // T_pre and neither phase vanishes from the objective.
    let gen_tokens = 256.0;
    let objective = t_pre
        + gen_tokens * (cfg.alpha * t_dec_long + (1.0 - cfg.alpha) * t_dec_short);
    DsePoint {
        design,
        feasible: true,
        reject_reason: None,
        t_pre,
        t_dec_long,
        t_dec_short,
        objective,
    }
}

/// Evaluate one (tlmm, prefill, decode) grid point through the uncached
/// reference path — exposed for the property tests and the explorer
/// example.
pub fn evaluate_grid_point(
    cfg: &DseConfig,
    tlmm_pe: usize,
    pre_dsp: usize,
    dec_dsp: usize,
) -> DsePoint {
    evaluate(cfg, candidate(cfg, tlmm_pe, pre_dsp, dec_dsp))
}

// ---------------------------------------------------------------------------
// Fast evaluation kernel
// ---------------------------------------------------------------------------

/// Per-grid evaluation kernel: one [`SurfaceFactory`] amortizes the
/// design-independent analytic work, and the Eq. 2 / routability check
/// sums [`ResourceVec`]s without materializing floorplan objects, then
/// funnels into the same [`validate_budget`] rule the reference path
/// uses. Every output is bit-identical to [`evaluate`] (asserted by the
/// kernel tests and the `prop_surface_matches_phase_model` property
/// test).
#[derive(Debug, Clone)]
pub struct DseKernel {
    cfg: DseConfig,
    factory: SurfaceFactory,
    /// Warm-start hook: a sweep-wide surface cache shared with other
    /// explorations of the same (device, shape, page size). `None` (the
    /// cold path) builds each surface directly from the factory; results
    /// are bit-identical either way.
    surfaces: Option<Arc<Mutex<SurfaceCache>>>,
    norm_res: ResourceVec,
    other_res: ResourceVec,
    /// The token debug-partition pblock a static design still reserves.
    static_dummy_pblock: ResourceVec,
}

impl DseKernel {
    pub fn new(cfg: &DseConfig) -> Self {
        let factory = SurfaceFactory::new(&cfg.device, &cfg.shape, DSE_PAGE_TOKENS);
        Self::with_shared_opt(cfg, factory, None)
    }

    /// Warm-started kernel: reuse a pre-built [`SurfaceFactory`] and a
    /// shared [`SurfaceCache`] across invocations — the same mechanism
    /// `pd-swap codesign` uses for its serving pass, applied to the plain
    /// grid exploration.
    pub fn with_shared(
        cfg: &DseConfig,
        factory: SurfaceFactory,
        surfaces: Arc<Mutex<SurfaceCache>>,
    ) -> Self {
        Self::with_shared_opt(cfg, factory, Some(surfaces))
    }

    fn with_shared_opt(
        cfg: &DseConfig,
        factory: SurfaceFactory,
        surfaces: Option<Arc<Mutex<SurfaceCache>>>,
    ) -> Self {
        let dummy = ResourceVec::ZERO.max(&ResourceVec::new(64.0, 128.0, 0.0, 0.0, 0.0));
        Self {
            cfg: cfg.clone(),
            factory,
            surfaces,
            norm_res: NormEngine::PAPER.resources(),
            other_res: crate::engines::design::other_static(),
            static_dummy_pblock: dummy * (1.0 / PBLOCK_FILL_CEILING),
        }
    }

    pub fn config(&self) -> &DseConfig {
        &self.cfg
    }

    /// Evaluate one grid point without materializing floorplan objects.
    pub fn evaluate(&self, tlmm_pe: usize, pre_dsp: usize, dec_dsp: usize) -> DsePoint {
        let cfg = &self.cfg;
        let design = candidate(cfg, tlmm_pe, pre_dsp, dec_dsp);
        let tlmm_res = design.tlmm.resources();
        let pre_res = design.prefill_attn.resources();
        let dec_res = design.decode_attn.resources();
        // Replays StaticRegion::total + ReconfigurablePartition::plan in
        // the same operation order as `AcceleratorDesign::region_plan`.
        let (static_total, pblock) = match cfg.hosting {
            AttentionHosting::Reconfigurable => (
                ResourceVec::ZERO + tlmm_res + self.norm_res + self.other_res,
                ResourceVec::ZERO.max(&pre_res).max(&dec_res) * (1.0 / PBLOCK_FILL_CEILING),
            ),
            AttentionHosting::StaticBoth => (
                ResourceVec::ZERO + tlmm_res + self.norm_res + self.other_res + pre_res
                    + dec_res,
                self.static_dummy_pblock,
            ),
        };
        let total = static_total + pblock;
        // Same accept/reject rule (and diagnostics) as the reference path:
        // `region_plan().validate()` funnels into this checker too.
        if let Err(reason) = validate_budget(static_total, total, &cfg.device) {
            return DsePoint {
                design,
                feasible: false,
                reject_reason: Some(reason),
                t_pre: f64::INFINITY,
                t_dec_long: f64::INFINITY,
                t_dec_short: f64::INFINITY,
                objective: f64::INFINITY,
            };
        }
        let (t_pre, t_dec_long, t_dec_short) = match &self.surfaces {
            // Warm path: one construction per (design, page size) across
            // every sharer of the cache; a miss is pure arithmetic, so
            // the lock is held for nanoseconds.
            Some(cache) => {
                let s = cache
                    .lock()
                    .expect("surface cache poisoned")
                    .get_with(&self.factory, &design);
                (
                    s.prefill(cfg.l_prefill).total,
                    s.decode_step(cfg.l_long).total,
                    s.decode_step(cfg.l_short).total,
                )
            }
            None => {
                let s = self.factory.surface(&design);
                (
                    s.prefill(cfg.l_prefill).total,
                    s.decode_step(cfg.l_long).total,
                    s.decode_step(cfg.l_short).total,
                )
            }
        };
        finish_point(cfg, design, t_pre, t_dec_long, t_dec_short)
    }
}

// ---------------------------------------------------------------------------
// Bounded top-k
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Ranked {
    objective: f64,
    seq: usize,
    point: DsePoint,
}

impl PartialEq for Ranked {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Ranked {}
impl PartialOrd for Ranked {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ranked {
    fn cmp(&self, other: &Self) -> Ordering {
        // Feasible objectives are finite by construction; ties break by
        // grid order so the heap is fully deterministic.
        self.objective
            .partial_cmp(&other.objective)
            .unwrap_or(Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Bounded best-k collector ordered by `(objective, sequence)`: O(log k)
/// per offer, never holding more than `k` points — the replacement for
/// the clone-every-feasible-point-then-truncate pattern.
#[derive(Debug)]
pub struct TopK {
    cap: usize,
    /// Max-heap: the worst retained point sits at the top for eviction.
    heap: BinaryHeap<Ranked>,
}

impl TopK {
    pub fn new(cap: usize) -> Self {
        Self { cap, heap: BinaryHeap::with_capacity(cap + 1) }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer one candidate; it is kept iff it ranks inside the best `cap`
    /// seen so far.
    pub fn offer(&mut self, objective: f64, seq: usize, point: DsePoint) {
        if self.cap == 0 {
            return;
        }
        let entry = Ranked { objective, seq, point };
        if self.heap.len() < self.cap {
            self.heap.push(entry);
            return;
        }
        if let Some(worst) = self.heap.peek() {
            if entry.cmp(worst) == Ordering::Less {
                self.heap.pop();
                self.heap.push(entry);
            }
        }
    }

    /// The retained points, best first (objective ascending, grid order
    /// within ties — matching a stable sort over the full feasible set).
    pub fn into_sorted(self) -> Vec<DsePoint> {
        let mut v = self.heap.into_vec();
        v.sort_by(|a, b| a.cmp(b));
        v.into_iter().map(|r| r.point).collect()
    }
}

// ---------------------------------------------------------------------------
// Exploration
// ---------------------------------------------------------------------------

/// Full grid exploration on the fast kernel, parallelized over scoped
/// threads. Errors (instead of panicking) when no grid point is feasible.
pub fn explore(cfg: &DseConfig) -> Result<DseResult> {
    explore_threads(cfg, default_threads())
}

/// [`explore`] pinned to one thread — the serial reference the
/// determinism tests compare against.
pub fn explore_serial(cfg: &DseConfig) -> Result<DseResult> {
    explore_threads(cfg, 1)
}

/// [`explore`] with an explicit worker count. The reduction runs serially
/// over the grid-ordered evaluations, so the returned [`DseResult`] is
/// identical (bit for bit) for every `threads` value.
pub fn explore_threads(cfg: &DseConfig, threads: usize) -> Result<DseResult> {
    let kernel = DseKernel::new(cfg);
    let grid = cfg.grid();
    let points = par_map(&grid, threads, |&(t, p, d)| kernel.evaluate(t, p, d));
    reduce(cfg, points)
}

/// Warm-started [`explore`]: reuse a caller-owned [`SurfaceFactory`] and
/// shared [`SurfaceCache`] (build the factory with [`DSE_PAGE_TOKENS`])
/// instead of constructing them per call — the codesign warm-start
/// applied to the plain `pd-swap dse` path, so repeated explorations of
/// the same (device, shape) pay surface construction once. `threads == 0`
/// means auto. Bit-identical to [`explore`].
pub fn explore_with(
    cfg: &DseConfig,
    factory: &SurfaceFactory,
    surfaces: &Arc<Mutex<SurfaceCache>>,
    threads: usize,
) -> Result<DseResult> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let kernel = DseKernel::with_shared(cfg, factory.clone(), Arc::clone(surfaces));
    let grid = cfg.grid();
    let points = par_map(&grid, threads, |&(t, p, d)| kernel.evaluate(t, p, d));
    reduce(cfg, points)
}

/// The uncached exploration path: serial, one floorplan + [`PhaseModel`]
/// per grid point, same reduction. Retained as the baseline the
/// `hotpath_kernel` bench measures the kernel speedup against (both paths
/// must return identical results).
pub fn explore_uncached(cfg: &DseConfig) -> Result<DseResult> {
    let points: Vec<DsePoint> = cfg
        .grid()
        .into_iter()
        .map(|(t, p, d)| evaluate_grid_point(cfg, t, p, d))
        .collect();
    reduce(cfg, points)
}

/// Serial grid-order reduction shared by every exploration path.
fn reduce(cfg: &DseConfig, points: Vec<DsePoint>) -> Result<DseResult> {
    let explored = points.len();
    let mut feasible = 0usize;
    let mut top = TopK::new(TOP_K);
    let mut best: Option<DsePoint> = None;
    for (seq, point) in points.into_iter().enumerate() {
        if !point.feasible {
            continue;
        }
        feasible += 1;
        // Primary: minimize Eq. 6 (exact comparison — once decode
        // attention is memory-bound its latency is independent of the
        // engine size, so the ties that matter are bit-exact under the
        // surface kernel). Tie-break: prefer the largest decode engine
        // that still fits — the RP is already sized by the prefill RM, so
        // the extra PEs are free ("allocates the maximum available
        // resources to the active stage", §4.3). Further ties keep the
        // earliest grid point, making the rule a total order that any
        // evaluation parallelism preserves.
        let better = match &best {
            None => true,
            Some(b) => {
                point.objective < b.objective
                    || (point.objective == b.objective
                        && point.design.decode_attn.n_dsp > b.design.decode_attn.n_dsp)
            }
        };
        if better {
            best = Some(point.clone());
        }
        top.offer(point.objective, seq, point);
    }
    let Some(best) = best else {
        bail!(
            "no feasible design among {} grid points of {} — widen the search or relax T_pre_max",
            explored,
            cfg.shape.name
        )
    };
    Ok(DseResult { best, explored, feasible, top: top.into_sorted() })
}

/// One iteration record of the Fig. 4b implementation loop.
#[derive(Debug, Clone)]
pub struct FlowIteration {
    pub attempt: usize,
    pub design_name: String,
    pub outcome: std::result::Result<f64, String>,
}

/// The automated implementation flow: try to "place and route" the design
/// (validate the floorplan), and on failure shrink the dynamic-region
/// engines by `step` DSPs and retry — the §3.3.3 feedback loop. Returns
/// the final design and the iteration log.
pub fn implement_with_feedback(
    device: &DeviceConfig,
    mut design: AcceleratorDesign,
    step: usize,
    max_iters: usize,
) -> (Option<AcceleratorDesign>, Vec<FlowIteration>) {
    let mut log = Vec::new();
    let base_name = design.name.clone();
    for attempt in 0..max_iters {
        let outcome = design
            .region_plan()
            .map_err(|e| e.to_string())
            .and_then(|p| p.validate(device).map(|r| r.peak_utilization));
        let ok = outcome.is_ok();
        log.push(FlowIteration {
            attempt,
            design_name: design.name.clone(),
            outcome: outcome.clone(),
        });
        if ok {
            return (Some(design), log);
        }
        // Shrink the dynamic region (never the static TLMM — the paper
        // reduces "PE count or parallelism" of the RP tenants).
        let pre = design.prefill_attn.n_dsp.saturating_sub(step);
        let dec = design.decode_attn.n_dsp.saturating_sub(step);
        if pre < step || dec < step {
            break;
        }
        design.prefill_attn.n_dsp = pre;
        design.decode_attn.n_dsp = dec;
        design.name = format!("{} (shrunk@{})", base_name, attempt + 1);
    }
    (None, log)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::{ResourceVec, KV260};
    use crate::model::BITNET_0_73B;

    fn quick_cfg(hosting: AttentionHosting) -> DseConfig {
        let mut cfg = DseConfig::paper_default(BITNET_0_73B, KV260.clone(), hosting);
        // Coarser grid to keep tests quick (250 is the static sweet spot:
        // larger engines blow the floorplan, smaller ones the TTFT cap).
        cfg.tlmm_grid = vec![320];
        cfg.prefill_grid = vec![100, 200, 250, 300, 400];
        cfg.decode_grid = vec![25, 50, 150, 250, 300];
        cfg
    }

    #[test]
    fn dpr_search_finds_bigger_engines_than_static() {
        let dpr = explore(&quick_cfg(AttentionHosting::Reconfigurable)).unwrap();
        let stat = explore(&quick_cfg(AttentionHosting::StaticBoth)).unwrap();
        let dpr_attn =
            dpr.best.design.prefill_attn.n_dsp + dpr.best.design.decode_attn.n_dsp;
        let stat_attn =
            stat.best.design.prefill_attn.n_dsp + stat.best.design.decode_attn.n_dsp;
        // Time-sharing the partition buys strictly more attention silicon.
        assert!(
            dpr_attn > stat_attn,
            "dpr {dpr_attn} DSP vs static {stat_attn} DSP"
        );
    }

    #[test]
    fn dpr_objective_beats_static() {
        let dpr = explore(&quick_cfg(AttentionHosting::Reconfigurable)).unwrap();
        let stat = explore(&quick_cfg(AttentionHosting::StaticBoth)).unwrap();
        assert!(
            dpr.best.objective < stat.best.objective,
            "dpr {:.3} vs static {:.3}",
            dpr.best.objective,
            stat.best.objective
        );
    }

    #[test]
    fn all_feasible_points_satisfy_eq2() {
        let cfg = quick_cfg(AttentionHosting::Reconfigurable);
        let res = explore(&cfg).unwrap();
        for p in &res.top {
            let plan = p.design.region_plan().unwrap();
            assert!(plan.validate(&KV260).is_ok(), "{}", p.design.name);
        }
        assert!(res.feasible <= res.explored);
    }

    #[test]
    fn infeasible_points_report_reasons() {
        let cfg = quick_cfg(AttentionHosting::StaticBoth);
        // Giant static engines cannot fit.
        let p = evaluate(&cfg, candidate(&cfg, 320, 450, 350));
        assert!(!p.feasible);
        assert!(p.reject_reason.is_some());
        assert!(p.objective.is_infinite());
    }

    #[test]
    fn t_pre_constraint_rejects() {
        let mut cfg = quick_cfg(AttentionHosting::Reconfigurable);
        cfg.t_pre_max = 0.5; // unreachable for 768-token prefill on KV260
        let p = evaluate(&cfg, candidate(&cfg, 320, 300, 250));
        assert!(!p.feasible);
        assert!(p.reject_reason.unwrap().contains("T_pre"));
    }

    #[test]
    fn kernel_matches_uncached_evaluate_bitwise() {
        for hosting in [AttentionHosting::Reconfigurable, AttentionHosting::StaticBoth] {
            let cfg = quick_cfg(hosting);
            let kernel = DseKernel::new(&cfg);
            for (t, p, d) in cfg.grid() {
                let fast = kernel.evaluate(t, p, d);
                let slow = evaluate_grid_point(&cfg, t, p, d);
                assert_eq!(fast.feasible, slow.feasible, "({t},{p},{d})");
                assert_eq!(fast.reject_reason, slow.reject_reason, "({t},{p},{d})");
                assert_eq!(
                    fast.objective.to_bits(),
                    slow.objective.to_bits(),
                    "({t},{p},{d})"
                );
                assert_eq!(fast.t_pre.to_bits(), slow.t_pre.to_bits(), "({t},{p},{d})");
                assert_eq!(
                    fast.t_dec_long.to_bits(),
                    slow.t_dec_long.to_bits(),
                    "({t},{p},{d})"
                );
            }
        }
    }

    #[test]
    fn exhausted_grid_is_an_error_not_a_panic() {
        let mut cfg = quick_cfg(AttentionHosting::Reconfigurable);
        cfg.t_pre_max = 0.001; // nothing can prefill 768 tokens in 1 ms
        let err = explore(&cfg).unwrap_err();
        assert!(err.to_string().contains("no feasible design"), "{err}");
        assert!(explore_uncached(&cfg).is_err());
    }

    #[test]
    fn top_k_is_bounded_sorted_and_deterministic() {
        let cfg = quick_cfg(AttentionHosting::Reconfigurable);
        let res = explore(&cfg).unwrap();
        assert!(res.top.len() <= TOP_K);
        assert!(!res.top.is_empty());
        for w in res.top.windows(2) {
            assert!(w[0].objective <= w[1].objective);
        }
        // The best point leads the top list.
        assert_eq!(res.top[0].objective.to_bits(), res.best.objective.to_bits());
    }

    #[test]
    fn parallel_explore_matches_serial() {
        for hosting in [AttentionHosting::Reconfigurable, AttentionHosting::StaticBoth] {
            let cfg = quick_cfg(hosting);
            let serial = explore_serial(&cfg).unwrap();
            for threads in [2, 3, 8] {
                let par = explore_threads(&cfg, threads).unwrap();
                assert_eq!(par.explored, serial.explored);
                assert_eq!(par.feasible, serial.feasible);
                assert_eq!(par.best.design.name, serial.best.design.name);
                assert_eq!(
                    par.best.objective.to_bits(),
                    serial.best.objective.to_bits()
                );
                assert_eq!(par.top.len(), serial.top.len());
                for (a, b) in par.top.iter().zip(&serial.top) {
                    assert_eq!(a.design.name, b.design.name);
                    assert_eq!(a.objective.to_bits(), b.objective.to_bits());
                }
            }
        }
    }

    #[test]
    fn warm_started_explore_matches_cold_bitwise() {
        // The shared factory + cache path must be a pure performance
        // optimization: identical best/top lists to the bit, and a second
        // exploration through the same cache (all surfaces now warm) must
        // replay the same result again.
        let cfg = quick_cfg(AttentionHosting::Reconfigurable);
        let cold = explore(&cfg).unwrap();
        let factory = SurfaceFactory::new(&cfg.device, &cfg.shape, DSE_PAGE_TOKENS);
        let surfaces = Arc::new(Mutex::new(SurfaceCache::new()));
        for threads in [0, 1, 4] {
            let warm = explore_with(&cfg, &factory, &surfaces, threads).unwrap();
            assert_eq!(warm.explored, cold.explored);
            assert_eq!(warm.feasible, cold.feasible);
            assert_eq!(warm.best.design.name, cold.best.design.name);
            assert_eq!(warm.best.objective.to_bits(), cold.best.objective.to_bits());
            assert_eq!(warm.top.len(), cold.top.len());
            for (a, b) in warm.top.iter().zip(&cold.top) {
                assert_eq!(a.design.name, b.design.name);
                assert_eq!(a.objective.to_bits(), b.objective.to_bits());
                assert_eq!(a.t_pre.to_bits(), b.t_pre.to_bits());
            }
        }
    }

    #[test]
    fn feedback_loop_shrinks_to_fit() {
        // Start from an over-provisioned DPR design; the flow must shrink
        // it until the floorplan passes.
        let mut d = AcceleratorDesign::pd_swap();
        d.prefill_attn.n_dsp = 700;
        d.decode_attn.n_dsp = 700;
        // Make the oversized RP actually violate capacity.
        let (fixed, log) = implement_with_feedback(&KV260, d, 50, 20);
        let fixed = fixed.expect("flow should converge");
        assert!(log.len() > 1, "must have iterated");
        assert!(fixed.region_plan().unwrap().validate(&KV260).is_ok());
        assert!(fixed.prefill_attn.n_dsp < 700);
    }

    #[test]
    fn feedback_loop_gives_up_gracefully() {
        // A static region that already exceeds the device can never fit.
        let mut d = AcceleratorDesign::pd_swap();
        d.tlmm = TlmmEngine { n_pe: 2000 };
        let _ = ResourceVec::ZERO; // (import anchor)
        let (fixed, log) = implement_with_feedback(&KV260, d, 50, 10);
        assert!(fixed.is_none());
        assert!(!log.is_empty());
    }

    #[test]
    fn paper_scale_dse_lands_near_shipped_config() {
        // With the full paper grid, the chosen DPR design should land in
        // the neighbourhood of the shipped config (Table 2): prefill RM
        // within [250, 450] DSP and decode RM within [150, 350].
        let cfg = DseConfig::paper_default(
            BITNET_0_73B,
            KV260.clone(),
            AttentionHosting::Reconfigurable,
        );
        let res = explore(&cfg).unwrap();
        let d = &res.best.design;
        assert!(
            (250..=450).contains(&d.prefill_attn.n_dsp),
            "prefill {} DSP",
            d.prefill_attn.n_dsp
        );
        assert!(
            (150..=350).contains(&d.decode_attn.n_dsp),
            "decode {} DSP",
            d.decode_attn.n_dsp
        );
    }
}
