//! Design space exploration + automated implementation flow (§3.3, Fig. 4b).
//!
//! Searches the engine-parallelism space under the Eq. 2 resource
//! constraint and minimizes the paper's Eq. 6 objective:
//!
//! ```text
//! min  T_pre(L_pre) + α·T_dec(L_long) + (1-α)·T_dec(L_short)
//! s.t. T_pre <= T_pre_max
//!      r_proj + max(r_pre, r_dec) <= R_total        (DPR hosting)
//!      r_proj + r_pre + r_dec     <= R_total        (static hosting)
//! ```
//!
//! with α = 0.7 weighting long-context decode. The same explorer runs for
//! both hostings, which *is* the paper's headline ablation: the best
//! static design is the TeLLMe-class baseline, the best DPR design is
//! PD-Swap.
//!
//! [`implement_with_feedback`] models the Fig. 4b build loop: validate the
//! floorplan, and on a routability failure shrink the dynamic-region
//! parallelism and retry ("if overall timing closure still fails ...
//! iteratively reduce resource utilization in the dynamic partition").

use crate::engines::{
    AcceleratorDesign, AttentionHosting, DecodeAttentionEngine, NormEngine,
    PhaseModel, PrefillAttentionEngine, ScheduleQuality, TlmmEngine,
};
use crate::fpga::DeviceConfig;
use crate::model::ModelShape;

/// Exploration parameters (defaults = the paper's setup).
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub shape: ModelShape,
    pub device: DeviceConfig,
    pub hosting: AttentionHosting,
    /// Prefill length for the T_pre term (and the TTFT constraint).
    pub l_prefill: usize,
    /// Long/short decode contexts of Eq. 6.
    pub l_long: usize,
    pub l_short: usize,
    /// Long-context weight α.
    pub alpha: f64,
    /// Responsiveness constraint T_pre^max (seconds).
    pub t_pre_max: f64,
    /// Search grids (DSP counts / PE counts).
    pub tlmm_grid: Vec<usize>,
    pub prefill_grid: Vec<usize>,
    pub decode_grid: Vec<usize>,
}

impl DseConfig {
    pub fn paper_default(shape: ModelShape, device: DeviceConfig, hosting: AttentionHosting) -> Self {
        Self {
            shape,
            device,
            hosting,
            l_prefill: 768,
            l_long: 2048,
            l_short: 128,
            alpha: 0.7,
            t_pre_max: 12.0,
            tlmm_grid: vec![160, 240, 320, 400],
            prefill_grid: (2..=18).map(|i| i * 25).collect(),
            decode_grid: (1..=12).map(|i| i * 25).collect(),
        }
    }
}

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub design: AcceleratorDesign,
    pub feasible: bool,
    pub reject_reason: Option<String>,
    pub t_pre: f64,
    pub t_dec_long: f64,
    pub t_dec_short: f64,
    pub objective: f64,
}

/// Exploration outcome.
#[derive(Debug)]
pub struct DseResult {
    pub best: DsePoint,
    pub explored: usize,
    pub feasible: usize,
    /// Top candidates by objective (for the explorer example's report).
    pub top: Vec<DsePoint>,
}

fn candidate(
    cfg: &DseConfig,
    tlmm_pe: usize,
    pre_dsp: usize,
    dec_dsp: usize,
) -> AcceleratorDesign {
    let (sched_pre, sched_dec, kv_opt) = match cfg.hosting {
        // A dedicated RM per phase: tailored dataflow + the §3.2.3 remap.
        AttentionHosting::Reconfigurable => {
            (ScheduleQuality::Tailored, ScheduleQuality::Tailored, true)
        }
        // One static datapath compromises both phases.
        AttentionHosting::StaticBoth => {
            (ScheduleQuality::Generic, ScheduleQuality::Generic, false)
        }
    };
    AcceleratorDesign {
        name: format!(
            "{}(tlmm={tlmm_pe},pre={pre_dsp},dec={dec_dsp})",
            match cfg.hosting {
                AttentionHosting::Reconfigurable => "dpr",
                AttentionHosting::StaticBoth => "static",
            }
        ),
        tlmm: TlmmEngine { n_pe: tlmm_pe },
        norm: NormEngine::PAPER,
        prefill_attn: PrefillAttentionEngine { n_dsp: pre_dsp, schedule: sched_pre },
        decode_attn: DecodeAttentionEngine {
            n_dsp: dec_dsp,
            schedule: sched_dec,
            kv_optimized_ports: kv_opt,
        },
        hosting: cfg.hosting,
    }
}

/// Evaluate one candidate against constraints + objective.
pub fn evaluate(cfg: &DseConfig, design: AcceleratorDesign) -> DsePoint {
    // Constraint: Eq. 2 / static fit + routability, via the floorplanner.
    let plan = match design.region_plan() {
        Ok(p) => p,
        Err(e) => {
            return DsePoint {
                design,
                feasible: false,
                reject_reason: Some(e.to_string()),
                t_pre: f64::INFINITY,
                t_dec_long: f64::INFINITY,
                t_dec_short: f64::INFINITY,
                objective: f64::INFINITY,
            }
        }
    };
    if let Err(e) = plan.validate(&cfg.device) {
        return DsePoint {
            design,
            feasible: false,
            reject_reason: Some(e),
            t_pre: f64::INFINITY,
            t_dec_long: f64::INFINITY,
            t_dec_short: f64::INFINITY,
            objective: f64::INFINITY,
        };
    }

    let model = PhaseModel::new(design.clone(), cfg.device.clone());
    let t_pre = model.prefill(&cfg.shape, cfg.l_prefill).total;
    let t_dec_long = model.decode_step(&cfg.shape, cfg.l_long).total;
    let t_dec_short = model.decode_step(&cfg.shape, cfg.l_short).total;

    // Constraint: user-perceived responsiveness (Eq. 4).
    if t_pre > cfg.t_pre_max {
        return DsePoint {
            design,
            feasible: false,
            reject_reason: Some(format!(
                "T_pre {:.2}s exceeds T_pre_max {:.2}s",
                t_pre, cfg.t_pre_max
            )),
            t_pre,
            t_dec_long,
            t_dec_short,
            objective: f64::INFINITY,
        };
    }

    // Eq. 6. The decode terms are per-token latencies; the paper weights
    // them directly (α on the long-context term). We scale the decode
    // terms to a representative 256-token generation so the units match
    // T_pre and neither phase vanishes from the objective.
    let gen_tokens = 256.0;
    let objective = t_pre
        + gen_tokens * (cfg.alpha * t_dec_long + (1.0 - cfg.alpha) * t_dec_short);
    DsePoint {
        design,
        feasible: true,
        reject_reason: None,
        t_pre,
        t_dec_long,
        t_dec_short,
        objective,
    }
}

/// Evaluate one (tlmm, prefill, decode) grid point — exposed for the
/// property tests and the explorer example.
pub fn evaluate_grid_point(
    cfg: &DseConfig,
    tlmm_pe: usize,
    pre_dsp: usize,
    dec_dsp: usize,
) -> DsePoint {
    evaluate(cfg, candidate(cfg, tlmm_pe, pre_dsp, dec_dsp))
}

/// Full grid exploration.
pub fn explore(cfg: &DseConfig) -> DseResult {
    let mut best: Option<DsePoint> = None;
    let mut top: Vec<DsePoint> = Vec::new();
    let mut explored = 0;
    let mut feasible = 0;

    for &tlmm_pe in &cfg.tlmm_grid {
        for &pre_dsp in &cfg.prefill_grid {
            for &dec_dsp in &cfg.decode_grid {
                explored += 1;
                let point = evaluate(cfg, candidate(cfg, tlmm_pe, pre_dsp, dec_dsp));
                if !point.feasible {
                    continue;
                }
                feasible += 1;
                top.push(point.clone());
                // Primary: minimize Eq. 6. Tie-break: prefer the largest
                // decode engine that still fits — once decode attention is
                // memory-bound extra PEs are objective-neutral, and the RP
                // is already sized by the prefill RM, so they are free
                // ("allocates the maximum available resources to the
                // active stage", §4.3).
                let better = match &best {
                    None => true,
                    Some(b) => {
                        point.objective < b.objective - 1e-9
                            || (point.objective <= b.objective + 1e-9
                                && point.design.decode_attn.n_dsp
                                    > b.design.decode_attn.n_dsp)
                    }
                };
                if better {
                    best = Some(point);
                }
            }
        }
    }
    top.sort_by(|a, b| a.objective.partial_cmp(&b.objective).unwrap());
    top.truncate(10);
    DseResult {
        best: best.expect("no feasible design in the grid — widen the search"),
        explored,
        feasible,
        top,
    }
}

/// One iteration record of the Fig. 4b implementation loop.
#[derive(Debug, Clone)]
pub struct FlowIteration {
    pub attempt: usize,
    pub design_name: String,
    pub outcome: Result<f64, String>,
}

/// The automated implementation flow: try to "place and route" the design
/// (validate the floorplan), and on failure shrink the dynamic-region
/// engines by `step` DSPs and retry — the §3.3.3 feedback loop. Returns
/// the final design and the iteration log.
pub fn implement_with_feedback(
    device: &DeviceConfig,
    mut design: AcceleratorDesign,
    step: usize,
    max_iters: usize,
) -> (Option<AcceleratorDesign>, Vec<FlowIteration>) {
    let mut log = Vec::new();
    let base_name = design.name.clone();
    for attempt in 0..max_iters {
        let outcome = design
            .region_plan()
            .map_err(|e| e.to_string())
            .and_then(|p| p.validate(device).map(|r| r.peak_utilization));
        let ok = outcome.is_ok();
        log.push(FlowIteration {
            attempt,
            design_name: design.name.clone(),
            outcome: outcome.clone(),
        });
        if ok {
            return (Some(design), log);
        }
        // Shrink the dynamic region (never the static TLMM — the paper
        // reduces "PE count or parallelism" of the RP tenants).
        let pre = design.prefill_attn.n_dsp.saturating_sub(step);
        let dec = design.decode_attn.n_dsp.saturating_sub(step);
        if pre < step || dec < step {
            break;
        }
        design.prefill_attn.n_dsp = pre;
        design.decode_attn.n_dsp = dec;
        design.name = format!("{} (shrunk@{})", base_name, attempt + 1);
    }
    (None, log)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::{ResourceVec, KV260};
    use crate::model::BITNET_0_73B;

    fn quick_cfg(hosting: AttentionHosting) -> DseConfig {
        let mut cfg = DseConfig::paper_default(BITNET_0_73B, KV260.clone(), hosting);
        // Coarser grid to keep tests quick (250 is the static sweet spot:
        // larger engines blow the floorplan, smaller ones the TTFT cap).
        cfg.tlmm_grid = vec![320];
        cfg.prefill_grid = vec![100, 200, 250, 300, 400];
        cfg.decode_grid = vec![25, 50, 150, 250, 300];
        cfg
    }

    #[test]
    fn dpr_search_finds_bigger_engines_than_static() {
        let dpr = explore(&quick_cfg(AttentionHosting::Reconfigurable));
        let stat = explore(&quick_cfg(AttentionHosting::StaticBoth));
        let dpr_attn =
            dpr.best.design.prefill_attn.n_dsp + dpr.best.design.decode_attn.n_dsp;
        let stat_attn =
            stat.best.design.prefill_attn.n_dsp + stat.best.design.decode_attn.n_dsp;
        // Time-sharing the partition buys strictly more attention silicon.
        assert!(
            dpr_attn > stat_attn,
            "dpr {dpr_attn} DSP vs static {stat_attn} DSP"
        );
    }

    #[test]
    fn dpr_objective_beats_static() {
        let dpr = explore(&quick_cfg(AttentionHosting::Reconfigurable));
        let stat = explore(&quick_cfg(AttentionHosting::StaticBoth));
        assert!(
            dpr.best.objective < stat.best.objective,
            "dpr {:.3} vs static {:.3}",
            dpr.best.objective,
            stat.best.objective
        );
    }

    #[test]
    fn all_feasible_points_satisfy_eq2() {
        let cfg = quick_cfg(AttentionHosting::Reconfigurable);
        let res = explore(&cfg);
        for p in &res.top {
            let plan = p.design.region_plan().unwrap();
            assert!(plan.validate(&KV260).is_ok(), "{}", p.design.name);
        }
        assert!(res.feasible <= res.explored);
    }

    #[test]
    fn infeasible_points_report_reasons() {
        let cfg = quick_cfg(AttentionHosting::StaticBoth);
        // Giant static engines cannot fit.
        let p = evaluate(&cfg, candidate(&cfg, 320, 450, 350));
        assert!(!p.feasible);
        assert!(p.reject_reason.is_some());
        assert!(p.objective.is_infinite());
    }

    #[test]
    fn t_pre_constraint_rejects() {
        let mut cfg = quick_cfg(AttentionHosting::Reconfigurable);
        cfg.t_pre_max = 0.5; // unreachable for 768-token prefill on KV260
        let p = evaluate(&cfg, candidate(&cfg, 320, 300, 250));
        assert!(!p.feasible);
        assert!(p.reject_reason.unwrap().contains("T_pre"));
    }

    #[test]
    fn feedback_loop_shrinks_to_fit() {
        // Start from an over-provisioned DPR design; the flow must shrink
        // it until the floorplan passes.
        let mut d = AcceleratorDesign::pd_swap();
        d.prefill_attn.n_dsp = 700;
        d.decode_attn.n_dsp = 700;
        // Make the oversized RP actually violate capacity.
        let (fixed, log) = implement_with_feedback(&KV260, d, 50, 20);
        let fixed = fixed.expect("flow should converge");
        assert!(log.len() > 1, "must have iterated");
        assert!(fixed.region_plan().unwrap().validate(&KV260).is_ok());
        assert!(fixed.prefill_attn.n_dsp < 700);
    }

    #[test]
    fn feedback_loop_gives_up_gracefully() {
        // A static region that already exceeds the device can never fit.
        let mut d = AcceleratorDesign::pd_swap();
        d.tlmm = TlmmEngine { n_pe: 2000 };
        let _ = ResourceVec::ZERO; // (import anchor)
        let (fixed, log) = implement_with_feedback(&KV260, d, 50, 10);
        assert!(fixed.is_none());
        assert!(!log.is_empty());
    }

    #[test]
    fn paper_scale_dse_lands_near_shipped_config() {
        // With the full paper grid, the chosen DPR design should land in
        // the neighbourhood of the shipped config (Table 2): prefill RM
        // within [250, 450] DSP and decode RM within [150, 350].
        let cfg = DseConfig::paper_default(
            BITNET_0_73B,
            KV260.clone(),
            AttentionHosting::Reconfigurable,
        );
        let res = explore(&cfg);
        let d = &res.best.design;
        assert!(
            (250..=450).contains(&d.prefill_attn.n_dsp),
            "prefill {} DSP",
            d.prefill_attn.n_dsp
        );
        assert!(
            (150..=350).contains(&d.decode_attn.n_dsp),
            "decode {} DSP",
            d.decode_attn.n_dsp
        );
    }
}
