//! Deterministic fault injection for the serving simulators
//! (`docs/ARCHITECTURE.md` extension #10).
//!
//! The paper assumes every PCAP partial reconfiguration lands on time and
//! DDR bandwidth is constant; real DPR on edge FPGAs fails those
//! assumptions (bitstream CRC errors force PCAP retries, co-tenants brown
//! out the DDR controller). A [`FaultPlan`] is a *seeded, virtual-time*
//! realization of those failure modes:
//!
//! - **PCAP swap failures** — each actual partial-bitstream load draws a
//!   Bernoulli failure with probability [`FaultPlan::swap_fail_prob`].
//!   Draws are keyed on `(seed, draw index)`, so any two engines that
//!   issue the same load sequence (which every bitwise-equivalence pair
//!   does by construction) see identical outcomes.
//! - **DDR brownout windows** — bounded `[start, end)` intervals during
//!   which bandwidth-bound latencies are scaled by `1 / bw_scale`.
//!   Windows are drawn up front, sorted and non-overlapping, and enter
//!   the timeline as explicit `FaultWindowStart`/`End` events.
//! - **SLO deadlines** — per-trace-family TTFT and end-to-end bounds; a
//!   request that cannot meet them is *shed* (KV pages freed, outcome
//!   recorded with `shed = true`).
//!
//! The inertness contract: [`FaultPlan::none`] (and any zero-intensity
//! spec) reports `is_active() == false` and the serving engines take the
//! exact pre-fault code paths — clocks, metrics, outcomes, and traces are
//! bitwise identical to an engine built before this module existed
//! (pinned by `prop_zero_fault_plan_is_bitwise_inert`).

use crate::util::rng::Rng;

/// After this many *consecutive* failures of the same logical swap, the
/// next attempt deterministically succeeds — modeling the controller
/// re-fetching a fresh bitstream image. This bounds every retry/repair
/// loop (termination is guaranteed, not just almost-sure), which the
/// event budget and the fuzzer rely on.
pub const SWAP_FAIL_STREAK_CAP: u32 = 16;

/// Per-request SLO deadlines, both measured from the request's arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Deadlines {
    /// Time-to-first-token bound (queueing + prefill + exposed swap).
    pub ttft_s: f64,
    /// End-to-end completion bound.
    pub e2e_s: f64,
}

/// One bounded DDR-bandwidth-degradation window on the virtual timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DdrWindow {
    pub start_s: f64,
    pub end_s: f64,
    /// Effective-bandwidth scale in (0, 1] while the window is open:
    /// bandwidth-bound latencies are multiplied by `1 / bw_scale`.
    pub bw_scale: f64,
}

/// Named fault presets (`pd-swap simulate --faults <preset>` and the
/// fuzzer's fault axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    /// No faults — the plan normalizes to [`FaultPlan::none`].
    None,
    /// High per-attempt PCAP failure probability, no DDR/deadline faults.
    SwapStorm,
    /// DDR brownout windows only.
    DdrBrownout,
    /// SLO deadlines only (per trace family).
    Deadlines,
    /// Everything at moderate intensity.
    Chaos,
}

impl FaultSpec {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "none" => Some(Self::None),
            "swap-storm" => Some(Self::SwapStorm),
            "ddr-brownout" => Some(Self::DdrBrownout),
            "deadlines" => Some(Self::Deadlines),
            "chaos" => Some(Self::Chaos),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::SwapStorm => "swap-storm",
            Self::DdrBrownout => "ddr-brownout",
            Self::Deadlines => "deadlines",
            Self::Chaos => "chaos",
        }
    }

    /// The fuzzer's fault axis: a small integer drawn by the case
    /// generator. 0 is `None` so the axis is biased toward fault-free
    /// cases by construction of the draw, and unknown values wrap.
    pub fn from_kind(kind: usize) -> Self {
        match kind % 5 {
            0 => Self::None,
            1 => Self::SwapStorm,
            2 => Self::DdrBrownout,
            3 => Self::Deadlines,
            _ => Self::Chaos,
        }
    }
}

/// A seeded, fully-materialized fault realization for one serving run.
///
/// Cloning is cheap and *resets nothing*: the draw counter is part of the
/// plan state, so clone a fresh plan per engine (the config is cloned per
/// run anyway) and two engines that issue the same swap sequence get the
/// same failure outcomes.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    active: bool,
    swap_fail_prob: f64,
    windows: Vec<DdrWindow>,
    deadlines: Option<Deadlines>,
    seed: u64,
    /// Failure draws taken so far. Each draw hashes `(seed, draws)` into
    /// a fresh PRNG stream — no long-lived generator state to desync.
    draws: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::none()
    }
}

impl FaultPlan {
    /// The inert plan: no failures, no windows, no deadlines, and every
    /// engine fast-path stays on the pre-fault code.
    pub fn none() -> Self {
        Self {
            active: false,
            swap_fail_prob: 0.0,
            windows: Vec::new(),
            deadlines: None,
            seed: 0,
            draws: 0,
        }
    }

    /// Realize a named preset for `seed` and a trace family (the family
    /// scales the deadline preset; pass the trace name, e.g.
    /// `"interactive"`). A zero-intensity realization normalizes to the
    /// inert plan.
    pub fn from_spec(spec: FaultSpec, seed: u64, family: &str) -> Self {
        match spec {
            FaultSpec::None => Self::none(),
            FaultSpec::SwapStorm => Self::build(seed, 0.55, 0, None, family),
            FaultSpec::DdrBrownout => Self::build(seed, 0.0, 3, None, family),
            FaultSpec::Deadlines => {
                Self::build(seed, 0.0, 0, Some(family_deadlines(family)), family)
            }
            FaultSpec::Chaos => {
                Self::build(seed, 0.35, 2, Some(family_deadlines(family)), family)
            }
        }
    }

    /// Swap-failure-only plan with an explicit probability — the
    /// `fault_tolerance` bench's storm knob.
    pub fn storm(seed: u64, swap_fail_prob: f64) -> Self {
        Self::build(seed, swap_fail_prob.clamp(0.0, 0.95), 0, None, "storm")
    }

    fn build(
        seed: u64,
        swap_fail_prob: f64,
        max_windows: usize,
        deadlines: Option<Deadlines>,
        _family: &str,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA01_75EE_D000_0010);
        let mut windows = Vec::new();
        if max_windows > 0 {
            let n = 1 + rng.below(max_windows);
            let mut t = 0.0;
            for _ in 0..n {
                t += 5.0 + rng.f64() * 40.0;
                let dur = 3.0 + rng.f64() * 12.0;
                let bw_scale = 0.4 + rng.f64() * 0.5;
                windows.push(DdrWindow { start_s: t, end_s: t + dur, bw_scale });
                t += dur;
            }
        }
        let active = swap_fail_prob > 0.0 || !windows.is_empty() || deadlines.is_some();
        Self { active, swap_fail_prob, windows, deadlines, seed, draws: 0 }
    }

    /// False iff the plan can never perturb a run. Engines gate every
    /// fault code path on this, which is what makes the zero-fault plan
    /// *structurally* inert rather than merely numerically inert.
    pub fn is_active(&self) -> bool {
        self.active
    }

    pub fn swap_fail_prob(&self) -> f64 {
        self.swap_fail_prob
    }

    /// The DDR brownout windows, sorted by start and non-overlapping.
    pub fn windows(&self) -> &[DdrWindow] {
        &self.windows
    }

    pub fn deadlines(&self) -> Option<Deadlines> {
        self.deadlines
    }

    /// Draw the outcome of one actual PCAP load attempt. `streak` is the
    /// count of consecutive failures of this logical swap so far; at
    /// [`SWAP_FAIL_STREAK_CAP`] the attempt deterministically succeeds
    /// (the draw is still consumed, so engines that disagree only on the
    /// cap would still share the stream).
    pub fn swap_attempt_fails(&mut self, streak: u32) -> bool {
        if !self.active || self.swap_fail_prob <= 0.0 {
            return false;
        }
        self.draws += 1;
        let mut r = Rng::new(self.seed ^ self.draws.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let fail = r.f64() < self.swap_fail_prob;
        fail && streak < SWAP_FAIL_STREAK_CAP
    }

    /// Failure draws consumed so far (diagnostics).
    pub fn draws(&self) -> u64 {
        self.draws
    }
}

/// Deadline presets per trace family. Virtual-time latencies on the
/// modeled edge device run seconds-per-prefill, so the bounds are loose
/// enough that a healthy run meets them and tight enough that queueing
/// collapse or a degraded fallback sheds the tail.
fn family_deadlines(family: &str) -> Deadlines {
    match family {
        "interactive" => Deadlines { ttft_s: 30.0, e2e_s: 180.0 },
        "mixed" => Deadlines { ttft_s: 60.0, e2e_s: 360.0 },
        "bursty" => Deadlines { ttft_s: 45.0, e2e_s: 300.0 },
        "long" => Deadlines { ttft_s: 120.0, e2e_s: 1200.0 },
        "million" => Deadlines { ttft_s: 30.0, e2e_s: 600.0 },
        _ => Deadlines { ttft_s: 60.0, e2e_s: 600.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_inert_and_zero_spec_normalizes_to_it() {
        let mut p = FaultPlan::none();
        assert!(!p.is_active());
        assert!(!p.swap_attempt_fails(0));
        assert_eq!(p.draws(), 0, "inert plan consumes no draws");
        let q = FaultPlan::from_spec(FaultSpec::None, 0xDEAD, "interactive");
        assert_eq!(p, q, "a zero-intensity spec IS the inert plan");
    }

    #[test]
    fn draws_are_deterministic_and_clone_independent() {
        let mut a = FaultPlan::from_spec(FaultSpec::SwapStorm, 7, "mixed");
        let mut b = a.clone();
        let xs: Vec<bool> = (0..64).map(|_| a.swap_attempt_fails(0)).collect();
        let ys: Vec<bool> = (0..64).map(|_| b.swap_attempt_fails(0)).collect();
        assert_eq!(xs, ys);
        assert!(xs.iter().any(|&f| f), "storm prob 0.55 must fail sometimes");
        assert!(xs.iter().any(|&f| !f), "and succeed sometimes");
    }

    #[test]
    fn different_seeds_draw_differently() {
        let mut a = FaultPlan::storm(1, 0.5);
        let mut b = FaultPlan::storm(2, 0.5);
        let xs: Vec<bool> = (0..256).map(|_| a.swap_attempt_fails(0)).collect();
        let ys: Vec<bool> = (0..256).map(|_| b.swap_attempt_fails(0)).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn streak_cap_forces_success() {
        // Probability 0.95 (the clamp ceiling): at the cap the draw is
        // still consumed but the outcome is forced to success.
        let mut p = FaultPlan::storm(3, 1.0);
        assert!((p.swap_fail_prob() - 0.95).abs() < 1e-12);
        for _ in 0..1000 {
            assert!(!p.swap_attempt_fails(SWAP_FAIL_STREAK_CAP));
        }
        assert_eq!(p.draws(), 1000);
    }

    #[test]
    fn brownout_windows_sorted_and_disjoint() {
        for seed in 0..32u64 {
            let p = FaultPlan::from_spec(FaultSpec::DdrBrownout, seed, "bursty");
            assert!(p.is_active());
            let ws = p.windows();
            assert!(!ws.is_empty() && ws.len() <= 3);
            for w in ws {
                assert!(w.start_s > 0.0 && w.end_s > w.start_s);
                assert!((0.4..=0.9).contains(&w.bw_scale), "scale {}", w.bw_scale);
            }
            for pair in ws.windows(2) {
                assert!(pair[0].end_s <= pair[1].start_s, "windows overlap");
            }
        }
    }

    #[test]
    fn deadlines_follow_trace_family() {
        let p = FaultPlan::from_spec(FaultSpec::Deadlines, 0, "interactive");
        let d = p.deadlines().unwrap();
        assert!(d.ttft_s < d.e2e_s);
        let q = FaultPlan::from_spec(FaultSpec::Deadlines, 0, "long");
        assert!(q.deadlines().unwrap().ttft_s > d.ttft_s, "long-context gets looser bounds");
        assert!(p.windows().is_empty() && p.swap_fail_prob() == 0.0);
    }

    #[test]
    fn preset_names_round_trip() {
        for s in [
            FaultSpec::None,
            FaultSpec::SwapStorm,
            FaultSpec::DdrBrownout,
            FaultSpec::Deadlines,
            FaultSpec::Chaos,
        ] {
            assert_eq!(FaultSpec::from_name(s.name()), Some(s));
        }
        assert_eq!(FaultSpec::from_name("bogus"), None);
        assert_eq!(FaultSpec::from_kind(0), FaultSpec::None);
        assert_eq!(FaultSpec::from_kind(4), FaultSpec::Chaos);
    }
}
