//! Workload description: model shapes and per-phase op/byte accounting.
//!
//! This is the *analytic* view of the BitNet transformer that the
//! simulator, roofline model, and DSE consume — the functional twin lives
//! in `python/compile/model.py` and executes via [`crate::runtime`]. The
//! two views share shapes through `manifest.json`.

pub mod shapes;
pub mod workload;

pub use shapes::{ModelShape, Precision, BITNET_0_73B, E2E_100M, TEST, TINY};
pub use workload::{
    ArrivalPattern, BatchedDecodeWork, ComponentOps, DecodeStepWork, LengthClass, PhaseWork,
    PrefillWork, TraceEntry, TraceSpec,
};
