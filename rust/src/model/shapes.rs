//! Model shape constants (the analytic mirror of `python/compile/configs.py`).

/// Numeric precisions used by the accelerator's datapaths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Ternary weights packed base-3: 8 bits per 4 weights.
    Ternary,
    /// int8 activations.
    Int8,
    /// fp16 attention tensors (Q/K/V/O and the KV cache).
    Fp16,
    /// fp32 (CPU-PJRT functional path only).
    Fp32,
}

impl Precision {
    /// Storage bytes per element (ternary amortized: 0.25 B/weight).
    pub fn bytes(&self) -> f64 {
        match self {
            Precision::Ternary => 0.25,
            Precision::Int8 => 1.0,
            Precision::Fp16 => 2.0,
            Precision::Fp32 => 4.0,
        }
    }
}

/// A BitNet-style transformer's dimensions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelShape {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    /// KV cache element precision on the accelerator.
    pub kv_precision: Precision,
}

impl ModelShape {
    pub const fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Ternary linear parameter count (attention QKVO + SwiGLU FFN).
    pub fn linear_params(&self) -> u64 {
        let attn = 4 * self.d_model * self.d_model;
        let ffn = 3 * self.d_model * self.d_ff;
        (self.n_layers * (attn + ffn)) as u64
    }

    /// Embedding parameters (kept fp16 on the accelerator, tied lm-head).
    pub fn embed_params(&self) -> u64 {
        (self.vocab * self.d_model) as u64
    }

    pub fn total_params(&self) -> u64 {
        self.linear_params() + self.embed_params()
    }

    /// Bytes of packed ternary weights (the TLMM streaming/residency load).
    pub fn ternary_weight_bytes(&self) -> f64 {
        self.linear_params() as f64 * Precision::Ternary.bytes()
    }

    /// KV cache bytes per token of context (all layers, K+V).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64 * self.d_model as f64 * self.kv_precision.bytes()
    }

    /// KV cache bytes for a full context of `l` tokens.
    pub fn kv_bytes(&self, l: usize) -> f64 {
        self.kv_bytes_per_token() * l as f64
    }
}

/// The paper's model: BitNet b1.58 0.73B on the KV260.
pub const BITNET_0_73B: ModelShape = ModelShape {
    name: "bitnet-0.73b",
    n_layers: 24,
    d_model: 1536,
    n_heads: 24,
    d_ff: 4096,
    vocab: 32000,
    max_seq: 2048,
    kv_precision: Precision::Fp16,
};

/// The ~103M-parameter e2e driver model (PJRT-executable artifact exists).
pub const E2E_100M: ModelShape = ModelShape {
    name: "e2e-100m",
    n_layers: 10,
    d_model: 768,
    n_heads: 12,
    d_ff: 3072,
    vocab: 8192,
    max_seq: 640,
    kv_precision: Precision::Fp16,
};

/// Quickstart model.
pub const TINY: ModelShape = ModelShape {
    name: "tiny",
    n_layers: 4,
    d_model: 256,
    n_heads: 4,
    d_ff: 768,
    vocab: 2048,
    max_seq: 128,
    kv_precision: Precision::Fp16,
};

/// pytest/cargo-test model.
pub const TEST: ModelShape = ModelShape {
    name: "test",
    n_layers: 2,
    d_model: 128,
    n_heads: 4,
    d_ff: 384,
    vocab: 256,
    max_seq: 32,
    kv_precision: Precision::Fp16,
};

/// Look up a shape by artifact/config name.
pub fn by_name(name: &str) -> Option<ModelShape> {
    match name {
        "bitnet-0.73b" => Some(BITNET_0_73B),
        "e2e-100m" => Some(E2E_100M),
        "tiny" => Some(TINY),
        "test" => Some(TEST),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitnet_param_count_matches_paper() {
        // "BitNet 0.73B": linear + embedding params must land near 0.73e9.
        let p = BITNET_0_73B.total_params() as f64;
        assert!((0.65e9..0.80e9).contains(&p), "params {p:e}");
    }

    #[test]
    fn e2e_is_about_100m() {
        let p = E2E_100M.total_params() as f64;
        assert!((0.9e8..1.15e8).contains(&p), "params {p:e}");
    }

    #[test]
    fn kv_bytes_per_token() {
        // 2 * 24 layers * 1536 * 2B = 147,456 B/token for the paper model.
        assert_eq!(BITNET_0_73B.kv_bytes_per_token(), 147_456.0);
        // 2048-token context: ~302 MB — the Fig. 6 long-context pain.
        let total = BITNET_0_73B.kv_bytes(2048);
        assert!((2.9e8..3.1e8).contains(&total));
    }

    #[test]
    fn ternary_weights_exceed_uram() {
        // 0.73B ternary weights ~ 168 MB >> the 2.25 MB of URAM: weights
        // must stream from DDR each step (T_weights in Eqs. 3/5).
        let bytes = BITNET_0_73B.ternary_weight_bytes();
        assert!(bytes > 100e6, "bytes {bytes:e}");
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("tiny").unwrap(), TINY);
        assert!(by_name("nope").is_none());
    }
}
