//! Per-phase op and byte accounting (the numerators of Eqs. 3 and 5),
//! plus trace-driven workload specification for the serving simulators.
//!
//! Counts MAC operations and DDR bytes for each pipeline component so the
//! engine latency models and the roofline analysis share one source of
//! truth. Conventions:
//!
//! * a MAC = one multiply-accumulate (2 FLOPs in GPU-marketing units);
//! * weights: ternary linears stream packed codes from DDR (they do NOT
//!   fit in URAM at 0.73B scale — URAM holds the working set / LUT tables);
//! * KV cache: fp16 in DDR, read in full every decode step, written one
//!   token per step.
//!
//! The trace half ([`TraceSpec`]) describes *arrival processes* — Poisson
//! rates, on/off burst patterns, context-length mixtures — as plain
//! `(arrival, prompt_len, gen_len)` entries, deliberately below the
//! coordinator layer so the event-driven server, the benches, and the CLI
//! all draw from one generator
//! ([`crate::coordinator::requests_from_trace`] lifts entries into
//! requests).

use crate::util::rng::Rng;

use super::shapes::ModelShape;

/// Ops/bytes of one logical component of a phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComponentOps {
    /// Multiply-accumulate count.
    pub macs: f64,
    /// DDR read bytes.
    pub read_bytes: f64,
    /// DDR write bytes.
    pub write_bytes: f64,
}

impl ComponentOps {
    pub const ZERO: ComponentOps = ComponentOps { macs: 0.0, read_bytes: 0.0, write_bytes: 0.0 };

    pub fn total_bytes(&self) -> f64 {
        self.read_bytes + self.write_bytes
    }

    /// Arithmetic intensity in MACs/byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.macs / self.total_bytes().max(1.0)
    }

    pub fn add(&self, o: &ComponentOps) -> ComponentOps {
        ComponentOps {
            macs: self.macs + o.macs,
            read_bytes: self.read_bytes + o.read_bytes,
            write_bytes: self.write_bytes + o.write_bytes,
        }
    }
}

/// Common interface for the two phases.
pub trait PhaseWork {
    fn projection(&self) -> ComponentOps;
    fn attention(&self) -> ComponentOps;
    fn norm_elementwise(&self) -> ComponentOps;
    fn total(&self) -> ComponentOps {
        self.projection()
            .add(&self.attention())
            .add(&self.norm_elementwise())
    }
}

/// Prefill of `l` prompt tokens (whole model).
#[derive(Debug, Clone, Copy)]
pub struct PrefillWork {
    pub shape: ModelShape,
    pub l: usize,
}

impl PhaseWork for PrefillWork {
    /// All ternary linears over L tokens: QKVO (4·d²) + SwiGLU (3·d·dff)
    /// per layer per token. Reads: packed weights once per *phase* (tiles
    /// are reused across all L tokens — the paper's "batch of GEMVs"
    /// orchestration) + int8 activations.
    fn projection(&self) -> ComponentOps {
        let s = &self.shape;
        let per_token =
            (4 * s.d_model * s.d_model + 3 * s.d_model * s.d_ff) as f64;
        let macs = per_token * self.l as f64 * s.n_layers as f64;
        let weight_reads = s.ternary_weight_bytes();
        let act_bytes =
            (self.l * s.d_model) as f64 * s.n_layers as f64 * 7.0; // 7 tensors/layer
        ComponentOps {
            macs,
            read_bytes: weight_reads + act_bytes,
            write_bytes: act_bytes,
        }
    }

    /// FlashAttention: QK^T (L²·d/2 causal) + PV (same) per layer, fp16
    /// streams; causal halves the score matrix.
    fn attention(&self) -> ComponentOps {
        let s = &self.shape;
        let l = self.l as f64;
        let macs = s.n_layers as f64 * (l * l / 2.0) * s.d_model as f64 * 2.0;
        let qkv_bytes = 3.0 * l * s.d_model as f64 * 2.0 * s.n_layers as f64;
        let out_bytes = l * s.d_model as f64 * 2.0 * s.n_layers as f64;
        // KV cache write-out for the decode phase.
        let kv_write = s.kv_bytes(self.l);
        ComponentOps {
            macs,
            read_bytes: qkv_bytes,
            write_bytes: out_bytes + kv_write,
        }
    }

    /// RMSNorm + RoPE + SwiGLU activation + residuals: ~10 ops/element.
    fn norm_elementwise(&self) -> ComponentOps {
        let s = &self.shape;
        let elems = (self.l * s.d_model * s.n_layers) as f64;
        ComponentOps { macs: elems * 10.0, read_bytes: 0.0, write_bytes: 0.0 }
    }
}

/// One decode step at context length `l` (the new token attends 0..l-1).
#[derive(Debug, Clone, Copy)]
pub struct DecodeStepWork {
    pub shape: ModelShape,
    pub l: usize,
}

impl PhaseWork for DecodeStepWork {
    /// Single-token GEMVs; the whole packed weight set streams from DDR
    /// every step (nothing amortizes it at batch 1 — this is T_weights,
    /// the decode floor).
    fn projection(&self) -> ComponentOps {
        let s = &self.shape;
        let macs = ((4 * s.d_model * s.d_model + 3 * s.d_model * s.d_ff)
            * s.n_layers) as f64;
        ComponentOps {
            macs,
            read_bytes: s.ternary_weight_bytes(),
            write_bytes: (s.d_model * s.n_layers) as f64,
        }
    }

    /// q·K^T -> softmax -> ·V over the cached context: 2·L·d MACs/layer,
    /// and — the decode bottleneck — the entire fp16 KV cache read.
    fn attention(&self) -> ComponentOps {
        let s = &self.shape;
        let macs = 2.0 * (self.l * s.d_model) as f64 * s.n_layers as f64;
        ComponentOps {
            macs,
            read_bytes: s.kv_bytes(self.l),
            write_bytes: s.kv_bytes_per_token(), // this token's K/V append
        }
    }

    fn norm_elementwise(&self) -> ComponentOps {
        let s = &self.shape;
        let elems = (s.d_model * s.n_layers) as f64;
        ComponentOps { macs: elems * 10.0, read_bytes: 0.0, write_bytes: 0.0 }
    }
}

/// One *batched* decode step: `batch` resident streams each emit one
/// token, every stream attending its own context of `l` tokens.
///
/// The paper's decode engine is batch-1 (one resident request), which is
/// what makes `T_weights` the decode floor: the entire packed ternary
/// weight set streams from DDR for a single token's GEMVs. With `batch`
/// resident streams the weight traffic is *shared* — the same tile pass
/// feeds every stream's activations — while the KV traffic stays
/// per-stream (each stream reads its own cache). So projection arithmetic
/// intensity grows ~linearly with `batch` and attention intensity stays
/// flat: the roofline mechanics behind multi-stream decode serving (our
/// extension beyond the paper; see `docs/ARCHITECTURE.md`).
#[derive(Debug, Clone, Copy)]
pub struct BatchedDecodeWork {
    pub shape: ModelShape,
    /// Per-stream context length (uniform across the batch).
    pub l: usize,
    /// Resident streams stepping together.
    pub batch: usize,
}

impl PhaseWork for BatchedDecodeWork {
    /// `batch` tokens' GEMVs against ONE shared pass over the packed
    /// weights — the amortization that batching exists for. Composed
    /// from [`DecodeStepWork`] so the single-stream accounting stays the
    /// one source of the per-token formulas: MACs and activation writes
    /// scale with the batch, the weight read does not.
    fn projection(&self) -> ComponentOps {
        let one = DecodeStepWork { shape: self.shape, l: self.l }.projection();
        ComponentOps {
            macs: one.macs * self.batch as f64,
            read_bytes: one.read_bytes,
            write_bytes: one.write_bytes * self.batch as f64,
        }
    }

    /// Per-stream KV streaming: `batch` independent caches are read in
    /// full, so bytes and MACs both scale with the batch (AI is flat).
    fn attention(&self) -> ComponentOps {
        let one = DecodeStepWork { shape: self.shape, l: self.l }.attention();
        ComponentOps {
            macs: one.macs * self.batch as f64,
            read_bytes: one.read_bytes * self.batch as f64,
            write_bytes: one.write_bytes * self.batch as f64,
        }
    }

    fn norm_elementwise(&self) -> ComponentOps {
        let one = DecodeStepWork { shape: self.shape, l: self.l }.norm_elementwise();
        ComponentOps {
            macs: one.macs * self.batch as f64,
            read_bytes: one.read_bytes,
            write_bytes: one.write_bytes,
        }
    }
}

// ---------------------------------------------------------------------------
// Trace-driven workload specification (serving extension, not in the paper)
// ---------------------------------------------------------------------------

/// How requests arrive over virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Memoryless arrivals at a constant mean rate (req/s).
    Poisson { rate: f64 },
    /// On/off (interrupted Poisson) bursts: `burst_rate` for the first
    /// `on_secs` of every `period_secs`, `base_rate` for the rest — the
    /// "several short requests land together" regime §3.4 worries about.
    Bursty {
        base_rate: f64,
        burst_rate: f64,
        on_secs: f64,
        period_secs: f64,
    },
}

impl ArrivalPattern {
    /// Instantaneous rate at time `t` (for thinning).
    fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalPattern::Poisson { rate } => rate,
            ArrivalPattern::Bursty { base_rate, burst_rate, on_secs, period_secs } => {
                if period_secs <= 0.0 {
                    return base_rate;
                }
                if t.rem_euclid(period_secs) < on_secs {
                    burst_rate
                } else {
                    base_rate
                }
            }
        }
    }

    /// Upper bound of the rate function (thinning envelope).
    fn rate_max(&self) -> f64 {
        match *self {
            ArrivalPattern::Poisson { rate } => rate,
            ArrivalPattern::Bursty { base_rate, burst_rate, .. } => base_rate.max(burst_rate),
        }
    }
}

/// One component of the context-length mixture.
#[derive(Debug, Clone, Copy)]
pub struct LengthClass {
    /// Relative weight (need not be normalized).
    pub weight: f64,
    /// Prompt length range, sampled log-uniformly (short prompts common,
    /// long ones present).
    pub prompt: (usize, usize),
    /// Generation length range, sampled uniformly.
    pub gen: (usize, usize),
}

/// One generated trace entry: what arrives, when, and how big.
#[derive(Debug, Clone, Copy)]
pub struct TraceEntry {
    pub arrival: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// Index into the spec's mixture (for per-class reporting).
    pub class: usize,
}

/// A trace-driven workload: an arrival process plus a context-length
/// mixture. Generation is deterministic in the seed.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub n_requests: usize,
    pub arrivals: ArrivalPattern,
    pub mixture: Vec<LengthClass>,
    pub seed: u64,
}

impl TraceSpec {
    /// Interactive edge-assistant traffic: short prompts, short answers.
    pub fn interactive(n_requests: usize, rate: f64, seed: u64) -> Self {
        Self {
            n_requests,
            arrivals: ArrivalPattern::Poisson { rate },
            mixture: vec![LengthClass { weight: 1.0, prompt: (32, 512), gen: (16, 128) }],
            seed,
        }
    }

    /// Mixed continuous traffic at long context: mostly interactive
    /// requests with a long-context analytics class whose prompt+gen
    /// reaches `long_ctx` tokens — the regime where swap-policy choice
    /// (hysteresis/lookahead vs. eager) matters.
    pub fn mixed_long_context(n_requests: usize, rate: f64, long_ctx: usize, seed: u64) -> Self {
        let long_hi = long_ctx.saturating_sub(256).max(1024);
        Self {
            n_requests,
            arrivals: ArrivalPattern::Poisson { rate },
            mixture: vec![
                LengthClass { weight: 0.75, prompt: (64, 512), gen: (16, 96) },
                LengthClass { weight: 0.25, prompt: (long_hi / 2, long_hi), gen: (64, 256) },
            ],
            seed,
        }
    }

    /// Sparse long-generation traffic: short prompts, generations that
    /// run for a thousand-plus tokens, arrivals far enough apart (fixed
    /// low Poisson rate; the CLI rate knob is deliberately ignored, like
    /// [`Self::bursty`]'s) that decode usually runs with an empty
    /// backlog. This is the steady-state regime where the event core's
    /// analytic fast-forward folds almost every token-step event — the
    /// README's long-trace quickstart and the `event_fast_forward` bench
    /// both draw from it. Generation stays within `BITNET_0_73B`'s 2048
    /// sequence ceiling (prompt ≤ 256 + gen ≤ 1792).
    pub fn long_decode(n_requests: usize, seed: u64) -> Self {
        Self {
            n_requests,
            arrivals: ArrivalPattern::Poisson { rate: 0.004 },
            mixture: vec![LengthClass {
                weight: 1.0,
                prompt: (64, 256),
                gen: (1024, 1792),
            }],
            seed,
        }
    }

    /// Bursty short-request traffic (the §3.4 "multiple short-token
    /// requests" scenario): quiet baseline with periodic arrival storms.
    pub fn bursty(n_requests: usize, seed: u64) -> Self {
        Self {
            n_requests,
            arrivals: ArrivalPattern::Bursty {
                base_rate: 0.02,
                burst_rate: 1.0,
                on_secs: 20.0,
                period_secs: 300.0,
            },
            mixture: vec![LengthClass { weight: 1.0, prompt: (32, 384), gen: (8, 64) }],
            seed,
        }
    }

    /// Million-request endurance traffic: sustained decode-heavy serving
    /// over long horizons. Same sparse-arrival regime as
    /// [`Self::long_decode`] (fixed low Poisson rate; the CLI rate knob
    /// is ignored) with a small interactive side class so the prefill
    /// path stays exercised — the preset the `event_million` bench and
    /// the streamed-arrival path ([`ArrivalStream`] +
    /// `EventServer::run_streamed`) are built around. The point is not
    /// the shape of any one request but the *count*: with streamed
    /// arrivals, interference-aware fast-forward folding, and the
    /// bounded outcome sink, a run over this preset holds O(resident)
    /// memory no matter how large `n_requests` gets.
    pub fn million(n_requests: usize, seed: u64) -> Self {
        Self {
            n_requests,
            arrivals: ArrivalPattern::Poisson { rate: 0.004 },
            mixture: vec![
                LengthClass { weight: 0.9, prompt: (48, 192), gen: (1024, 1792) },
                LengthClass { weight: 0.1, prompt: (32, 128), gen: (64, 256) },
            ],
            seed,
        }
    }

    /// Lazy iterator form of [`Self::generate`]: the SAME entries in the
    /// SAME order from the SAME RNG draw sequence, produced one at a
    /// time in O(1) memory instead of materializing the whole trace.
    /// `generate()` is implemented on top of this, so the two can never
    /// drift; `workload_stream_matches_generate_bitwise` pins the
    /// equivalence explicitly. Arrival times are non-decreasing by
    /// construction (the thinned Poisson clock only moves forward) —
    /// the window invariant `EventServer::run_streamed` relies on.
    pub fn stream(&self) -> ArrivalStream {
        assert!(!self.mixture.is_empty(), "trace needs at least one length class");
        assert!(
            self.arrivals.rate_max() > 0.0,
            "arrival pattern has zero peak rate: no request would ever arrive"
        );
        ArrivalStream {
            rng: Rng::new(self.seed),
            envelope: self.arrivals.rate_max(),
            total_w: self.mixture.iter().map(|c| c.weight.max(0.0)).sum(),
            t: 0.0,
            emitted: 0,
            spec: self.clone(),
        }
    }

    /// Generate the trace: non-homogeneous Poisson arrivals via Lewis
    /// thinning against the pattern's rate envelope, lengths drawn from
    /// the mixture. Entries are sorted by arrival. Materializes
    /// [`Self::stream`]; million-request consumers should iterate the
    /// stream directly instead.
    pub fn generate(&self) -> Vec<TraceEntry> {
        let mut out = Vec::with_capacity(self.n_requests);
        out.extend(self.stream());
        out
    }

    /// Mean offered load in tokens (prompt + gen) per second, from the
    /// generated entries — a quick sanity number for bench headers.
    pub fn offered_tokens_per_sec(entries: &[TraceEntry]) -> f64 {
        let Some(last) = entries.last() else { return 0.0 };
        let span = last.arrival.max(1e-9);
        let tokens: usize = entries.iter().map(|e| e.prompt_len + e.gen_len).sum();
        tokens as f64 / span
    }
}

/// Lazy trace generator: the iterator behind [`TraceSpec::stream`] /
/// [`TraceSpec::generate`]. Holds only the RNG state and the thinned
/// Poisson clock — O(1) memory regardless of `n_requests` — and
/// replays exactly the draw sequence the eager generator used to make,
/// so `spec.stream().collect::<Vec<_>>() == spec.generate()` bitwise.
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    spec: TraceSpec,
    rng: Rng,
    /// Thinning envelope: upper bound of the arrival-rate function.
    envelope: f64,
    /// Total mixture weight (class pick is by subtraction against it).
    total_w: f64,
    /// Current thinned-Poisson clock; non-decreasing across `next()`.
    t: f64,
    emitted: usize,
}

impl Iterator for ArrivalStream {
    type Item = TraceEntry;

    fn next(&mut self) -> Option<TraceEntry> {
        if self.emitted >= self.spec.n_requests {
            return None;
        }
        loop {
            self.t += self.rng.exponential(self.envelope);
            // Thinning: keep the candidate with prob rate(t)/envelope.
            if self.rng.f64() * self.envelope > self.spec.arrivals.rate_at(self.t) {
                continue;
            }
            // Pick a mixture class by weight.
            let mut pick = self.rng.f64() * self.total_w.max(1e-300);
            let mut class = 0;
            for (i, c) in self.spec.mixture.iter().enumerate() {
                pick -= c.weight.max(0.0);
                if pick <= 0.0 {
                    class = i;
                    break;
                }
            }
            let c = &self.spec.mixture[class];
            let (plo, phi) = c.prompt;
            let (plo, phi) = (plo.max(1), phi.max(plo.max(1)));
            let lp = (plo as f64).ln() + self.rng.f64() * ((phi as f64).ln() - (plo as f64).ln());
            let prompt_len = (lp.exp().round() as usize).clamp(plo, phi);
            let (glo, ghi) = c.gen;
            let gen_len = self.rng.range(glo.min(ghi), ghi.max(glo));
            self.emitted += 1;
            return Some(TraceEntry { arrival: self.t, prompt_len, gen_len, class });
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        // Exact: thinning always terminates (envelope > 0 is asserted at
        // construction), so every `next()` before exhaustion yields.
        let rem = self.spec.n_requests.saturating_sub(self.emitted);
        (rem, Some(rem))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shapes::BITNET_0_73B;

    #[test]
    fn prefill_attention_scales_quadratically() {
        let w1 = PrefillWork { shape: BITNET_0_73B, l: 256 };
        let w2 = PrefillWork { shape: BITNET_0_73B, l: 512 };
        let r = w2.attention().macs / w1.attention().macs;
        assert!((r - 4.0).abs() < 0.01, "ratio {r}");
        // Projections scale linearly.
        let rp = w2.projection().macs / w1.projection().macs;
        assert!((rp - 2.0).abs() < 0.01, "ratio {rp}");
    }

    #[test]
    fn decode_attention_scales_linearly() {
        let w1 = DecodeStepWork { shape: BITNET_0_73B, l: 512 };
        let w2 = DecodeStepWork { shape: BITNET_0_73B, l: 1024 };
        let r = w2.attention().read_bytes / w1.attention().read_bytes;
        assert!((r - 2.0).abs() < 0.01);
        // Projection cost is context-independent.
        assert_eq!(w1.projection().macs, w2.projection().macs);
    }

    #[test]
    fn asymmetry_prefill_compute_bound_decode_memory_bound() {
        // The paper's §2.1 asymmetry, in numbers: prefill attention AI is
        // orders of magnitude above decode attention AI.
        let pre = PrefillWork { shape: BITNET_0_73B, l: 1024 }.attention();
        let dec = DecodeStepWork { shape: BITNET_0_73B, l: 1024 }.attention();
        assert!(
            pre.arithmetic_intensity() > 50.0 * dec.arithmetic_intensity(),
            "prefill AI {:.2} vs decode AI {:.2}",
            pre.arithmetic_intensity(),
            dec.arithmetic_intensity()
        );
        // Decode attention is memory-dominated: < 1 MAC/byte.
        assert!(dec.arithmetic_intensity() < 1.0);
    }

    #[test]
    fn batched_decode_amortizes_weight_traffic() {
        // Projection AI grows ~linearly with the batch (shared weight
        // stream); attention AI is flat (per-stream KV).
        let b1 = BatchedDecodeWork { shape: BITNET_0_73B, l: 1024, batch: 1 };
        let b8 = BatchedDecodeWork { shape: BITNET_0_73B, l: 1024, batch: 8 };
        let r_proj =
            b8.projection().arithmetic_intensity() / b1.projection().arithmetic_intensity();
        assert!((7.5..8.05).contains(&r_proj), "proj AI ratio {r_proj:.2}");
        let r_attn =
            b8.attention().arithmetic_intensity() / b1.attention().arithmetic_intensity();
        assert!((r_attn - 1.0).abs() < 1e-9, "attn AI ratio {r_attn:.3}");
        // Batch-1 matches the single-stream accounting exactly.
        let one = DecodeStepWork { shape: BITNET_0_73B, l: 1024 };
        assert_eq!(b1.projection().macs, one.projection().macs);
        assert_eq!(b1.projection().read_bytes, one.projection().read_bytes);
        assert_eq!(b1.attention(), one.attention());
        assert_eq!(b1.norm_elementwise(), one.norm_elementwise());
    }

    #[test]
    fn decode_kv_read_matches_cache_size() {
        let w = DecodeStepWork { shape: BITNET_0_73B, l: 2048 };
        assert_eq!(w.attention().read_bytes, BITNET_0_73B.kv_bytes(2048));
    }

    #[test]
    fn totals_are_sums() {
        let w = PrefillWork { shape: BITNET_0_73B, l: 128 };
        let t = w.total();
        let s = w
            .projection()
            .add(&w.attention())
            .add(&w.norm_elementwise());
        assert_eq!(t, s);
    }

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let spec = TraceSpec::mixed_long_context(64, 0.1, 16 * 1024, 7);
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.gen_len, y.gen_len);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn mixture_respects_class_ranges() {
        let spec = TraceSpec::mixed_long_context(256, 0.5, 16 * 1024, 3);
        let entries = spec.generate();
        let mut long_seen = 0;
        for e in &entries {
            let c = &spec.mixture[e.class];
            assert!((c.prompt.0..=c.prompt.1).contains(&e.prompt_len), "prompt {e:?}");
            assert!((c.gen.0..=c.gen.1).contains(&e.gen_len), "gen {e:?}");
            if e.class == 1 {
                long_seen += 1;
                assert!(e.prompt_len >= (16 * 1024 - 256) / 2);
            }
        }
        // ~25% weight: both classes must actually appear.
        assert!(long_seen > 16 && long_seen < 128, "long class count {long_seen}");
    }

    #[test]
    fn poisson_rate_roughly_holds() {
        let spec = TraceSpec::interactive(400, 2.0, 11);
        let entries = spec.generate();
        let span = entries.last().unwrap().arrival;
        let rate = entries.len() as f64 / span;
        assert!((1.6..2.4).contains(&rate), "empirical rate {rate:.2}");
    }

    #[test]
    fn bursty_trace_clusters_arrivals() {
        let spec = TraceSpec::bursty(200, 5);
        let entries = spec.generate();
        // Inter-arrival CV² well above 1 distinguishes the on/off process
        // from plain Poisson (CV² ≈ 1).
        let gaps: Vec<f64> =
            entries.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var =
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv2 = var / (mean * mean);
        assert!(cv2 > 1.5, "cv² {cv2:.2} — arrivals not bursty");
        assert!(TraceSpec::offered_tokens_per_sec(&entries) > 0.0);
    }

    #[test]
    fn workload_stream_matches_generate_bitwise() {
        // One spec per arrival pattern + mixture shape; the stream must
        // replay the eager generator's exact draw sequence.
        let specs = [
            TraceSpec::interactive(96, 0.5, 41),
            TraceSpec::mixed_long_context(64, 0.1, 16 * 1024, 42),
            TraceSpec::long_decode(24, 43),
            TraceSpec::bursty(128, 44),
            TraceSpec::million(200, 45),
        ];
        for spec in &specs {
            let eager = spec.generate();
            let lazy: Vec<TraceEntry> = spec.stream().collect();
            assert_eq!(eager.len(), lazy.len());
            for (a, b) in eager.iter().zip(&lazy) {
                assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
                assert_eq!(a.prompt_len, b.prompt_len);
                assert_eq!(a.gen_len, b.gen_len);
                assert_eq!(a.class, b.class);
            }
        }
    }

    #[test]
    fn stream_is_resumable_mid_iteration() {
        // Cloning the stream freezes its RNG + clock state: the clone and
        // the original must produce identical suffixes.
        let spec = TraceSpec::million(50, 9);
        let mut s = spec.stream();
        for _ in 0..20 {
            s.next().unwrap();
        }
        let mut fork = s.clone();
        for _ in 0..30 {
            let a = s.next().unwrap();
            let b = fork.next().unwrap();
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.gen_len, b.gen_len);
        }
        assert!(s.next().is_none());
        assert_eq!(s.size_hint(), (0, Some(0)));
    }

    #[test]
    fn million_preset_is_decode_heavy_and_underloaded() {
        let spec = TraceSpec::million(300, 17);
        let entries = spec.generate();
        assert_eq!(entries.len(), 300);
        for w in entries.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let gen_tokens: usize = entries.iter().map(|e| e.gen_len).sum();
        let prompt_tokens: usize = entries.iter().map(|e| e.prompt_len).sum();
        // Decode-heavy: generated tokens dominate prompt tokens.
        assert!(gen_tokens > 4 * prompt_tokens, "{gen_tokens} vs {prompt_tokens}");
        // Underloaded: mean inter-arrival gap (≈250 s at rate 0.004) far
        // exceeds any plausible per-request service time, so the backlog
        // stays bounded and the O(resident) memory claim holds.
        let span = entries.last().unwrap().arrival;
        let mean_gap = span / entries.len() as f64;
        assert!(mean_gap > 100.0, "mean gap {mean_gap:.1}s");
    }
}
