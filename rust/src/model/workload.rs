//! Per-phase op and byte accounting (the numerators of Eqs. 3 and 5).
//!
//! Counts MAC operations and DDR bytes for each pipeline component so the
//! engine latency models and the roofline analysis share one source of
//! truth. Conventions:
//!
//! * a MAC = one multiply-accumulate (2 FLOPs in GPU-marketing units);
//! * weights: ternary linears stream packed codes from DDR (they do NOT
//!   fit in URAM at 0.73B scale — URAM holds the working set / LUT tables);
//! * KV cache: fp16 in DDR, read in full every decode step, written one
//!   token per step.

use super::shapes::ModelShape;

/// Ops/bytes of one logical component of a phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComponentOps {
    /// Multiply-accumulate count.
    pub macs: f64,
    /// DDR read bytes.
    pub read_bytes: f64,
    /// DDR write bytes.
    pub write_bytes: f64,
}

impl ComponentOps {
    pub const ZERO: ComponentOps = ComponentOps { macs: 0.0, read_bytes: 0.0, write_bytes: 0.0 };

    pub fn total_bytes(&self) -> f64 {
        self.read_bytes + self.write_bytes
    }

    /// Arithmetic intensity in MACs/byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.macs / self.total_bytes().max(1.0)
    }

    pub fn add(&self, o: &ComponentOps) -> ComponentOps {
        ComponentOps {
            macs: self.macs + o.macs,
            read_bytes: self.read_bytes + o.read_bytes,
            write_bytes: self.write_bytes + o.write_bytes,
        }
    }
}

/// Common interface for the two phases.
pub trait PhaseWork {
    fn projection(&self) -> ComponentOps;
    fn attention(&self) -> ComponentOps;
    fn norm_elementwise(&self) -> ComponentOps;
    fn total(&self) -> ComponentOps {
        self.projection()
            .add(&self.attention())
            .add(&self.norm_elementwise())
    }
}

/// Prefill of `l` prompt tokens (whole model).
#[derive(Debug, Clone, Copy)]
pub struct PrefillWork {
    pub shape: ModelShape,
    pub l: usize,
}

impl PhaseWork for PrefillWork {
    /// All ternary linears over L tokens: QKVO (4·d²) + SwiGLU (3·d·dff)
    /// per layer per token. Reads: packed weights once per *phase* (tiles
    /// are reused across all L tokens — the paper's "batch of GEMVs"
    /// orchestration) + int8 activations.
    fn projection(&self) -> ComponentOps {
        let s = &self.shape;
        let per_token =
            (4 * s.d_model * s.d_model + 3 * s.d_model * s.d_ff) as f64;
        let macs = per_token * self.l as f64 * s.n_layers as f64;
        let weight_reads = s.ternary_weight_bytes();
        let act_bytes =
            (self.l * s.d_model) as f64 * s.n_layers as f64 * 7.0; // 7 tensors/layer
        ComponentOps {
            macs,
            read_bytes: weight_reads + act_bytes,
            write_bytes: act_bytes,
        }
    }

    /// FlashAttention: QK^T (L²·d/2 causal) + PV (same) per layer, fp16
    /// streams; causal halves the score matrix.
    fn attention(&self) -> ComponentOps {
        let s = &self.shape;
        let l = self.l as f64;
        let macs = s.n_layers as f64 * (l * l / 2.0) * s.d_model as f64 * 2.0;
        let qkv_bytes = 3.0 * l * s.d_model as f64 * 2.0 * s.n_layers as f64;
        let out_bytes = l * s.d_model as f64 * 2.0 * s.n_layers as f64;
        // KV cache write-out for the decode phase.
        let kv_write = s.kv_bytes(self.l);
        ComponentOps {
            macs,
            read_bytes: qkv_bytes,
            write_bytes: out_bytes + kv_write,
        }
    }

    /// RMSNorm + RoPE + SwiGLU activation + residuals: ~10 ops/element.
    fn norm_elementwise(&self) -> ComponentOps {
        let s = &self.shape;
        let elems = (self.l * s.d_model * s.n_layers) as f64;
        ComponentOps { macs: elems * 10.0, read_bytes: 0.0, write_bytes: 0.0 }
    }
}

/// One decode step at context length `l` (the new token attends 0..l-1).
#[derive(Debug, Clone, Copy)]
pub struct DecodeStepWork {
    pub shape: ModelShape,
    pub l: usize,
}

impl PhaseWork for DecodeStepWork {
    /// Single-token GEMVs; the whole packed weight set streams from DDR
    /// every step (nothing amortizes it at batch 1 — this is T_weights,
    /// the decode floor).
    fn projection(&self) -> ComponentOps {
        let s = &self.shape;
        let macs = ((4 * s.d_model * s.d_model + 3 * s.d_model * s.d_ff)
            * s.n_layers) as f64;
        ComponentOps {
            macs,
            read_bytes: s.ternary_weight_bytes(),
            write_bytes: (s.d_model * s.n_layers) as f64,
        }
    }

    /// q·K^T -> softmax -> ·V over the cached context: 2·L·d MACs/layer,
    /// and — the decode bottleneck — the entire fp16 KV cache read.
    fn attention(&self) -> ComponentOps {
        let s = &self.shape;
        let macs = 2.0 * (self.l * s.d_model) as f64 * s.n_layers as f64;
        ComponentOps {
            macs,
            read_bytes: s.kv_bytes(self.l),
            write_bytes: s.kv_bytes_per_token(), // this token's K/V append
        }
    }

    fn norm_elementwise(&self) -> ComponentOps {
        let s = &self.shape;
        let elems = (s.d_model * s.n_layers) as f64;
        ComponentOps { macs: elems * 10.0, read_bytes: 0.0, write_bytes: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shapes::BITNET_0_73B;

    #[test]
    fn prefill_attention_scales_quadratically() {
        let w1 = PrefillWork { shape: BITNET_0_73B, l: 256 };
        let w2 = PrefillWork { shape: BITNET_0_73B, l: 512 };
        let r = w2.attention().macs / w1.attention().macs;
        assert!((r - 4.0).abs() < 0.01, "ratio {r}");
        // Projections scale linearly.
        let rp = w2.projection().macs / w1.projection().macs;
        assert!((rp - 2.0).abs() < 0.01, "ratio {rp}");
    }

    #[test]
    fn decode_attention_scales_linearly() {
        let w1 = DecodeStepWork { shape: BITNET_0_73B, l: 512 };
        let w2 = DecodeStepWork { shape: BITNET_0_73B, l: 1024 };
        let r = w2.attention().read_bytes / w1.attention().read_bytes;
        assert!((r - 2.0).abs() < 0.01);
        // Projection cost is context-independent.
        assert_eq!(w1.projection().macs, w2.projection().macs);
    }

    #[test]
    fn asymmetry_prefill_compute_bound_decode_memory_bound() {
        // The paper's §2.1 asymmetry, in numbers: prefill attention AI is
        // orders of magnitude above decode attention AI.
        let pre = PrefillWork { shape: BITNET_0_73B, l: 1024 }.attention();
        let dec = DecodeStepWork { shape: BITNET_0_73B, l: 1024 }.attention();
        assert!(
            pre.arithmetic_intensity() > 50.0 * dec.arithmetic_intensity(),
            "prefill AI {:.2} vs decode AI {:.2}",
            pre.arithmetic_intensity(),
            dec.arithmetic_intensity()
        );
        // Decode attention is memory-dominated: < 1 MAC/byte.
        assert!(dec.arithmetic_intensity() < 1.0);
    }

    #[test]
    fn decode_kv_read_matches_cache_size() {
        let w = DecodeStepWork { shape: BITNET_0_73B, l: 2048 };
        assert_eq!(w.attention().read_bytes, BITNET_0_73B.kv_bytes(2048));
    }

    #[test]
    fn totals_are_sums() {
        let w = PrefillWork { shape: BITNET_0_73B, l: 128 };
        let t = w.total();
        let s = w
            .projection()
            .add(&w.attention())
            .add(&w.norm_elementwise());
        assert_eq!(t, s);
    }
}
