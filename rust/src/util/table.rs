//! Fixed-width table rendering for the eval harnesses (Table 1, Table 2,
//! and the figure series are all printed as aligned text tables).

/// A simple text table with a header row and alignment-aware columns.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    /// Right-align numeric-looking cells.
    right: Vec<bool>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        let header: Vec<String> = header.into_iter().map(Into::into).collect();
        let right = vec![false; header.len()];
        Self { header, rows: Vec::new(), right }
    }

    /// Mark columns (by index) as right-aligned.
    pub fn right_align(mut self, cols: &[usize]) -> Self {
        for &c in cols {
            if c < self.right.len() {
                self.right[c] = true;
            }
        }
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column separators and a rule under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                if self.right[i] {
                    s.push_str(&format!(" {}{} |", " ".repeat(pad), c));
                } else {
                    s.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
                }
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with engineering-friendly precision (3 significant-ish
/// places, trailing-zero trimmed).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    let s = if a >= 100.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.1}")
    } else if a >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    };
    s
}

/// Format seconds with an adaptive unit.
pub fn ftime(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{:.2} s", seconds)
    } else if seconds >= 1e-3 {
        format!("{:.2} ms", seconds * 1e3)
    } else {
        format!("{:.1} µs", seconds * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "val"]).right_align(&[1]);
        t.row(vec!["a", "1.5"]);
        t.row(vec!["longer", "10"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
        assert!(lines[2].contains("| a      |"));
        assert!(lines[3].contains("|  10 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn num_formats() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.0), "1234");
        assert_eq!(fnum(27.83), "27.8");
        assert_eq!(fnum(5.666), "5.67");
        assert_eq!(fnum(0.123456), "0.123");
        assert_eq!(ftime(2.5), "2.50 s");
        assert_eq!(ftime(0.045), "45.00 ms");
        assert_eq!(ftime(31e-6), "31.0 µs");
    }
}
