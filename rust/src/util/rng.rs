//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Used for sampling, synthetic workload generation, and the property-test
//! driver. Deterministic across platforms (pure integer arithmetic), which
//! keeps the simulator runs and test failures reproducible.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n) (n > 0). Lemire-style rejection-free enough
    /// for our non-cryptographic uses.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times for the serving
    /// workload generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Choose one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let lambda = 4.0;
        let mean = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..32).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
    }
}
