//! Deterministic data parallelism on `std::thread::scope` (rayon is
//! unavailable offline).
//!
//! [`par_map`] splits the input into at most `threads` contiguous chunks,
//! evaluates each chunk on its own scoped thread, and joins the results
//! back **in chunk order** — so the output `Vec` is always index-aligned
//! with the input, regardless of which worker finished first. Any
//! reduction the caller runs over that output in index order is therefore
//! bit-identical to the serial evaluation, which is what the DSE's
//! determinism contract (`explore_threads(cfg, 1) == explore_threads(cfg,
//! n)`) rests on.

use std::num::NonZeroUsize;

/// Threads to use by default: physical parallelism, capped so sweeps stay
/// polite on shared machines.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
        .min(8)
}

/// Map `f` over `items` on up to `threads` scoped threads, preserving
/// input order. `threads <= 1` (or a small input) degenerates to a plain
/// serial map with no thread spawned.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads == 1 {
        return items.iter().map(&f).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|slice| scope.spawn(move || slice.iter().map(f).collect::<Vec<R>>()))
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_matches_serial() {
        let items: Vec<u64> = (0..1000).collect();
        let serial: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for threads in [1, 2, 3, 7, 16] {
            let par = par_map(&items, threads, |x| x * x + 1);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn handles_degenerate_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(&empty, 4, |x| *x).is_empty());
        assert_eq!(par_map(&[42u32], 8, |x| x + 1), vec![43]);
    }

    #[test]
    fn default_threads_is_sane() {
        let t = default_threads();
        assert!((1..=8).contains(&t));
    }
}
