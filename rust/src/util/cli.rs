//! Tiny flag parser for the `pd-swap` binary and examples (clap is
//! unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a collected usage/error report.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.bools.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own args.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// Comma-separated list of usizes (e.g. `--lengths 64,128,256`).
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key}: bad entry '{s}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        // NB: a bare `--flag` followed by a non-flag token would consume it
        // as a value (the parser has no boolean schema), so boolean flags
        // go last or use `--flag=...` — documented parser behaviour.
        let a = parse("serve extra --model tiny --steps=32 --verbose");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.get_usize("steps", 0), 32);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("model", "test"), "test");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("rate", 0.5), 0.5);
    }

    #[test]
    fn lists() {
        let a = parse("--lengths 64,128, 256");
        // note: spaces split args; only the first token is the value
        assert_eq!(a.get_usize_list("lengths", &[]), vec![64, 128]);
        let b = parse("--lengths 64,128,256");
        assert_eq!(b.get_usize_list("lengths", &[1]), vec![64, 128, 256]);
        let c = parse("");
        assert_eq!(c.get_usize_list("lengths", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn boolean_at_end() {
        let a = parse("--check");
        assert!(a.flag("check"));
    }
}
