//! In-crate substrates for what would normally come from crates.io.
//!
//! The reproduction environment is fully offline with a minimal registry
//! (only the `xla` PJRT bindings and `anyhow`/`thiserror` resolve), so the
//! support libraries are built here, each small, documented, and tested:
//!
//! * [`json`] — recursive-descent JSON parser + serializer (manifests,
//!   golden traces, eval outputs).
//! * [`rng`] — deterministic SplitMix64/xoshiro256** PRNG (sampling,
//!   workload generation, property tests).
//! * [`bench`] — measurement harness used by `benches/*` (warmup, repeats,
//!   percentile stats, table printing).
//! * [`prop`] — a miniature property-testing driver (random cases +
//!   shrinking-lite) used for the coordinator/DSE invariants.
//! * [`cli`] — flag parsing for the `pd-swap` binary and examples.
//! * [`table`] — fixed-width table rendering shared by eval harnesses.
//! * [`par`] — deterministic chunked parallel map on scoped threads
//!   (rayon replacement for the DSE and codesign sweeps).

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod table;
