//! Measurement harness for `benches/*` (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with percentile statistics, plus the
//! throughput bookkeeping the paper-table benches need. Deliberately
//! simple: monotonic clock, no outlier rejection beyond percentiles.

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    /// items/s given `items` of work per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms mean  {:>10.3} ms p50  {:>10.3} ms p95  ({} iters)",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.p50.as_secs_f64() * 1e3,
            self.p95.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Bench runner: time `f` with `warmup` unmeasured then `iters` measured
/// calls.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    from_samples(name, samples)
}

/// Adaptive runner: keeps iterating until `budget` elapses (at least 3
/// iterations), suited for calls whose cost is unknown up front.
pub fn run_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Stats {
    // One warmup call.
    f();
    let start = Instant::now();
    let mut samples = Vec::new();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    from_samples(name, samples)
}

fn from_samples(name: &str, mut samples: Vec<Duration>) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let pct = |p: f64| samples[((iters as f64 * p) as usize).min(iters - 1)];
    Stats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: pct(0.50),
        p95: pct(0.95),
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// Section header used by the bench binaries so their output reads like
/// the paper's tables.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Schema version of the [`envelope`] wrapper around every JSON report
/// this crate writes (`BENCH_*.json`, `codesign --out`). Bump when the
/// envelope's own layout changes, not when a report body does.
pub const REPORT_SCHEMA_VERSION: u64 = 1;

/// Best-effort git revision of the working tree, read directly from
/// `.git/` (no subprocess): `HEAD` is followed through one `ref: `
/// indirection, falling back to `packed-refs`. `None` outside a git
/// checkout — reports stay writable anywhere.
pub fn git_rev() -> Option<String> {
    let head = std::fs::read_to_string(".git/HEAD").ok()?;
    let head = head.trim();
    let Some(r) = head.strip_prefix("ref: ") else {
        // Detached HEAD: the file holds the commit hash itself.
        return Some(head.to_string()).filter(|s| !s.is_empty());
    };
    let r = r.trim();
    if let Ok(direct) = std::fs::read_to_string(format!(".git/{r}")) {
        let direct = direct.trim();
        if !direct.is_empty() {
            return Some(direct.to_string());
        }
    }
    let packed = std::fs::read_to_string(".git/packed-refs").ok()?;
    packed
        .lines()
        .filter(|l| !l.starts_with('#') && !l.starts_with('^'))
        .find_map(|l| match l.split_once(' ') {
            Some((hash, name)) if name.trim() == r => Some(hash.to_string()),
            _ => None,
        })
}

/// FNV-1a (64-bit) over the body's compact serialization — the
/// envelope's content fingerprint. Dependency-free and stable across
/// platforms (the serializer is deterministic).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Host fingerprint for report comparability: OS, architecture, and
/// logical CPU count. Throughput numbers (requests/s, events/s) are only
/// meaningful against a baseline from comparable hardware — the
/// fingerprint lets the trending tooling flag cross-host comparisons
/// instead of silently mixing them.
pub fn host_fingerprint() -> crate::util::json::Value {
    use crate::util::json::Value;
    let cpus = std::thread::available_parallelism().map_or(0, |n| n.get());
    Value::Obj(vec![
        ("os".into(), Value::Str(std::env::consts::OS.into())),
        ("arch".into(), Value::Str(std::env::consts::ARCH.into())),
        ("cpus".into(), Value::Num(cpus as f64)),
    ])
}

/// Wrap a report body in the versioned envelope (first slice of the
/// ROADMAP's artifact-trending item): schema version, git revision when
/// available, host fingerprint, and a content hash of the body.
/// Consumers that predate the envelope unwrap via [`report_body`], which
/// also passes legacy documents (including pre-`host` envelopes)
/// through untouched — the extra field is additive.
pub fn envelope(body: &crate::util::json::Value) -> crate::util::json::Value {
    use crate::util::json::Value;
    Value::Obj(vec![
        ("schema_version".into(), Value::Num(REPORT_SCHEMA_VERSION as f64)),
        (
            "git_rev".into(),
            git_rev().map(Value::Str).unwrap_or(Value::Null),
        ),
        ("host".into(), host_fingerprint()),
        (
            "config_hash".into(),
            Value::Str(format!("{:016x}", fnv1a(body.to_string().as_bytes()))),
        ),
        ("report".into(), body.clone()),
    ])
}

/// The report body of a parsed document: unwraps the [`envelope`] when
/// one is present (`schema_version` marks it), passes legacy documents
/// through unchanged — so `bench_check` and `codesign_diff` accept both.
pub fn report_body(v: &crate::util::json::Value) -> &crate::util::json::Value {
    if v.get("schema_version").is_some() {
        v.get("report").unwrap_or(v)
    } else {
        v
    }
}

/// Write a machine-readable bench summary (the `BENCH_*.json` convention:
/// one pretty-printed JSON document per bench binary, parsed by the
/// regression tooling), wrapped in the versioned [`envelope`]. Returns
/// the path for the caller's report line.
pub fn write_json_report<'p>(
    path: &'p str,
    v: &crate::util::json::Value,
) -> std::io::Result<&'p str> {
    std::fs::write(path, envelope(v).to_pretty())?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Bench-regression gating (`benches/baselines/*.json` vs live reports)
// ---------------------------------------------------------------------------

use crate::util::json::Value;

/// One regression gate from a committed baseline file: a dotted path
/// into the live `BENCH_*.json` report, the expected value, and the
/// direction in which deviation counts as a regression.
#[derive(Debug, Clone)]
pub struct Gate {
    /// Dotted path; numeric segments index arrays (`contexts.0.speedup`).
    pub path: String,
    pub value: f64,
    /// `true`: regression when current < value×(1−tol). `false` (a
    /// latency-style metric): regression when current > value×(1+tol).
    pub higher_is_better: bool,
    pub tolerance: f64,
    /// Advisory gates are reported but never fail the comparison — used
    /// for estimated baselines awaiting a `--bless` calibration run.
    pub advisory: bool,
}

/// Verdict on one gate.
#[derive(Debug, Clone)]
pub struct GateResult {
    pub gate: Gate,
    /// Value found in the live report (`None`: path missing).
    pub current: Option<f64>,
    pub regressed: bool,
}

/// The full comparison verdict for one report.
#[derive(Debug, Clone, Default)]
pub struct Comparison {
    pub results: Vec<GateResult>,
}

impl Comparison {
    /// Gates that regressed and are not advisory — these fail the build.
    pub fn failures(&self) -> Vec<&GateResult> {
        self.results
            .iter()
            .filter(|r| r.regressed && !r.gate.advisory)
            .collect()
    }

    pub fn ok(&self) -> bool {
        self.failures().is_empty()
    }
}

/// Resolve a dotted path (`policies.eager.swaps`, `contexts.1.speedup`)
/// in a JSON report; numeric segments index arrays.
pub fn lookup_path<'v>(v: &'v Value, path: &str) -> Option<&'v Value> {
    let mut cur = v;
    for seg in path.split('.') {
        cur = match seg.parse::<usize>() {
            Ok(i) => cur.as_arr()?.get(i)?,
            Err(_) => cur.get(seg)?,
        };
    }
    Some(cur)
}

/// Parse the `gates` array of a baseline document. Malformed entries are
/// skipped (the baseline is hand-maintained; a typo should not panic the
/// gate runner — `bench_check` reports the parsed-gate count instead).
pub fn parse_gates(baseline: &Value) -> Vec<Gate> {
    let default_tol = baseline
        .get("tolerance")
        .and_then(Value::as_f64)
        .unwrap_or(0.10);
    let Some(gates) = baseline.get("gates").and_then(Value::as_arr) else {
        return Vec::new();
    };
    gates
        .iter()
        .filter_map(|g| {
            Some(Gate {
                path: g.get("path")?.as_str()?.to_string(),
                value: g.get("value")?.as_f64()?,
                higher_is_better: g
                    .get("higher_is_better")
                    .and_then(Value::as_bool)
                    .unwrap_or(true),
                tolerance: g.get("tolerance").and_then(Value::as_f64).unwrap_or(default_tol),
                advisory: g.get("advisory").and_then(Value::as_bool).unwrap_or(false),
            })
        })
        .collect()
}

/// Compare a live `BENCH_*.json` report against its committed baseline:
/// every gate whose current value falls outside `value × (1 ∓ tolerance)`
/// in the regression direction (or whose path vanished from the report)
/// is flagged. The CI `bench-smoke` job fails on any non-advisory flag.
pub fn compare_reports(baseline: &Value, current: &Value) -> Comparison {
    let mut results = Vec::new();
    for gate in parse_gates(baseline) {
        let cur = lookup_path(current, &gate.path).and_then(Value::as_f64);
        let regressed = match cur {
            None => true, // the metric disappeared: that IS a regression
            Some(c) => {
                if gate.higher_is_better {
                    c < gate.value * (1.0 - gate.tolerance)
                } else {
                    c > gate.value * (1.0 + gate.tolerance)
                }
            }
        };
        results.push(GateResult { gate, current: cur, regressed });
    }
    Comparison { results }
}

/// `--bless` support: rewrite each gate's expected `value` from the
/// current report and clear its `advisory` marker. Run on a machine with
/// a toolchain after intentional performance changes, then commit the
/// updated baseline.
pub fn bless_baseline(baseline: &Value, current: &Value) -> Value {
    let Value::Obj(pairs) = baseline else {
        return baseline.clone();
    };
    let pairs = pairs
        .iter()
        .map(|(k, v)| {
            if k != "gates" {
                return (k.clone(), v.clone());
            }
            let Some(gates) = v.as_arr() else {
                return (k.clone(), v.clone());
            };
            let blessed: Vec<Value> = gates
                .iter()
                .map(|g| {
                    let Value::Obj(gp) = g else { return g.clone() };
                    let measured = g
                        .get("path")
                        .and_then(Value::as_str)
                        .and_then(|p| lookup_path(current, p))
                        .and_then(Value::as_f64);
                    let gp = gp
                        .iter()
                        .filter(|(gk, _)| gk != "advisory" && gk != "_note")
                        .map(|(gk, gv)| {
                            if gk == "value" {
                                if let Some(m) = measured {
                                    return (gk.clone(), Value::Num(m));
                                }
                            }
                            (gk.clone(), gv.clone())
                        })
                        .collect();
                    Value::Obj(gp)
                })
                .collect();
            (k.clone(), Value::Arr(blessed))
        })
        .collect();
    Value::Obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = run("noop", 2, 50, || {
            std::hint::black_box(3u64.wrapping_mul(7));
        });
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn run_for_hits_minimum() {
        let s = run_for("sleepless", Duration::from_millis(1), || {});
        assert!(s.iters >= 3);
    }

    #[test]
    fn json_report_round_trips() {
        use crate::util::json::{self, Value};
        let v = Value::Obj(vec![
            ("bench".into(), Value::Str("unit".into())),
            ("x".into(), Value::Num(1.5)),
        ]);
        let path = std::env::temp_dir().join("pd_swap_bench_report_test.json");
        let path_s = path.to_str().unwrap();
        write_json_report(path_s, &v).unwrap();
        let back = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // The written document is enveloped; the body round-trips through
        // report_body.
        assert_eq!(back.get("schema_version").unwrap().as_f64(), Some(1.0));
        assert!(back.get("config_hash").unwrap().as_str().is_some());
        let body = report_body(&back);
        assert_eq!(body.get("x").unwrap().as_f64(), Some(1.5));
        assert_eq!(body.get("bench").unwrap().as_str(), Some("unit"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn envelope_hashes_content_and_unwraps_both_formats() {
        use crate::util::json::Value;
        let a = Value::Obj(vec![("x".into(), Value::Num(1.0))]);
        let b = Value::Obj(vec![("x".into(), Value::Num(2.0))]);
        let ea = envelope(&a);
        let eb = envelope(&b);
        // Same body => same fingerprint; different body => different.
        assert_eq!(
            ea.get("config_hash").unwrap().as_str(),
            envelope(&a).get("config_hash").unwrap().as_str()
        );
        assert_ne!(
            ea.get("config_hash").unwrap().as_str(),
            eb.get("config_hash").unwrap().as_str()
        );
        // Enveloped documents unwrap to the body; legacy ones pass
        // through untouched.
        assert_eq!(report_body(&ea).get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(report_body(&a).get("x").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn envelope_carries_the_host_fingerprint() {
        use crate::util::json::Value;
        let e = envelope(&Value::Obj(vec![("x".into(), Value::Num(1.0))]));
        let host = e.get("host").expect("envelope must carry host");
        assert_eq!(host.get("os").unwrap().as_str(), Some(std::env::consts::OS));
        assert_eq!(host.get("arch").unwrap().as_str(), Some(std::env::consts::ARCH));
        assert!(host.get("cpus").unwrap().as_f64().unwrap() >= 0.0);
        // Legacy documents — and pre-`host` envelopes — still unwrap:
        // report_body keys on schema_version alone.
        let pre_host = Value::Obj(vec![
            ("schema_version".into(), Value::Num(1.0)),
            ("report".into(), Value::Obj(vec![("y".into(), Value::Num(3.0))])),
        ]);
        assert_eq!(report_body(&pre_host).get("y").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn compare_reports_gates_and_blesses() {
        use crate::util::json;
        let baseline = json::parse(
            r#"{
              "tolerance": 0.10,
              "gates": [
                {"path": "a.tokens_per_sec", "value": 100.0},
                {"path": "rows.1.speedup", "value": 1.0, "tolerance": 0.0},
                {"path": "lat.p95_s", "value": 2.0, "higher_is_better": false},
                {"path": "a.estimated", "value": 5.0, "advisory": true},
                {"path": "gone.metric", "value": 1.0}
              ]
            }"#,
        )
        .unwrap();
        let current = json::parse(
            r#"{
              "a": {"tokens_per_sec": 95.0, "estimated": 1.0},
              "rows": [{"speedup": 0.5}, {"speedup": 1.001}],
              "lat": {"p95_s": 2.5}
            }"#,
        )
        .unwrap();
        let cmp = compare_reports(&baseline, &current);
        assert_eq!(cmp.results.len(), 5);
        // 95 ≥ 100×0.9: fine. speedup 1.001 ≥ 1.0: fine. p95 2.5 > 2.2:
        // regression. advisory regressed but doesn't fail. missing path
        // regresses.
        let failed: Vec<&str> =
            cmp.failures().iter().map(|r| r.gate.path.as_str()).collect();
        assert_eq!(failed, vec!["lat.p95_s", "gone.metric"]);
        assert!(!cmp.ok());
        let advisory = &cmp.results[3];
        assert!(advisory.regressed && advisory.gate.advisory);

        // Blessing rewrites values from the live report and clears the
        // advisory marker; unmatched paths keep their old value.
        let blessed = bless_baseline(&baseline, &current);
        let gates = parse_gates(&blessed);
        assert_eq!(gates[0].value, 95.0);
        assert_eq!(gates[2].value, 2.5);
        assert_eq!(gates[3].value, 1.0);
        assert!(!gates[3].advisory, "bless clears advisory");
        assert_eq!(gates[4].value, 1.0, "missing path keeps old value");
        let cmp2 = compare_reports(&blessed, &current);
        assert_eq!(cmp2.failures().len(), 1, "only the vanished metric still fails");
    }

    #[test]
    fn lookup_path_walks_objects_and_arrays() {
        use crate::util::json;
        let v = json::parse(r#"{"a": [10, {"b": {"c": 42}}]}"#).unwrap();
        assert_eq!(lookup_path(&v, "a.0").unwrap().as_f64(), Some(10.0));
        assert_eq!(lookup_path(&v, "a.1.b.c").unwrap().as_f64(), Some(42.0));
        assert!(lookup_path(&v, "a.2").is_none());
        assert!(lookup_path(&v, "a.1.x").is_none());
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(100),
            p50: Duration::from_millis(100),
            p95: Duration::from_millis(100),
            min: Duration::from_millis(100),
            max: Duration::from_millis(100),
        };
        assert!((s.throughput(10.0) - 100.0).abs() < 1e-9);
    }
}
