//! Measurement harness for `benches/*` (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with percentile statistics, plus the
//! throughput bookkeeping the paper-table benches need. Deliberately
//! simple: monotonic clock, no outlier rejection beyond percentiles.

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    /// items/s given `items` of work per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms mean  {:>10.3} ms p50  {:>10.3} ms p95  ({} iters)",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.p50.as_secs_f64() * 1e3,
            self.p95.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Bench runner: time `f` with `warmup` unmeasured then `iters` measured
/// calls.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    from_samples(name, samples)
}

/// Adaptive runner: keeps iterating until `budget` elapses (at least 3
/// iterations), suited for calls whose cost is unknown up front.
pub fn run_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Stats {
    // One warmup call.
    f();
    let start = Instant::now();
    let mut samples = Vec::new();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    from_samples(name, samples)
}

fn from_samples(name: &str, mut samples: Vec<Duration>) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let pct = |p: f64| samples[((iters as f64 * p) as usize).min(iters - 1)];
    Stats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: pct(0.50),
        p95: pct(0.95),
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// Section header used by the bench binaries so their output reads like
/// the paper's tables.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write a machine-readable bench summary (the `BENCH_*.json` convention:
/// one pretty-printed JSON document per bench binary, parsed by the
/// regression tooling). Returns the path for the caller's report line.
pub fn write_json_report<'p>(
    path: &'p str,
    v: &crate::util::json::Value,
) -> std::io::Result<&'p str> {
    std::fs::write(path, v.to_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = run("noop", 2, 50, || {
            std::hint::black_box(3u64.wrapping_mul(7));
        });
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn run_for_hits_minimum() {
        let s = run_for("sleepless", Duration::from_millis(1), || {});
        assert!(s.iters >= 3);
    }

    #[test]
    fn json_report_round_trips() {
        use crate::util::json::{self, Value};
        let v = Value::Obj(vec![
            ("bench".into(), Value::Str("unit".into())),
            ("x".into(), Value::Num(1.5)),
        ]);
        let path = std::env::temp_dir().join("pd_swap_bench_report_test.json");
        let path_s = path.to_str().unwrap();
        write_json_report(path_s, &v).unwrap();
        let back = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("x").unwrap().as_f64(), Some(1.5));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(100),
            p50: Duration::from_millis(100),
            p95: Duration::from_millis(100),
            min: Duration::from_millis(100),
            max: Duration::from_millis(100),
        };
        assert!((s.throughput(10.0) - 100.0).abs() < 1e-9);
    }
}
