//! Minimal JSON: recursive-descent parser + serializer.
//!
//! Covers the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes (incl. `\uXXXX` and surrogate pairs), numbers with exponents,
//! literals. Object key order is preserved (the manifest contract depends
//! on `weight_order` staying ordered). No external dependencies.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object (pairs; lookup is linear, objects are small).
    Obj(Vec<(String, Value)>),
}

impl Value {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing ergonomics).
    pub fn req(&self, key: &str) -> Result<&Value, Error> {
        self.get(key).ok_or_else(|| Error::MissingKey(key.to_string()))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Typed vector extraction helpers.
    pub fn to_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn to_i32_vec(&self) -> Option<Vec<i32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_i64().map(|x| x as i32))
            .collect()
    }

    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    // -- constructors ------------------------------------------------------

    pub fn from_pairs(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    // -- serialization -----------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, padc) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * (depth + 1)),
                " ".repeat(w * depth),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push(']');
            }
            Value::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("missing key: {0}")]
    MissingKey(String),
    #[error("type mismatch for key {0}")]
    Type(String),
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Value, Error> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse from raw bytes (UTF-8 checked).
pub fn parse_bytes(input: &[u8]) -> Result<Value, Error> {
    let s = std::str::from_utf8(input)
        .map_err(|e| Error::Parse(e.valid_up_to(), "invalid utf-8".into()))?;
    parse(s)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(self.pos, msg.to_string())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), Error> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, Error> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, Error> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value, Error> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, Error> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, Error> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let cp =
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(cp)
                        } else {
                            char::from_u32(hi)
                        };
                        out.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble multi-byte UTF-8 (we already validated).
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        self.pos = start + width;
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, Error> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("short \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, Error> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| Error::Parse(start, format!("bad number '{s}'")))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Ordered map alias used by a few eval harnesses.
pub type Map = BTreeMap<String, Value>;

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\n\"y\""}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\n\"y\"");
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap().as_str().unwrap(), "é");
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
        // raw multi-byte passthrough
        assert_eq!(parse("\"héllo\"").unwrap().as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn round_trip() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":{"c":true,"d":null},"e":"s"}"#,
            r#"[[],{},[{"k":"v"}]]"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "round trip failed for {c}");
            let v3 = parse(&v.to_pretty()).unwrap();
            assert_eq!(v, v3, "pretty round trip failed for {c}");
        }
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        match &v {
            Value::Obj(pairs) => {
                let keys: Vec<_> = pairs.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(keys, vec!["z", "a", "m"]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"xs":[1,2,3],"f":[0.5,1.5]}"#).unwrap();
        assert_eq!(v.get("xs").unwrap().to_usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.get("f").unwrap().to_f32_vec().unwrap(), vec![0.5, 1.5]);
        assert!(v.get("f").unwrap().to_usize_vec().is_none());
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn big_ints_stay_exact() {
        // n_params for 0.73B must survive the f64 path.
        let v = parse("730000000").unwrap();
        assert_eq!(v.as_i64().unwrap(), 730_000_000);
        assert_eq!(v.to_string(), "730000000");
    }
}
