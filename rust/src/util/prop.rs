//! Miniature property-testing driver (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs; on
//! failure it retries with progressively "smaller" regenerated inputs
//! (shrinking-lite: re-draw with a shrunken size hint) and reports the
//! smallest failing case's seed so the exact run can be replayed with
//! [`replay`].
//!
//! Used by the coordinator/DSE/memory invariant tests — see
//! `rust/tests/prop_invariants.rs`.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Size hint passed to the generator (generators should scale their
    /// output, e.g. vector lengths, by this).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0x5EED, max_size: 64 }
    }
}

/// Outcome of a failed property with reproduction info.
#[derive(Debug)]
pub struct Failure<T: std::fmt::Debug> {
    pub input: T,
    pub case_seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop` over `cfg.cases` inputs drawn by `gen`.
///
/// `gen(rng, size)` produces an input; `prop(&input)` returns
/// `Err(message)` on violation. Panics with a replayable report on the
/// smallest failure found.
pub fn check<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    let mut failure: Option<Failure<T>> = None;

    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(message) = prop(&input) {
            failure = Some(Failure { input, case_seed, size, message });
            break;
        }
    }

    let Some(mut fail) = failure else { return };

    // Shrinking-lite: re-draw at smaller sizes from derived seeds, keep the
    // smallest input that still fails.
    let mut shrink_meta = Rng::new(fail.case_seed ^ 0xDEAD_BEEF);
    let mut size = fail.size;
    while size > 1 {
        size /= 2;
        let mut found_smaller = false;
        for _ in 0..32 {
            let seed = shrink_meta.next_u64();
            let mut rng = Rng::new(seed);
            let input = gen(&mut rng, size);
            if let Err(message) = prop(&input) {
                fail = Failure { input, case_seed: seed, size, message };
                found_smaller = true;
                break;
            }
        }
        if !found_smaller {
            break;
        }
    }

    panic!(
        "property failed (replay with seed=0x{seed:X}, size={size}):\n  input: {input:?}\n  violation: {msg}",
        seed = fail.case_seed,
        size = fail.size,
        input = fail.input,
        msg = fail.message,
    );
}

/// Re-run a single failing case from its reported seed and size.
pub fn replay<T, G, P>(case_seed: u64, size: usize, mut gen: G, mut prop: P) -> Result<(), String>
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    prop(&gen(&mut rng, size))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config { cases: 64, ..Default::default() },
            |rng, size| (0..size).map(|_| rng.below(100)).collect::<Vec<_>>(),
            |xs| {
                if xs.iter().all(|&x| x < 100) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_replay_info() {
        check(
            Config { cases: 64, ..Default::default() },
            |rng, size| rng.below(size + 8),
            |&x| if x < 4 { Ok(()) } else { Err(format!("{x} >= 4")) },
        );
    }

    /// Extract the text after `key` up to the first of `stop` from a
    /// panic report — the parsing a human replaying a failure does.
    fn field<'a>(msg: &'a str, key: &str, stop: &[char]) -> &'a str {
        let start = msg.find(key).unwrap_or_else(|| panic!("report lacks '{key}': {msg}"))
            + key.len();
        let rest = &msg[start..];
        let end = rest.find(|c| stop.contains(&c)).unwrap_or(rest.len());
        &rest[..end]
    }

    #[test]
    fn shrink_reports_a_strictly_smaller_failing_size() {
        // A property that fails iff the size-scaled input reaches 2,
        // with a generator that returns the size itself: fully
        // deterministic, so the whole shrink trajectory is pinned.
        // Cases run at sizes 1, 9, 17, ... — case 1 (size 9) is the
        // first failure; halving re-draws then fail at 4 and 2, pass at
        // 1, so the report must say size=2: strictly smaller than 9.
        use std::cell::Cell;
        let first_fail = Cell::new(0usize);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(
                Config { cases: 8, seed: 42, max_size: 64 },
                |_, size| size,
                |&x| {
                    if x < 2 {
                        Ok(())
                    } else {
                        if first_fail.get() == 0 {
                            first_fail.set(x);
                        }
                        Err(format!("{x} >= 2"))
                    }
                },
            )
        }));
        let payload = result.expect_err("the failing property must panic");
        let msg = payload.downcast_ref::<String>().expect("panic payload is a String");
        assert_eq!(first_fail.get(), 9, "first failure is case 1 at size 1 + 1*64/8");
        let final_size: usize = field(msg, "size=", &[')']).parse().unwrap();
        assert!(
            final_size < first_fail.get(),
            "shrunk size {final_size} must be strictly smaller than the initial {}",
            first_fail.get()
        );
        assert_eq!(final_size, 2, "greedy halving bottoms out at the smallest failing size");
        assert!(msg.contains("input: 2"), "the report carries the shrunk input: {msg}");
    }

    #[test]
    fn reported_seed_replays_the_identical_input() {
        // Drive a genuinely random property to failure, parse the
        // replay coordinates out of the panic report the way a human
        // would, and check `replay` regenerates the exact same input
        // and verdict.
        let gen = |rng: &mut Rng, _size: usize| rng.below(1000);
        let prop = |&x: &usize| if x < 10 { Ok(()) } else { Err(format!("{x} >= 10")) };
        let result = std::panic::catch_unwind(|| {
            check(Config { cases: 64, seed: 0xFEED, max_size: 16 }, gen, prop)
        });
        let payload = result.expect_err("the failing property must panic");
        let msg = payload.downcast_ref::<String>().expect("panic payload is a String");
        let seed = u64::from_str_radix(field(msg, "seed=0x", &[',']), 16).unwrap();
        let size: usize = field(msg, "size=", &[')']).parse().unwrap();
        let reported_input = field(msg, "input: ", &['\n']).to_string();

        let mut replayed = None;
        let res = replay(seed, size, gen, |&x: &usize| {
            replayed = Some(x);
            prop(&x)
        });
        assert!(res.is_err(), "the replayed case must still fail");
        assert_eq!(
            format!("{:?}", replayed.expect("prop ran")),
            reported_input,
            "replay(case_seed) must regenerate the identical input"
        );
    }

    #[test]
    fn replay_reproduces() {
        // Find a failing case manually, then replay it.
        let mut meta = Rng::new(123);
        let mut found = None;
        for _ in 0..256 {
            let seed = meta.next_u64();
            let mut rng = Rng::new(seed);
            let x = rng.below(100);
            if x >= 50 {
                found = Some((seed, x));
                break;
            }
        }
        let (seed, x) = found.expect("should find a failing case");
        let res = replay(
            seed,
            1,
            |rng, _| rng.below(100),
            |&y| if y < 50 { Ok(()) } else { Err("big".into()) },
        );
        assert!(res.is_err(), "replay of x={x} must still fail");
    }
}
