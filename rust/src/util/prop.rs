//! Miniature property-testing driver (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs; on
//! failure it retries with progressively "smaller" regenerated inputs
//! (shrinking-lite: re-draw with a shrunken size hint) and reports the
//! smallest failing case's seed so the exact run can be replayed with
//! [`replay`].
//!
//! Used by the coordinator/DSE/memory invariant tests — see
//! `rust/tests/prop_invariants.rs`.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Size hint passed to the generator (generators should scale their
    /// output, e.g. vector lengths, by this).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0x5EED, max_size: 64 }
    }
}

/// Outcome of a failed property with reproduction info.
#[derive(Debug)]
pub struct Failure<T: std::fmt::Debug> {
    pub input: T,
    pub case_seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop` over `cfg.cases` inputs drawn by `gen`.
///
/// `gen(rng, size)` produces an input; `prop(&input)` returns
/// `Err(message)` on violation. Panics with a replayable report on the
/// smallest failure found.
pub fn check<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    let mut failure: Option<Failure<T>> = None;

    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(message) = prop(&input) {
            failure = Some(Failure { input, case_seed, size, message });
            break;
        }
    }

    let Some(mut fail) = failure else { return };

    // Shrinking-lite: re-draw at smaller sizes from derived seeds, keep the
    // smallest input that still fails.
    let mut shrink_meta = Rng::new(fail.case_seed ^ 0xDEAD_BEEF);
    let mut size = fail.size;
    while size > 1 {
        size /= 2;
        let mut found_smaller = false;
        for _ in 0..32 {
            let seed = shrink_meta.next_u64();
            let mut rng = Rng::new(seed);
            let input = gen(&mut rng, size);
            if let Err(message) = prop(&input) {
                fail = Failure { input, case_seed: seed, size, message };
                found_smaller = true;
                break;
            }
        }
        if !found_smaller {
            break;
        }
    }

    panic!(
        "property failed (replay with seed=0x{seed:X}, size={size}):\n  input: {input:?}\n  violation: {msg}",
        seed = fail.case_seed,
        size = fail.size,
        input = fail.input,
        msg = fail.message,
    );
}

/// Re-run a single failing case from its reported seed and size.
pub fn replay<T, G, P>(case_seed: u64, size: usize, mut gen: G, mut prop: P) -> Result<(), String>
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    prop(&gen(&mut rng, size))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config { cases: 64, ..Default::default() },
            |rng, size| (0..size).map(|_| rng.below(100)).collect::<Vec<_>>(),
            |xs| {
                if xs.iter().all(|&x| x < 100) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_replay_info() {
        check(
            Config { cases: 64, ..Default::default() },
            |rng, size| rng.below(size + 8),
            |&x| if x < 4 { Ok(()) } else { Err(format!("{x} >= 4")) },
        );
    }

    #[test]
    fn replay_reproduces() {
        // Find a failing case manually, then replay it.
        let mut meta = Rng::new(123);
        let mut found = None;
        for _ in 0..256 {
            let seed = meta.next_u64();
            let mut rng = Rng::new(seed);
            let x = rng.below(100);
            if x >= 50 {
                found = Some((seed, x));
                break;
            }
        }
        let (seed, x) = found.expect("should find a failing case");
        let res = replay(
            seed,
            1,
            |rng, _| rng.below(100),
            |&y| if y < 50 { Ok(()) } else { Err("big".into()) },
        );
        assert!(res.is_err(), "replay of x={x} must still fail");
    }
}
