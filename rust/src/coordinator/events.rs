//! Event-driven serving core: a virtual-clock event queue over arrivals,
//! per-layer prefill completions, decode steps, PCAP swap start/finish,
//! and KV-pool evictions, driving the [`super::fsm::PhaseFsm`] per
//! device.
//!
//! **Why this exists.** The paper's evaluation (and
//! [`super::sim_server::SimServer`], which reproduces it) advances time
//! in *phase-batch rounds*: prefill a batch, swap once, decode the batch
//! to completion. That is faithful to the paper's one-request-at-a-time
//! edge profile, but it cannot represent the regime the paper's §3.4
//! worries about and where DPR either pays off or thrashes — *continuous
//! mixed traffic*, where new prompts arrive while earlier requests are
//! mid-decode and the controller must decide, swap by swap, whether the
//! single reconfigurable attention slot belongs to prefill or decode.
//! [`EventServer`] models exactly that: requests arrive on a virtual
//! clock, prefill progress is visible layer by layer (the final layer's
//! attention completion is the paper's §3.4 early-trigger point), decode
//! advances one token-step event at a time, and every PCAP load is an
//! explicit start→finish interval on the timeline.
//!
//! **What is the paper's and what is ours.** The phase FSM, the §3.4
//! early trigger, and the overlap arithmetic are the paper's mechanisms
//! (see [`crate::reconfig`]). The *when-to-swap* arbitration under
//! contention ([`crate::reconfig::SwapPolicy`]) and the multi-request KV
//! residency ([`crate::kvpool`]) are serving extensions:
//! [`SwapPolicy::Eager`] reproduces the paper's behavior, while
//! `Hysteresis`/`Lookahead` exist only here.
//!
//! Decode latency accounting differs deliberately from the phase-batch
//! server: TPOT samples are **wall inter-token gaps** — if the fabric
//! leaves decode to go prefill a newcomer, the interposed swap pair and
//! prefill time land in the resident requests' token gaps. That is the
//! latency a co-tenant actually observes, and it is what makes
//! swap-policy quality measurable.
//!
//! **Multi-stream decode** ([`EventServerConfig::decode_batch`] > 1,
//! another beyond-paper extension): each decode token-step event batches
//! up to `decode_batch` pool-resident streams in the same round-robin
//! order [`super::sim_server::SimServer`] uses, stepping them through one
//! [`crate::engines::LatencySurface::decode_step_batched_paged`] call —
//! the batch shares a single pass over the packed weight stream, so every
//! resident beyond the first amortizes the `T_weights` decode floor while
//! paying only its own paged KV traffic. There is exactly ONE decode
//! scheduler: a batch of one *is* the paper's single-stream flow (the
//! batch-1 closed form is bit-identical to the single-step form, and a
//! single-selection step emits the same `DecodeStepDone` event the
//! pre-batching engine did), so the paper-faithful timeline is preserved
//! bit-for-bit without a duplicated single-stream path.
//!
//! **Allocation-free hot path.** The steady-state decode loop performs no
//! heap allocation: the batch selection writes into scratch buffers owned
//! by the server (one step event is in flight at a time, so the buffers
//! are stable until the completion handler reads them), the completion
//! event carries no heap payload, and the policy outlook is maintained
//! incrementally — arrival/extraction/requeue update two counters instead
//! of re-scanning the queue, and the batched decode estimate uses the
//! uniform-context closed form
//! ([`crate::engines::LatencySurface::decode_step_uniform_paged`]) instead
//! of materializing a per-decision context vector. The `hotpath_kernel`
//! bench gates this with a counting allocator.
//!
//! ```
//! use pd_swap::coordinator::{EventServer, EventServerConfig, Request};
//! use pd_swap::fpga::KV260;
//! use pd_swap::model::BITNET_0_73B;
//! use pd_swap::reconfig::SwapPolicy;
//!
//! let cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
//! let mut server = EventServer::new(cfg).unwrap();
//! server.run(vec![Request::synthetic(0, 128, 8, 0.0)]).unwrap();
//! assert_eq!(server.metrics.requests_completed.get(), 1);
//! assert_eq!(server.metrics.tokens_generated.get(), 8);
//! assert!(server.clock() > 0.0);
//! ```

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashSet};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::engines::{AcceleratorDesign, AttentionHosting, LatencySurface, PhaseModel};
use crate::faults::FaultPlan;
use crate::fpga::{DeviceConfig, ReconfigState};
use crate::kvpool::{EvictionPolicy, KvPool, KvPoolConfig, PoolError};
use crate::metrics::ServerMetrics;
use crate::model::ModelShape;
use crate::reconfig::policy::{est_prefill_time, round_trip_exposed};
use crate::reconfig::{
    DecisionPoint, OverlapScheduler, SwapController, SwapOutlook, SwapPolicy, SwapRetryPolicy,
    RM_DECODE, RM_PREFILL,
};
use crate::telemetry::TraceRecorder;

use super::fastforward::{fits_before, member_step_bound, FastForwardStats};
use super::fsm::{Phase, PhaseFsm};
use super::request::{OutcomeSink, Request, RequestOutcome};
use super::scheduler::{Policy, Scheduler};

/// Runaway guard, workload-independent part: events any run may spend
/// beyond the per-request budget (cold-start swaps, idle transitions).
/// The full budget is `MAX_EVENTS_BASE + arrivals × per_request` (see
/// [`EventServer::event_budget`]) so that a stepped million-request run
/// — legitimately billions of events — is not mistaken for a livelock,
/// while an actual livelock still trips in bounded time.
const MAX_EVENTS_BASE: u64 = 10_000;

/// Event-log bound (oldest entries win; the log is diagnostics, not
/// accounting). `--log-tail N` swaps this head capture for a tail ring.
const MAX_LOG: usize = 16_384;

/// One occurrence on the virtual timeline.
#[derive(Debug, Clone)]
pub enum SimEvent {
    /// A request joins the arrival queue.
    Arrival(Request),
    /// Prefill finished transformer layer `layer` (progress marker; the
    /// final layer's *attention* completion is [`SimEvent::PrefillTrigger`]).
    PrefillLayerDone { id: u64, layer: usize },
    /// The §3.4 early-trigger point: final-layer prefill attention done,
    /// only the static-region tail remains — the swap decision point.
    PrefillTrigger { id: u64 },
    /// Prefill fully complete; the prompt's KV is resident.
    PrefillDone { id: u64 },
    /// A PCAP partial reconfiguration finished loading.
    SwapDone { to_decode: bool },
    /// One decode token-step completed for request `id`.
    DecodeStepDone { id: u64 },
    /// One *batched* decode token-step completed: the `n` streams the
    /// server's scratch selection buffer holds (round-robin selection
    /// order, `first` leading) each gained one token, sharing a single
    /// weight-stream pass (multi-stream decode, `decode_batch > 1`). The
    /// event deliberately carries no heap payload — at most one step
    /// event is in flight, so the selection buffer is stable until this
    /// completion is handled and the steady-state loop never allocates.
    DecodeBatchDone { first: u64, n: usize },
    /// A KV-pool eviction happened (bookkeeping is synchronous; the
    /// event marks the preemption on the timeline).
    KvEvicted { victim: u64 },
    /// Fault injection: the backoff after a failed PCAP load elapsed —
    /// re-issue the load (a retry of the in-flight logical swap, or a
    /// degraded-mode repair attempt).
    SwapFailed { to_decode: bool },
    /// Fault injection: request `id`'s SLO deadline passed (`e2e` false
    /// = the TTFT bound). A no-op if the request already completed.
    DeadlineExceeded { id: u64, e2e: bool },
    /// Fault injection: DDR brownout window `idx` opens.
    FaultWindowStart { idx: usize },
    /// Fault injection: DDR brownout window `idx` closes.
    FaultWindowEnd { idx: usize },
}

impl SimEvent {
    fn kind(&self) -> &'static str {
        match self {
            SimEvent::Arrival(_) => "arrival",
            SimEvent::PrefillLayerDone { .. } => "prefill-layer",
            SimEvent::PrefillTrigger { .. } => "prefill-trigger",
            SimEvent::PrefillDone { .. } => "prefill-done",
            SimEvent::SwapDone { to_decode: true } => "swap-done-decode",
            SimEvent::SwapDone { to_decode: false } => "swap-done-prefill",
            SimEvent::DecodeStepDone { .. } => "decode-step",
            SimEvent::DecodeBatchDone { .. } => "decode-batch",
            SimEvent::KvEvicted { .. } => "kv-evicted",
            SimEvent::SwapFailed { to_decode: true } => "swap-failed-decode",
            SimEvent::SwapFailed { to_decode: false } => "swap-failed-prefill",
            SimEvent::DeadlineExceeded { e2e: true, .. } => "deadline-e2e",
            SimEvent::DeadlineExceeded { e2e: false, .. } => "deadline-ttft",
            SimEvent::FaultWindowStart { .. } => "fault-window-start",
            SimEvent::FaultWindowEnd { .. } => "fault-window-end",
        }
    }

    fn subject(&self) -> u64 {
        match self {
            SimEvent::Arrival(r) => r.id,
            SimEvent::PrefillLayerDone { id, .. }
            | SimEvent::PrefillTrigger { id }
            | SimEvent::PrefillDone { id }
            | SimEvent::DecodeStepDone { id }
            | SimEvent::DeadlineExceeded { id, .. } => *id,
            SimEvent::DecodeBatchDone { first, .. } => *first,
            SimEvent::SwapDone { .. } | SimEvent::SwapFailed { .. } => u64::MAX,
            SimEvent::KvEvicted { victim } => *victim,
            SimEvent::FaultWindowStart { idx } | SimEvent::FaultWindowEnd { idx } => {
                *idx as u64
            }
        }
    }
}

#[derive(Debug)]
struct Entry {
    at: f64,
    /// Tie-class at equal timestamps: 0 = arrival, 1 = everything else.
    /// Arrivals popping first at a shared timestamp is what the
    /// materialized path already does implicitly — `run` seeds every
    /// arrival before any derived event exists, so arrivals hold the
    /// lowest sequence numbers and win every tie. Making the rule a
    /// class instead of an accident keeps the streamed path
    /// (`run_streamed`, which pushes arrivals lazily with *later*
    /// sequence numbers) bit-identical to the materialized one.
    cls: u8,
    seq: u64,
    ev: SimEvent,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Virtual times are finite by construction; ties break arrivals
        // first (see `cls`), then by push order, so the simulation is
        // fully deterministic and independent of when arrivals were
        // pushed (bulk-seeded or streamed).
        self.at
            .partial_cmp(&other.at)
            .unwrap_or(Ordering::Equal)
            .then(self.cls.cmp(&other.cls))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Deterministic min-heap of timestamped events (arrivals first, then
/// FIFO within a timestamp).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

impl EventQueue {
    /// Queue with room for `n` events before the heap reallocates (bulk
    /// arrival seeding pushes the whole workload at once).
    pub fn with_capacity(n: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(n), seq: 0 }
    }

    /// Reserve room for `n` more events.
    pub fn reserve(&mut self, n: usize) {
        self.heap.reserve(n);
    }

    pub fn push(&mut self, at: f64, ev: SimEvent) {
        debug_assert!(at.is_finite(), "event scheduled at non-finite time");
        let cls = u8::from(!matches!(ev, SimEvent::Arrival(_)));
        self.heap.push(Reverse(Entry { at, cls, seq: self.seq, ev }));
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(f64, SimEvent)> {
        self.heap.pop().map(|Reverse(e)| (e.at, e.ev))
    }

    /// Timestamp of the earliest queued event without popping it — the
    /// fast-forward horizon: decode steps may be folded analytically only
    /// while they finish strictly before this time (at a tie the queued
    /// event pops first, so the fold yields).
    pub fn peek_at(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    /// The earliest queued event (time + payload) without popping it —
    /// the interference-aware fold inspects it to decide whether the
    /// event can perturb the decode set or may be absorbed in place.
    pub fn peek(&self) -> Option<(f64, &SimEvent)> {
        self.heap.peek().map(|Reverse(e)| (e.at, &e.ev))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// One timeline record for diagnostics (`pd-swap simulate --log`).
#[derive(Debug, Clone, Copy)]
pub struct EventRecord {
    pub at: f64,
    pub kind: &'static str,
    pub subject: u64,
}

/// Bounded diagnostic event log. Two retention shapes, both O(cap):
/// head capture (the historical behavior — keep the first `cap`
/// records, drop the rest) and tail ring (`--log-tail N` — keep the
/// *last* `cap` records by overwriting in place), which is what you
/// want when a million-request run misbehaves near the end.
#[derive(Debug, Clone)]
struct EventLog {
    buf: Vec<EventRecord>,
    cap: usize,
    keep_tail: bool,
    /// Ring write position (tail mode, once `buf` is full).
    head: usize,
    dropped: u64,
}

impl EventLog {
    fn head_capture(cap: usize) -> Self {
        Self { buf: Vec::new(), cap, keep_tail: false, head: 0, dropped: 0 }
    }

    fn tail_ring(cap: usize) -> Self {
        Self { buf: Vec::new(), cap: cap.max(1), keep_tail: true, head: 0, dropped: 0 }
    }

    fn push(&mut self, rec: EventRecord) {
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else if self.keep_tail {
            // Ring overwrite: `head` is the oldest slot.
            self.buf[self.head] = rec;
            self.head = (self.head + 1) % self.cap.max(1);
            self.dropped += 1;
        } else {
            self.dropped += 1;
        }
    }

    /// Records in timeline order (oldest first), unwrapping the ring.
    fn snapshot(&self) -> Vec<EventRecord> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

/// One resident request mid-decode. Shared with the phase-batch
/// [`super::sim_server::SimServer`].
#[derive(Debug)]
pub(crate) struct InFlight {
    pub(crate) req: Request,
    /// Tokens currently in the KV cache.
    pub(crate) ctx: usize,
    /// Tokens generated so far this serve attempt.
    pub(crate) tokens: usize,
    /// When this request's prefill finished (absolute sim time).
    pub(crate) prefill_done: f64,
    /// Admission-capped token ceiling for this reservation.
    pub(crate) token_cap: usize,
    /// Start of this request's first decode step (TTFT anchor).
    pub(crate) first_step: Option<f64>,
    /// Completion time of the latest token (wall TPOT anchor).
    pub(crate) last_token: Option<f64>,
}

impl InFlight {
    pub(crate) fn new(req: Request, prefill_done: f64, token_cap: usize) -> Self {
        let ctx = req.prompt_len.min(token_cap);
        Self { req, ctx, tokens: 0, prefill_done, token_cap, first_step: None, last_token: None }
    }

    /// Generation finished: token budget spent, graph capacity reached,
    /// or reservation cap hit.
    pub(crate) fn done(&self, max_seq: usize) -> bool {
        self.tokens >= self.req.max_new_tokens
            || self.ctx >= max_seq
            || self.ctx >= self.token_cap
    }

    /// Tokens this request can still generate.
    fn remaining(&self, max_seq: usize) -> usize {
        self.req
            .max_new_tokens
            .saturating_sub(self.tokens)
            .min(self.token_cap.min(max_seq).saturating_sub(self.ctx))
    }
}

/// A prefill in flight on the fabric.
#[derive(Debug)]
struct PrefillJob {
    req: Request,
    done_at: f64,
    /// The §3.4 decode swap was started at the trigger point.
    swap_committed: bool,
}

/// Configuration for the event-driven server.
#[derive(Debug, Clone)]
pub struct EventServerConfig {
    pub design: AcceleratorDesign,
    pub device: DeviceConfig,
    pub shape: ModelShape,
    /// Paged KV-cache pool sizing + admission/eviction policy.
    pub pool: KvPoolConfig,
    /// When to move the attention slot between phases.
    pub policy: SwapPolicy,
    /// Use the §3.4 latency-overlapped early trigger for prefill→decode
    /// swaps (the paper's mechanism; `false` swaps sequentially).
    pub overlap: bool,
    /// Cap on concurrently resident requests (decode set + the prefill
    /// in flight); the KV pool still gates below this.
    pub max_residents: usize,
    /// Streams stepped per decode token-step event. 1 = the paper's
    /// single-stream decode flow (bit-identical to the pre-batching
    /// engine); B > 1 batches up to B pool-resident streams per step in
    /// round-robin order, sharing one weight-stream pass
    /// ([`crate::engines::PhaseModel::decode_step_batched`]) — our
    /// multi-stream serving extension.
    pub decode_batch: usize,
    /// Drive the hot path from a precomputed
    /// [`crate::engines::LatencySurface`] (O(1) per query) instead of
    /// re-deriving the phase model per token-step event. Bit-identical
    /// results either way — the direct path exists for the
    /// `hotpath_kernel` bench and the equivalence tests.
    pub use_surface: bool,
    /// Optional pre-built surface to use instead of constructing one in
    /// [`EventServer::new`] — sweeps that build many servers for the same
    /// design (the `codesign` joint exploration) share construction
    /// through a [`crate::engines::SurfaceCache`]. Must have been built
    /// for this config's (design, device, shape, `pool.page_tokens`);
    /// the cache keys on exactly that tuple. Ignored when `use_surface`
    /// is false.
    pub surface: Option<Arc<LatencySurface>>,
    /// The caller already validated this design's floorplan (the codesign
    /// sweep's DSE pass runs the same [`crate::fpga::region::validate_budget`]
    /// rule on every candidate): skip the per-server revalidation and
    /// program the device directly. Debug builds still assert validity.
    pub assume_feasible: bool,
    /// Record phase-span telemetry ([`crate::telemetry::TraceRecorder`])
    /// keyed to the virtual clock. Off by default: the disabled recorder
    /// is bitwise-inert (clocks, metrics, outcomes identical — pinned by
    /// `tracing_disabled_is_bitwise_identical_to_enabled`) and
    /// allocation-free (gated by the `hotpath_kernel` counting-allocator
    /// bench).
    pub trace: bool,
    /// Analytically fold steady-state decode stretches into one pass
    /// instead of one queue event per token (see
    /// [`super::fastforward`] and `docs/ARCHITECTURE.md` extension #7).
    /// **Bit-identical** to the stepped path — clocks, TPOT/TTFT,
    /// outcome order, eviction log, and metrics are unchanged (pinned by
    /// `prop_fast_forward_matches_stepped`); only the diagnostic event
    /// log and the Chrome trace coalesce (per-token `decode-step` spans
    /// become one `decode-ff` span carrying `{k, step_s}`). Default on;
    /// `simulate --no-fast-forward` is the escape hatch.
    ///
    /// ```
    /// use pd_swap::coordinator::{EventServer, EventServerConfig, Request};
    /// use pd_swap::fpga::KV260;
    /// use pd_swap::model::BITNET_0_73B;
    /// use pd_swap::reconfig::SwapPolicy;
    ///
    /// let run = |fast_forward: bool| {
    ///     let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
    ///     cfg.fast_forward = fast_forward;
    ///     let mut s = EventServer::new(cfg).unwrap();
    ///     s.run(vec![Request::synthetic(0, 128, 64, 0.0)]).unwrap();
    ///     (s.clock().to_bits(), s.events_processed(), s.fast_forward_stats().steps)
    /// };
    /// let (clock_ff, events_ff, skipped) = run(true);
    /// let (clock_stepped, events_stepped, _) = run(false);
    /// assert_eq!(clock_ff, clock_stepped); // bit-identical virtual clock
    /// assert_eq!(events_ff + skipped, events_stepped); // every skip was one event
    /// assert!(events_ff < events_stepped);
    /// ```
    pub fast_forward: bool,
    /// Completed-request records retained verbatim in
    /// [`EventServer::outcomes`] (head retention; completions beyond the
    /// cap are counted in [`super::OutcomeSink::dropped`], and the
    /// metrics histograms still see every request). The default,
    /// [`super::OutcomeSink::DEFAULT_RETAIN`], keeps every outcome for
    /// all pre-existing workload sizes; million-request runs keep O(cap)
    /// memory. `usize::MAX` = retain everything.
    pub outcome_retain: usize,
    /// `Some(n)`: keep the *last* `n` diagnostic event records in a ring
    /// (the `simulate --log-tail N` knob — bounded even on huge traces,
    /// and the tail is where a late-run bug lives). `None`: the
    /// historical head capture of the first 16384 records.
    pub log_tail: Option<usize>,
    /// Schedule the per-layer `PrefillLayerDone` progress markers
    /// (`n_layers − 1` queue events per prefill). They are pure timeline
    /// diagnostics: dispatch is a no-op, the phase FSM waits in
    /// `Prefill` regardless, and the Chrome-trace layer instants are
    /// emitted analytically at admission (not from these events) — so
    /// disabling them changes *only* `events_processed` and the
    /// diagnostic event log, bit-for-bit nothing else (pinned by
    /// `layer_markers_off_is_semantically_identical`). Default on;
    /// million-request runs turn them off (`simulate --no-layer-events`)
    /// to stop paying `n_layers` queue events per request for markers
    /// nobody reads at that scale.
    pub prefill_layer_events: bool,
    /// Deterministic fault injection (extension #10): seeded PCAP
    /// swap-failure draws, bounded DDR-bandwidth brownout windows, and
    /// per-request SLO deadlines. [`FaultPlan::none`] (the default) is
    /// **bitwise inert**: every fault code path is gated on
    /// [`FaultPlan::is_active`], so clocks, metrics, outcomes, and
    /// fingerprints are identical to a build without this field — the
    /// 5th semantics contract, pinned by
    /// `prop_zero_fault_plan_is_bitwise_inert`.
    pub faults: FaultPlan,
    /// What to do when a PCAP load fails: capped exponential backoff in
    /// virtual time for `max_attempts`, then degraded static-unified
    /// fallback (default) or fail-stop. Only consulted when `faults`
    /// is active.
    pub retry: SwapRetryPolicy,
}

impl EventServerConfig {
    pub fn pd_swap(shape: ModelShape, device: DeviceConfig, policy: SwapPolicy) -> Self {
        let pool = KvPoolConfig::for_device(&shape, &device);
        Self {
            design: AcceleratorDesign::pd_swap(),
            device,
            shape,
            pool,
            policy,
            overlap: true,
            max_residents: 8,
            decode_batch: 1,
            use_surface: true,
            surface: None,
            assume_feasible: false,
            trace: false,
            fast_forward: true,
            outcome_retain: OutcomeSink::DEFAULT_RETAIN,
            log_tail: None,
            prefill_layer_events: true,
            faults: FaultPlan::none(),
            retry: SwapRetryPolicy::default(),
        }
    }
}

/// The continuous event-driven serving simulator (single DPR device).
pub struct EventServer {
    cfg: EventServerConfig,
    model: PhaseModel,
    /// O(1) analytic kernel for the per-event hot path (None = direct
    /// phase-model evaluation; see `EventServerConfig::use_surface`).
    surface: Option<LatencySurface>,
    swap: SwapController,
    overlap_sched: OverlapScheduler,
    fsm: PhaseFsm,
    kv_pool: KvPool,
    queue: EventQueue,
    sched: Scheduler,
    prefilling: Option<PrefillJob>,
    decode: Vec<InFlight>,
    /// Round-robin position in `decode`.
    cursor: usize,
    /// A `DecodeStepDone`/`DecodeBatchDone` is scheduled (the decode
    /// engine is busy).
    step_inflight: bool,
    /// Scratch: ids selected for the in-flight (batched) step, in
    /// round-robin order. Owned by the server so the completion event
    /// needs no heap payload; capacity is retained across steps, so the
    /// steady-state loop never allocates.
    batch_ids: Vec<u64>,
    /// Scratch: the selected streams' contexts (parallel to `batch_ids`).
    batch_ctxs: Vec<usize>,
    /// Incrementally maintained arrived-backlog count (every queued
    /// request has arrived — arrivals enter through their timeline event
    /// — so this equals `sched.arrived_backlog(clock).0` at all times;
    /// the policy outlook asserts that in debug builds instead of
    /// re-scanning the queue per decision).
    backlog_n: usize,
    /// Incrementally maintained arrived-backlog prompt-token sum (the
    /// `sched.arrived_backlog(clock).1` twin of `backlog_n`).
    backlog_tokens: usize,
    /// Incrementally maintained sum of the decode set's remaining
    /// generation tokens: +remaining on entry, −1 per applied token,
    /// −remaining on any removal (completion, capacity cap, eviction).
    decode_rem_tokens: usize,
    /// Requests that have prefilled at least once (re-prefill = eviction
    /// recompute, charged to `metrics.recompute_overhead`).
    prefilled: HashSet<u64>,
    /// Requests already evicted once — never victims again.
    evicted_once: HashSet<u64>,
    clock: f64,
    started: bool,
    /// Queue events popped by [`Self::run`] (the [`Self::event_budget`]
    /// livelock guard and the fast-forward reduction's denominator).
    events_processed: u64,
    /// Arrival events ever pushed into the queue (bulk-seeded or
    /// streamed) — the completeness check's expected count and the
    /// event-budget scale factor.
    arrivals_total: u64,
    /// Fast-forward fold counters (`steps` = decode events skipped).
    ff: FastForwardStats,
    /// Working copy of the fault plan (owns the deterministic draw
    /// counter — same plan + same event sequence ⇒ same draws).
    faults: FaultPlan,
    /// Degraded-mode pricing engine (the static-unified fallback
    /// architecture); built only when the fault plan is active.
    degraded_model: Option<PhaseModel>,
    degraded_surface: Option<LatencySurface>,
    /// Serving on the static fallback after swap-retry exhaustion.
    degraded: bool,
    degraded_since: f64,
    /// A degraded-mode background repair load is in flight on the PCAP.
    repair_inflight: bool,
    /// Consecutive failed PCAP loads for the current logical swap chain
    /// (retries and repairs continue it; success resets it). Forced
    /// success at [`crate::faults::SWAP_FAIL_STREAK_CAP`].
    swap_failure_streak: u32,
    /// `SwapRetryPolicy::fail_stop` tripped: everything sheds.
    fail_stopped: bool,
    /// Deadline-exceeded residents awaiting shed outside a step.
    shed_due: Vec<u64>,
    /// Multiplicative latency penalty of the open DDR brownout window
    /// (1.0 = healthy; [`Self::with_ddr_penalty`] skips the multiply at
    /// exactly 1.0 so zero-fault floats are untouched).
    ddr_penalty: f64,
    log: EventLog,
    pub metrics: ServerMetrics,
    /// Completed-request records, bounded by
    /// [`EventServerConfig::outcome_retain`]. Derefs to
    /// `[RequestOutcome]`, so reads look exactly like the unbounded
    /// `Vec` this replaced.
    pub outcomes: OutcomeSink,
    /// Phase-span telemetry (inert unless `cfg.trace`); export with
    /// [`crate::telemetry::TraceRecorder::to_chrome_json`].
    pub recorder: TraceRecorder,
}

impl EventServer {
    pub fn new(cfg: EventServerConfig) -> Result<Self> {
        if cfg.design.hosting != AttentionHosting::Reconfigurable {
            bail!("EventServer models DPR swap scheduling; static designs have no swaps to schedule");
        }
        let model = PhaseModel::new(cfg.design.clone(), cfg.device.clone());
        let surface = if cfg.use_surface {
            Some(match &cfg.surface {
                Some(shared) => {
                    // A mismatched injection would silently simulate a
                    // different accelerator; the key makes it one
                    // comparison, so check it even in release builds.
                    let expect = crate::engines::SurfaceKey::new(
                        &cfg.design,
                        &cfg.device,
                        &cfg.shape,
                        cfg.pool.page_tokens,
                    );
                    if shared.key() != &expect {
                        bail!(
                            "injected latency surface was built for a different \
                             configuration (design/device/shape/page-size mismatch)"
                        );
                    }
                    shared.as_ref().clone()
                }
                None => LatencySurface::new(
                    &cfg.design,
                    &cfg.device,
                    &cfg.shape,
                    cfg.pool.page_tokens,
                ),
            })
        } else {
            None
        };
        let programmed = if cfg.assume_feasible {
            cfg.design.program_prevalidated(&cfg.device)?
        } else {
            cfg.design.program(&cfg.device)?
        };
        let swap = SwapController::new(programmed);
        let lat = swap.device.reconfig_latency();
        let overlap_sched = OverlapScheduler::new(model.clone(), lat);
        let kv_pool = KvPool::new(cfg.pool.clone());
        let recorder = TraceRecorder::from_flag(cfg.trace);
        let log = match cfg.log_tail {
            Some(n) => EventLog::tail_ring(n),
            None => EventLog::head_capture(MAX_LOG),
        };
        let outcomes = OutcomeSink::with_capacity(cfg.outcome_retain);
        // Degraded-mode fallback engine: the static-unified architecture
        // (both phases resident, no DPR) prices serving after swap-retry
        // exhaustion. Built only when faults can actually occur, so the
        // zero-fault construction path is untouched.
        let (degraded_model, degraded_surface) = if cfg.faults.is_active() {
            let d = AcceleratorDesign::tellme_static();
            let m = PhaseModel::new(d.clone(), cfg.device.clone());
            let s = if cfg.use_surface {
                Some(LatencySurface::new(&d, &cfg.device, &cfg.shape, cfg.pool.page_tokens))
            } else {
                None
            };
            (Some(m), s)
        } else {
            (None, None)
        };
        let faults = cfg.faults.clone();
        Ok(Self {
            cfg,
            model,
            surface,
            swap,
            overlap_sched,
            fsm: PhaseFsm::new(),
            kv_pool,
            queue: EventQueue::default(),
            sched: Scheduler::new(Policy::SwapPerRequest),
            prefilling: None,
            decode: Vec::new(),
            cursor: 0,
            step_inflight: false,
            batch_ids: Vec::new(),
            batch_ctxs: Vec::new(),
            backlog_n: 0,
            backlog_tokens: 0,
            decode_rem_tokens: 0,
            prefilled: HashSet::new(),
            evicted_once: HashSet::new(),
            clock: 0.0,
            started: false,
            events_processed: 0,
            arrivals_total: 0,
            ff: FastForwardStats::default(),
            faults,
            degraded_model,
            degraded_surface,
            degraded: false,
            degraded_since: 0.0,
            repair_inflight: false,
            swap_failure_streak: 0,
            fail_stopped: false,
            shed_due: Vec::new(),
            ddr_penalty: 1.0,
            log,
            metrics: ServerMetrics::default(),
            outcomes,
            recorder,
        })
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// The paged KV pool (occupancy/conservation stats).
    pub fn pool(&self) -> &KvPool {
        &self.kv_pool
    }

    /// The event timeline (bounded; diagnostics only). Head capture by
    /// default; the last-`n` ring when [`EventServerConfig::log_tail`]
    /// is set — the snapshot unwraps the ring into timeline order.
    pub fn event_log(&self) -> Vec<EventRecord> {
        self.log.snapshot()
    }

    /// Diagnostic records that fell outside the log bound (head capture:
    /// everything after the first 16384; tail ring: everything before
    /// the last `n`).
    pub fn event_log_dropped(&self) -> u64 {
        self.log.dropped
    }

    /// Arrival events ever pushed (bulk-seeded or streamed).
    pub fn arrivals_total(&self) -> u64 {
        self.arrivals_total
    }

    /// Queue events popped over the run. With fast-forward on, the
    /// stepped engine would have processed
    /// `fast_forward_stats().stepped_equivalent(events_processed())`
    /// events for the same (bit-identical) result — the ratio the
    /// `event_fast_forward` bench gates at ≥ 10×.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Fast-forward fold counters (zero when `cfg.fast_forward` is off
    /// or no steady-state stretch ever qualified).
    pub fn fast_forward_stats(&self) -> FastForwardStats {
        self.ff
    }

    // -- analytic kernel (surface-accelerated, bit-identical fallback) -----

    /// Apply the open DDR-brownout window's multiplicative latency
    /// penalty. The multiply is skipped at exactly 1.0 (the healthy
    /// state), so zero-fault floats pass through untouched — bitwise
    /// inertness of the fault layer depends on this.
    #[inline]
    fn with_ddr_penalty(&self, t: f64) -> f64 {
        if self.ddr_penalty != 1.0 {
            t * self.ddr_penalty
        } else {
            t
        }
    }

    fn prefill_lat(&self, l: usize) -> crate::engines::PrefillLatency {
        match &self.surface {
            Some(s) => s.prefill(l),
            None => self.model.prefill(&self.cfg.shape, l),
        }
    }

    /// Prefill total under the active fault regime: priced on the
    /// degraded static-unified engine while in fallback, on the healthy
    /// engine otherwise, with the brownout penalty applied to either.
    fn effective_prefill_total(&self, l: usize) -> f64 {
        let t = if self.degraded {
            match &self.degraded_surface {
                Some(s) => s.prefill(l).total,
                None => self
                    .degraded_model
                    .as_ref()
                    .expect("degraded engine exists whenever faults are active")
                    .prefill(&self.cfg.shape, l)
                    .total,
            }
        } else {
            self.prefill_lat(l).total
        };
        self.with_ddr_penalty(t)
    }

    /// One decode step at context `l` under the pool's page size.
    fn decode_step_total(&self, l: usize) -> f64 {
        let t = if self.degraded {
            match &self.degraded_surface {
                Some(s) => s.decode_step_paged(l, self.cfg.pool.page_tokens).total,
                None => self
                    .degraded_model
                    .as_ref()
                    .expect("degraded engine exists whenever faults are active")
                    .decode_step_paged(&self.cfg.shape, l, self.cfg.pool.page_tokens)
                    .total,
            }
        } else {
            match &self.surface {
                Some(s) => s.decode_step_paged(l, self.cfg.pool.page_tokens).total,
                None => self
                    .model
                    .decode_step_paged(&self.cfg.shape, l, self.cfg.pool.page_tokens)
                    .total,
            }
        };
        self.with_ddr_penalty(t)
    }

    /// One *batched* decode step over per-stream contexts `ctxs` (shared
    /// weight stream, per-stream paged KV) under the pool's page size.
    fn decode_batch_total(&self, ctxs: &[usize]) -> f64 {
        let t = if self.degraded {
            match &self.degraded_surface {
                Some(s) => s.decode_step_batched_paged(ctxs, self.cfg.pool.page_tokens).total,
                None => self
                    .degraded_model
                    .as_ref()
                    .expect("degraded engine exists whenever faults are active")
                    .decode_step_batched_paged(&self.cfg.shape, ctxs, self.cfg.pool.page_tokens)
                    .total,
            }
        } else {
            match &self.surface {
                Some(s) => s.decode_step_batched_paged(ctxs, self.cfg.pool.page_tokens).total,
                None => self
                    .model
                    .decode_step_batched_paged(&self.cfg.shape, ctxs, self.cfg.pool.page_tokens)
                    .total,
            }
        };
        self.with_ddr_penalty(t)
    }

    /// Uniform-context batched step (`batch` streams all at context `l`)
    /// — bit-identical to [`Self::decode_batch_total`] over `[l; batch]`
    /// without materializing the slice (the policy outlook's estimate).
    fn decode_uniform_total(&self, l: usize, batch: usize) -> f64 {
        let t = if self.degraded {
            match &self.degraded_surface {
                Some(s) => s.decode_step_uniform_paged(l, batch, self.cfg.pool.page_tokens).total,
                None => self
                    .degraded_model
                    .as_ref()
                    .expect("degraded engine exists whenever faults are active")
                    .decode_step_uniform_paged(
                        &self.cfg.shape,
                        l,
                        batch,
                        self.cfg.pool.page_tokens,
                    )
                    .total,
            }
        } else {
            match &self.surface {
                Some(s) => s.decode_step_uniform_paged(l, batch, self.cfg.pool.page_tokens).total,
                None => self
                    .model
                    .decode_step_uniform_paged(
                        &self.cfg.shape,
                        l,
                        batch,
                        self.cfg.pool.page_tokens,
                    )
                    .total,
            }
        };
        self.with_ddr_penalty(t)
    }

    /// §3.4 early-trigger offset into a prefill of `l` tokens.
    fn trigger_offset(&self, l: usize) -> f64 {
        match &self.surface {
            Some(s) => s.overlapped(l, self.overlap_sched.reconfig_latency).trigger,
            None => self.overlap_sched.overlapped(&self.cfg.shape, l).trigger,
        }
    }

    /// Estimated time to prefill the arrived backlog (policy outlook).
    fn est_prefill(&self, n: usize, prompt_tokens: usize) -> f64 {
        match &self.surface {
            Some(s) => {
                crate::reconfig::policy::est_prefill_time_with(
                    |l| s.prefill(l).total,
                    n,
                    prompt_tokens,
                )
            }
            None => est_prefill_time(&self.model, &self.cfg.shape, n, prompt_tokens),
        }
    }

    /// Exposed cost of a decode→prefill→decode round trip (policy outlook).
    fn round_trip(&self, mean_prompt: usize) -> f64 {
        match &self.surface {
            Some(s) => s.round_trip_exposed(mean_prompt, self.overlap_sched.reconfig_latency),
            None => round_trip_exposed(&self.overlap_sched, &self.cfg.shape, mean_prompt),
        }
    }

    /// Serve one workload to completion. Single-shot: build a fresh
    /// server per workload so metrics and device state start cold.
    pub fn run(&mut self, mut workload: Vec<Request>) -> Result<&ServerMetrics> {
        if self.started {
            bail!("EventServer::run is single-shot; build a fresh server per workload");
        }
        self.started = true;
        self.seed_fault_events();
        workload.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        self.queue.reserve(workload.len());
        for r in workload {
            self.arrivals_total += 1;
            self.queue.push(r.arrival.max(0.0), SimEvent::Arrival(r));
        }
        // Everything is in the queue already: the refill source is dry.
        self.event_loop(&mut || None)?;
        self.finalize_run()
    }

    /// Serve a *streamed* workload to completion: arrivals are pulled
    /// lazily from `workload` (non-decreasing arrival times — e.g.
    /// [`super::requests_from_stream`] over
    /// [`crate::model::TraceSpec::stream`]) and at most `window` of them
    /// sit in the event queue at any moment. Each popped arrival pulls
    /// exactly one replacement, so the queue always holds the earliest
    /// not-yet-dispatched arrival and pops stay globally time-ordered —
    /// which, with the arrivals-first tie class on [`EventQueue`], makes
    /// this **bit-identical** to `run` over the materialized workload
    /// (pinned by `prop_streamed_matches_materialized`): same clocks,
    /// counters, histograms, and outcome order, at O(window + resident)
    /// queue memory instead of O(total requests).
    pub fn run_streamed(
        &mut self,
        workload: impl IntoIterator<Item = Request>,
        window: usize,
    ) -> Result<&ServerMetrics> {
        if self.started {
            bail!("EventServer::run_streamed is single-shot; build a fresh server per workload");
        }
        self.started = true;
        self.seed_fault_events();
        let window = window.max(1);
        let mut src = workload.into_iter();
        let mut last_arrival = 0.0f64;
        for _ in 0..window {
            let Some(r) = src.next() else { break };
            let at = r.arrival.max(0.0);
            if at < last_arrival {
                bail!(
                    "streamed workload must be sorted by arrival: {} after {}",
                    at,
                    last_arrival
                );
            }
            last_arrival = at;
            self.arrivals_total += 1;
            self.queue.push(at, SimEvent::Arrival(r));
        }
        let mut refill_err: Option<String> = None;
        {
            let mut refill = || -> Option<Request> {
                let r = src.next()?;
                let at = r.arrival.max(0.0);
                if at < last_arrival {
                    // Surfaced after the loop: the closure cannot bail.
                    refill_err.get_or_insert_with(|| {
                        format!("streamed workload must be sorted by arrival: {at} after {last_arrival}")
                    });
                    return None;
                }
                last_arrival = at;
                Some(r)
            };
            self.event_loop(&mut refill)?;
        }
        if let Some(msg) = refill_err {
            bail!("{msg}");
        }
        self.finalize_run()
    }

    /// The shared pop→dispatch→pump loop. `refill` is the streamed
    /// arrival source: invoked exactly once per *popped* arrival (by the
    /// dispatcher and by the fast-forward absorption alike), so the
    /// arrival window stays at its seeded size until the source runs
    /// dry. Bulk runs pass a dry source.
    fn event_loop(&mut self, refill: &mut dyn FnMut() -> Option<Request>) -> Result<()> {
        while let Some((at, ev)) = self.queue.pop() {
            self.events_processed += 1;
            if self.events_processed > self.event_budget() {
                bail!("event budget exceeded — serving livelock");
            }
            self.clock = self.clock.max(at);
            self.log.push(EventRecord { at, kind: ev.kind(), subject: ev.subject() });
            if matches!(ev, SimEvent::Arrival(_)) {
                self.pull_arrival(refill);
            }
            self.dispatch(ev)?;
            self.pump(refill)?;
        }
        Ok(())
    }

    /// Seed the DDR brownout window open/close events. Runs before any
    /// arrival is pushed — in **both** run modes — so the window events
    /// hold the same low sequence numbers either way and the streamed
    /// path stays bit-identical to the materialized one under faults.
    fn seed_fault_events(&mut self) {
        for (idx, w) in self.faults.windows().iter().enumerate() {
            self.queue.push(w.start_s.max(0.0), SimEvent::FaultWindowStart { idx });
            self.queue.push(w.end_s.max(0.0), SimEvent::FaultWindowEnd { idx });
        }
    }

    /// Pull one replacement arrival from the streamed source into the
    /// queue (no-op once the source is dry).
    fn pull_arrival(&mut self, refill: &mut dyn FnMut() -> Option<Request>) {
        if let Some(r) = refill() {
            self.arrivals_total += 1;
            self.queue.push(r.arrival.max(0.0), SimEvent::Arrival(r));
        }
    }

    /// Livelock guard: generous per-request ceiling (two prefills' worth
    /// of layer markers + every token as its own event + swap/eviction
    /// overhead) plus a workload-independent base. Scales with arrivals
    /// seen so far, so stepped million-request runs fit while a true
    /// livelock (events with no progress) still trips.
    fn event_budget(&self) -> u64 {
        let shape = &self.cfg.shape;
        let mut per_request =
            2 * (shape.max_seq as u64) + 2 * (shape.n_layers as u64) + 20;
        if self.faults.is_active() {
            // Fault headroom: each logical swap chain costs at most a
            // SwapFailed + SwapDone pair per failed attempt, bounded by
            // the forced-success streak cap, plus two deadline events
            // per request.
            per_request += 4 * (crate::faults::SWAP_FAIL_STREAK_CAP as u64 + 4);
        }
        let flat = MAX_EVENTS_BASE + 2 * self.faults.windows().len() as u64;
        flat + self.arrivals_total.saturating_mul(per_request)
    }

    /// Completeness check + pool-stat mirroring shared by both run modes.
    fn finalize_run(&mut self) -> Result<&ServerMetrics> {
        if self.degraded {
            // The run ended still in fallback (no repair ever landed):
            // close the degraded-time gauge at the final clock.
            self.degraded = false;
            self.metrics.degraded_seconds += (self.clock - self.degraded_since).max(0.0);
        }
        // Conservation: every arrival either completed or was shed —
        // nothing is silently dropped (satellite of extension #10).
        let accounted =
            self.metrics.requests_completed.get() + self.metrics.requests_shed.get();
        if accounted != self.arrivals_total
            || !self.sched.is_empty()
            || self.prefilling.is_some()
            || !self.decode.is_empty()
        {
            bail!(
                "serving incomplete: {}/{} requests done ({} shed), {} queued, {} decoding",
                self.metrics.requests_completed.get(),
                self.arrivals_total,
                self.metrics.requests_shed.get(),
                self.sched.queue_len(),
                self.decode.len()
            );
        }
        // Mirror the pool's conservation stats into the metric bundle.
        let high_water = self.kv_pool.stats.high_water_pages as u64;
        self.metrics.kv_pool_high_water.observe(high_water);
        let d = self.kv_pool.stats.evicted.saturating_sub(self.metrics.kv_evictions.get());
        self.metrics.kv_evictions.add(d);
        let d = self
            .kv_pool
            .stats
            .capped_admissions
            .saturating_sub(self.metrics.kv_admissions_capped.get());
        self.metrics.kv_admissions_capped.add(d);
        Ok(&self.metrics)
    }

    // -- event handlers ----------------------------------------------------

    fn dispatch(&mut self, ev: SimEvent) -> Result<()> {
        match ev {
            SimEvent::Arrival(r) => {
                if self.fail_stopped {
                    // Fail-stop tripped: arrivals are shed at the door
                    // (counted, never queued, no deadline timers).
                    self.record_shed(r.id, r.prompt_len, r.arrival, None, "fail-stop");
                    return Ok(());
                }
                if let Some(d) = self.faults.deadlines() {
                    let a = r.arrival.max(0.0);
                    self.queue
                        .push(a + d.ttft_s, SimEvent::DeadlineExceeded { id: r.id, e2e: false });
                    self.queue
                        .push(a + d.e2e_s, SimEvent::DeadlineExceeded { id: r.id, e2e: true });
                }
                // Incremental outlook: the request is in the queue AND has
                // arrived (its timeline event just fired), so it joins the
                // backlog counters here and leaves them at extraction.
                self.backlog_n += 1;
                self.backlog_tokens += r.prompt_len;
                self.sched.admit(r);
                Ok(())
            }
            // Progress + timeline markers; bookkeeping already done.
            SimEvent::PrefillLayerDone { .. } | SimEvent::KvEvicted { .. } => Ok(()),
            SimEvent::PrefillTrigger { id } => self.on_trigger(id),
            SimEvent::PrefillDone { id } => self.on_prefill_done(id),
            SimEvent::SwapDone { to_decode } => self.on_swap_done(to_decode),
            SimEvent::DecodeStepDone { id } => self.on_step_done(id),
            SimEvent::DecodeBatchDone { first, n } => self.on_batch_done(first, n),
            SimEvent::SwapFailed { to_decode } => self.on_swap_failed(to_decode),
            SimEvent::DeadlineExceeded { id, e2e } => self.on_deadline(id, e2e),
            SimEvent::FaultWindowStart { idx } => {
                self.on_fault_window(idx, true);
                Ok(())
            }
            SimEvent::FaultWindowEnd { idx } => {
                self.on_fault_window(idx, false);
                Ok(())
            }
        }
    }

    /// §3.4 trigger: final-layer prefill attention done. Decide whether
    /// to start the decode swap now (overlapping it with the prefill
    /// tail) or keep the prefill RM for more queued prompts.
    fn on_trigger(&mut self, id: u64) -> Result<()> {
        let (job_id, done_at, committed) = match self.prefilling.as_ref() {
            Some(j) => (j.req.id, j.done_at, j.swap_committed),
            None => return Ok(()),
        };
        if job_id != id || committed {
            return Ok(());
        }
        if self.degraded || self.fail_stopped {
            // Degraded mode serves both phases on the static fallback —
            // there is no §3.4 trigger swap to commit (and the repair
            // path owns the PCAP).
            return Ok(());
        }
        if self.shed_due.contains(&id) {
            return Ok(()); // this prefill is deadline-doomed: don't swap for it
        }
        let shape = self.cfg.shape;
        // Decode-side work after this prefill lands.
        let cap = self.kv_pool.token_cap(id).unwrap_or(shape.max_seq);
        let job_req = self.prefilling.as_ref().unwrap();
        let prompt = job_req.req.prompt_len.min(cap);
        let job_rem = job_req
            .req
            .max_new_tokens
            .min(cap.min(shape.max_seq).saturating_sub(prompt));
        let decode_tokens: usize = self.decode_rem_tokens + job_rem;
        if decode_tokens == 0 {
            return Ok(()); // nothing to decode afterwards: keep prefilling
        }
        let o = self.outlook(job_rem, prompt);
        let commit = self.cfg.policy.swap_to_decode_at_trigger(&o);
        self.recorder
            .decision(self.clock, &self.cfg.policy, DecisionPoint::AtTrigger, &o, commit);
        if !commit {
            return Ok(()); // policy keeps the prefill RM
        }
        let was_live = self.swap.device.is_live(RM_DECODE, self.clock);
        let ready = self.swap.trigger_decode_swap(self.clock)?;
        self.fsm
            .begin_swap(true, ready)
            .map_err(|e| anyhow::anyhow!("trigger swap: {e}"))?;
        if !was_live {
            self.metrics.reconfigurations.inc();
            self.metrics.swaps_to_decode.inc();
            let lat = self.overlap_sched.reconfig_latency;
            let exposed = (ready - done_at).max(0.0);
            self.metrics.record_reconfig_exposure(lat, exposed);
            self.recorder.swap_span(self.clock, ready, true, lat, exposed);
        }
        self.prefilling.as_mut().unwrap().swap_committed = true;
        // Decode admissible at max(prefill_end, decode_ready) — §3.4 rule.
        self.queue.push(ready.max(done_at), SimEvent::SwapDone { to_decode: true });
        Ok(())
    }

    fn on_prefill_done(&mut self, id: u64) -> Result<()> {
        let Some(job) = self.prefilling.take() else { return Ok(()) };
        debug_assert_eq!(job.req.id, id);
        if let Some(pos) = self.shed_due.iter().position(|&s| s == id) {
            // Its deadline passed while it was on the fabric: the prefill
            // ran to completion (the work is spent), but the request sheds
            // instead of entering decode.
            self.shed_due.remove(pos);
            self.kv_pool
                .complete(id)
                .map_err(|e| anyhow::anyhow!("shedding request {id}: {e}"))?;
            self.record_shed(id, job.req.prompt_len, job.req.arrival, None, "deadline");
            if !job.swap_committed {
                self.fsm
                    .finish_prefill()
                    .map_err(|e| anyhow::anyhow!("finish prefill: {e}"))?;
            }
            return Ok(());
        }
        let shape = self.cfg.shape;
        let cap = self.kv_pool.token_cap(id).unwrap_or(shape.max_seq);
        self.kv_pool
            .ensure_tokens(id, job.req.prompt_len.min(cap), self.clock)
            .map_err(|e| anyhow::anyhow!("prefill KV write: {e}"))?;
        let f = InFlight::new(job.req, self.clock, cap);
        if f.done(shape.max_seq) {
            // Zero-token generation (or capacity-capped at the prompt):
            // the request completes straight out of prefill.
            self.finish(f)?;
        } else {
            self.decode_rem_tokens += f.remaining(shape.max_seq);
            self.decode.push(f);
        }
        if !job.swap_committed {
            self.fsm
                .finish_prefill()
                .map_err(|e| anyhow::anyhow!("finish prefill: {e}"))?;
        }
        Ok(())
    }

    fn on_swap_done(&mut self, to_decode: bool) -> Result<()> {
        // Fault draw at the instant the PCAP load would land — and only
        // when a load is actually in flight. A was-live no-op SwapDone
        // (the device already held the RM, nothing loaded) consumes no
        // randomness, so low-fault timelines stay aligned with the
        // zero-fault one until the first real load.
        let loading = matches!(self.swap.device.state(), ReconfigState::Loading { .. });
        if self.faults.is_active()
            && loading
            && self.faults.swap_attempt_fails(self.swap_failure_streak)
        {
            return self.on_swap_attempt_failed(to_decode);
        }
        self.swap_failure_streak = 0;
        self.swap.device.settle(self.clock);
        if self.repair_inflight {
            // A degraded-mode background repair landed: the fabric holds
            // a healthy RM again. The FSM never entered `Swapping` for
            // the repair, so there is no swap completion to run.
            self.repair_inflight = false;
            self.exit_degraded();
            return Ok(());
        }
        self.fsm
            .complete_swap(self.clock)
            .map_err(|e| anyhow::anyhow!("swap completion: {e}"))?;
        Ok(())
    }

    /// A PCAP load attempt failed (drawn at its landing time). Retry with
    /// capped exponential backoff in virtual time; on exhaustion, fall
    /// back (degraded static-unified serving with scheduled background
    /// repairs) or trip fail-stop, per [`SwapRetryPolicy`].
    fn on_swap_attempt_failed(&mut self, to_decode: bool) -> Result<()> {
        self.metrics.swap_failures.inc();
        self.swap_failure_streak += 1;
        self.swap
            .device
            .fail_reconfig(self.clock)
            .map_err(|e| anyhow::anyhow!("failing reconfig: {e}"))?;
        self.recorder.swap_failed(self.clock, self.swap_failure_streak, to_decode);
        if self.repair_inflight {
            // A background repair failed: stay degraded, try again after
            // the full backoff cap (repairs are best-effort background
            // work; the streak continues, so the forced-success cap
            // still bounds the loop).
            self.repair_inflight = false;
            self.queue.push(
                self.clock + self.cfg.retry.backoff_cap_s,
                SimEvent::SwapFailed { to_decode },
            );
            return Ok(());
        }
        if self.swap_failure_streak < self.cfg.retry.max_attempts {
            self.queue.push(
                self.clock + self.cfg.retry.backoff(self.swap_failure_streak),
                SimEvent::SwapFailed { to_decode },
            );
            return Ok(());
        }
        // Retries exhausted: abandon the in-flight logical swap. The FSM
        // resumes the phase it left; reconcile that with what the engine
        // actually holds now (the prefill may have completed, the decode
        // set may have drained, while the swap chain was retrying).
        let resumed = self
            .fsm
            .fail_swap()
            .map_err(|e| anyhow::anyhow!("abandoning swap: {e}"))?;
        if let Some(job) = self.prefilling.as_mut() {
            // The §3.4 commit is void — the decode swap it committed to
            // was abandoned — so prefill completion must release the FSM
            // itself again.
            job.swap_committed = false;
        }
        match resumed {
            Phase::Prefill if self.prefilling.is_none() => {
                self.fsm
                    .finish_prefill()
                    .map_err(|e| anyhow::anyhow!("post-failure prefill drain: {e}"))?;
            }
            Phase::Decode if self.decode.is_empty() => {
                self.fsm
                    .finish_request()
                    .map_err(|e| anyhow::anyhow!("post-failure decode drain: {e}"))?;
            }
            _ => {}
        }
        if self.cfg.retry.fail_stop {
            return self.trip_fail_stop();
        }
        self.degraded = true;
        self.degraded_since = self.clock;
        self.recorder.degraded_enter(self.clock);
        // Schedule the first background repair attempt.
        self.queue.push(
            self.clock + self.cfg.retry.backoff_cap_s,
            SimEvent::SwapFailed { to_decode },
        );
        Ok(())
    }

    /// The post-failure backoff elapsed: re-issue the PCAP load — as a
    /// live retry of the in-flight logical swap (FSM still `Swapping`),
    /// or as a degraded-mode background repair.
    fn on_swap_failed(&mut self, to_decode: bool) -> Result<()> {
        if self.fail_stopped {
            return Ok(());
        }
        let rm = if to_decode { RM_DECODE } else { RM_PREFILL };
        if self.degraded {
            if self.repair_inflight {
                return Ok(()); // a repair is already on the PCAP
            }
            let ready = self
                .swap
                .device
                .start_reconfig(rm, self.clock)
                .map_err(|e| anyhow::anyhow!("repair reconfig: {e}"))?;
            self.repair_inflight = true;
            self.recorder
                .swap_retry(self.clock, self.swap_failure_streak + 1, ready - self.clock);
            self.queue.push(ready, SimEvent::SwapDone { to_decode });
            return Ok(());
        }
        self.metrics.swap_retries.inc();
        let ready = self
            .swap
            .device
            .start_reconfig(rm, self.clock)
            .map_err(|e| anyhow::anyhow!("retry reconfig: {e}"))?;
        self.fsm
            .retry_swap(ready)
            .map_err(|e| anyhow::anyhow!("retry swap: {e}"))?;
        self.recorder
            .swap_retry(self.clock, self.swap_failure_streak + 1, ready - self.clock);
        self.queue.push(ready, SimEvent::SwapDone { to_decode });
        Ok(())
    }

    /// SLO deadline timer for `id` fired. "Completed wins": a request
    /// that already finished is untouched (the timer is a no-op).
    /// Otherwise the request sheds from wherever it sits — immediately
    /// if still queued, deferred to a safe point if resident.
    fn on_deadline(&mut self, id: u64, e2e: bool) -> Result<()> {
        if self.fail_stopped {
            return Ok(());
        }
        if let Some(r) = self.sched.remove(id) {
            // Still queued: never admitted, so no pool reservation to
            // free — just the backlog counters.
            self.backlog_n = self.backlog_n.saturating_sub(1);
            self.backlog_tokens = self.backlog_tokens.saturating_sub(r.prompt_len);
            self.record_shed(r.id, r.prompt_len, r.arrival, None, "deadline");
            return Ok(());
        }
        if self.prefilling.as_ref().is_some_and(|j| j.req.id == id) {
            if !self.shed_due.contains(&id) {
                self.shed_due.push(id);
            }
            return Ok(());
        }
        if let Some(f) = self.decode.iter().find(|f| f.req.id == id) {
            // The TTFT bound is met the moment the first decode step
            // started; only the e2e bound can still shed a decoding
            // request.
            if !e2e && f.first_step.is_some() {
                return Ok(());
            }
            if !self.shed_due.contains(&id) {
                self.shed_due.push(id);
            }
        }
        Ok(())
    }

    /// DDR brownout window open/close: a multiplicative slowdown on
    /// every phase latency evaluated while the window is open. Events
    /// already in flight keep their priced times — only newly scheduled
    /// work sees the penalty (and the fast-forward fold cannot straddle
    /// a window edge: these events Block the interference lattice).
    fn on_fault_window(&mut self, idx: usize, start: bool) {
        let Some(w) = self.faults.windows().get(idx).copied() else { return };
        if start {
            self.ddr_penalty = if w.bw_scale > 0.0 { 1.0 / w.bw_scale } else { 1.0 };
            self.recorder.fault_window(
                w.start_s.max(0.0),
                (w.end_s - w.start_s).max(0.0),
                w.bw_scale,
            );
        } else {
            self.ddr_penalty = 1.0;
        }
    }

    /// Leave degraded mode (a repair load landed): close the
    /// degraded-time gauge.
    fn exit_degraded(&mut self) {
        if !self.degraded {
            return;
        }
        self.degraded = false;
        self.metrics.degraded_seconds += (self.clock - self.degraded_since).max(0.0);
        self.recorder.degraded_exit(self.clock);
    }

    /// Count and record a shed request (deadline miss or fail-stop).
    /// Shed requests contribute no tokens to `tokens_generated` and no
    /// samples to the latency histograms — goodput counts useful work
    /// only — but they land in `outcomes` with `shed: true` so the
    /// conservation check (completed + shed == arrivals) is auditable.
    fn record_shed(
        &mut self,
        id: u64,
        prompt_len: usize,
        arrival: f64,
        first_step: Option<f64>,
        reason: &'static str,
    ) {
        self.prefilled.remove(&id);
        self.evicted_once.remove(&id);
        self.metrics.requests_shed.inc();
        self.recorder.request_shed(id, self.clock, reason);
        self.outcomes.push(RequestOutcome {
            id,
            prompt_len,
            generated: Vec::new(),
            ttft: first_step.map(|t| (t - arrival).max(0.0)).unwrap_or(0.0),
            e2e: (self.clock - arrival).max(0.0),
            mean_tpot: 0.0,
            shed: true,
        });
    }

    /// Apply deferred deadline sheds at a safe point (no step in
    /// flight). "Completed wins": a request that finished on the step
    /// already in flight when its deadline fired drops silently here.
    /// A request still prefilling sheds at its `PrefillDone` instead.
    fn drain_shed_due(&mut self) -> Result<()> {
        if self.step_inflight {
            return Ok(());
        }
        let mut i = 0;
        while i < self.shed_due.len() {
            let id = self.shed_due[i];
            if self.prefilling.as_ref().is_some_and(|j| j.req.id == id) {
                i += 1;
                continue;
            }
            self.shed_due.remove(i);
            if let Some(idx) = self.decode.iter().position(|f| f.req.id == id) {
                let f = self.decode.remove(idx);
                self.decode_rem_tokens = self
                    .decode_rem_tokens
                    .saturating_sub(f.remaining(self.cfg.shape.max_seq));
                if idx < self.cursor {
                    self.cursor -= 1;
                }
                self.kv_pool
                    .complete(f.req.id)
                    .map_err(|e| anyhow::anyhow!("shedding request {}: {e}", f.req.id))?;
                self.record_shed(
                    f.req.id,
                    f.req.prompt_len,
                    f.req.arrival,
                    f.first_step,
                    "deadline",
                );
            }
        }
        Ok(())
    }

    /// [`SwapRetryPolicy::fail_stop`] tripped: retries exhausted and no
    /// fallback. Shed everything queued or resident, free every KV
    /// reservation, and refuse future arrivals — the naive baseline the
    /// `fault_tolerance` bench compares degraded fallback against.
    fn trip_fail_stop(&mut self) -> Result<()> {
        self.fail_stopped = true;
        while let Some(id) = self.sched.peek().map(|r| r.id) {
            let r = self.sched.remove(id).expect("peeked head must remove");
            self.record_shed(r.id, r.prompt_len, r.arrival, None, "fail-stop");
        }
        self.backlog_n = 0;
        self.backlog_tokens = 0;
        if let Some(job) = self.prefilling.take() {
            self.kv_pool
                .complete(job.req.id)
                .map_err(|e| anyhow::anyhow!("fail-stop shed {}: {e}", job.req.id))?;
            self.record_shed(job.req.id, job.req.prompt_len, job.req.arrival, None, "fail-stop");
            if matches!(self.fsm.phase(), Phase::Prefill) {
                self.fsm
                    .finish_prefill()
                    .map_err(|e| anyhow::anyhow!("fail-stop prefill drain: {e}"))?;
            }
        }
        debug_assert!(!self.step_inflight, "fail-stop trips only outside a step");
        while let Some(f) = self.decode.pop() {
            self.kv_pool
                .complete(f.req.id)
                .map_err(|e| anyhow::anyhow!("fail-stop shed {}: {e}", f.req.id))?;
            self.record_shed(
                f.req.id,
                f.req.prompt_len,
                f.req.arrival,
                f.first_step,
                "fail-stop",
            );
        }
        self.decode_rem_tokens = 0;
        self.cursor = 0;
        if matches!(self.fsm.phase(), Phase::Decode) {
            self.fsm
                .finish_request()
                .map_err(|e| anyhow::anyhow!("fail-stop decode drain: {e}"))?;
        }
        self.shed_due.clear();
        Ok(())
    }

    /// Degraded-mode phase change: the static fallback hosts both
    /// phases, so the transition is free — zero virtual time, no PCAP
    /// traffic (the repair path owns the device), no swap metrics.
    fn enter_phase_degraded(&mut self, to_decode: bool) -> Result<()> {
        self.fsm
            .begin_swap(to_decode, self.clock)
            .map_err(|e| anyhow::anyhow!("degraded phase change: {e}"))?;
        self.fsm
            .complete_swap(self.clock)
            .map_err(|e| anyhow::anyhow!("degraded phase change: {e}"))?;
        Ok(())
    }

    /// Apply one completed token to stream `id` at the current clock:
    /// context/token growth, the wall inter-token TPOT sample, the pool
    /// LRU touch, completion, and the round-robin cursor advance. The
    /// single source of per-stream token semantics — shared by the
    /// single-stream and batched completion handlers so the two engines
    /// cannot drift.
    fn apply_token_step(&mut self, id: u64) -> Result<()> {
        let Some(idx) = self.decode.iter().position(|f| f.req.id == id) else {
            return Ok(());
        };
        let shape = self.cfg.shape;
        {
            let f = &mut self.decode[idx];
            f.ctx += 1;
            f.tokens += 1;
            let anchor = f.last_token.or(f.first_step).unwrap_or(self.clock);
            f.last_token = Some(self.clock);
            let gap = (self.clock - anchor).max(0.0);
            self.metrics.tpot.record(gap);
        }
        // The applied token shrinks both remaining-token bounds by one.
        self.decode_rem_tokens = self.decode_rem_tokens.saturating_sub(1);
        self.kv_pool.touch(id, self.clock);
        if self.decode[idx].done(shape.max_seq) {
            let f = self.decode.remove(idx);
            self.decode_rem_tokens =
                self.decode_rem_tokens.saturating_sub(f.remaining(shape.max_seq));
            self.finish(f)?;
            if idx < self.cursor {
                self.cursor -= 1;
            }
        } else {
            self.cursor = idx + 1;
        }
        Ok(())
    }

    fn on_step_done(&mut self, id: u64) -> Result<()> {
        self.step_inflight = false;
        self.apply_token_step(id)
    }

    /// A batched decode step completed: every stream the scratch
    /// selection buffer holds gained one token at `self.clock`.
    /// Per-stream bookkeeping is [`Self::apply_token_step`] in selection
    /// order — the same helper the single-stream handler uses, so the two
    /// completion shapes cannot drift. The buffer is read by index (one
    /// step in flight at a time, nothing mutates it mid-handling).
    fn on_batch_done(&mut self, first: u64, n: usize) -> Result<()> {
        self.step_inflight = false;
        debug_assert_eq!(self.batch_ids.len(), n, "selection buffer out of sync");
        debug_assert_eq!(self.batch_ids.first().copied(), Some(first));
        let mut k = 0;
        while k < n && k < self.batch_ids.len() {
            let id = self.batch_ids[k];
            self.apply_token_step(id)?;
            k += 1;
        }
        Ok(())
    }

    // -- decisions ---------------------------------------------------------

    /// Central decision dispatcher, called after every event: whenever
    /// the fabric is free, pick the next action (prefill / decode step /
    /// swap) per the FSM state and the swap policy. `refill` is the
    /// streamed arrival source, forwarded to the fast-forward fold so
    /// absorbed arrivals keep the window full.
    fn pump(&mut self, refill: &mut dyn FnMut() -> Option<Request>) -> Result<()> {
        loop {
            if !self.shed_due.is_empty() {
                self.drain_shed_due()?;
            }
            match self.fsm.phase() {
                // PCAP busy or prefill events in flight: wait.
                Phase::Swapping { .. } | Phase::Prefill => return Ok(()),
                Phase::Decode => {
                    if self.step_inflight {
                        return Ok(());
                    }
                    if self.decode.is_empty() {
                        self.fsm
                            .finish_request()
                            .map_err(|e| anyhow::anyhow!("decode drain: {e}"))?;
                        continue;
                    }
                    // Policy decision point 2: yield the fabric to
                    // waiting prompts?
                    if self.prefill_candidate_ready() {
                        let o = self.outlook(0, 0);
                        let yield_fabric = self.cfg.policy.swap_to_prefill_mid_decode(&o);
                        self.recorder.decision(
                            self.clock,
                            &self.cfg.policy,
                            DecisionPoint::MidDecode,
                            &o,
                            yield_fabric,
                        );
                        if yield_fabric {
                            if self.degraded {
                                // Static fallback hosts both phases: the
                                // phase change is free and never touches
                                // the device.
                                self.enter_phase_degraded(false)?;
                                continue;
                            }
                            return self.begin_prefill_swap();
                        }
                    }
                    // Steady state (dormant backlog, whole decode set
                    // selected every step): fold whole token-steps
                    // analytically before scheduling the next real one.
                    // The fold is bit-identical to stepping, so falling
                    // through to `try_schedule_step` afterwards resumes
                    // the normal path at the fold's boundary (the
                    // completing step, the pool-pressure step, or the
                    // first *interfering* queued event).
                    if self.cfg.fast_forward {
                        self.try_fast_forward(refill)?;
                    }
                    if self.try_schedule_step()? {
                        return Ok(());
                    }
                    // Decode set drained while securing KV pages.
                    continue;
                }
                Phase::Idle => {
                    if self.fail_stopped {
                        return Ok(()); // everything sheds at dispatch
                    }
                    let can_prefill = self.prefill_candidate_ready();
                    let has_decode = !self.decode.is_empty();
                    if !can_prefill && !has_decode {
                        return Ok(()); // idle until the next arrival
                    }
                    if self.degraded {
                        // Static fallback serves both phases without the
                        // device: prefer prompts (they unblock decode
                        // work), else decode what's resident.
                        if can_prefill && self.start_prefill()? {
                            return Ok(());
                        }
                        if has_decode {
                            self.enter_phase_degraded(true)?;
                            continue;
                        }
                        return Ok(());
                    }
                    let prefill_live = self.swap.device.is_live(RM_PREFILL, self.clock);
                    let decode_live = self.swap.device.is_live(RM_DECODE, self.clock);
                    // Contention is resolved relative to the RM that is
                    // already loaded — staying is free, leaving costs a
                    // PCAP pair. (Deciding against the live RM with the
                    // *other* side's rule would let Eager oscillate
                    // between the two swap decisions forever.)
                    let go_prefill = if can_prefill && !has_decode {
                        true
                    } else if has_decode && !can_prefill {
                        false
                    } else if prefill_live {
                        // Fabric is prefill-configured (we just paid to
                        // get here, or are mid queue-drain): keep it;
                        // the §3.4 trigger rule sends it back.
                        true
                    } else if decode_live {
                        // Leaving a live decode RM reuses the mid-decode
                        // rule: waiting prompts vs. the swap pair.
                        let o = self.outlook(0, 0);
                        let yield_fabric = self.cfg.policy.swap_to_prefill_mid_decode(&o);
                        self.recorder.decision(
                            self.clock,
                            &self.cfg.policy,
                            DecisionPoint::MidDecode,
                            &o,
                            yield_fabric,
                        );
                        yield_fabric
                    } else {
                        true // cold fabric: nothing is decodable yet
                    };
                    if !go_prefill {
                        return self.begin_decode_entry();
                    }
                    if !prefill_live {
                        return self.begin_prefill_swap();
                    }
                    if self.start_prefill()? {
                        return Ok(());
                    }
                    // Extraction failed despite the candidate check
                    // (defensive): fall back to decode if possible.
                    if has_decode {
                        return self.begin_decode_entry();
                    }
                    return Ok(());
                }
            }
        }
    }

    /// Is there an arrived, pool-admissible request at the queue head
    /// with a residency slot free?
    fn prefill_candidate_ready(&self) -> bool {
        if self.decode.len() + usize::from(self.prefilling.is_some()) >= self.cfg.max_residents
        {
            return false;
        }
        match self.sched.peek() {
            Some(r) if r.arrival <= self.clock + 1e-12 => {
                self.kv_pool.admits_now(r.prompt_len, r.max_new_tokens)
            }
            _ => false,
        }
    }

    /// Is every residency slot taken by the decode set itself? (During a
    /// fold `prefilling` is `None`, so the decode set alone decides.)
    fn residency_saturated(&self) -> bool {
        self.decode.len() + usize::from(self.prefilling.is_some()) >= self.cfg.max_residents
    }

    /// Can the arrived backlog interfere with a decode fold? A backlog
    /// is **dormant** when `prefill_candidate_ready` is false for a
    /// reason that cannot change while the fold runs (phase stays
    /// `Decode`, no member completes, KV only grows):
    ///
    /// * empty — trivially dormant;
    /// * residency-saturated — the decode set holds every slot and the
    ///   [`member_step_bound`] guarantees no completion inside the fold;
    /// * head not immediately admissible — and *monotonically* so:
    ///   `Fits` needs `need ≤ free_pages`, and free pages only shrink
    ///   while the fold grows KV; `EvictThenFit`'s feasibility depends
    ///   only on `need` vs the pool total (every resident is evictable
    ///   in the plan), which the fold never changes; `Capped` needs an
    ///   empty pool, impossible mid-decode. So an inadmissible head
    ///   stays inadmissible for the whole fold, and the stepped
    ///   equivalent's per-step `prefill_candidate_ready` re-check is
    ///   false at every step — the fold skips nothing the stepped path
    ///   would have done.
    fn backlog_is_dormant(&self) -> bool {
        if self.backlog_n == 0 || self.residency_saturated() {
            return true;
        }
        match self.sched.peek() {
            Some(r) => !self.kv_pool.admits_now(r.prompt_len, r.max_new_tokens),
            None => true,
        }
    }

    /// May a *newly absorbed* arrival be folded through? Mirrors
    /// [`Self::backlog_is_dormant`] for the request the fold is about to
    /// admit into the scheduler queue: with residency saturated it can
    /// never be extracted mid-fold; with a non-empty backlog it joins
    /// the tail behind a head that stays inadmissible (dormancy was
    /// established at fold entry and is monotone); otherwise it becomes
    /// the head itself and must be inadmissible right now.
    fn arrival_is_dormant(&self, r: &Request) -> bool {
        if self.residency_saturated() {
            return true;
        }
        if self.backlog_n == 0 {
            !self.kv_pool.admits_now(r.prompt_len, r.max_new_tokens)
        } else {
            true
        }
    }

    /// Snapshot both phases' backlogs for the policy. `extra_rem` /
    /// `extra_ctx` fold in the request currently prefilling (trigger-time
    /// decisions count it as imminent decode work).
    ///
    /// **Incremental-outlook invariant.** The backlog quantities are NOT
    /// recomputed here: `backlog_n`/`backlog_tokens` track the arrived
    /// queue (updated at arrival, extraction, and eviction-requeue) and
    /// `decode_rem_tokens` tracks the decode set's remaining generation
    /// budget (updated at entry, per applied token, and at every
    /// removal), so a policy decision costs O(1) plus a fold over the
    /// `max_residents`-bounded decode set for the representative context.
    /// Debug builds assert both counters against the full re-scan.
    fn outlook(&self, extra_rem: usize, extra_ctx: usize) -> SwapOutlook {
        let shape = self.cfg.shape;
        let (n_pend, tok_pend) = (self.backlog_n, self.backlog_tokens);
        debug_assert_eq!(
            (n_pend, tok_pend),
            self.sched.arrived_backlog(self.clock),
            "incremental backlog counters diverged from the queue"
        );
        debug_assert_eq!(
            self.decode_rem_tokens,
            self.decode.iter().map(|f| f.remaining(shape.max_seq)).sum::<usize>(),
            "incremental decode-remaining counter diverged from the decode set"
        );
        let decode_pending_tokens = self.decode_rem_tokens + extra_rem;
        let decode_ready = self.decode.len() + usize::from(extra_rem > 0);
        let rep_ctx = self
            .decode
            .iter()
            .map(|f| f.ctx)
            .max()
            .unwrap_or(0)
            .max(extra_ctx)
            .max(1);
        // Policies price decode work at what a token actually costs under
        // the configured residency: with multi-stream decode the batched
        // step amortizes the shared weight stream across the (capped)
        // batch, so the per-token estimate is `batched total / batch`.
        // `decode_batch == 1` keeps the original single-stream estimate
        // bit for bit, and the uniform-context closed form keeps the
        // B > 1 estimate allocation-free.
        let batch = self.cfg.decode_batch.max(1);
        let est_decode_step = if batch <= 1 {
            self.decode_step_total(rep_ctx)
        } else {
            let eff = batch.min(decode_ready.max(1));
            self.decode_uniform_total(rep_ctx, eff) / eff as f64
        };
        let mean_prompt = if n_pend > 0 { (tok_pend / n_pend).max(1) } else { 1 };
        SwapOutlook {
            pending_prefill: n_pend,
            pending_prefill_tokens: tok_pend,
            est_prefill_time: self.est_prefill(n_pend, tok_pend),
            decode_ready,
            decode_pending_tokens,
            est_decode_step,
            reconfig_latency: self.overlap_sched.reconfig_latency,
            est_round_trip_exposed: self.round_trip(mean_prompt),
        }
    }

    /// Start (or skip, if already live) the PCAP load of the prefill RM.
    fn begin_prefill_swap(&mut self) -> Result<()> {
        let was_live = self.swap.device.is_live(RM_PREFILL, self.clock);
        let ready = self.swap.ensure_prefill(self.clock)?;
        self.fsm
            .begin_swap(false, ready)
            .map_err(|e| anyhow::anyhow!("prefill swap: {e}"))?;
        if !was_live {
            self.metrics.reconfigurations.inc();
            self.metrics.swaps_to_prefill.inc();
            // The prefill-direction load has no §3.4 tail to hide behind:
            // the whole PCAP time is exposed (traced, but — as before
            // this telemetry existed — not charged to the exposure
            // histograms, which account the decode-direction §3.4 path).
            let lat = self.overlap_sched.reconfig_latency;
            self.recorder.swap_span(self.clock, ready, false, lat, ready - self.clock);
        }
        self.queue.push(ready, SimEvent::SwapDone { to_decode: false });
        Ok(())
    }

    /// Enter decode from Idle (sequential swap — no prefill tail to hide
    /// behind, so any PCAP time is fully exposed).
    fn begin_decode_entry(&mut self) -> Result<()> {
        let was_live = self.swap.device.is_live(RM_DECODE, self.clock);
        let ready = self.swap.trigger_decode_swap(self.clock)?;
        self.fsm
            .begin_swap(true, ready)
            .map_err(|e| anyhow::anyhow!("decode swap: {e}"))?;
        if !was_live {
            self.metrics.reconfigurations.inc();
            self.metrics.swaps_to_decode.inc();
            let lat = self.overlap_sched.reconfig_latency;
            let exposed = (ready - self.clock).max(0.0);
            self.metrics.record_reconfig_exposure(lat, exposed);
            self.recorder.swap_span(self.clock, ready, true, lat, exposed);
        }
        self.queue.push(ready, SimEvent::SwapDone { to_decode: true });
        Ok(())
    }

    /// Extract the queue head (committing its KV reservation) and put it
    /// on the fabric: schedules per-layer progress, the §3.4 trigger, and
    /// completion. Returns false if extraction yielded nothing.
    fn start_prefill(&mut self) -> Result<bool> {
        let now = self.clock;
        let pool = &mut self.kv_pool;
        let rec = &mut self.recorder;
        let mut batch = self.sched.next_batch_filtered(now, |r| {
            let plan = pool.admission_plan(r.prompt_len, r.max_new_tokens);
            let admitted = plan.admits_immediately()
                && pool.execute_admission(r.id, 0, plan, now).unwrap_or(false);
            let kind = if admitted { "kv-admit" } else { "kv-reject" };
            rec.kv_instant(kind, now, r.id, pool.used_pages(), pool.total_pages());
            admitted
        });
        let Some(req) = batch.pop() else { return Ok(false) };
        // Extraction removes the head from the arrived backlog.
        debug_assert!(self.backlog_n > 0, "extracted a request the backlog never saw");
        self.backlog_n = self.backlog_n.saturating_sub(1);
        self.backlog_tokens = self.backlog_tokens.saturating_sub(req.prompt_len);
        let id = req.id;
        let shape = self.cfg.shape;
        let l = req.prompt_len.max(1);
        let pre_total = self.effective_prefill_total(l);
        let first_pass = self.prefilled.insert(id);
        if !first_pass {
            // Second prefill of an evicted request: pure recompute tax.
            self.metrics.recompute_overhead.record(pre_total);
        }
        let done_at = now + pre_total;
        let trigger_at = if self.cfg.overlap && !self.degraded {
            now + self.with_ddr_penalty(self.trigger_offset(l))
        } else {
            done_at
        };
        self.fsm
            .begin_prefill()
            .map_err(|e| anyhow::anyhow!("begin prefill: {e}"))?;
        let n_layers = shape.n_layers.max(1);
        if self.cfg.prefill_layer_events {
            // Pure progress markers: n_layers − 1 no-op queue events per
            // prefill. Million-request runs disable them (see the
            // `prefill_layer_events` docs — everything except
            // `events_processed` and the diagnostic log is bit-identical
            // either way; the recorder's layer instants below are
            // emitted analytically, not from these events).
            for layer in 1..n_layers {
                let at = now + pre_total * layer as f64 / n_layers as f64;
                self.queue.push(at, SimEvent::PrefillLayerDone { id, layer });
            }
        }
        self.queue.push(trigger_at.min(done_at), SimEvent::PrefillTrigger { id });
        self.queue.push(done_at, SimEvent::PrefillDone { id });
        if self.recorder.is_enabled() {
            // The whole prefill timeline is analytic, so record it here
            // at admission — per-track emission stays monotone in ts.
            if first_pass {
                self.recorder.request_queued(id, req.arrival.max(0.0).min(now), now);
            }
            self.recorder.prefill_span(id, now, pre_total, l, !first_pass);
            let trig_ts = trigger_at.min(done_at);
            let mut layer = 1;
            // Layer instants are monotone; interleave the trigger at its
            // place on the timeline so the track stays ts-ordered.
            while layer < n_layers {
                let at = now + pre_total * layer as f64 / n_layers as f64;
                if at > trig_ts {
                    break;
                }
                self.recorder.prefill_layer(id, at, layer);
                layer += 1;
            }
            self.recorder.trigger(id, trig_ts);
            while layer < n_layers {
                let at = now + pre_total * layer as f64 / n_layers as f64;
                self.recorder.prefill_layer(id, at, layer);
                layer += 1;
            }
        }
        self.prefilling = Some(PrefillJob { req, done_at, swap_committed: false });
        Ok(true)
    }

    /// Analytic decode fast-forward (the [`EventServerConfig::fast_forward`]
    /// gate; pure bounds in [`super::fastforward`], invariant + bitwise
    /// argument in `docs/ARCHITECTURE.md` extension #7).
    ///
    /// Preconditions — the **steady-state invariant**. Any failure just
    /// means the stepped path runs, so declining can never change a run:
    /// * no step event in flight, nothing prefilling, and a **dormant
    ///   arrived backlog** ([`Self::backlog_is_dormant`]: empty,
    ///   residency-saturated, or an immediately-inadmissible head — in
    ///   every case `prefill_candidate_ready` is false for a reason that
    ///   is monotone over the fold, so the stepped equivalent makes no
    ///   policy decision between steps);
    /// * the whole decode set fits one batch (`len ≤ decode_batch`): the
    ///   round-robin selection then picks the same members in the same
    ///   order every step from the same start index;
    /// * no member completes inside the fold
    ///   ([`member_step_bound`]) — completion releases pages, may drain
    ///   the set, and re-enters the Idle-phase decisions;
    /// * every folded step finishes strictly before the earliest
    ///   **interfering** queued event ([`fits_before`]; ties yield to
    ///   the queue's tie-break). A queued *dormant arrival* is not
    ///   interfering: the fold pops it and replays the dispatcher's
    ///   arrival bookkeeping in place (see the interference lattice in
    ///   [`super::fastforward`]), exactly as the stepped engine would
    ///   have between two step events. Each folded step's KV page
    ///   growth is still dry-run against the real reservations —
    ///   pool-exhaustion steps, swaps, evictions, and capacity caps
    ///   always run through the real queue.
    ///
    /// Within those bounds the fold replays [`Self::try_schedule_step`] +
    /// [`Self::apply_token_step`]'s arithmetic in their exact order —
    /// per-member `ensure_tokens`/TTFT anchor at schedule time, the
    /// `clock + step` accumulation, per-member gap → TPOT sample → LRU
    /// touch at completion time — so every float and counter lands
    /// bit-identical, and only the per-token event machinery (heap
    /// push/pop, dispatch, log records, per-token trace spans) is
    /// skipped. Absorbed arrivals commute bitwise with the surrounding
    /// step: the stepped engine pops them mid-step (step in flight, pump
    /// returns immediately), and their bookkeeping (backlog counters,
    /// scheduler append) reads no clock and touches nothing the step's
    /// completion effects read. Telemetry-enabled runs get one coalesced
    /// `decode-ff` span per member instead of `k` `decode-step` spans.
    fn try_fast_forward(
        &mut self,
        refill: &mut dyn FnMut() -> Option<Request>,
    ) -> Result<()> {
        let n = self.decode.len();
        let b_max = self.cfg.decode_batch.max(1);
        if n == 0
            || n > b_max
            || self.step_inflight
            || self.prefilling.is_some()
            || !self.backlog_is_dormant()
        {
            return Ok(());
        }
        let shape = self.cfg.shape;
        let min_rem =
            self.decode.iter().map(|f| f.remaining(shape.max_seq)).min().unwrap_or(0);
        let k_max = member_step_bound(min_rem);
        if k_max == 0 {
            return Ok(());
        }
        // Frozen selection order: the stepped scheduler's first pick
        // reduces the cursor mod len and later picks follow positionally,
        // so with the whole set selected every step starts at `start` and
        // walks the same rotation (`try_schedule_step` re-derives this
        // per step; here it is hoisted).
        let start = self.cursor % n;
        let mut ctxs = std::mem::take(&mut self.batch_ctxs);
        let t0 = self.clock;
        let mut t = t0;
        let mut k: usize = 0;
        let mut step0 = 0.0f64;
        'fold: while k < k_max {
            ctxs.clear();
            for j in 0..n {
                ctxs.push(self.decode[(start + j) % n].ctx);
            }
            let step = self.decode_batch_total(&ctxs);
            // Interference lattice over the earliest queued event:
            // Clear (fires after this step) / Absorb (dormant arrival —
            // pop it, replay the dispatcher's arrival bookkeeping, keep
            // folding) / Block (anything else ends the fold). Absorbing
            // re-peeks: the streamed refill may push the next arrival
            // into the same horizon.
            loop {
                enum Verdict {
                    Clear,
                    Absorb,
                    Block,
                }
                let verdict = match self.queue.peek() {
                    None => Verdict::Clear,
                    Some((at, _)) if fits_before(t, step, Some(at)) => Verdict::Clear,
                    Some((_, SimEvent::Arrival(r))) if self.arrival_is_dormant(r) => {
                        Verdict::Absorb
                    }
                    Some(_) => Verdict::Block, // interfering: step for real
                };
                match verdict {
                    Verdict::Clear => break,
                    Verdict::Block => break 'fold,
                    Verdict::Absorb => {
                        let (at, ev) = self.queue.pop().expect("peeked entry vanished");
                        let (kind, subject) = (ev.kind(), ev.subject());
                        let SimEvent::Arrival(r) = ev else {
                            unreachable!("peeked a dormant arrival")
                        };
                        // Mirror `event_loop` for this one event, minus
                        // the clock max (at ≤ t + step, and the fold
                        // publishes `t + step` after the commit below;
                        // the stepped engine's interim `clock = at` is
                        // never observable — with the step in flight its
                        // pump returns before anything reads the clock).
                        self.events_processed += 1;
                        if self.events_processed > self.event_budget() {
                            self.batch_ctxs = ctxs;
                            bail!("event budget exceeded — serving livelock");
                        }
                        self.log.push(EventRecord { at, kind, subject });
                        self.pull_arrival(refill);
                        // Mirror the dispatcher's deadline-timer pushes in
                        // the exact same order (refill arrival, then TTFT,
                        // then e2e), so the queue's sequence numbering
                        // matches the stepped path's push order.
                        if let Some(d) = self.faults.deadlines() {
                            let a = r.arrival.max(0.0);
                            self.queue.push(
                                a + d.ttft_s,
                                SimEvent::DeadlineExceeded { id: r.id, e2e: false },
                            );
                            self.queue.push(
                                a + d.e2e_s,
                                SimEvent::DeadlineExceeded { id: r.id, e2e: true },
                            );
                        }
                        self.backlog_n += 1;
                        self.backlog_tokens += r.prompt_len;
                        self.sched.admit(r);
                        self.ff.record_absorbed_arrival();
                    }
                }
            }
            // Dry-run this step's KV growth. If any member would exhaust
            // the pool, the whole step — with its partial growth and
            // eviction handling — belongs to the stepped path.
            let mut extra_pages = 0usize;
            for j in 0..n {
                let f = &self.decode[(start + j) % n];
                let need = self.cfg.pool.pages_for_tokens(f.ctx + 1);
                let reserved =
                    self.kv_pool.reserved_pages_of(f.req.id).unwrap_or(0);
                extra_pages += need.saturating_sub(reserved);
            }
            if extra_pages > self.kv_pool.free_pages() {
                break;
            }
            // Commit. Schedule-time effects first (KV growth + the TTFT
            // anchor), exactly as the selection loop orders them ...
            for j in 0..n {
                let i = (start + j) % n;
                let id = self.decode[i].req.id;
                let next_tokens = self.decode[i].ctx + 1;
                self.kv_pool
                    .ensure_tokens(id, next_tokens, t)
                    .map_err(|e| anyhow::anyhow!("kv grow (fast-forward): {e}"))?;
                if self.decode[i].first_step.is_none() {
                    self.decode[i].first_step = Some(t);
                }
            }
            // ... then completion-time effects at `t + step`, member by
            // member in selection order (the `apply_token_step` fold).
            let done_at = t + step;
            for j in 0..n {
                let i = (start + j) % n;
                let id = self.decode[i].req.id;
                {
                    let f = &mut self.decode[i];
                    f.ctx += 1;
                    f.tokens += 1;
                    let anchor = f.last_token.or(f.first_step).unwrap_or(done_at);
                    f.last_token = Some(done_at);
                    let gap = (done_at - anchor).max(0.0);
                    self.metrics.tpot.record(gap);
                }
                self.kv_pool.touch(id, done_at);
            }
            if k == 0 {
                step0 = step;
            }
            t = done_at;
            k += 1;
        }
        self.batch_ctxs = ctxs;
        if k == 0 {
            return Ok(());
        }
        self.clock = t;
        // O(batch) outlook/bookkeeping for the K applied steps: the bulk
        // twin of `apply_token_step`'s per-token decrement and cursor
        // advance (the last applied member leaves `cursor = idx + 1`).
        self.decode_rem_tokens = self.decode_rem_tokens.saturating_sub(k * n);
        self.cursor = (start + n - 1) % n + 1;
        self.ff.record_fold(k as u64);
        if self.recorder.is_enabled() {
            // One coalesced span per member instead of k per-token
            // spans; entry context reconstructs as ctx − k.
            for j in 0..n {
                let f = &self.decode[(start + j) % n];
                self.recorder.decode_fast_forward(
                    f.req.id,
                    t0,
                    t - t0,
                    k,
                    n,
                    f.ctx - k,
                    step0,
                );
            }
        }
        Ok(())
    }

    /// The ONE decode scheduler: select up to `decode_batch` pool-resident
    /// streams in round-robin order (securing each stream's next KV slot,
    /// evicting per policy under pool pressure), then schedule ONE step
    /// event covering all of them — the batch shares a single
    /// weight-stream pass. A selection of one *is* the paper's
    /// single-stream flow: it emits the same `DecodeStepDone` event at
    /// the same virtual time (the batch-1 closed form is bit-identical to
    /// the single-step form), which is how `decode_batch = 1` preserves
    /// the pre-batching engine's timeline bit for bit without a second
    /// scheduler. PR 4's `batched_path_at_batch1_reproduces_single_path_bitwise`
    /// proved this selection loop equivalent to the legacy single-stream
    /// path before that path was deleted.
    ///
    /// The selection writes into the server-owned scratch buffers
    /// (`batch_ids`/`batch_ctxs`), which stay stable until the completion
    /// handler reads them — the steady-state loop performs no heap
    /// allocation. Returns false if the decode set drained instead.
    fn try_schedule_step(&mut self) -> Result<bool> {
        let shape = self.cfg.shape;
        let b_max = self.cfg.decode_batch.max(1);
        // Take the scratch buffers for the selection loop (borrow-splits
        // them from `self`); capacity is retained, so no allocation.
        let mut ids = std::mem::take(&mut self.batch_ids);
        let mut ctxs = std::mem::take(&mut self.batch_ctxs);
        ids.clear();
        ctxs.clear();
        while !self.decode.is_empty() && ids.len() < b_max {
            let len = self.decode.len();
            // Round-robin: the engine cursor picks the first stream; each
            // further candidate follows the previously selected one.
            let i = match ids.last() {
                None => {
                    self.cursor %= len;
                    self.cursor
                }
                Some(last) => {
                    let j = self
                        .decode
                        .iter()
                        .position(|f| f.req.id == *last)
                        .expect("selected stream cannot vanish during selection");
                    (j + 1) % len
                }
            };
            let id = self.decode[i].req.id;
            if ids.contains(&id) {
                break; // wrapped: every ready stream is already batched
            }
            if self.decode[i].done(shape.max_seq) {
                let f = self.decode.remove(i);
                self.decode_rem_tokens =
                    self.decode_rem_tokens.saturating_sub(f.remaining(shape.max_seq));
                self.finish(f)?;
                if i < self.cursor {
                    self.cursor -= 1;
                }
                continue;
            }
            let next_tokens = self.decode[i].ctx + 1;
            match self.kv_pool.ensure_tokens(id, next_tokens, self.clock) {
                Ok(()) => {
                    if self.decode[i].first_step.is_none() {
                        self.decode[i].first_step = Some(self.clock);
                    }
                    ids.push(id);
                    ctxs.push(self.decode[i].ctx);
                }
                Err(PoolError::Exhausted { .. }) => {
                    let evict = self.cfg.pool.eviction == EvictionPolicy::EvictAndRecompute;
                    let victim = if evict {
                        // Streams already in this batch hold the pages the
                        // step is about to use — never victims.
                        self.kv_pool.lru_victim(|v| {
                            v != id
                                && !ids.contains(&v)
                                && !self.evicted_once.contains(&v)
                                && self.decode.iter().any(|f| f.req.id == v)
                        })
                    } else {
                        None
                    };
                    if let Some(vid) = victim {
                        self.kv_pool
                            .evict_at(vid, self.clock)
                            .map_err(|e| anyhow::anyhow!("{e}"))?;
                        self.recorder.kv_instant(
                            "kv-evict",
                            self.clock,
                            vid,
                            self.kv_pool.used_pages(),
                            self.kv_pool.total_pages(),
                        );
                        self.evicted_once.insert(vid);
                        let j = self
                            .decode
                            .iter()
                            .position(|f| f.req.id == vid)
                            .expect("victim must be decoding");
                        let preempted = self.decode.remove(j);
                        self.decode_rem_tokens = self
                            .decode_rem_tokens
                            .saturating_sub(preempted.remaining(shape.max_seq));
                        if j < self.cursor {
                            self.cursor -= 1;
                        }
                        // Back to the queue with the age-based fairness
                        // tiebreak; it rejoins the arrived backlog (its
                        // arrival is in the past by construction).
                        self.backlog_n += 1;
                        self.backlog_tokens += preempted.req.prompt_len;
                        self.sched.requeue_front(preempted.req);
                        self.queue.push(self.clock, SimEvent::KvEvicted { victim: vid });
                        continue;
                    }
                    if !ids.is_empty() {
                        // The exhaustion may be transient — caused by the
                        // batch's own page growth (batch-mates are never
                        // victims). Schedule the partial batch; completing
                        // it can free pages, and this stream gets retried
                        // at its round-robin turn instead of being
                        // silently truncated.
                        break;
                    }
                    // No stream can make progress: deliver what we have
                    // (capacity-capped generation).
                    let f = self.decode.remove(i);
                    self.decode_rem_tokens =
                        self.decode_rem_tokens.saturating_sub(f.remaining(shape.max_seq));
                    self.finish(f)?;
                    if i < self.cursor {
                        self.cursor -= 1;
                    }
                    continue;
                }
                Err(e) => return Err(anyhow::anyhow!("kv grow: {e}")),
            }
        }
        if ids.is_empty() {
            self.batch_ids = ids;
            self.batch_ctxs = ctxs;
            return Ok(false);
        }
        // One closed-form evaluation for the whole selection; a selection
        // of one goes out as the paper's single-stream step event (same
        // arithmetic — the batch-1 form is bit-identical to the single
        // form — and the same event kind the pre-batching engine logged).
        let step = self.decode_batch_total(&ctxs);
        if self.recorder.is_enabled() {
            // Batched steps are attributed to every member stream: each
            // track shows its own token timeline, sharing the step span.
            for (id, ctx) in ids.iter().zip(&ctxs) {
                self.recorder.decode_step(*id, self.clock, step, ids.len(), *ctx);
            }
        }
        if ids.len() == 1 {
            self.queue.push(self.clock + step, SimEvent::DecodeStepDone { id: ids[0] });
        } else {
            self.queue.push(
                self.clock + step,
                SimEvent::DecodeBatchDone { first: ids[0], n: ids.len() },
            );
        }
        self.step_inflight = true;
        self.batch_ids = ids;
        self.batch_ctxs = ctxs;
        Ok(true)
    }

    /// Release the pool reservation and record the outcome.
    fn finish(&mut self, f: InFlight) -> Result<()> {
        self.kv_pool
            .complete(f.req.id)
            .map_err(|e| anyhow::anyhow!("completing request {}: {e}", f.req.id))?;
        // O(resident) memory: a finished id never returns (ids are
        // unique per workload), so its recompute/eviction history is
        // dead weight — without this, the two sets grow with *total*
        // requests served.
        self.prefilled.remove(&f.req.id);
        self.evicted_once.remove(&f.req.id);
        self.recorder.kv_instant(
            "kv-release",
            self.clock,
            f.req.id,
            self.kv_pool.used_pages(),
            self.kv_pool.total_pages(),
        );
        // First token comes out of prefill logits; TTFT counts queueing +
        // prefill + any exposed swap + the wait for the first decode slot.
        let first = f.first_step.unwrap_or(f.prefill_done);
        let ttft = (first - f.req.arrival).max(0.0);
        let e2e = (self.clock - f.req.arrival).max(0.0);
        self.metrics.ttft.record(ttft);
        self.metrics.e2e.record(e2e);
        self.metrics.tokens_generated.add(f.tokens as u64);
        self.metrics.requests_completed.inc();
        let last = f.last_token.unwrap_or(first);
        self.outcomes.push(RequestOutcome {
            id: f.req.id,
            prompt_len: f.req.prompt_len,
            generated: Vec::new(),
            ttft,
            e2e,
            // Wall span of this request's decode divided by its tokens —
            // includes interleaved co-tenants' steps AND any interposed
            // prefill/swap detours (the latency a co-tenant observes).
            mean_tpot: if f.tokens > 0 { (last - first) / f.tokens as f64 } else { 0.0 },
            shed: false,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fingerprint::semantic_fingerprint;
    use crate::fpga::KV260;
    use crate::kvpool::AdmissionControl;
    use crate::model::BITNET_0_73B;

    fn server(policy: SwapPolicy) -> EventServer {
        EventServer::new(EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy))
            .unwrap()
    }

    /// A long-context request decoding while short prompts arrive — the
    /// contention pattern that separates the policies.
    fn contended_workload() -> Vec<Request> {
        let mut w = vec![Request::synthetic(0, 256, 128, 0.0)];
        for i in 0..5u64 {
            w.push(Request::synthetic(1 + i, 64, 8, 4.0 + i as f64));
        }
        w
    }

    #[test]
    fn event_queue_orders_by_time_then_seq() {
        let mut q = EventQueue::default();
        q.push(2.0, SimEvent::PrefillDone { id: 0 });
        q.push(1.0, SimEvent::PrefillTrigger { id: 1 });
        q.push(1.0, SimEvent::PrefillDone { id: 2 });
        q.push(0.5, SimEvent::SwapDone { to_decode: true });
        assert_eq!(q.len(), 4);
        let (t1, e1) = q.pop().unwrap();
        assert_eq!(t1, 0.5);
        assert!(matches!(e1, SimEvent::SwapDone { .. }));
        // Tie at t=1.0: push order wins.
        let (_, e2) = q.pop().unwrap();
        assert!(matches!(e2, SimEvent::PrefillTrigger { id: 1 }));
        let (_, e3) = q.pop().unwrap();
        assert!(matches!(e3, SimEvent::PrefillDone { id: 2 }));
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert!(q.is_empty());
    }

    #[test]
    fn eager_serves_workload_to_completion() {
        let mut s = server(SwapPolicy::Eager);
        let m = s.run(contended_workload()).unwrap();
        assert_eq!(m.requests_completed.get(), 6);
        assert_eq!(m.tokens_generated.get(), 128 + 5 * 8);
        assert!(m.reconfigurations.get() >= 2);
        assert_eq!(
            m.reconfigurations.get(),
            m.swaps_to_prefill.get() + m.swaps_to_decode.get()
        );
        let pool = s.pool();
        pool.check_invariants().unwrap();
        assert_eq!(pool.resident_count(), 0, "pool must drain");
        assert!(s.clock() > 0.0);
        // Latency accounting sane for every request.
        for o in &s.outcomes {
            assert!(o.ttft >= 0.0 && o.e2e >= o.ttft - 1e-9, "{o:?}");
        }
    }

    #[test]
    fn event_log_covers_the_taxonomy() {
        let mut s = server(SwapPolicy::Eager);
        s.run(contended_workload()).unwrap();
        let kinds: std::collections::HashSet<&'static str> =
            s.event_log().iter().map(|r| r.kind).collect();
        for k in [
            "arrival",
            "prefill-layer",
            "prefill-trigger",
            "prefill-done",
            "swap-done-decode",
            "decode-step",
        ] {
            assert!(kinds.contains(k), "missing event kind {k}");
        }
        // The log is time-ordered.
        for w in s.event_log().windows(2) {
            assert!(w[1].at >= w[0].at);
        }
    }

    #[test]
    fn policies_complete_identical_work() {
        let w = contended_workload();
        let mut totals = Vec::new();
        for p in [
            SwapPolicy::Eager,
            SwapPolicy::hysteresis_default(),
            SwapPolicy::lookahead_default(),
        ] {
            let mut s = server(p);
            let m = s.run(w.clone()).unwrap();
            totals.push((m.requests_completed.get(), m.tokens_generated.get()));
        }
        assert!(totals.windows(2).all(|t| t[0] == t[1]), "{totals:?}");
    }

    #[test]
    fn hysteresis_thrashes_less_than_eager() {
        let w = contended_workload();
        let mut eager = server(SwapPolicy::Eager);
        eager.run(w.clone()).unwrap();
        let mut hyst = server(SwapPolicy::hysteresis_default());
        hyst.run(w).unwrap();
        assert!(
            hyst.metrics.reconfigurations.get() < eager.metrics.reconfigurations.get(),
            "hysteresis {} swaps vs eager {}",
            hyst.metrics.reconfigurations.get(),
            eager.metrics.reconfigurations.get()
        );
        // Same work, fewer swap stalls: the batch finishes no later.
        assert!(hyst.clock() <= eager.clock() + 1e-9);
    }

    #[test]
    fn zero_token_requests_complete_out_of_prefill() {
        let mut s = server(SwapPolicy::Eager);
        let w = vec![
            Request::synthetic(0, 128, 0, 0.0),
            Request::synthetic(1, 64, 4, 0.0),
        ];
        let m = s.run(w).unwrap();
        assert_eq!(m.requests_completed.get(), 2);
        assert_eq!(m.tokens_generated.get(), 4);
        let zero = s.outcomes.iter().find(|o| o.id == 0).unwrap();
        assert!(zero.ttft > 0.0, "prefill time counts");
        assert_eq!(zero.mean_tpot, 0.0);
        s.pool().check_invariants().unwrap();
    }

    #[test]
    fn optimistic_pressure_evicts_requeues_and_completes() {
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(40)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut s = EventServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
        s.run(w).unwrap();
        assert_eq!(s.metrics.requests_completed.get(), 4, "evicted requests finish later");
        assert!(s.metrics.kv_evictions.get() >= 1, "pool pressure must evict");
        assert!(s.metrics.recompute_overhead.count() >= 1, "re-prefill charged");
        let pool = s.pool();
        pool.check_invariants().unwrap();
        assert_eq!(pool.resident_count(), 0);
        assert_eq!(pool.stats.admitted, pool.stats.completed + pool.stats.evicted);
    }

    #[test]
    fn overlap_hides_trigger_swap_exposure() {
        // 1800-token prompt: tail ≫ reconfig, and 8 tokens of headroom
        // below max_seq so a decode swap actually happens.
        let w = vec![Request::synthetic(0, 1800, 8, 0.0)];
        let mut with = server(SwapPolicy::Eager);
        with.run(w.clone()).unwrap();
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.overlap = false;
        let mut without = EventServer::new(cfg).unwrap();
        without.run(w).unwrap();
        // At L=1800 the tail hides the whole PCAP load; sequentially the
        // full ~45 ms is exposed.
        assert_eq!(with.metrics.reconfig_exposed.max(), 0.0);
        assert!(without.metrics.reconfig_exposed.max() > 0.03);
        assert!(with.clock() < without.clock());
    }

    #[test]
    fn surface_and_direct_kernels_agree_bitwise() {
        // The surface is a cached restatement of the phase model, not an
        // approximation: the whole virtual timeline must come out
        // bit-identical with it on or off, for every policy.
        for policy in [
            SwapPolicy::Eager,
            SwapPolicy::hysteresis_default(),
            SwapPolicy::lookahead_default(),
        ] {
            let w = contended_workload();
            let mut fast = server(policy);
            fast.run(w.clone()).unwrap();
            let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
            cfg.use_surface = false;
            let mut slow = EventServer::new(cfg).unwrap();
            slow.run(w).unwrap();
            assert_eq!(fast.clock().to_bits(), slow.clock().to_bits(), "{policy:?}");
            assert_eq!(
                fast.metrics.tokens_generated.get(),
                slow.metrics.tokens_generated.get()
            );
            assert_eq!(
                fast.metrics.reconfigurations.get(),
                slow.metrics.reconfigurations.get()
            );
            assert_eq!(
                fast.metrics.tpot.mean().to_bits(),
                slow.metrics.tpot.mean().to_bits()
            );
            assert_eq!(
                fast.metrics.ttft.mean().to_bits(),
                slow.metrics.ttft.mean().to_bits()
            );
        }
    }

    /// The hotpath-kernel bench's backlog-heavy mixed long-context trace
    /// (`benches/hotpath_kernel.rs::mixed_workload`) — the regression
    /// anchor the batch-1 equivalence is pinned on.
    fn bench_mixed_trace() -> Vec<Request> {
        use crate::model::TraceSpec;
        let spec = TraceSpec::mixed_long_context(40, 0.5, BITNET_0_73B.max_seq, 42);
        crate::coordinator::requests_from_trace(&spec.generate())
    }

    /// The swap-policy bench's arrival-storm trace shape (scaled down).
    fn bench_bursty_trace() -> Vec<Request> {
        use crate::model::TraceSpec;
        let spec = TraceSpec::bursty(24, 5);
        crate::coordinator::requests_from_trace(&spec.generate())
    }

    /// Run a trace through the unified core at a decode batch, with the
    /// surface kernel on or off.
    fn run_unified(
        policy: SwapPolicy,
        decode_batch: usize,
        use_surface: bool,
        wl: Vec<Request>,
    ) -> EventServer {
        let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
        cfg.decode_batch = decode_batch;
        cfg.use_surface = use_surface;
        let mut s = EventServer::new(cfg).unwrap();
        s.run(wl).unwrap();
        s
    }

    #[test]
    fn unified_core_reproduces_pr4_clocks_across_backends_and_batches() {
        // The PR 4 contract chain, post-collapse. PR 4 proved (test
        // `batched_path_at_batch1_reproduces_single_path_bitwise`) that
        // the batched selection loop at a batch of one reproduces the
        // legacy single-stream scheduler's virtual clocks bitwise on
        // these exact traces. PR 5 collapsed the engine onto that
        // selection loop unchanged — the de-allocation swapped per-step
        // `Vec`s for value-identical scratch buffers, the batch-1 step
        // still evaluates the closed form that is bit-identical to the
        // single-step form, and a single selection emits the same
        // `DecodeStepDone` event. The live regression pin that remains:
        // the whole timeline must come out bit-identical from the two
        // independent arithmetic backends (surface vs direct phase
        // model), per trace, per policy, at batch 1 AND batch 4 —
        // clocks, wall TPOT, TTFT, e2e, and per-request outcome order.
        for (name, wl) in [
            ("mixed", bench_mixed_trace()),
            ("bursty", bench_bursty_trace()),
        ] {
            for policy in [SwapPolicy::Eager, SwapPolicy::hysteresis_default()] {
                for b in [1usize, 4] {
                    let fast = run_unified(policy, b, true, wl.clone());
                    let slow = run_unified(policy, b, false, wl.clone());
                    assert_eq!(
                        fast.clock().to_bits(),
                        slow.clock().to_bits(),
                        "{name}/{policy:?}/B={b}: virtual clocks diverged"
                    );
                    assert_eq!(
                        fast.metrics.tokens_generated.get(),
                        slow.metrics.tokens_generated.get()
                    );
                    assert_eq!(
                        fast.metrics.reconfigurations.get(),
                        slow.metrics.reconfigurations.get()
                    );
                    assert_eq!(
                        fast.metrics.tpot.mean().to_bits(),
                        slow.metrics.tpot.mean().to_bits(),
                        "{name}/{policy:?}/B={b}: wall TPOT diverged"
                    );
                    assert_eq!(
                        fast.metrics.ttft.mean().to_bits(),
                        slow.metrics.ttft.mean().to_bits()
                    );
                    assert_eq!(
                        fast.metrics.e2e.mean().to_bits(),
                        slow.metrics.e2e.mean().to_bits()
                    );
                    assert_eq!(fast.outcomes.len(), slow.outcomes.len());
                    for (a, c) in fast.outcomes.iter().zip(&slow.outcomes) {
                        assert_eq!(a.id, c.id, "{name}/B={b}: completion order changed");
                        assert_eq!(a.ttft.to_bits(), c.ttft.to_bits());
                        assert_eq!(a.e2e.to_bits(), c.e2e.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn batch_cap_is_inert_with_a_single_resident() {
        // With at most one resident, every selection is a batch of one —
        // so `decode_batch = 4` must reproduce the `decode_batch = 1`
        // timeline bit for bit through the SAME unified scheduler (the
        // only differences a larger cap could introduce are the selection
        // width and the outlook's amortized estimate, and both collapse
        // at an effective batch of one).
        let wl = bench_mixed_trace();
        for policy in [SwapPolicy::Eager, SwapPolicy::hysteresis_default()] {
            let run_b = |b: usize| {
                let mut cfg =
                    EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
                cfg.max_residents = 1;
                cfg.decode_batch = b;
                let mut s = EventServer::new(cfg).unwrap();
                s.run(wl.clone()).unwrap();
                s
            };
            let b1 = run_b(1);
            let b4 = run_b(4);
            assert_eq!(b1.clock().to_bits(), b4.clock().to_bits(), "{policy:?}");
            assert_eq!(
                b1.metrics.tpot.mean().to_bits(),
                b4.metrics.tpot.mean().to_bits()
            );
            assert_eq!(
                b1.metrics.ttft.mean().to_bits(),
                b4.metrics.ttft.mean().to_bits()
            );
            assert_eq!(
                b1.metrics.tokens_generated.get(),
                b4.metrics.tokens_generated.get()
            );
            // Every step event went out as a single-stream step.
            assert!(b4.event_log().iter().all(|r| r.kind != "decode-batch"));
        }
    }

    #[test]
    fn scratch_buffers_never_leak_state_across_steps() {
        // Staggered budgets shrink the live batch 4 → 3 → 2 → 1 as
        // streams complete, so the scratch selection buffers are reused
        // at every width. A stale id leaking across steps would either
        // double-step a stream (token conservation breaks) or step a
        // departed one (the run errors); determinism across a fresh rerun
        // pins the exact timeline.
        let budgets = [8usize, 16, 24, 96];
        let wl: Vec<Request> = budgets
            .iter()
            .enumerate()
            .map(|(i, &g)| Request::synthetic(i as u64, 128, g, 0.0))
            .collect();
        let run = || {
            let mut cfg =
                EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
            cfg.decode_batch = 4;
            let mut s = EventServer::new(cfg).unwrap();
            s.run(wl.clone()).unwrap();
            s
        };
        let a = run();
        let b = run();
        assert_eq!(
            a.metrics.tokens_generated.get(),
            budgets.iter().sum::<usize>() as u64,
            "every stream generated exactly its budget"
        );
        assert_eq!(a.clock().to_bits(), b.clock().to_bits());
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.e2e.to_bits(), y.e2e.to_bits());
        }
        a.pool().check_invariants().unwrap();
        assert_eq!(a.pool().resident_count(), 0);
        // The shrinking batch exercised both event shapes: true batches
        // while several streams were live, single-stream steps at the
        // tail.
        let kinds: std::collections::HashSet<&'static str> =
            a.event_log().iter().map(|r| r.kind).collect();
        assert!(kinds.contains("decode-batch"), "wide batches must have run");
        assert!(kinds.contains("decode-step"), "the lone tail stream steps single");
    }

    #[test]
    fn incremental_outlook_counters_drain_to_zero() {
        // The incremental backlog/remaining counters are debug-asserted
        // against full re-scans at every policy decision; at drain they
        // must all return to zero (conservation end-to-end).
        for policy in [
            SwapPolicy::Eager,
            SwapPolicy::hysteresis_default(),
            SwapPolicy::lookahead_default(),
        ] {
            let mut s = server(policy);
            s.run(contended_workload()).unwrap();
            assert_eq!(s.backlog_n, 0, "{policy:?}");
            assert_eq!(s.backlog_tokens, 0, "{policy:?}");
            assert_eq!(s.decode_rem_tokens, 0, "{policy:?}");
        }
        // Also under eviction pressure (requeues re-enter the backlog).
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(40)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut s = EventServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
        s.run(w).unwrap();
        assert!(s.metrics.kv_evictions.get() >= 1, "pressure must evict");
        assert_eq!(s.backlog_n, 0);
        assert_eq!(s.backlog_tokens, 0);
        assert_eq!(s.decode_rem_tokens, 0);
    }

    #[test]
    fn multistream_decode_amortizes_the_weight_stream() {
        // Four simultaneous residents: at decode_batch 4 every step
        // shares one weight pass, so the workload finishes sooner and the
        // wall inter-token gap shrinks vs the batch-1 engine.
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 64, 0.0)).collect();
        let mut b1 = server(SwapPolicy::Eager);
        b1.run(w.clone()).unwrap();
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.decode_batch = 4;
        let mut b4 = EventServer::new(cfg).unwrap();
        b4.run(w).unwrap();
        assert_eq!(
            b1.metrics.tokens_generated.get(),
            b4.metrics.tokens_generated.get(),
            "same work either way"
        );
        assert!(
            b4.clock() < b1.clock(),
            "batched {:.2}s vs single {:.2}s — batching must shorten the makespan",
            b4.clock(),
            b1.clock()
        );
        assert!(
            b4.metrics.tpot.mean() < b1.metrics.tpot.mean(),
            "batched wall TPOT {:.1}ms vs single {:.1}ms",
            b4.metrics.tpot.mean() * 1e3,
            b1.metrics.tpot.mean() * 1e3
        );
        b4.pool().check_invariants().unwrap();
        assert_eq!(b4.pool().resident_count(), 0);
        // The batched timeline actually used batched step events.
        assert!(b4.event_log().iter().any(|r| r.kind == "decode-batch"));
    }

    #[test]
    fn batched_decode_under_pool_pressure_completes_everyone() {
        // Optimistic admission + small pool: eviction happens mid-batch
        // selection; every request must still complete exactly once.
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.decode_batch = 4;
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(40)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut s = EventServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
        s.run(w).unwrap();
        assert_eq!(s.metrics.requests_completed.get(), 4);
        assert!(s.metrics.kv_evictions.get() >= 1, "pool pressure must evict");
        let pool = s.pool();
        pool.check_invariants().unwrap();
        assert_eq!(pool.resident_count(), 0);
        assert_eq!(pool.stats.admitted, pool.stats.completed + pool.stats.evicted);
    }

    #[test]
    fn tracing_disabled_is_bitwise_identical_to_enabled() {
        // The recorder only reads the virtual clock; flipping it on must
        // not perturb a single bit of the simulation — clocks, latency
        // histograms, token counts, outcome order and values.
        for policy in [
            SwapPolicy::Eager,
            SwapPolicy::hysteresis_default(),
            SwapPolicy::lookahead_default(),
        ] {
            let w = contended_workload();
            let mut off = server(policy);
            off.run(w.clone()).unwrap();
            let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
            cfg.trace = true;
            let mut on = EventServer::new(cfg).unwrap();
            on.run(w).unwrap();
            assert_eq!(off.clock().to_bits(), on.clock().to_bits(), "{policy:?}");
            assert_eq!(
                off.metrics.tpot.mean().to_bits(),
                on.metrics.tpot.mean().to_bits()
            );
            assert_eq!(
                off.metrics.ttft.mean().to_bits(),
                on.metrics.ttft.mean().to_bits()
            );
            assert_eq!(
                off.metrics.e2e.mean().to_bits(),
                on.metrics.e2e.mean().to_bits()
            );
            assert_eq!(
                off.metrics.tokens_generated.get(),
                on.metrics.tokens_generated.get()
            );
            assert_eq!(
                off.metrics.reconfigurations.get(),
                on.metrics.reconfigurations.get()
            );
            assert_eq!(off.outcomes.len(), on.outcomes.len());
            for (a, b) in off.outcomes.iter().zip(&on.outcomes) {
                assert_eq!(a.id, b.id, "{policy:?}: outcome order changed");
                assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
                assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
            }
            // Off really is off; on really recorded the taxonomy.
            assert!(off.recorder.is_empty());
            assert!(!on.recorder.is_empty());
            assert!(on.recorder.decision_count() >= 1, "{policy:?}");
            let names: std::collections::HashSet<&'static str> =
                on.recorder.events().iter().map(|e| e.name).collect();
            for n in ["queued", "prefill", "trigger", "decode-step", "pcap-to-decode"] {
                assert!(names.contains(n), "{policy:?}: missing span {n}");
            }
            crate::telemetry::validate_chrome_trace(&on.recorder.to_chrome_json())
                .unwrap_or_else(|e| panic!("{policy:?}: {e}"));
        }
    }

    #[test]
    fn traces_are_byte_identical_across_runs() {
        let run = || {
            let mut cfg = EventServerConfig::pd_swap(
                BITNET_0_73B,
                KV260.clone(),
                SwapPolicy::lookahead_default(),
            );
            cfg.trace = true;
            let mut s = EventServer::new(cfg).unwrap();
            s.run(contended_workload()).unwrap();
            s.recorder.to_chrome_json().to_string()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn eviction_pressure_trace_stays_well_formed() {
        // Evicted requests re-prefill: their tracks gain re-prefill spans
        // and the KV track gains evict instants — emission must stay
        // ts-monotone per track through the preemption churn.
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.decode_batch = 4;
        cfg.trace = true;
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(40)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut s = EventServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
        s.run(w).unwrap();
        assert!(s.metrics.kv_evictions.get() >= 1);
        let names: std::collections::HashSet<&'static str> =
            s.recorder.events().iter().map(|e| e.name).collect();
        assert!(names.contains("kv-evict"));
        assert!(names.contains("re-prefill"));
        assert!(names.contains("kv-release"));
        crate::telemetry::validate_chrome_trace(&s.recorder.to_chrome_json()).unwrap();
        // The breakdown table covers every request exactly once.
        let table = s.recorder.breakdown_table();
        assert_eq!(table.lines().count(), 1 + 4, "header + one row per request");
    }

    #[test]
    fn run_is_single_shot() {
        let mut s = server(SwapPolicy::Eager);
        s.run(vec![Request::synthetic(0, 64, 4, 0.0)]).unwrap();
        assert!(s.run(vec![]).is_err());
    }

    fn run_ff(
        policy: SwapPolicy,
        batch: usize,
        fast_forward: bool,
        w: Vec<Request>,
    ) -> EventServer {
        let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
        cfg.decode_batch = batch;
        cfg.fast_forward = fast_forward;
        let mut s = EventServer::new(cfg).unwrap();
        s.run(w).unwrap();
        s
    }

    #[test]
    fn fast_forward_is_bit_identical_under_contention() {
        // The tentpole contract: flipping `fast_forward` must not move a
        // single bit of the semantic surface, on a trace that exercises
        // mid-decode arrivals, swaps, and every policy family.
        for policy in [
            SwapPolicy::Eager,
            SwapPolicy::hysteresis_default(),
            SwapPolicy::lookahead_default(),
        ] {
            for batch in [1usize, 4] {
                let on = run_ff(policy, batch, true, contended_workload());
                let off = run_ff(policy, batch, false, contended_workload());
                assert_eq!(
                    semantic_fingerprint(&on),
                    semantic_fingerprint(&off),
                    "{policy:?} B={batch}: fast-forward changed the timeline"
                );
                assert_eq!(off.fast_forward_stats().steps, 0);
                // Every folded token-step stands in for exactly one
                // stepped queue event — no more, no fewer.
                assert_eq!(
                    on.fast_forward_stats()
                        .stepped_equivalent(on.events_processed()),
                    off.events_processed(),
                    "{policy:?} B={batch}: skipped-step accounting drifted"
                );
            }
        }
    }

    #[test]
    fn fast_forward_folds_long_decode_to_few_events() {
        // One long generation with an empty backlog is the best case:
        // all but the completing step fold into a handful of passes.
        let w = vec![Request::synthetic(0, 128, 1024, 0.0)];
        let on = run_ff(SwapPolicy::Eager, 1, true, w.clone());
        let off = run_ff(SwapPolicy::Eager, 1, false, w);
        assert_eq!(semantic_fingerprint(&on), semantic_fingerprint(&off));
        let ff = on.fast_forward_stats();
        assert!(ff.folds >= 1);
        assert!(ff.steps >= 1000, "{ff:?}: nearly every step should fold");
        let ratio = off.events_processed() as f64 / on.events_processed() as f64;
        assert!(ratio >= 10.0, "only {ratio:.1}x fewer events");
    }

    #[test]
    fn fast_forward_defers_to_pool_pressure() {
        // Optimistic admission + tiny pool: decode growth hits
        // `Exhausted` mid-run and evicts. The fold's dry-run must hand
        // every pool-touching step to the stepped path so the eviction
        // log, recompute accounting, and grow-denied stats come out
        // identical either way.
        let mk = |fast_forward: bool| {
            let mut cfg =
                EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
            cfg.decode_batch = 4;
            cfg.fast_forward = fast_forward;
            cfg.pool = cfg
                .pool
                .clone()
                .with_total_pages(40)
                .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
            let mut s = EventServer::new(cfg).unwrap();
            let w: Vec<Request> =
                (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
            s.run(w).unwrap();
            s
        };
        let on = mk(true);
        let off = mk(false);
        assert!(off.metrics.kv_evictions.get() >= 1, "pressure must evict");
        assert_eq!(semantic_fingerprint(&on), semantic_fingerprint(&off));
    }

    #[test]
    fn fast_forward_trace_coalesces_decode_spans() {
        // With tracing on, a fold emits one `decode-ff` span per member
        // instead of hundreds of per-step spans; the trace still
        // validates and the step-exact columns of the breakdown agree.
        let mk = |fast_forward: bool| {
            let mut cfg =
                EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
            cfg.trace = true;
            cfg.fast_forward = fast_forward;
            let mut s = EventServer::new(cfg).unwrap();
            s.run(vec![Request::synthetic(0, 128, 256, 0.0)]).unwrap();
            s
        };
        let on = mk(true);
        let off = mk(false);
        assert_eq!(on.clock().to_bits(), off.clock().to_bits());
        let n_ff = on
            .recorder
            .events()
            .iter()
            .filter(|e| e.name == "decode-ff")
            .count();
        assert!(n_ff >= 1, "the fold must record a coalesced span");
        assert!(
            on.recorder.len() < off.recorder.len(),
            "coalescing must shrink the trace"
        );
        crate::telemetry::validate_chrome_trace(&on.recorder.to_chrome_json()).unwrap();
        // TTFT and token columns are bit-exact by construction (the
        // span carries the first step's exact duration); compare those.
        let col = |table: &str, idx: usize| -> Vec<String> {
            table
                .lines()
                .skip(1)
                .map(|l| l.split_whitespace().nth(idx).unwrap().to_string())
                .collect::<Vec<_>>()
        };
        let (ta, tb) = (on.recorder.breakdown_table(), off.recorder.breakdown_table());
        assert_eq!(col(&ta, 5), col(&tb, 5), "ttft_s column diverged");
        assert_eq!(col(&ta, 6), col(&tb, 6), "token column diverged");
    }

    #[test]
    fn event_queue_pops_arrivals_first_at_ties() {
        // The arrivals-first tie class: an arrival pushed *after* a
        // derived event at the same timestamp still pops first — the
        // rule that makes lazily-pushed (streamed) arrivals land in the
        // same order the bulk-seeded path gives them implicitly.
        let mut q = EventQueue::default();
        q.push(1.0, SimEvent::PrefillDone { id: 0 });
        q.push(1.0, SimEvent::Arrival(Request::synthetic(7, 64, 4, 1.0)));
        let (_, first) = q.pop().unwrap();
        assert!(matches!(first, SimEvent::Arrival(_)), "arrival must win the tie");
        let (_, second) = q.pop().unwrap();
        assert!(matches!(second, SimEvent::PrefillDone { id: 0 }));
        // Within a class, push order still rules.
        q.push(2.0, SimEvent::Arrival(Request::synthetic(8, 64, 4, 2.0)));
        q.push(2.0, SimEvent::Arrival(Request::synthetic(9, 64, 4, 2.0)));
        assert_eq!(q.pop().unwrap().1.subject(), 8);
        assert_eq!(q.pop().unwrap().1.subject(), 9);
    }

    #[test]
    fn event_queue_orders_by_at_then_class_then_seq() {
        // The full (at, class, seq) ordering contract, exercised
        // directly: time is the primary key; at equal times arrivals
        // (class 0) precede every derived event (class 1); within a
        // (time, class) cell push order (seq) rules — regardless of the
        // interleaving the pushes arrived in. peek()/peek_at() must
        // agree with the pop that follows them at every step.
        let mut q = EventQueue::with_capacity(4);
        assert!(q.is_empty());
        assert_eq!(q.peek_at(), None);
        q.push(2.0, SimEvent::DecodeStepDone { id: 20 });
        q.push(1.0, SimEvent::PrefillDone { id: 11 });
        q.push(2.0, SimEvent::Arrival(Request::synthetic(21, 64, 4, 2.0)));
        q.push(1.0, SimEvent::PrefillTrigger { id: 12 });
        q.push(1.0, SimEvent::Arrival(Request::synthetic(10, 64, 4, 1.0)));
        q.push(2.0, SimEvent::DecodeStepDone { id: 22 });
        assert_eq!(q.len(), 6);
        let mut order = Vec::new();
        loop {
            let Some(at_peek) = q.peek_at() else { break };
            let subject_peek = q.peek().map(|(_, ev)| ev.subject()).unwrap();
            let (at, ev) = q.pop().unwrap();
            assert_eq!(at.to_bits(), at_peek.to_bits(), "peek_at disagrees with pop");
            assert_eq!(ev.subject(), subject_peek, "peek disagrees with pop");
            order.push(ev.subject());
        }
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        // t=1: the arrival first (class), then 11, 12 in push order;
        // t=2: the arrival first, then 20, 22 in push order.
        assert_eq!(order, vec![10, 11, 12, 21, 20, 22]);
    }

    /// One saturated long decode with short requests landing mid-stream:
    /// with `max_residents = 1` every mid-decode arrival is provably
    /// dormant (the residency slot is held by the decode itself), so the
    /// fold absorbs them instead of breaking — the swap-adjacent idle
    /// gaps the tentpole targets.
    fn saturated_run(fast_forward: bool) -> EventServer {
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.max_residents = 1;
        cfg.fast_forward = fast_forward;
        let mut s = EventServer::new(cfg).unwrap();
        let mut w = vec![Request::synthetic(0, 128, 768, 0.0)];
        for i in 0..4u64 {
            w.push(Request::synthetic(1 + i, 64, 8, 5.0 + i as f64 * 0.5));
        }
        s.run(w).unwrap();
        s
    }

    #[test]
    fn fold_absorbs_dormant_arrivals_under_saturation() {
        let on = saturated_run(true);
        let off = saturated_run(false);
        assert_eq!(
            semantic_fingerprint(&on),
            semantic_fingerprint(&off),
            "absorbing a dormant arrival moved the timeline"
        );
        let ff = on.fast_forward_stats();
        assert!(
            ff.absorbed_arrivals >= 1,
            "{ff:?}: saturated mid-decode arrivals must be absorbed, not block the fold"
        );
        assert_eq!(off.fast_forward_stats().absorbed_arrivals, 0);
        // Absorbed arrivals are real events (counted in events_processed),
        // so the skipped-step conservation law still closes exactly.
        assert_eq!(
            ff.stepped_equivalent(on.events_processed()),
            off.events_processed(),
            "absorption broke the events + steps conservation law"
        );
        assert_eq!(on.arrivals_total(), 5);
    }

    #[test]
    fn layer_markers_off_is_semantically_identical() {
        // `prefill_layer_events = false` removes n_layers−1 pure-marker
        // queue events per prefill and nothing else: the semantic surface
        // is bit-identical, only `events_processed` and the diagnostic
        // log shrink.
        let run = |markers: bool| {
            let mut cfg =
                EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
            cfg.prefill_layer_events = markers;
            let mut s = EventServer::new(cfg).unwrap();
            s.run(contended_workload()).unwrap();
            s
        };
        let with = run(true);
        let without = run(false);
        assert_eq!(semantic_fingerprint(&with), semantic_fingerprint(&without));
        assert!(without.events_processed() < with.events_processed());
        assert_eq!(
            with.events_processed() - without.events_processed(),
            6 * (BITNET_0_73B.n_layers as u64 - 1),
            "exactly the marker events must disappear (6 prefills)"
        );
        assert!(without.event_log().iter().all(|r| r.kind != "prefill-layer"));
        assert!(with.event_log().iter().any(|r| r.kind == "prefill-layer"));
    }

    #[test]
    fn log_tail_ring_keeps_the_last_records() {
        let full = {
            let mut s = server(SwapPolicy::Eager);
            s.run(contended_workload()).unwrap();
            s
        };
        let tail = {
            let mut cfg =
                EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
            cfg.log_tail = Some(8);
            let mut s = EventServer::new(cfg).unwrap();
            s.run(contended_workload()).unwrap();
            s
        };
        // Same deterministic run, different retention: the ring holds
        // exactly the last 8 records of the full log, in timeline order.
        let full_log = full.event_log();
        let tail_log = tail.event_log();
        assert!(full_log.len() > 8, "fixture too small to exercise the ring");
        assert_eq!(tail_log.len(), 8);
        assert_eq!(tail.event_log_dropped(), (full_log.len() - 8) as u64);
        assert_eq!(full.event_log_dropped(), 0);
        for (a, b) in tail_log.iter().zip(&full_log[full_log.len() - 8..]) {
            assert_eq!((a.at.to_bits(), a.kind, a.subject), (b.at.to_bits(), b.kind, b.subject));
        }
        // Retention shape is diagnostics-only: the timeline is untouched.
        assert_eq!(semantic_fingerprint(&full), semantic_fingerprint(&tail));
    }

    #[test]
    fn outcome_retention_caps_the_sink_but_not_the_metrics() {
        let full = {
            let mut s = server(SwapPolicy::Eager);
            s.run(contended_workload()).unwrap();
            s
        };
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.outcome_retain = 2;
        let mut s = EventServer::new(cfg).unwrap();
        s.run(contended_workload()).unwrap();
        assert_eq!(s.outcomes.len(), 2, "head retention keeps the first two");
        assert_eq!(s.outcomes.dropped(), 4);
        // The retained head is verbatim (same run, same completion order).
        for (a, b) in s.outcomes.iter().zip(full.outcomes.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
        }
        // Aggregates still see every request — only the per-request
        // records are bounded.
        assert_eq!(s.metrics.requests_completed.get(), 6);
        assert_eq!(s.metrics.e2e.count(), 6);
        assert_eq!(
            s.metrics.e2e.mean().to_bits(),
            full.metrics.e2e.mean().to_bits()
        );
    }

    #[test]
    fn run_streamed_matches_run_bitwise_at_unit_scale() {
        // The streaming contract at unit scale (the property test runs
        // the full preset × policy × batch matrix): lazy arrivals through
        // a bounded window reproduce the materialized run bit-for-bit,
        // for any window size.
        let wl = bench_mixed_trace();
        let mut mat = server(SwapPolicy::Eager);
        mat.run(wl.clone()).unwrap();
        for window in [1usize, 2, 7, 64] {
            let mut st = server(SwapPolicy::Eager);
            st.run_streamed(wl.clone(), window).unwrap();
            assert_eq!(
                semantic_fingerprint(&mat),
                semantic_fingerprint(&st),
                "window={window}: streamed run diverged from materialized"
            );
            assert_eq!(st.events_processed(), mat.events_processed(), "window={window}");
            assert_eq!(st.arrivals_total(), mat.arrivals_total());
        }
    }

    #[test]
    fn run_streamed_rejects_unsorted_arrivals() {
        let wl = vec![
            Request::synthetic(0, 64, 4, 1.0),
            Request::synthetic(1, 64, 4, 0.5),
        ];
        // Caught at window seeding…
        let mut s = server(SwapPolicy::Eager);
        let err = s.run_streamed(wl.clone(), 4).unwrap_err().to_string();
        assert!(err.contains("sorted by arrival"), "{err}");
        // …and through the mid-run refill side-channel.
        let mut s = server(SwapPolicy::Eager);
        let err = s.run_streamed(wl, 1).unwrap_err().to_string();
        assert!(err.contains("sorted by arrival"), "{err}");
    }

    #[test]
    fn fast_forward_stops_short_of_queued_arrivals() {
        // A second request lands mid-generation: the fold may only
        // consume the gap strictly before that arrival, then the stepped
        // path takes over so the mid-decode policy decision happens at
        // exactly the stepped clock.
        let w = vec![
            Request::synthetic(0, 128, 512, 0.0),
            Request::synthetic(1, 64, 16, 6.0),
        ];
        for policy in [SwapPolicy::Eager, SwapPolicy::lookahead_default()] {
            let on = run_ff(policy, 1, true, w.clone());
            let off = run_ff(policy, 1, false, w.clone());
            assert_eq!(
                semantic_fingerprint(&on),
                semantic_fingerprint(&off),
                "{policy:?}: arrival horizon broke bit-identity"
            );
            assert!(on.fast_forward_stats().steps > 0, "{policy:?}: nothing folded");
        }
    }
}
