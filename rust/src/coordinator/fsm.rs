//! Phase state machine for the PD-Swap controller.
//!
//! Encodes §3.2.1/§3.4 as checked transitions:
//!
//! ```text
//!        ┌───────────┐ prefill_done(trigger swap) ┌──────────┐
//! Idle ─▶│  Prefill  │───────────────────────────▶│ Swapping │
//!   ▲    └───────────┘                            └────┬─────┘
//!   │          ▲                                       │ swap_done
//!   │          │ next request (swap back to prefill)   ▼
//!   │    ┌─────┴─────┐◀──────────────────────────┌──────────┐
//!   └────│ (Swapping)│        request_done       │  Decode  │
//!        └───────────┘◀──────────────────────────└──────────┘
//! ```
//!
//! Illegal transitions (decode before the swap completes, prefill while
//! decoding, ...) are hard errors — the property tests drive random event
//! sequences at this to show the §3.4 correctness rule can't be violated.

use thiserror::Error;

/// Coordinator phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Idle,
    Prefill,
    /// Partial reconfiguration in flight; payload = target phase.
    Swapping {
        to_decode: bool,
    },
    Decode,
}

/// FSM violation.
#[derive(Debug, Error, PartialEq)]
pub enum FsmError {
    #[error("cannot {event} while in {phase:?}")]
    IllegalTransition { event: &'static str, phase: Phase },
    #[error("decode admission before swap completion (§3.4 violation)")]
    DecodeBeforeSwapDone,
}

/// The phase FSM with swap-completion bookkeeping.
#[derive(Debug, Clone)]
pub struct PhaseFsm {
    phase: Phase,
    /// Simulation/wall time at which the in-flight swap completes.
    swap_done_at: f64,
    /// Phase the in-flight swap departed from — where [`Self::fail_swap`]
    /// returns the machine when the PCAP load is abandoned.
    resume: Phase,
    /// Telemetry: number of swaps performed.
    pub swaps: u64,
}

impl Default for PhaseFsm {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseFsm {
    pub fn new() -> Self {
        Self { phase: Phase::Idle, swap_done_at: 0.0, resume: Phase::Idle, swaps: 0 }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// Admit a request: Idle -> Prefill (the prefill RM must already be
    /// live — on a cold device call `begin_swap(to_decode=false)` first).
    pub fn begin_prefill(&mut self) -> Result<(), FsmError> {
        match self.phase {
            Phase::Idle => {
                self.phase = Phase::Prefill;
                Ok(())
            }
            p => Err(FsmError::IllegalTransition { event: "begin_prefill", phase: p }),
        }
    }

    /// Start a partial reconfiguration completing at `done_at`.
    /// Legal from Idle (cold load), Prefill (the §3.4 early trigger — the
    /// prefill *tail* keeps running in the static region), or Decode
    /// (swap back for the next request).
    pub fn begin_swap(&mut self, to_decode: bool, done_at: f64) -> Result<(), FsmError> {
        match self.phase {
            Phase::Idle | Phase::Prefill | Phase::Decode => {
                self.resume = self.phase;
                self.phase = Phase::Swapping { to_decode };
                self.swap_done_at = done_at;
                self.swaps += 1;
                Ok(())
            }
            p @ Phase::Swapping { .. } => {
                Err(FsmError::IllegalTransition { event: "begin_swap", phase: p })
            }
        }
    }

    /// Re-arm the in-flight swap after a failed PCAP load attempt: stay
    /// in `Swapping` (the retried load occupies the serial PCAP exactly
    /// like the first attempt did — a concurrent `begin_swap` is still
    /// illegal, so a retry can never double-book the RP) with a new
    /// completion deadline. Legal **only** mid-swap.
    pub fn retry_swap(&mut self, done_at: f64) -> Result<(), FsmError> {
        match self.phase {
            Phase::Swapping { .. } => {
                self.swap_done_at = done_at;
                Ok(())
            }
            p => Err(FsmError::IllegalTransition { event: "retry_swap", phase: p }),
        }
    }

    /// Abandon the in-flight swap (retry budget exhausted): return to the
    /// phase the swap departed from. The caller owns reconciling that
    /// phase with reality — e.g. a §3.4 trigger swap departs from
    /// `Prefill`, but by the time its retries exhaust the prefill job has
    /// finished, so the engine immediately follows with
    /// [`Self::finish_prefill`]. Legal **only** mid-swap.
    pub fn fail_swap(&mut self) -> Result<Phase, FsmError> {
        match self.phase {
            Phase::Swapping { .. } => {
                self.phase = self.resume;
                Ok(self.phase)
            }
            p => Err(FsmError::IllegalTransition { event: "fail_swap", phase: p }),
        }
    }

    /// Complete the swap at time `now`. Errors if the PCAP hasn't finished.
    pub fn complete_swap(&mut self, now: f64) -> Result<Phase, FsmError> {
        match self.phase {
            Phase::Swapping { to_decode } => {
                if now + 1e-12 < self.swap_done_at {
                    return Err(FsmError::DecodeBeforeSwapDone);
                }
                self.phase = if to_decode { Phase::Decode } else { Phase::Idle };
                Ok(self.phase)
            }
            p => Err(FsmError::IllegalTransition { event: "complete_swap", phase: p }),
        }
    }

    /// Finish a prefill *without* a committed decode swap: Prefill ->
    /// Idle. Used by the continuous event-driven server when the swap
    /// policy decides to keep the prefill RM and serve another queued
    /// prompt instead of triggering the §3.4 decode swap.
    pub fn finish_prefill(&mut self) -> Result<(), FsmError> {
        match self.phase {
            Phase::Prefill => {
                self.phase = Phase::Idle;
                Ok(())
            }
            p => Err(FsmError::IllegalTransition { event: "finish_prefill", phase: p }),
        }
    }

    /// Finish decoding a request: Decode -> Idle.
    pub fn finish_request(&mut self) -> Result<(), FsmError> {
        match self.phase {
            Phase::Decode => {
                self.phase = Phase::Idle;
                Ok(())
            }
            p => Err(FsmError::IllegalTransition { event: "finish_request", phase: p }),
        }
    }

    /// Can decode work be admitted right now?
    pub fn decode_admissible(&self, now: f64) -> bool {
        match self.phase {
            Phase::Decode => true,
            Phase::Swapping { to_decode: true } => now + 1e-12 >= self.swap_done_at,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn happy_path() {
        let mut f = PhaseFsm::new();
        // Cold load of the prefill RM.
        f.begin_swap(false, 0.045).unwrap();
        f.complete_swap(0.045).unwrap();
        f.begin_prefill().unwrap();
        // §3.4 early trigger while the tail runs.
        f.begin_swap(true, 1.045).unwrap();
        assert!(!f.decode_admissible(1.0));
        f.complete_swap(1.05).unwrap();
        assert_eq!(f.phase(), Phase::Decode);
        assert!(f.decode_admissible(1.05));
        f.finish_request().unwrap();
        assert_eq!(f.phase(), Phase::Idle);
        assert_eq!(f.swaps, 2);
    }

    #[test]
    fn decode_before_swap_completion_is_rejected() {
        let mut f = PhaseFsm::new();
        f.begin_prefill().unwrap();
        f.begin_swap(true, 10.0).unwrap();
        assert_eq!(f.complete_swap(9.0).unwrap_err(), FsmError::DecodeBeforeSwapDone);
        assert!(!f.decode_admissible(9.0));
        // Completing on time works.
        f.complete_swap(10.0).unwrap();
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut f = PhaseFsm::new();
        assert!(f.finish_request().is_err());
        assert!(f.complete_swap(0.0).is_err());
        f.begin_prefill().unwrap();
        assert!(f.begin_prefill().is_err());
        f.begin_swap(true, 1.0).unwrap();
        assert!(f.begin_swap(true, 2.0).is_err(), "PCAP is serial");
        assert!(f.begin_prefill().is_err());
    }

    #[test]
    fn back_to_back_prefills_without_swap() {
        // The continuous server's "stay in prefill" path: each prefill
        // closes with finish_prefill, no swap in between.
        let mut f = PhaseFsm::new();
        f.begin_swap(false, 0.01).unwrap();
        f.complete_swap(0.01).unwrap();
        for _ in 0..3 {
            f.begin_prefill().unwrap();
            f.finish_prefill().unwrap();
        }
        assert_eq!(f.phase(), Phase::Idle);
        assert_eq!(f.swaps, 1, "only the cold load swapped");
        // finish_prefill is only legal from Prefill.
        assert!(f.finish_prefill().is_err());
    }

    #[test]
    fn failed_trigger_swap_retried_mid_prefill_never_double_books() {
        // §3.4 storm scenario: the decode swap triggered mid-prefill
        // fails and is retried (possibly repeatedly). Throughout, the
        // machine stays in Swapping — a second begin_swap (which would
        // double-book the serial PCAP / the RP region) stays illegal,
        // and decode admission honors the *latest* retry deadline.
        let mut f = PhaseFsm::new();
        f.begin_swap(false, 0.045).unwrap();
        f.complete_swap(0.045).unwrap();
        f.begin_prefill().unwrap();
        f.begin_swap(true, 1.045).unwrap(); // early trigger
        for attempt in 1..=3u32 {
            let redo = 1.045 + attempt as f64 * 0.050;
            f.retry_swap(redo).unwrap();
            assert!(matches!(f.phase(), Phase::Swapping { to_decode: true }));
            assert!(f.begin_swap(true, redo).is_err(), "retry must not double-book");
            assert!(f.begin_swap(false, redo).is_err());
            assert!(!f.decode_admissible(redo - 0.001), "old deadline must not leak");
            assert!(f.decode_admissible(redo));
        }
        assert_eq!(f.swaps, 2, "retries re-arm the same logical swap");
        f.complete_swap(1.195).unwrap();
        assert_eq!(f.phase(), Phase::Decode);
    }

    #[test]
    fn exhausted_trigger_swap_resumes_prefill_then_finishes_once() {
        // Retry budget exhausted mid-prefill: fail_swap returns to
        // Prefill (the departed-from phase), after which finish_prefill
        // is legal exactly once — the inconsistent double-finish the
        // satellite test guards against is an error.
        let mut f = PhaseFsm::new();
        f.begin_swap(false, 0.045).unwrap();
        f.complete_swap(0.045).unwrap();
        f.begin_prefill().unwrap();
        f.begin_swap(true, 1.045).unwrap();
        f.retry_swap(1.095).unwrap();
        assert_eq!(f.fail_swap().unwrap(), Phase::Prefill);
        assert!(f.fail_swap().is_err(), "nothing in flight to fail");
        assert!(f.retry_swap(2.0).is_err(), "nothing in flight to retry");
        f.finish_prefill().unwrap();
        assert!(f.finish_prefill().is_err(), "finish_prefill must not re-enter");
        assert_eq!(f.phase(), Phase::Idle);
    }

    #[test]
    fn exhausted_swap_resumes_decode_and_idle() {
        // fail_swap from a decode→prefill swap resumes Decode; from a
        // cold (Idle) load it resumes Idle.
        let mut f = PhaseFsm::new();
        f.begin_swap(true, 0.045).unwrap();
        assert_eq!(f.fail_swap().unwrap(), Phase::Idle);
        f.begin_swap(true, 0.1).unwrap();
        f.complete_swap(0.1).unwrap();
        f.begin_swap(false, 0.2).unwrap();
        assert_eq!(f.fail_swap().unwrap(), Phase::Decode);
        f.finish_request().unwrap();
        assert_eq!(f.phase(), Phase::Idle);
    }

    #[test]
    fn swap_back_to_prefill_from_decode() {
        let mut f = PhaseFsm::new();
        f.begin_swap(false, 0.0).unwrap();
        f.complete_swap(0.0).unwrap();
        f.begin_prefill().unwrap();
        f.begin_swap(true, 0.1).unwrap();
        f.complete_swap(0.1).unwrap();
        // Next request arrives: swap back while still in Decode.
        f.begin_swap(false, 0.2).unwrap();
        assert_eq!(f.complete_swap(0.2).unwrap(), Phase::Idle);
        f.begin_prefill().unwrap();
    }
}
