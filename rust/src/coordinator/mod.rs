//! The inference coordinator — the paper's PS-side "global inference
//! controller" (§3.2.1), generalized into a small serving runtime.
//!
//! * [`request`] — request/response types + the synthetic workload
//!   generator (Poisson arrivals, edge-profile prompt/generation lengths).
//! * [`fsm`] — the phase state machine: `Idle → Prefill → Swapping →
//!   Decode → ...`, enforcing the §3.4 safety rule (no decode until the
//!   decode RM is fully loaded) as a type-level protocol.
//! * [`scheduler`] — admission + batching policies. `SwapPerRequest` is
//!   the paper's flow; `BatchedPhases` amortizes one swap over a queue of
//!   requests (our extension for the multi-request edge scenario §3.4
//!   worries about). Batch extraction is gated per-request by the KV
//!   pool ([`Scheduler::next_batch_filtered`]) and evicted requests
//!   re-enter at the queue front ([`Scheduler::requeue_front`]).
//! * [`sim_server`] — phase-batch serving simulation on the KV260 model:
//!   every figure in the paper's evaluation is a query against this. It
//!   owns a [`crate::kvpool::KvPool`]: requests are admitted only when
//!   their pages fit the modeled DDR KV budget, decode rounds interleave
//!   round-robin across residents, and pool exhaustion triggers the
//!   configured eviction policy (evict-and-recompute or cap-in-place).
//! * [`events`] — the continuous event-driven serving core: a
//!   virtual-clock event queue over arrivals, per-layer prefill
//!   completions, decode steps, PCAP swap start/finish, and KV-pool
//!   evictions, with swap-scheduling policies
//!   ([`crate::reconfig::SwapPolicy`]) arbitrating the single
//!   reconfigurable attention slot under mixed traffic (our serving
//!   extension; `EagerSwap` reproduces the paper's behavior).
//! * [`fastforward`] — the pure bounds behind the event core's analytic
//!   decode fast-forward: steady-state decode stretches are folded into
//!   one pass, bit-identical to the stepped path but O(1) in events,
//!   with dormant arrivals absorbed mid-fold (the interference lattice;
//!   see `docs/ARCHITECTURE.md` extensions #7–#8).
//! * [`fingerprint`] — the shared semantic fingerprint every bitwise
//!   engine-equivalence pin compares, from the hand-written property
//!   tests to the differential fuzzer's oracle ([`crate::fuzz`]).
//! * [`live`] — the same coordinator logic driving *real* PJRT execution
//!   of the AOT artifacts (tokens are real; FPGA timing is reported from
//!   the simulator running in lockstep). Requires the `pjrt` cargo
//!   feature (and an XLA installation).

pub mod events;
pub mod fastforward;
pub mod fingerprint;
pub mod fsm;
#[cfg(feature = "pjrt")]
pub mod live;
pub mod request;
pub mod scheduler;
pub mod sim_server;

pub use events::{EventQueue, EventRecord, EventServer, EventServerConfig, SimEvent};
pub use fastforward::FastForwardStats;
pub use fingerprint::semantic_fingerprint;
pub use fsm::{Phase, PhaseFsm};
#[cfg(feature = "pjrt")]
pub use live::{LiveServer, LiveServerConfig};
pub use request::{
    generate_workload, OutcomeSink, Request, RequestOutcome, requests_from_stream,
    requests_from_trace, WorkloadConfig,
};
pub use scheduler::{Policy, Scheduler};
pub use sim_server::{SimServer, SimServerConfig};
