//! Admission and batching policy.
//!
//! The paper serves one request at a time (edge profile) with one swap per
//! request. When several short requests queue up, each would pay its own
//! swap pair — §3.4 notes "multiple short-token requests in edge scenarios
//! may still expose noticeable delays". [`Policy::BatchedPhases`] is the
//! natural coordinator-level answer (our extension, labeled as such in
//! EXPERIMENTS.md): drain the queue phase-by-phase — prefill every queued
//! request under the prefill RM, swap once, then decode them all — paying
//! one swap pair per *batch* instead of per request.

use std::collections::VecDeque;

use super::request::Request;

/// Batching policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's flow: prefill -> swap -> decode, per request.
    SwapPerRequest,
    /// Drain the queue in phases, one swap pair per batch (extension).
    BatchedPhases {
        /// Cap on requests per phase-batch (KV-cache DDR footprint bound).
        max_batch: usize,
    },
}

/// FIFO scheduler with policy-driven batch extraction.
#[derive(Debug)]
pub struct Scheduler {
    pub policy: Policy,
    queue: VecDeque<Request>,
    /// Conservation accounting (checked by the property tests): at drain,
    /// `dispatched == admitted + requeued`.
    pub admitted: u64,
    pub dispatched: u64,
    /// Requests put back at the queue front (KV-pool preemption).
    pub requeued: u64,
    /// Batch extractions cut short by an admission rejection (the head
    /// request stayed queued for a later batch).
    pub deferrals: u64,
    /// Requests removed from the queue without dispatch (SLO deadline
    /// shed / fail-stop). Conservation with removal becomes
    /// `dispatched + removed == admitted + requeued` at drain.
    pub removed: u64,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Self {
        Self {
            policy,
            queue: VecDeque::new(),
            admitted: 0,
            dispatched: 0,
            requeued: 0,
            deferrals: 0,
            removed: 0,
        }
    }

    pub fn admit(&mut self, r: Request) {
        self.admitted += 1;
        self.queue.push_back(r);
    }

    /// Reserve backlog capacity ahead of a bulk admission wave (the
    /// event server's overload regimes park whole arrival bursts here;
    /// reserving once beats the VecDeque's doubling growth).
    pub fn reserve(&mut self, n: usize) {
        self.queue.reserve(n);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Head of the queue (the next request strict FIFO would serve).
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// `(count, total prompt tokens)` of queued requests that have
    /// already arrived at `now` — the prefill-side backlog the swap
    /// policies weigh against interrupting decode.
    pub fn arrived_backlog(&self, now: f64) -> (usize, usize) {
        self.queue
            .iter()
            .filter(|r| r.arrival <= now + 1e-12)
            .fold((0, 0), |(n, t), r| (n + 1, t + r.prompt_len))
    }

    /// Earliest arrival among queued requests (for clock advancement).
    pub fn next_arrival(&self) -> Option<f64> {
        self.queue.iter().map(|r| r.arrival).fold(None, |acc, a| {
            Some(acc.map_or(a, |b: f64| b.min(a)))
        })
    }

    /// Extract the next batch to serve at time `now`: requests that have
    /// arrived, respecting FIFO order and the policy's batch cap.
    pub fn next_batch(&mut self, now: f64) -> Vec<Request> {
        self.next_batch_filtered(now, |_| true)
    }

    /// [`Self::next_batch`] with a per-request admission gate (the KV-pool
    /// hook): extraction stops at the first queued request `admit`
    /// rejects — strict FIFO, no head-of-line bypass, so a rejected head
    /// is retried first in a later batch when the pool has drained. The
    /// callback typically reserves pool pages as a side effect.
    pub fn next_batch_filtered<F>(&mut self, now: f64, mut admit: F) -> Vec<Request>
    where
        F: FnMut(&Request) -> bool,
    {
        let cap = match self.policy {
            Policy::SwapPerRequest => 1,
            Policy::BatchedPhases { max_batch } => max_batch.max(1),
        };
        let mut batch = Vec::new();
        while batch.len() < cap {
            match self.queue.front() {
                Some(r) if r.arrival <= now + 1e-12 => {
                    if !admit(r) {
                        self.deferrals += 1;
                        break;
                    }
                    batch.push(self.queue.pop_front().unwrap());
                }
                _ => break,
            }
        }
        self.dispatched += batch.len() as u64;
        batch
    }

    /// Preemption hook: an evicted request goes back toward the queue
    /// front so it is re-served (and re-prefilled) promptly — with an
    /// age-based fairness tiebreak. A first preemption returns to the
    /// very front (the recompute tax should not also pay a full queueing
    /// delay), but a request preempted `k` times yields `k−1` positions
    /// to the waiters it has already delayed, and never jumps ahead of a
    /// request that arrived before it did. Without this, a long-context
    /// decode that keeps losing its KV reservation parks at the head
    /// forever and — because batch extraction is strict FIFO — starves
    /// every newly arrived prefill behind it.
    pub fn requeue_front(&mut self, mut r: Request) {
        self.requeued += 1;
        r.requeues += 1;
        // Insert after the LAST strictly-older entry (earlier yields may
        // have interleaved younger requests ahead of older ones, so a
        // prefix scan would undercount).
        let older = self
            .queue
            .iter()
            .rposition(|q| q.arrival < r.arrival)
            .map_or(0, |i| i + 1);
        let yielded = (r.requeues as usize - 1).min(self.queue.len());
        self.queue.insert(older.max(yielded).min(self.queue.len()), r);
    }

    /// Remove a queued request by id without dispatching it (the SLO
    /// shed path: its deadline passed while it waited). Returns the
    /// request so the caller can record the shed outcome; `None` if `id`
    /// is not queued (already dispatched or never admitted).
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let pos = self.queue.iter().position(|r| r.id == id)?;
        self.removed += 1;
        self.queue.remove(pos)
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request::synthetic(id, 64, 16, arrival)
    }

    #[test]
    fn fifo_order_preserved() {
        let mut s = Scheduler::new(Policy::BatchedPhases { max_batch: 8 });
        for i in 0..5 {
            s.admit(req(i, i as f64 * 0.1));
        }
        let batch = s.next_batch(1.0);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn swap_per_request_takes_one() {
        let mut s = Scheduler::new(Policy::SwapPerRequest);
        s.admit(req(0, 0.0));
        s.admit(req(1, 0.0));
        assert_eq!(s.next_batch(0.0).len(), 1);
        assert_eq!(s.next_batch(0.0).len(), 1);
        assert!(s.next_batch(0.0).is_empty());
    }

    #[test]
    fn future_arrivals_not_dispatched() {
        let mut s = Scheduler::new(Policy::BatchedPhases { max_batch: 8 });
        s.admit(req(0, 0.0));
        s.admit(req(1, 5.0));
        let b = s.next_batch(1.0);
        assert_eq!(b.len(), 1);
        assert_eq!(s.queue_len(), 1);
        assert_eq!(s.next_arrival(), Some(5.0));
    }

    #[test]
    fn batch_cap_respected() {
        let mut s = Scheduler::new(Policy::BatchedPhases { max_batch: 3 });
        for i in 0..7 {
            s.admit(req(i, 0.0));
        }
        assert_eq!(s.next_batch(0.0).len(), 3);
        assert_eq!(s.next_batch(0.0).len(), 3);
        assert_eq!(s.next_batch(0.0).len(), 1);
    }

    #[test]
    fn filtered_extraction_stops_at_rejection_without_bypass() {
        let mut s = Scheduler::new(Policy::BatchedPhases { max_batch: 8 });
        for i in 0..5 {
            s.admit(req(i, 0.0));
        }
        // Reject request 2: the batch is 0,1 — 3 and 4 must NOT bypass.
        let batch = s.next_batch_filtered(0.0, |r| r.id != 2);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.queue_len(), 3);
        assert_eq!(s.deferrals, 1);
        // Next attempt admits everything remaining, head first.
        let batch = s.next_batch_filtered(0.0, |_| true);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    #[test]
    fn requeue_front_preempts_fifo() {
        let mut s = Scheduler::new(Policy::BatchedPhases { max_batch: 8 });
        s.admit(req(0, 0.0));
        s.admit(req(1, 0.0));
        let batch = s.next_batch(0.0);
        assert_eq!(batch.len(), 2);
        // Evict request 1 mid-serve; it must come back before any newer work.
        s.admit(req(2, 0.0));
        s.requeue_front(batch[1].clone());
        let batch = s.next_batch(0.0);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(s.requeued, 1);
        assert_eq!(s.dispatched, 4, "request 1 dispatched twice");
        assert_eq!(s.dispatched, s.admitted + s.requeued);
    }

    #[test]
    fn repeated_requeue_yields_to_waiters() {
        let mut s = Scheduler::new(Policy::SwapPerRequest);
        s.admit(req(0, 0.0)); // long-context request, will thrash
        s.admit(req(1, 0.1));
        s.admit(req(2, 0.2));
        // First preemption: straight back to the front.
        let long = s.next_batch(1.0).pop().unwrap();
        s.requeue_front(long);
        let long = s.next_batch(1.0).pop().unwrap();
        assert_eq!(long.id, 0);
        // Second preemption: yields one position — request 1 now runs
        // before the thrashing request.
        s.requeue_front(long);
        assert_eq!(s.next_batch(1.0).pop().unwrap().id, 1);
        let long = s.next_batch(1.0).pop().unwrap();
        assert_eq!(long.id, 0);
        // Third preemption: yields two positions, but only one waiter is
        // left, so it lands at the back.
        s.requeue_front(long);
        assert_eq!(s.next_batch(1.0).pop().unwrap().id, 2);
        assert_eq!(s.next_batch(1.0).pop().unwrap().id, 0);
        assert!(s.is_empty());
        assert_eq!(s.dispatched, s.admitted + s.requeued);
    }

    #[test]
    fn requeue_never_jumps_older_arrivals() {
        let mut s = Scheduler::new(Policy::BatchedPhases { max_batch: 8 });
        s.admit(req(0, 0.0));
        s.admit(req(1, 5.0));
        let batch = s.next_batch(10.0);
        assert_eq!(batch.len(), 2);
        // Both preempted, oldest first: 0 goes back to the front, and 1
        // — though preempted for the first time — must not cut ahead of
        // the older request 0.
        s.requeue_front(batch[0].clone());
        s.requeue_front(batch[1].clone());
        let order: Vec<u64> = s.next_batch(10.0).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn remove_sheds_by_id_and_counts() {
        let mut s = Scheduler::new(Policy::BatchedPhases { max_batch: 8 });
        for i in 0..3 {
            s.admit(req(i, 0.0));
        }
        let r = s.remove(1).expect("queued");
        assert_eq!(r.id, 1);
        assert!(s.remove(1).is_none(), "second removal finds nothing");
        assert!(s.remove(99).is_none(), "unknown id finds nothing");
        let order: Vec<u64> = s.next_batch(0.0).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 2], "FIFO order survives the removal");
        assert_eq!(s.removed, 1);
        assert!(s.is_empty());
        // Conservation with the shed path: dispatched + removed
        // accounts for every admission.
        assert_eq!(s.dispatched + s.removed, s.admitted + s.requeued);
    }

    #[test]
    fn conservation_counters() {
        let mut s = Scheduler::new(Policy::SwapPerRequest);
        for i in 0..4 {
            s.admit(req(i, 0.0));
        }
        let mut got = 0;
        while !s.is_empty() {
            got += s.next_batch(0.0).len();
        }
        assert_eq!(got, 4);
        assert_eq!(s.admitted, 4);
        assert_eq!(s.dispatched, 4);
    }
}
