//! Analytic decode fast-forward: the pure bounds behind
//! [`EventServer`](super::EventServer)'s O(events) → O(folds) skip.
//!
//! Between scheduling decisions the resident decode batch is in **steady
//! state**: the same streams are selected in the same round-robin order
//! every step, each step's duration is a closed form of the members'
//! contexts ([`LatencySurface::decode_step_batched_paged`]), and nothing
//! on the event queue interferes until the next *structural* event. The
//! event core exploits this by folding K whole token-steps into one pass
//! — replaying the per-step arithmetic in the exact left-fold order the
//! stepped path uses (so clocks, TPOT samples, and pool accounting stay
//! **bit-identical**) while skipping the per-token event machinery
//! (heap push/pop, dispatch, log append, pump re-entry).
//!
//! **Interference lattice.** Not every queued event is structural. The
//! fold classifies the earliest queued event into one of three verdicts:
//!
//! * **Clear** — it fires after the candidate step completes
//!   ([`fits_before`]): fold on.
//! * **Absorb** — it is an [`Arrival`](super::SimEvent::Arrival) whose
//!   request provably cannot be extracted while the fold runs (*dormant*:
//!   the residency slots are saturated by the decode set itself, or it
//!   joins a backlog whose head is not immediately pool-admissible —
//!   both conditions monotone over a fold, since folding only grows KV
//!   and never completes a member). The fold pops it, runs the exact
//!   arrival bookkeeping the dispatcher would (backlog counters +
//!   scheduler admit + log + streamed-window refill), and keeps folding
//!   — these are the swap-adjacent idle gaps the stepped path burned
//!   events on.
//! * **Block** — anything else (an admissible arrival, a swap
//!   completion, a prefill marker, an eviction echo): the fold ends and
//!   the event runs through the real queue.
//!
//! This module holds the pure, independently testable pieces: the
//! member-exhaustion bound, the horizon predicate, and the fold's
//! statistics. The fold and the dormancy predicates live in `events.rs`
//! (they read the server's private state); `docs/ARCHITECTURE.md`
//! extensions #7 and #8 state the invariant and the bitwise argument in
//! full.
//!
//! [`LatencySurface::decode_step_batched_paged`]: crate::engines::LatencySurface::decode_step_batched_paged

/// How many whole token-steps can run before the earliest member of the
/// decode set exhausts its token budget, given the minimum
/// `InFlight::remaining` across the batch.
///
/// The bound is `min_remaining − 1`, **not** `min_remaining`: the step
/// that completes a stream must run through the normal event path
/// (completion removes the stream, releases its KV pages, may drain the
/// decode set, and re-enters the swap-policy decision points), so the
/// fold always stops one token short of the earliest finisher.
///
/// ```
/// use pd_swap::coordinator::fastforward::member_step_bound;
///
/// assert_eq!(member_step_bound(100), 99); // 99 foldable, 100th completes a stream
/// assert_eq!(member_step_bound(1), 0);    // next step finishes someone: no fold
/// assert_eq!(member_step_bound(0), 0);    // saturating (empty/done set)
/// ```
pub fn member_step_bound(min_remaining: usize) -> usize {
    min_remaining.saturating_sub(1)
}

/// Would a step of duration `step` starting at `clock` finish strictly
/// before the next queued event at `next_at` (`None` = empty queue)?
///
/// Strict inequality is load-bearing: at an exact tie the queued event
/// was pushed *earlier* (lower sequence number), so the stepped engine
/// pops it **first** and the post-step pump sees its effects (an arrival
/// joins the backlog, a swap settles). The fold therefore yields to the
/// real queue at ties; anything else would reorder the tie-break that
/// makes the event core deterministic.
///
/// ```
/// use pd_swap::coordinator::fastforward::fits_before;
///
/// assert!(fits_before(10.0, 0.5, Some(11.0)));   // 10.5 < 11.0: fold on
/// assert!(!fits_before(10.0, 1.0, Some(11.0)));  // exact tie: queue wins
/// assert!(!fits_before(10.0, 2.0, Some(11.0)));  // event interposes
/// assert!(fits_before(10.0, 1e9, None));         // empty queue: no horizon
/// ```
pub fn fits_before(clock: f64, step: f64, next_at: Option<f64>) -> bool {
    match next_at {
        Some(t) => clock + step < t,
        None => true,
    }
}

/// Counters for the fast-forward fold (diagnostics; deliberately kept
/// out of [`ServerMetrics`](crate::coordinator::ServerMetrics) so
/// metric bundles compare clean across `fast_forward` on/off).
///
/// `steps` counts *skipped events*: each folded token-step would have
/// been exactly one `DecodeStepDone`/`DecodeBatchDone` on the queue, so
/// the stepped-equivalent event count of a run is
/// `events_processed + steps`. Absorbed arrivals are **not** skipped
/// events — the fold pops and dispatches them for real (they count in
/// `events_processed`); `absorbed_arrivals` only attributes how many
/// arrivals were handled inside folds rather than between them.
///
/// ```
/// use pd_swap::coordinator::fastforward::FastForwardStats;
///
/// let mut s = FastForwardStats::default();
/// s.record_fold(99);
/// s.record_fold(7);
/// s.record_absorbed_arrival();
/// assert_eq!((s.folds, s.steps, s.absorbed_arrivals), (2, 106, 1));
/// assert_eq!(s.stepped_equivalent(34), 140); // 34 real events + 106 skipped
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FastForwardStats {
    /// Fast-forward passes that applied at least one step.
    pub folds: u64,
    /// Token-steps applied inside folds (= decode events skipped).
    pub steps: u64,
    /// Dormant arrivals absorbed mid-fold (real events, handled without
    /// ending the fold).
    pub absorbed_arrivals: u64,
}

impl FastForwardStats {
    /// Account one fold that applied `k` token-steps.
    pub fn record_fold(&mut self, k: u64) {
        self.folds += 1;
        self.steps += k;
    }

    /// Account one dormant arrival absorbed inside a fold.
    pub fn record_absorbed_arrival(&mut self) {
        self.absorbed_arrivals += 1;
    }

    /// The event count the stepped engine would have processed for the
    /// same run: every folded step maps back to exactly one queue event.
    pub fn stepped_equivalent(&self, events_processed: u64) -> u64 {
        events_processed + self.steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn member_bound_stops_one_short_of_the_finisher() {
        assert_eq!(member_step_bound(2), 1);
        assert_eq!(member_step_bound(usize::MAX), usize::MAX - 1);
    }

    #[test]
    fn horizon_is_strict_at_ties() {
        // The queued event's lower seq wins a tie in `EventQueue`; the
        // predicate must mirror that by refusing the tie.
        assert!(!fits_before(0.0, 1.0, Some(1.0)));
        assert!(fits_before(0.0, 1.0 - f64::EPSILON, Some(1.0)));
    }

    #[test]
    fn stats_add_up() {
        let mut s = FastForwardStats::default();
        assert_eq!(s.stepped_equivalent(5), 5); // no folds: identity
        s.record_fold(0);
        s.record_fold(3);
        assert_eq!(s.folds, 2);
        assert_eq!(s.stepped_equivalent(5), 8);
    }
}
