//! The live coordinator: same scheduling/FSM logic, but the compute is
//! *real* — every prefill and decode step executes the AOT HLO artifacts
//! through PJRT ([`crate::runtime::InferenceEngine`]).
//!
//! Two clocks run in lockstep:
//!
//! * **wall clock** — actual CPU time of the PJRT executions (reported as
//!   "host" numbers; this is NOT a KV260 measurement);
//! * **simulated clock** — what the same token trace would cost on the
//!   modeled KV260 with PD-Swap (reconfigurations included), so the live
//!   example reports paper-comparable numbers next to real tokens.

use std::time::Instant;

use anyhow::Result;

use crate::engines::PhaseModel;
use crate::metrics::ServerMetrics;
use crate::model::{shapes, ModelShape};
use crate::reconfig::OverlapScheduler;
use crate::runtime::{sample, InferenceEngine, PagedKvView, SamplerConfig};
use crate::util::rng::Rng;

use super::request::{Request, RequestOutcome};

/// Live server configuration.
pub struct LiveServerConfig {
    /// Artifact directory (e.g. `artifacts/e2e-100m`).
    pub artifacts_dir: std::path::PathBuf,
    pub sampler: SamplerConfig,
    pub seed: u64,
    /// Attach the KV260 simulator in lockstep (reports simulated timing).
    pub simulate_fpga: bool,
}

/// Live serving results for one request.
#[derive(Debug, Clone)]
pub struct LiveOutcome {
    pub outcome: RequestOutcome,
    /// Simulated KV260 TTFT / e2e for the same trace (if enabled).
    pub sim_ttft: Option<f64>,
    pub sim_e2e: Option<f64>,
}

/// PJRT-backed server.
pub struct LiveServer {
    pub engine: InferenceEngine,
    sampler: SamplerConfig,
    rng: Rng,
    sim: Option<(PhaseModel, OverlapScheduler, ModelShape)>,
    /// Wall-clock metrics (host CPU).
    pub metrics: ServerMetrics,
    /// Simulated-KV260 metrics (if enabled).
    pub sim_metrics: ServerMetrics,
}

impl LiveServer {
    pub fn new(cfg: LiveServerConfig) -> Result<Self> {
        let engine = InferenceEngine::load(&cfg.artifacts_dir)?;
        let sim = if cfg.simulate_fpga {
            let name = engine.manifest().config.name.clone();
            let shape = shapes::by_name(&name)
                .unwrap_or(crate::model::BITNET_0_73B);
            let design = crate::engines::AcceleratorDesign::pd_swap();
            let device = crate::fpga::KV260.clone();
            let fpga = design.program(&device)?;
            let model = PhaseModel::new(design, device);
            let ov = OverlapScheduler::new(model.clone(), fpga.reconfig_latency());
            Some((model, ov, shape))
        } else {
            None
        };
        Ok(Self {
            engine,
            sampler: cfg.sampler,
            rng: Rng::new(cfg.seed),
            sim,
            metrics: ServerMetrics::default(),
            sim_metrics: ServerMetrics::default(),
        })
    }

    /// Serve one request to completion (real tokens out).
    pub fn serve(&mut self, r: &Request) -> Result<LiveOutcome> {
        anyhow::ensure!(!r.prompt.is_empty(), "live requests need real tokens");
        let t0 = Instant::now();

        // Prefill (real).
        let pre = self.engine.prefill(&r.prompt)?;
        let mut cache = pre.cache;
        let mut tok = sample(&pre.logits, &self.sampler, &mut self.rng);
        let ttft = t0.elapsed().as_secs_f64();

        // Decode (real).
        let mut generated = Vec::with_capacity(r.max_new_tokens);
        let decode_start = Instant::now();
        for _ in 0..r.max_new_tokens {
            generated.push(tok);
            if !cache.has_room() {
                break;
            }
            let step0 = Instant::now();
            let (logits, c) = self.engine.decode(tok, cache)?;
            cache = c;
            tok = sample(&logits, &self.sampler, &mut self.rng);
            self.metrics.tpot.record(step0.elapsed().as_secs_f64());
        }
        let e2e = t0.elapsed().as_secs_f64();
        let n = generated.len();

        self.metrics.ttft.record(ttft);
        self.metrics.e2e.record(e2e);
        self.metrics.tokens_generated.add(n as u64);
        self.metrics.requests_completed.inc();

        // Page accounting in lockstep with the simulator's pool: the
        // high-water mark is the worst-case *reservation* a WorstCase
        // admission would commit for this request (prompt + full
        // generation, clamped to the graph's capacity) — not just the
        // pages actually written, which can be fewer on early exit.
        let page_tokens = crate::kvpool::PAGE_TOKENS_DEFAULT;
        let worst_tokens = (r.prompt_len + r.max_new_tokens).min(cache.capacity);
        let reserved = PagedKvView::new(page_tokens, worst_tokens, cache.capacity);
        self.sim_metrics
            .kv_pool_high_water
            .observe(reserved.pages_used() as u64);
        debug_assert!(cache.paged_view(page_tokens).pages_used() <= reserved.pages_used());

        // Simulated-KV260 lockstep accounting for the same trace.
        let (sim_ttft, sim_e2e) = if let Some((model, ov, shape)) = &self.sim {
            let timeline = ov.overlapped(shape, r.prompt_len.min(shape.max_seq));
            let s_ttft = timeline.prefill_end + timeline.exposed;
            let gen = n.min(shape.max_seq.saturating_sub(r.prompt_len));
            let s_dec = model.decode_span(shape, r.prompt_len.min(shape.max_seq), gen);
            self.sim_metrics.ttft.record(s_ttft);
            self.sim_metrics.e2e.record(s_ttft + s_dec);
            self.sim_metrics.reconfig_exposed.record(timeline.exposed);
            self.sim_metrics.reconfigurations.add(2);
            self.sim_metrics.tokens_generated.add(gen as u64);
            self.sim_metrics.requests_completed.inc();
            if gen > 0 {
                self.sim_metrics.tpot.record(s_dec / gen as f64);
            }
            (Some(s_ttft), Some(s_ttft + s_dec))
        } else {
            (None, None)
        };

        Ok(LiveOutcome {
            outcome: RequestOutcome {
                id: r.id,
                prompt_len: r.prompt_len,
                generated,
                ttft,
                e2e,
                mean_tpot: if n > 0 {
                    decode_start.elapsed().as_secs_f64() / n as f64
                } else {
                    0.0
                },
                shed: false,
            },
            sim_ttft,
            sim_e2e,
        })
    }

    /// Serve a workload sequentially (edge profile: one request at a time).
    pub fn run(&mut self, workload: &[Request]) -> Result<Vec<LiveOutcome>> {
        workload.iter().map(|r| self.serve(r)).collect()
    }
}
