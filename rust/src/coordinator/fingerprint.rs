//! The shared semantic fingerprint behind every engine-equivalence pin.
//!
//! Four documented contracts promise *bitwise* agreement between engine
//! configurations (see README §"Semantics contracts"): surface ≡ phase
//! model, batch-1 ≡ single-stream, fast-forward ≡ stepped, and
//! streamed ≡ materialized. Each pin — the hand-written property tests
//! and the differential fuzzer's oracle alike — compares the same
//! folded string produced here, so "bit-identical" means one thing
//! everywhere.

use std::fmt::Write as _;

use super::events::EventServer;

/// Everything the bitwise engine-equivalence contracts pin, folded into
/// one comparable string: the virtual clock, every counter, the latency
/// histograms (count + mean/min/max/median bits), the per-request
/// outcome order and values, the pool's eviction log and conservation
/// stats. The diagnostic event log and the Chrome trace are deliberately
/// excluded — fast-forward folds skip log records and coalesce spans by
/// design, and `events_processed()` is exactly the quantity the fast
/// paths exist to change.
///
/// Floats are rendered via [`f64::to_bits`] so the comparison is exact:
/// two fingerprints are equal iff every pinned value is equal to the
/// last bit.
///
/// # Examples
///
/// The fast-forward contract in one assertion — folding a steady-state
/// decode must not move a bit of the semantic surface:
///
/// ```
/// use pd_swap::coordinator::{semantic_fingerprint, EventServer, EventServerConfig, Request};
/// use pd_swap::fpga::KV260;
/// use pd_swap::model::BITNET_0_73B;
/// use pd_swap::reconfig::SwapPolicy;
///
/// let run = |fast_forward: bool| {
///     let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
///     cfg.fast_forward = fast_forward;
///     let mut s = EventServer::new(cfg).unwrap();
///     s.run(vec![Request::synthetic(0, 128, 64, 0.0)]).unwrap();
///     semantic_fingerprint(&s)
/// };
/// assert_eq!(run(true), run(false));
/// ```
pub fn semantic_fingerprint(s: &EventServer) -> String {
    let m = &s.metrics;
    let mut out = String::new();
    let _ = writeln!(out, "clock {:x}", s.clock().to_bits());
    let _ = writeln!(
        out,
        "counts {} {} {} {} {} {} {} {}",
        m.requests_completed.get(),
        m.tokens_generated.get(),
        m.reconfigurations.get(),
        m.swaps_to_prefill.get(),
        m.swaps_to_decode.get(),
        m.kv_evictions.get(),
        m.kv_admissions_capped.get(),
        m.kv_pool_high_water.get(),
    );
    for (name, h) in [
        ("tpot", &m.tpot),
        ("ttft", &m.ttft),
        ("e2e", &m.e2e),
        ("recompute", &m.recompute_overhead),
    ] {
        let _ = writeln!(
            out,
            "{name} {} {:x} {:x} {:x} {:x}",
            h.count(),
            h.mean().to_bits(),
            h.min().to_bits(),
            h.max().to_bits(),
            h.quantile(0.5).to_bits(),
        );
    }
    for o in &s.outcomes {
        if o.shed {
            continue;
        }
        let _ = writeln!(
            out,
            "outcome {} {} {:x} {:x} {:x}",
            o.id,
            o.prompt_len,
            o.ttft.to_bits(),
            o.e2e.to_bits(),
            o.mean_tpot.to_bits(),
        );
    }
    // Fault-layer surface (extension #10). Zero-fault runs emit NOTHING
    // here — the 5th semantics contract (zero-fault ≡ fault-layer-free)
    // compares fingerprints bitwise, so these lines appear only when a
    // fault actually manifested.
    for o in &s.outcomes {
        if o.shed {
            let _ =
                writeln!(out, "shed {} {} {:x}", o.id, o.prompt_len, o.e2e.to_bits());
        }
    }
    if m.requests_shed.get() != 0
        || m.swap_failures.get() != 0
        || m.swap_retries.get() != 0
        || m.degraded_seconds != 0.0
    {
        let _ = writeln!(
            out,
            "faults {} {} {} {:x}",
            m.requests_shed.get(),
            m.swap_failures.get(),
            m.swap_retries.get(),
            m.degraded_seconds.to_bits(),
        );
    }
    for (at, id) in &s.pool().eviction_log {
        let _ = writeln!(out, "evict {:x} {id}", at.to_bits());
    }
    let _ = writeln!(out, "pool {:?}", s.pool().stats);
    out
}
