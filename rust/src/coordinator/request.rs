//! Request/response types and the synthetic edge workload generator.

use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids. For simulator-only runs this may be empty with
    /// `prompt_len` carrying the length; the live server requires tokens.
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    /// Tokens to generate.
    pub max_new_tokens: usize,
    /// Arrival time (seconds since workload start).
    pub arrival: f64,
    /// Times this request has been preempted back into the queue
    /// (KV-pool eviction). Drives the scheduler's age-based fairness
    /// tiebreak: see [`super::Scheduler::requeue_front`].
    pub requeues: u32,
}

impl Request {
    /// Simulator-side request (length only).
    pub fn synthetic(id: u64, prompt_len: usize, max_new_tokens: usize, arrival: f64) -> Self {
        Self { id, prompt: Vec::new(), prompt_len, max_new_tokens, arrival, requeues: 0 }
    }

    /// Live request with real token ids.
    pub fn with_tokens(id: u64, prompt: Vec<i32>, max_new_tokens: usize, arrival: f64) -> Self {
        let prompt_len = prompt.len();
        Self { id, prompt, prompt_len, max_new_tokens, arrival, requeues: 0 }
    }
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: u64,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    /// Time-to-first-token (includes queueing + prefill + any exposed
    /// reconfiguration).
    pub ttft: f64,
    /// End-to-end latency.
    pub e2e: f64,
    /// Mean per-output-token latency over the decode phase.
    pub mean_tpot: f64,
}

/// Synthetic workload parameters (edge assistant profile).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// Mean request arrival rate (req/s). Edge devices see sparse,
    /// bursty single-user traffic; the default is deliberately low.
    pub arrival_rate: f64,
    /// Prompt length range (uniform in log space).
    pub prompt_len: (usize, usize),
    /// Generation length range.
    pub gen_len: (usize, usize),
    pub seed: u64,
    /// Vocabulary for real token ids (live runs); 0 = synthetic only.
    pub vocab: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            n_requests: 16,
            arrival_rate: 0.05,
            prompt_len: (32, 768),
            gen_len: (16, 128),
            seed: 0,
            vocab: 0,
        }
    }
}

/// Lift [`crate::model::TraceEntry`]s (the layer-agnostic trace
/// generator's output) into coordinator [`Request`]s, ids in arrival
/// order.
pub fn requests_from_trace(entries: &[crate::model::TraceEntry]) -> Vec<Request> {
    entries
        .iter()
        .enumerate()
        .map(|(i, e)| Request::synthetic(i as u64, e.prompt_len, e.gen_len, e.arrival))
        .collect()
}

/// Generate a Poisson-arrival workload.
pub fn generate_workload(cfg: &WorkloadConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0;
    (0..cfg.n_requests)
        .map(|i| {
            t += rng.exponential(cfg.arrival_rate.max(1e-9));
            let (plo, phi) = cfg.prompt_len;
            // Log-uniform: short prompts common, long ones present.
            let lp = (plo as f64).ln() + rng.f64() * ((phi as f64).ln() - (plo as f64).ln());
            let prompt_len = lp.exp().round() as usize;
            let gen = rng.range(cfg.gen_len.0, cfg.gen_len.1);
            let prompt = if cfg.vocab > 1 {
                (0..prompt_len)
                    .map(|_| 1 + rng.below(cfg.vocab - 1) as i32)
                    .collect()
            } else {
                Vec::new()
            };
            let mut r = Request::synthetic(i as u64, prompt_len, gen, t);
            r.prompt = prompt;
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic_and_ordered() {
        let cfg = WorkloadConfig { n_requests: 32, ..Default::default() };
        let a = generate_workload(&cfg);
        let b = generate_workload(&cfg);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.arrival, y.arrival);
        }
        // Arrivals strictly increase.
        for w in a.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
    }

    #[test]
    fn lengths_respect_ranges() {
        let cfg = WorkloadConfig {
            n_requests: 200,
            prompt_len: (16, 256),
            gen_len: (8, 64),
            ..Default::default()
        };
        for r in generate_workload(&cfg) {
            assert!((15..=257).contains(&r.prompt_len), "prompt {}", r.prompt_len);
            assert!((8..=64).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn vocab_generates_tokens() {
        let cfg = WorkloadConfig { n_requests: 4, vocab: 100, ..Default::default() };
        for r in generate_workload(&cfg) {
            assert_eq!(r.prompt.len(), r.prompt_len);
            assert!(r.prompt.iter().all(|&t| (1..100).contains(&t)));
        }
    }
}
