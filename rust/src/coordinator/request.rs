//! Request/response types and the synthetic edge workload generator.

use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids. For simulator-only runs this may be empty with
    /// `prompt_len` carrying the length; the live server requires tokens.
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    /// Tokens to generate.
    pub max_new_tokens: usize,
    /// Arrival time (seconds since workload start).
    pub arrival: f64,
    /// Times this request has been preempted back into the queue
    /// (KV-pool eviction). Drives the scheduler's age-based fairness
    /// tiebreak: see [`super::Scheduler::requeue_front`].
    pub requeues: u32,
}

impl Request {
    /// Simulator-side request (length only).
    pub fn synthetic(id: u64, prompt_len: usize, max_new_tokens: usize, arrival: f64) -> Self {
        Self { id, prompt: Vec::new(), prompt_len, max_new_tokens, arrival, requeues: 0 }
    }

    /// Live request with real token ids.
    pub fn with_tokens(id: u64, prompt: Vec<i32>, max_new_tokens: usize, arrival: f64) -> Self {
        let prompt_len = prompt.len();
        Self { id, prompt, prompt_len, max_new_tokens, arrival, requeues: 0 }
    }
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: u64,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    /// Time-to-first-token (includes queueing + prefill + any exposed
    /// reconfiguration).
    pub ttft: f64,
    /// End-to-end latency.
    pub e2e: f64,
    /// Mean per-output-token latency over the decode phase.
    pub mean_tpot: f64,
    /// The request was shed (SLO deadline exceeded or fail-stop
    /// fallback) instead of completing: KV pages were freed, any tokens
    /// in `generated` are partial, and `ttft`/`mean_tpot` are only
    /// meaningful if a first token was actually produced. Shed requests
    /// count toward arrivals but not `requests_completed` — the serving
    /// conservation law is `completed + shed == arrivals`.
    pub shed: bool,
}

/// Synthetic workload parameters (edge assistant profile).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// Mean request arrival rate (req/s). Edge devices see sparse,
    /// bursty single-user traffic; the default is deliberately low.
    pub arrival_rate: f64,
    /// Prompt length range (uniform in log space).
    pub prompt_len: (usize, usize),
    /// Generation length range.
    pub gen_len: (usize, usize),
    pub seed: u64,
    /// Vocabulary for real token ids (live runs); 0 = synthetic only.
    pub vocab: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            n_requests: 16,
            arrival_rate: 0.05,
            prompt_len: (32, 768),
            gen_len: (16, 128),
            seed: 0,
            vocab: 0,
        }
    }
}

/// Lift [`crate::model::TraceEntry`]s (the layer-agnostic trace
/// generator's output) into coordinator [`Request`]s, ids in arrival
/// order.
pub fn requests_from_trace(entries: &[crate::model::TraceEntry]) -> Vec<Request> {
    entries
        .iter()
        .enumerate()
        .map(|(i, e)| Request::synthetic(i as u64, e.prompt_len, e.gen_len, e.arrival))
        .collect()
}

/// Streaming form of [`requests_from_trace`]: the same id/field mapping
/// applied lazily, so `TraceSpec::stream()` can feed
/// `EventServer::run_streamed` without materializing the trace. For any
/// entry iterator `it`, `requests_from_stream(it).collect::<Vec<_>>()`
/// equals `requests_from_trace(&it.collect::<Vec<_>>())` field-for-field.
pub fn requests_from_stream(
    entries: impl Iterator<Item = crate::model::TraceEntry>,
) -> impl Iterator<Item = Request> {
    entries
        .enumerate()
        .map(|(i, e)| Request::synthetic(i as u64, e.prompt_len, e.gen_len, e.arrival))
}

/// Bounded retention for completed-request records.
///
/// Million-request runs cannot keep every [`RequestOutcome`] (each owns a
/// `generated` vec): the sink retains the first `cap` outcomes verbatim
/// (head retention — deterministic, and exactly what the existing tests
/// and examples index into) and counts the rest in `dropped`. Latency
/// *statistics* never lose anything: `ServerMetrics` histograms record
/// every request regardless of retention, and the reservoir there is
/// already bounded. `Deref<Target = [RequestOutcome]>` keeps every
/// `.len()` / `.iter()` / indexing call site working unchanged.
#[derive(Debug, Clone)]
pub struct OutcomeSink {
    kept: Vec<RequestOutcome>,
    cap: usize,
    dropped: u64,
}

impl OutcomeSink {
    /// Default retention cap (matches the metrics reservoir size): big
    /// enough that every pre-existing test/example sees full retention,
    /// small enough that a million-request run stays O(cap).
    pub const DEFAULT_RETAIN: usize = 1 << 16;

    /// Sink retaining at most `cap` outcomes (`usize::MAX` = keep all).
    pub fn with_capacity(cap: usize) -> Self {
        // No pre-allocation: `cap` may be huge (or MAX) while the run
        // completes only a handful of requests.
        Self { kept: Vec::new(), cap, dropped: 0 }
    }

    /// Record one completed request: kept verbatim below the cap,
    /// counted above it. O(1) amortized; beyond the cap, allocation-free.
    pub fn push(&mut self, outcome: RequestOutcome) {
        if self.kept.len() < self.cap {
            self.kept.push(outcome);
        } else {
            self.dropped += 1;
        }
    }

    /// Outcomes counted but not retained (total completions = `len() +
    /// dropped()`).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retention cap this sink was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

impl Default for OutcomeSink {
    fn default() -> Self {
        Self::with_capacity(Self::DEFAULT_RETAIN)
    }
}

impl std::ops::Deref for OutcomeSink {
    type Target = [RequestOutcome];
    fn deref(&self) -> &[RequestOutcome] {
        &self.kept
    }
}

impl<'a> IntoIterator for &'a OutcomeSink {
    type Item = &'a RequestOutcome;
    type IntoIter = std::slice::Iter<'a, RequestOutcome>;
    fn into_iter(self) -> Self::IntoIter {
        self.kept.iter()
    }
}

/// Generate a Poisson-arrival workload.
pub fn generate_workload(cfg: &WorkloadConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0;
    (0..cfg.n_requests)
        .map(|i| {
            t += rng.exponential(cfg.arrival_rate.max(1e-9));
            let (plo, phi) = cfg.prompt_len;
            // Log-uniform: short prompts common, long ones present.
            let lp = (plo as f64).ln() + rng.f64() * ((phi as f64).ln() - (plo as f64).ln());
            let prompt_len = lp.exp().round() as usize;
            let gen = rng.range(cfg.gen_len.0, cfg.gen_len.1);
            let prompt = if cfg.vocab > 1 {
                (0..prompt_len)
                    .map(|_| 1 + rng.below(cfg.vocab - 1) as i32)
                    .collect()
            } else {
                Vec::new()
            };
            let mut r = Request::synthetic(i as u64, prompt_len, gen, t);
            r.prompt = prompt;
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic_and_ordered() {
        let cfg = WorkloadConfig { n_requests: 32, ..Default::default() };
        let a = generate_workload(&cfg);
        let b = generate_workload(&cfg);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.arrival, y.arrival);
        }
        // Arrivals strictly increase.
        for w in a.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
    }

    #[test]
    fn lengths_respect_ranges() {
        let cfg = WorkloadConfig {
            n_requests: 200,
            prompt_len: (16, 256),
            gen_len: (8, 64),
            ..Default::default()
        };
        for r in generate_workload(&cfg) {
            assert!((15..=257).contains(&r.prompt_len), "prompt {}", r.prompt_len);
            assert!((8..=64).contains(&r.max_new_tokens));
        }
    }

    #[test]
    fn vocab_generates_tokens() {
        let cfg = WorkloadConfig { n_requests: 4, vocab: 100, ..Default::default() };
        for r in generate_workload(&cfg) {
            assert_eq!(r.prompt.len(), r.prompt_len);
            assert!(r.prompt.iter().all(|&t| (1..100).contains(&t)));
        }
    }

    fn outcome(id: u64) -> RequestOutcome {
        RequestOutcome {
            id,
            prompt_len: 8,
            generated: Vec::new(),
            ttft: 0.1,
            e2e: 1.0,
            mean_tpot: 0.01,
            shed: false,
        }
    }

    #[test]
    fn outcome_sink_retains_head_and_counts_drops() {
        let mut s = OutcomeSink::with_capacity(3);
        for id in 0..10 {
            s.push(outcome(id));
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.dropped(), 7);
        assert_eq!(s.capacity(), 3);
        // Head retention: first-completed ids survive.
        let ids: Vec<u64> = s.iter().map(|o| o.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        // Deref + IntoIterator surfaces behave like a slice.
        assert_eq!(s[1].id, 1);
        assert_eq!((&s).into_iter().count(), 3);
    }

    #[test]
    fn outcome_sink_retain_zero_keeps_nothing_counts_everything() {
        // The `--outcome-retain 0` boundary: pure counting mode. The
        // Deref surface must be an empty slice, not a panic, and every
        // push lands in dropped() exactly.
        let mut s = OutcomeSink::with_capacity(0);
        assert_eq!(s.capacity(), 0);
        assert_eq!(s.dropped(), 0);
        for id in 0..5 {
            s.push(outcome(id));
        }
        assert_eq!(s.len(), 0);
        assert_eq!(s.dropped(), 5);
        assert!(s.is_empty());
        assert_eq!(s.iter().count(), 0);
        assert!(s.first().is_none());
    }

    #[test]
    fn outcome_sink_retain_one_pins_the_first_completion() {
        // retain = 1: exactly the first-completed outcome survives with
        // its contents intact; dropped() accounts for the rest.
        let mut s = OutcomeSink::with_capacity(1);
        for id in 10..14 {
            s.push(outcome(id));
        }
        assert_eq!(s.len(), 1);
        assert_eq!(s.dropped(), 3);
        assert_eq!(s[0].id, 10);
        assert_eq!(s[0].prompt_len, 8);
        assert_eq!(s[0].ttft.to_bits(), 0.1f64.to_bits());
        assert_eq!(s[0].e2e.to_bits(), 1.0f64.to_bits());
        // len + dropped is the conservation the fuzz oracle checks.
        assert_eq!(s.len() as u64 + s.dropped(), 4);
    }

    #[test]
    fn outcome_sink_default_keeps_everything_small() {
        let mut s = OutcomeSink::default();
        for id in 0..100 {
            s.push(outcome(id));
        }
        assert_eq!(s.len(), 100);
        assert_eq!(s.dropped(), 0);
    }

    #[test]
    fn requests_from_stream_matches_eager_lift() {
        let spec = crate::model::TraceSpec::million(40, 3);
        let eager = requests_from_trace(&spec.generate());
        let lazy: Vec<Request> = requests_from_stream(spec.stream()).collect();
        assert_eq!(eager.len(), lazy.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        }
    }
}
