//! Event-driven serving simulation on the modeled KV260.
//!
//! Drives the full stack — scheduler → FSM → swap controller → phase
//! latency model — over a workload, with a simulated clock. This is the
//! machine behind Figs. 5/6 and the ablation benches: the same loop runs
//! a PD-Swap device (DPR + overlap), a PD-Swap device without overlap, or
//! a static baseline (no swaps at all), selected by configuration.

use anyhow::Result;

use crate::engines::{AcceleratorDesign, AttentionHosting, PhaseModel};
use crate::fpga::DeviceConfig;
use crate::metrics::ServerMetrics;
use crate::model::ModelShape;
use crate::reconfig::{OverlapScheduler, SwapController, RM_PREFILL};

use super::fsm::PhaseFsm;
use super::request::{Request, RequestOutcome};
use super::scheduler::{Policy, Scheduler};

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimServerConfig {
    pub design: AcceleratorDesign,
    pub device: DeviceConfig,
    pub shape: ModelShape,
    pub policy: Policy,
    /// Use the §3.4 latency-overlapped early trigger (PD-Swap default).
    pub overlap: bool,
}

impl SimServerConfig {
    pub fn pd_swap(shape: ModelShape, device: DeviceConfig) -> Self {
        Self {
            design: AcceleratorDesign::pd_swap(),
            device,
            shape,
            policy: Policy::SwapPerRequest,
            overlap: true,
        }
    }

    pub fn tellme_static(shape: ModelShape, device: DeviceConfig) -> Self {
        Self {
            design: AcceleratorDesign::tellme_static(),
            device,
            shape,
            policy: Policy::SwapPerRequest,
            overlap: false,
        }
    }
}

/// The simulated server.
pub struct SimServer {
    cfg: SimServerConfig,
    model: PhaseModel,
    swap: Option<SwapController>,
    overlap: Option<OverlapScheduler>,
    fsm: PhaseFsm,
    pub metrics: ServerMetrics,
    clock: f64,
    pub outcomes: Vec<RequestOutcome>,
}

impl SimServer {
    pub fn new(cfg: SimServerConfig) -> Result<Self> {
        let model = PhaseModel::new(cfg.design.clone(), cfg.device.clone());
        let uses_dpr = cfg.design.hosting == AttentionHosting::Reconfigurable;
        let swap = if uses_dpr {
            Some(SwapController::new(cfg.design.program(&cfg.device)?))
        } else {
            // Static design: validate the floorplan but never swap.
            cfg.design.program(&cfg.device)?;
            None
        };
        let overlap = if uses_dpr {
            let lat = swap.as_ref().unwrap().device.reconfig_latency();
            Some(OverlapScheduler::new(model.clone(), lat))
        } else {
            None
        };
        Ok(Self {
            cfg,
            model,
            swap,
            overlap,
            fsm: PhaseFsm::new(),
            metrics: ServerMetrics::default(),
            clock: 0.0,
            outcomes: Vec::new(),
        })
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Serve a whole workload to completion; returns the metric bundle.
    pub fn run(&mut self, mut workload: Vec<Request>) -> Result<&ServerMetrics> {
        workload.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut sched = Scheduler::new(self.cfg.policy);
        for r in workload {
            sched.admit(r);
        }

        while !sched.is_empty() {
            // Advance the clock to the next arrival if idle.
            if let Some(next) = sched.next_arrival() {
                if next > self.clock {
                    self.clock = next;
                }
            }
            let batch = sched.next_batch(self.clock);
            if batch.is_empty() {
                continue;
            }
            self.serve_batch(batch)?;
        }
        Ok(&self.metrics)
    }

    /// One phase-batch: prefill all, swap once, decode all.
    fn serve_batch(&mut self, batch: Vec<Request>) -> Result<()> {
        let shape = self.cfg.shape;

        // -- ensure prefill RM ------------------------------------------------
        if let Some(swap) = self.swap.as_mut() {
            if !swap.device.is_live(RM_PREFILL, self.clock) {
                self.fsm.begin_swap(false, 0.0).ok();
                let ready = swap.ensure_prefill(self.clock)?;
                self.fsm.complete_swap(f64::MAX.min(ready)).ok();
                self.metrics.reconfigurations.inc();
                self.clock = ready;
            }
        }

        // -- prefill phase ----------------------------------------------------
        // (start-of-prefill timestamps per request for TTFT accounting)
        let mut prefill_done = Vec::with_capacity(batch.len());
        let mut last_timeline = None;
        for r in &batch {
            self.fsm.begin_prefill().ok();
            let pre = self.model.prefill(&shape, r.prompt_len);
            self.clock += pre.total;
            prefill_done.push(self.clock);
            // Early-trigger the decode swap during the LAST request's tail
            // (batched mode keeps the prefill RM until the batch drains).
            let is_last = r.id == batch.last().unwrap().id;
            if is_last {
                if let (Some(swap), Some(ov)) = (self.swap.as_mut(), self.overlap.as_ref()) {
                    let timeline = if self.cfg.overlap {
                        ov.overlapped(&shape, r.prompt_len)
                    } else {
                        ov.sequential(&shape, r.prompt_len)
                    };
                    //

                    let trigger_abs = self.clock - pre.total + timeline.trigger;
                    self.fsm.begin_swap(true, trigger_abs + timeline.reconfig).ok();
                    let ready = swap.trigger_decode_swap(trigger_abs)?;
                    let admit = swap.decode_admissible_at(self.clock, ready);
                    self.metrics.reconfigurations.inc();
                    self.metrics.reconfig_exposed.record(admit - self.clock);
                    self.clock = admit;
                    self.fsm.complete_swap(admit).ok();
                    last_timeline = Some(timeline);
                }
            }
            let _ = last_timeline;
        }
        if self.swap.is_none() {
            // Static design: decode engine always live.
            self.fsm.begin_swap(true, self.clock).ok();
            self.fsm.complete_swap(self.clock).ok();
        }

        // -- decode phase -------------------------------------------------
        debug_assert!(self.fsm.decode_admissible(self.clock));
        for (r, pre_done) in batch.iter().zip(&prefill_done) {
            let mut ctx = r.prompt_len;
            let decode_start = self.clock;
            // First token comes out of prefill logits; TTFT counts queue +
            // prefill + exposed swap.
            let ttft = self.clock.max(*pre_done) - r.arrival;
            let mut tokens = 0usize;
            for _ in 0..r.max_new_tokens {
                if ctx >= shape.max_seq {
                    break;
                }
                let step = self.model.decode_step(&shape, ctx).total;
                self.clock += step;
                self.metrics.tpot.record(step);
                ctx += 1;
                tokens += 1;
            }
            let e2e = self.clock - r.arrival;
            self.metrics.ttft.record(ttft);
            self.metrics.e2e.record(e2e);
            self.metrics.tokens_generated.add(tokens as u64);
            self.metrics.requests_completed.inc();
            self.outcomes.push(RequestOutcome {
                id: r.id,
                prompt_len: r.prompt_len,
                generated: Vec::new(),
                ttft,
                e2e,
                mean_tpot: if tokens > 0 {
                    (self.clock - decode_start) / tokens as f64
                } else {
                    0.0
                },
            });
        }
        self.fsm.finish_request().ok();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{generate_workload, WorkloadConfig};
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn workload(n: usize) -> Vec<Request> {
        generate_workload(&WorkloadConfig {
            n_requests: n,
            prompt_len: (64, 512),
            gen_len: (8, 32),
            ..Default::default()
        })
    }

    #[test]
    fn pd_swap_serves_workload() {
        let mut s =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        let m = s.run(workload(6)).unwrap();
        assert_eq!(m.requests_completed.get(), 6);
        assert!(m.tokens_generated.get() > 0);
        assert!(m.reconfigurations.get() >= 6, "one swap pair per request");
        assert!(m.decode_throughput() > 5.0);
    }

    #[test]
    fn static_design_never_reconfigures() {
        let mut s =
            SimServer::new(SimServerConfig::tellme_static(BITNET_0_73B, KV260.clone()))
                .unwrap();
        let m = s.run(workload(4)).unwrap();
        assert_eq!(m.reconfigurations.get(), 0);
        assert_eq!(m.requests_completed.get(), 4);
    }

    #[test]
    fn pd_beats_static_on_e2e() {
        let w = workload(6);
        let mut pd =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        let mut st =
            SimServer::new(SimServerConfig::tellme_static(BITNET_0_73B, KV260.clone()))
                .unwrap();
        pd.run(w.clone()).unwrap();
        st.run(w).unwrap();
        assert!(
            pd.metrics.e2e.mean() < st.metrics.e2e.mean(),
            "pd {:.2}s vs static {:.2}s",
            pd.metrics.e2e.mean(),
            st.metrics.e2e.mean()
        );
    }

    #[test]
    fn overlap_reduces_exposed_latency() {
        let w = workload(5);
        let mut with = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        with.overlap = true;
        let mut without = with.clone();
        without.overlap = false;

        let mut a = SimServer::new(with).unwrap();
        let mut b = SimServer::new(without).unwrap();
        a.run(w.clone()).unwrap();
        b.run(w).unwrap();
        assert!(
            a.metrics.reconfig_exposed.mean() < b.metrics.reconfig_exposed.mean(),
            "overlap {:.1}ms vs sequential {:.1}ms",
            a.metrics.reconfig_exposed.mean() * 1e3,
            b.metrics.reconfig_exposed.mean() * 1e3
        );
        // TTFT improves accordingly.
        assert!(a.metrics.ttft.mean() <= b.metrics.ttft.mean() + 1e-9);
    }

    #[test]
    fn batched_policy_amortizes_swaps() {
        // Same 6 near-simultaneous requests; batched mode pays fewer swaps.
        let mut w = workload(6);
        for r in &mut w {
            r.arrival = 0.0;
        }
        let mut per_req = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        per_req.policy = Policy::SwapPerRequest;
        let mut batched = per_req.clone();
        batched.policy = Policy::BatchedPhases { max_batch: 8 };

        let mut a = SimServer::new(per_req).unwrap();
        let mut b = SimServer::new(batched).unwrap();
        a.run(w.clone()).unwrap();
        b.run(w).unwrap();
        assert!(
            b.metrics.reconfigurations.get() < a.metrics.reconfigurations.get(),
            "batched {} swaps vs per-request {}",
            b.metrics.reconfigurations.get(),
            a.metrics.reconfigurations.get()
        );
        // And the batch finishes sooner overall.
        assert!(b.clock() <= a.clock() + 1e-9);
    }

    #[test]
    fn cache_capacity_caps_generation() {
        let mut s =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        // One request whose generation would overflow max_seq.
        let r = Request::synthetic(0, BITNET_0_73B.max_seq - 4, 100, 0.0);
        s.run(vec![r]).unwrap();
        assert_eq!(s.metrics.tokens_generated.get(), 4);
    }
}
