//! Phase-batch serving simulation on the modeled KV260 — the paper's
//! round-synchronous flow.
//!
//! Drives the full stack — scheduler → KV pool → FSM → swap controller →
//! phase latency model — over a workload, with a simulated clock. This is
//! the machine behind Figs. 5/6 and the ablation benches: the same loop
//! runs a PD-Swap device (DPR + overlap), a PD-Swap device without
//! overlap, or a static baseline (no swaps at all), selected by
//! configuration.
//!
//! Time advances in *phase-batch rounds* (prefill the batch, swap once,
//! decode the batch to completion), which is faithful to the paper's
//! evaluation but cannot represent arrivals landing mid-decode. For
//! *continuous mixed traffic* — swap-policy arbitration, per-layer
//! prefill progress, wall inter-token latency — use the event-driven core
//! in [`super::events::EventServer`]; this module remains the
//! batch-synchronous reference the paper figures are reproduced on, and
//! shares its per-request bookkeeping (the crate-private `InFlight`) with
//! that engine. The decode rounds here interleave residents round-robin;
//! with [`SimServerConfig::decode_batch`] > 1 each round-robin position
//! groups up to that many consecutive ready streams into one
//! shared-weight-stream batched step
//! ([`crate::engines::LatencySurface::decode_step_batched_paged`]) — the
//! same grouping rule the event core uses — and `decode_batch = 1` keeps
//! the paper-figure-faithful one-stream-at-a-time rounds bit for bit (a
//! group of one evaluates the batch-1 closed form, which is bit-identical
//! to the single-step form).
//!
//! Multi-request serving (our extension beyond the paper's single-request
//! flow) is KV-capacity aware: every batch member holds a page
//! reservation in the [`crate::kvpool::KvPool`], batch extraction is
//! bounded by pool occupancy rather than a fixed cap, decode rounds are
//! interleaved round-robin across residents, and pool exhaustion is
//! resolved by the configured [`EvictionPolicy`] (evict-and-recompute
//! preempts the LRU resident back into the queue; keep-resident caps the
//! growing request instead).

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::engines::{AcceleratorDesign, AttentionHosting, LatencySurface, PhaseModel};
use crate::fpga::DeviceConfig;
use crate::kvpool::{EvictionPolicy, KvPool, KvPoolConfig, PoolError};
use crate::metrics::ServerMetrics;
use crate::model::ModelShape;
use crate::reconfig::{OverlapScheduler, SwapController, RM_PREFILL};
use crate::telemetry::TraceRecorder;

use super::events::InFlight;
use super::fsm::PhaseFsm;
use super::request::{OutcomeSink, Request, RequestOutcome};
use super::scheduler::{Policy, Scheduler};

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimServerConfig {
    pub design: AcceleratorDesign,
    pub device: DeviceConfig,
    pub shape: ModelShape,
    pub policy: Policy,
    /// Use the §3.4 latency-overlapped early trigger (PD-Swap default).
    pub overlap: bool,
    /// Paged KV-cache pool sizing + admission/eviction policy.
    pub pool: KvPoolConfig,
    /// Streams grouped per decode round position (1 = the paper's
    /// one-stream-at-a-time rounds, bit-identical to the pre-batching
    /// engine; B > 1 shares one weight-stream pass per group).
    pub decode_batch: usize,
    /// Record phase-span telemetry ([`crate::telemetry::TraceRecorder`])
    /// keyed to the virtual clock. Off by default; the disabled recorder
    /// is bitwise-inert.
    pub trace: bool,
}

impl SimServerConfig {
    pub fn pd_swap(shape: ModelShape, device: DeviceConfig) -> Self {
        let pool = KvPoolConfig::for_device(&shape, &device);
        Self {
            design: AcceleratorDesign::pd_swap(),
            device,
            shape,
            policy: Policy::SwapPerRequest,
            overlap: true,
            pool,
            decode_batch: 1,
            trace: false,
        }
    }

    pub fn tellme_static(shape: ModelShape, device: DeviceConfig) -> Self {
        let pool = KvPoolConfig::for_device(&shape, &device);
        Self {
            design: AcceleratorDesign::tellme_static(),
            device,
            shape,
            policy: Policy::SwapPerRequest,
            overlap: false,
            pool,
            decode_batch: 1,
            trace: false,
        }
    }
}

/// The simulated server.
pub struct SimServer {
    cfg: SimServerConfig,
    /// O(1) cached restatement of the phase model driving the per-request
    /// prefill and per-token decode rounds (bit-identical to direct
    /// [`PhaseModel`] calls; the overlap scheduler keeps its own model).
    surface: LatencySurface,
    swap: Option<SwapController>,
    overlap: Option<OverlapScheduler>,
    fsm: PhaseFsm,
    kv_pool: KvPool,
    /// Requests that have prefilled at least once (a second prefill is an
    /// eviction recompute and is charged to `metrics.recompute_overhead`).
    prefilled: HashSet<u64>,
    /// Requests already evicted once — never chosen as victims again, so
    /// every request completes in at most two serve attempts.
    evicted_once: HashSet<u64>,
    pub metrics: ServerMetrics,
    clock: f64,
    /// Completed-request records, bounded at
    /// [`super::OutcomeSink::DEFAULT_RETAIN`] like the event server's
    /// (derefs to `[RequestOutcome]`; the phase-batch engine serves
    /// paper-scale workloads, so the cap is never reached in practice).
    pub outcomes: OutcomeSink,
    /// Phase-span telemetry (inert unless `cfg.trace`); export with
    /// [`crate::telemetry::TraceRecorder::to_chrome_json`].
    pub recorder: TraceRecorder,
}

impl SimServer {
    pub fn new(cfg: SimServerConfig) -> Result<Self> {
        let model = PhaseModel::new(cfg.design.clone(), cfg.device.clone());
        let surface =
            LatencySurface::new(&cfg.design, &cfg.device, &cfg.shape, cfg.pool.page_tokens);
        let uses_dpr = cfg.design.hosting == AttentionHosting::Reconfigurable;
        let swap = if uses_dpr {
            Some(SwapController::new(cfg.design.program(&cfg.device)?))
        } else {
            // Static design: validate the floorplan but never swap.
            cfg.design.program(&cfg.device)?;
            None
        };
        let overlap = if uses_dpr {
            let lat = swap.as_ref().unwrap().device.reconfig_latency();
            Some(OverlapScheduler::new(model, lat))
        } else {
            None
        };
        let kv_pool = KvPool::new(cfg.pool.clone());
        let recorder = TraceRecorder::from_flag(cfg.trace);
        Ok(Self {
            cfg,
            surface,
            swap,
            overlap,
            fsm: PhaseFsm::new(),
            kv_pool,
            prefilled: HashSet::new(),
            evicted_once: HashSet::new(),
            metrics: ServerMetrics::default(),
            clock: 0.0,
            outcomes: OutcomeSink::default(),
            recorder,
        })
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// The paged KV pool (occupancy/fragmentation/conservation stats).
    pub fn pool(&self) -> &KvPool {
        &self.kv_pool
    }

    /// Serve a whole workload to completion; returns the metric bundle.
    /// Metrics and pool stats accumulate across calls; the per-run
    /// request-id bookkeeping resets so workloads may reuse ids.
    pub fn run(&mut self, mut workload: Vec<Request>) -> Result<&ServerMetrics> {
        self.prefilled.clear();
        self.evicted_once.clear();
        workload.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut sched = Scheduler::new(self.cfg.policy);
        for r in workload {
            sched.admit(r);
        }

        let mut stalls = 0usize;
        while !sched.is_empty() {
            // Advance the clock to the next arrival if idle.
            if let Some(next) = sched.next_arrival() {
                if next > self.clock {
                    self.clock = next;
                }
            }
            let batch = self.extract_batch(&mut sched);
            if batch.is_empty() {
                stalls += 1;
                if stalls > 10_000 {
                    bail!("scheduler stalled: head request never admitted to the KV pool");
                }
                continue;
            }
            stalls = 0;
            self.serve_batch(&mut sched, batch)?;
        }
        // Mirror the pool's conservation stats into the metric bundle —
        // `PoolStats` is the single source of truth for these counts.
        let high_water = self.kv_pool.stats.high_water_pages as u64;
        let evicted = self.kv_pool.stats.evicted;
        let capped = self.kv_pool.stats.capped_admissions;
        self.metrics.kv_pool_high_water.observe(high_water);
        let d = evicted.saturating_sub(self.metrics.kv_evictions.get());
        self.metrics.kv_evictions.add(d);
        let d = capped.saturating_sub(self.metrics.kv_admissions_capped.get());
        self.metrics.kv_admissions_capped.add(d);
        Ok(&self.metrics)
    }

    /// Pull the next phase-batch, bounding it by KV-pool occupancy: each
    /// extracted request commits a page reservation; extraction stops at
    /// the first head-of-queue request the pool cannot hold.
    fn extract_batch(&mut self, sched: &mut Scheduler) -> Vec<Request> {
        let now = self.clock;
        let pool = &mut self.kv_pool;
        let rec = &mut self.recorder;
        sched.next_batch_filtered(now, |r| {
            let plan = pool.admission_plan(r.prompt_len, r.max_new_tokens);
            // Batch-synchronous serving never evicts at admission time (the
            // only residents are batch-mates that have not run yet), so
            // EvictThenFit/Defer both close the batch for a later retry.
            let admitted = plan.admits_immediately()
                && pool.execute_admission(r.id, 0, plan, now).unwrap_or(false);
            let kind = if admitted { "kv-admit" } else { "kv-reject" };
            rec.kv_instant(kind, now, r.id, pool.used_pages(), pool.total_pages());
            admitted
        })
    }

    /// One phase-batch: prefill all, swap once, decode all (round-robin).
    fn serve_batch(&mut self, sched: &mut Scheduler, batch: Vec<Request>) -> Result<()> {
        let shape = self.cfg.shape;
        let page_tokens = self.cfg.pool.page_tokens;

        // -- ensure prefill RM ------------------------------------------------
        if let Some(swap) = self.swap.as_mut() {
            if !swap.device.is_live(RM_PREFILL, self.clock) {
                self.fsm.begin_swap(false, 0.0).ok();
                let ready = swap.ensure_prefill(self.clock)?;
                self.fsm.complete_swap(f64::MAX.min(ready)).ok();
                self.metrics.reconfigurations.inc();
                self.metrics.swaps_to_prefill.inc();
                // Nothing runs while the prefill RM loads: fully exposed.
                let lat = swap.device.reconfig_latency();
                self.recorder.swap_span(self.clock, ready, false, lat, ready - self.clock);
                self.clock = ready;
            }
        }

        // -- prefill phase ----------------------------------------------------
        // (start-of-prefill timestamps per request for TTFT accounting)
        let mut prefill_done = Vec::with_capacity(batch.len());
        for r in &batch {
            self.fsm.begin_prefill().ok();
            let pre = self.surface.prefill(r.prompt_len);
            let start = self.clock;
            self.clock += pre.total;
            prefill_done.push(self.clock);
            let first_pass = self.prefilled.insert(r.id);
            if !first_pass {
                // Second prefill of an evicted request: pure recompute tax.
                self.metrics.recompute_overhead.record(pre.total);
            }
            // The prompt's KV lands in the pool as it is written.
            let cap = self.kv_pool.token_cap(r.id).unwrap_or(shape.max_seq);
            self.kv_pool
                .ensure_tokens(r.id, r.prompt_len.min(cap), self.clock)
                .map_err(|e| anyhow::anyhow!("prefill KV write: {e}"))?;
            // Early-trigger the decode swap during the LAST request's tail
            // (batched mode keeps the prefill RM until the batch drains).
            let is_last = r.id == batch.last().unwrap().id;
            if is_last {
                if let (Some(swap), Some(ov)) = (self.swap.as_mut(), self.overlap.as_ref()) {
                    let timeline = if self.cfg.overlap {
                        ov.overlapped(&shape, r.prompt_len)
                    } else {
                        ov.sequential(&shape, r.prompt_len)
                    };
                    let trigger_abs = self.clock - pre.total + timeline.trigger;
                    self.fsm.begin_swap(true, trigger_abs + timeline.reconfig).ok();
                    let ready = swap.trigger_decode_swap(trigger_abs)?;
                    let admit = swap.decode_admissible_at(self.clock, ready);
                    self.metrics.reconfigurations.inc();
                    self.metrics.swaps_to_decode.inc();
                    let lat = swap.device.reconfig_latency();
                    self.metrics.record_reconfig_exposure(lat, admit - self.clock);
                    self.recorder.swap_span(
                        trigger_abs,
                        ready.max(trigger_abs),
                        true,
                        lat,
                        admit - self.clock,
                    );
                    self.clock = admit;
                    self.fsm.complete_swap(admit).ok();
                }
            }
            if self.recorder.is_enabled() {
                // The prefill timeline is analytic; the per-layer instants
                // and the §3.4 trigger are interleaved so the request
                // track stays ts-ordered.
                if first_pass {
                    self.recorder.request_queued(r.id, r.arrival.max(0.0).min(start), start);
                }
                self.recorder.prefill_span(r.id, start, pre.total, r.prompt_len, !first_pass);
                let trig_ts = if is_last {
                    self.overlap.as_ref().map(|ov| {
                        let t = if self.cfg.overlap {
                            ov.overlapped(&shape, r.prompt_len)
                        } else {
                            ov.sequential(&shape, r.prompt_len)
                        };
                        (start + t.trigger).min(start + pre.total)
                    })
                } else {
                    None
                };
                let n_layers = shape.n_layers.max(1);
                let mut layer = 1;
                while layer < n_layers {
                    let at = start + pre.total * layer as f64 / n_layers as f64;
                    if trig_ts.is_some_and(|t| at > t) {
                        break;
                    }
                    self.recorder.prefill_layer(r.id, at, layer);
                    layer += 1;
                }
                if let Some(t) = trig_ts {
                    self.recorder.trigger(r.id, t);
                }
                while layer < n_layers {
                    let at = start + pre.total * layer as f64 / n_layers as f64;
                    self.recorder.prefill_layer(r.id, at, layer);
                    layer += 1;
                }
            }
        }
        if self.swap.is_none() {
            // Static design: decode engine always live.
            self.fsm.begin_swap(true, self.clock).ok();
            self.fsm.complete_swap(self.clock).ok();
        }

        // -- decode phase (round-robin over residents) ------------------------
        debug_assert!(self.fsm.decode_admissible(self.clock));
        let decode_start = self.clock;
        let mut active: Vec<InFlight> = batch
            .into_iter()
            .zip(prefill_done)
            .map(|(req, prefill_done)| {
                let token_cap = self.kv_pool.token_cap(req.id).unwrap_or(shape.max_seq);
                InFlight::new(req, prefill_done, token_cap)
            })
            .collect();

        let b_max = self.cfg.decode_batch.max(1);
        // Group scratch, reused across rounds (allocation only grows it
        // to `b_max` once).
        let mut group_ids: Vec<u64> = Vec::new();
        let mut group_ctxs: Vec<usize> = Vec::new();
        while !active.is_empty() {
            let mut i = 0;
            while i < active.len() {
                // Assemble up to `decode_batch` consecutive ready streams
                // starting at the round-robin position: each secures its
                // next KV slot (evicting per policy under pool pressure)
                // exactly as the one-stream rounds did. A group of one IS
                // the paper flow — same decisions, and the batch-1 closed
                // form below is bit-identical to the single-step form.
                group_ids.clear();
                group_ctxs.clear();
                while i < active.len() && group_ids.len() < b_max {
                    if active[i].done(shape.max_seq) {
                        let f = active.remove(i);
                        self.finish_request(f, decode_start)?;
                        continue;
                    }
                    // Secure the KV slot for the next token, evicting per
                    // policy when the pool is exhausted.
                    let id = active[i].req.id;
                    let next_tokens = active[i].ctx + 1;
                    let grew = loop {
                        match self.kv_pool.ensure_tokens(id, next_tokens, self.clock) {
                            Ok(()) => break true,
                            Err(PoolError::Exhausted { .. }) => {
                                // First sweep any batch-mate that already
                                // finished generating but has not been visited
                                // yet this round: completing it releases its
                                // pages without discarding any work. (Group
                                // members are never done — they have not been
                                // stepped yet.)
                                let done_mate = active
                                    .iter()
                                    .position(|a| a.req.id != id && a.done(shape.max_seq));
                                if let Some(j) = done_mate {
                                    let f = active.remove(j);
                                    self.finish_request(f, decode_start)?;
                                    if j < i {
                                        i -= 1;
                                    }
                                    continue;
                                }
                                if self.cfg.pool.eviction != EvictionPolicy::EvictAndRecompute
                                {
                                    break false;
                                }
                                // Streams already in this group hold the pages
                                // the step is about to use — never victims.
                                let victim = self.kv_pool.lru_victim(|v| {
                                    v != id
                                        && !group_ids.contains(&v)
                                        && !self.evicted_once.contains(&v)
                                });
                                let Some(vid) = victim else { break false };
                                self.kv_pool
                                    .evict_at(vid, self.clock)
                                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                                self.recorder.kv_instant(
                                    "kv-evict",
                                    self.clock,
                                    vid,
                                    self.kv_pool.used_pages(),
                                    self.kv_pool.total_pages(),
                                );
                                self.evicted_once.insert(vid);
                                let j = active
                                    .iter()
                                    .position(|a| a.req.id == vid)
                                    .expect("victim must be an active batch member");
                                let preempted = active.remove(j);
                                // Preemption hook: back to the queue front — its
                                // generated-so-far tokens are discarded and its
                                // prompt re-prefilled on the next attempt.
                                sched.requeue_front(preempted.req);
                                if j < i {
                                    i -= 1;
                                }
                            }
                            Err(_) => break false,
                        }
                    };
                    if !grew {
                        if !group_ids.is_empty() {
                            // Partial group: step what is secured; this
                            // stream gets retried at its next round-robin
                            // turn (completing the group can free pages).
                            break;
                        }
                        // Capacity-capped: deliver what we have.
                        let f = active.remove(i);
                        self.finish_request(f, decode_start)?;
                        continue;
                    }
                    group_ids.push(id);
                    group_ctxs.push(active[i].ctx);
                    i += 1;
                }
                if group_ids.is_empty() {
                    continue;
                }
                // One shared weight-stream pass for the whole group.
                let step =
                    self.surface.decode_step_batched_paged(&group_ctxs, page_tokens).total;
                self.clock += step;
                for (gi, &id) in group_ids.iter().enumerate() {
                    let k = active
                        .iter()
                        .position(|a| a.req.id == id)
                        .expect("group member still active");
                    self.metrics.tpot.record(step);
                    // Batched steps attributed to every member stream.
                    self.recorder.decode_step(
                        id,
                        self.clock - step,
                        step,
                        group_ids.len(),
                        group_ctxs[gi],
                    );
                    active[k].ctx += 1;
                    active[k].tokens += 1;
                    self.kv_pool.touch(id, self.clock);
                }
            }
        }
        self.fsm.finish_request().ok();
        Ok(())
    }

    /// Release the pool reservation and record the outcome.
    fn finish_request(&mut self, f: InFlight, decode_start: f64) -> Result<()> {
        self.kv_pool
            .complete(f.req.id)
            .map_err(|e| anyhow::anyhow!("completing request {}: {e}", f.req.id))?;
        self.recorder.kv_instant(
            "kv-release",
            self.clock,
            f.req.id,
            self.kv_pool.used_pages(),
            self.kv_pool.total_pages(),
        );
        // First token comes out of prefill logits; TTFT counts queue +
        // prefill + exposed swap.
        let ttft = decode_start.max(f.prefill_done) - f.req.arrival;
        let e2e = self.clock - f.req.arrival;
        self.metrics.ttft.record(ttft);
        self.metrics.e2e.record(e2e);
        self.metrics.tokens_generated.add(f.tokens as u64);
        self.metrics.requests_completed.inc();
        self.outcomes.push(RequestOutcome {
            id: f.req.id,
            prompt_len: f.req.prompt_len,
            generated: Vec::new(),
            ttft,
            e2e,
            // Wall span of this request's decode divided by its tokens —
            // under round-robin this includes interleaved batch-mates'
            // steps (the latency a co-tenant actually observes).
            mean_tpot: if f.tokens > 0 {
                (self.clock - decode_start) / f.tokens as f64
            } else {
                0.0
            },
            shed: false,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{generate_workload, WorkloadConfig};
    use crate::fpga::KV260;
    use crate::kvpool::AdmissionControl;
    use crate::model::BITNET_0_73B;

    fn workload(n: usize) -> Vec<Request> {
        generate_workload(&WorkloadConfig {
            n_requests: n,
            prompt_len: (64, 512),
            gen_len: (8, 32),
            ..Default::default()
        })
    }

    #[test]
    fn pd_swap_serves_workload() {
        let mut s =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        let m = s.run(workload(6)).unwrap();
        assert_eq!(m.requests_completed.get(), 6);
        assert!(m.tokens_generated.get() > 0);
        assert!(m.reconfigurations.get() >= 6, "one swap pair per request");
        assert!(m.decode_throughput() > 5.0);
    }

    #[test]
    fn static_design_never_reconfigures() {
        let mut s =
            SimServer::new(SimServerConfig::tellme_static(BITNET_0_73B, KV260.clone()))
                .unwrap();
        let m = s.run(workload(4)).unwrap();
        assert_eq!(m.reconfigurations.get(), 0);
        assert_eq!(m.requests_completed.get(), 4);
    }

    #[test]
    fn pd_beats_static_on_e2e() {
        let w = workload(6);
        let mut pd =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        let mut st =
            SimServer::new(SimServerConfig::tellme_static(BITNET_0_73B, KV260.clone()))
                .unwrap();
        pd.run(w.clone()).unwrap();
        st.run(w).unwrap();
        assert!(
            pd.metrics.e2e.mean() < st.metrics.e2e.mean(),
            "pd {:.2}s vs static {:.2}s",
            pd.metrics.e2e.mean(),
            st.metrics.e2e.mean()
        );
    }

    #[test]
    fn overlap_reduces_exposed_latency() {
        let w = workload(5);
        let mut with = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        with.overlap = true;
        let mut without = with.clone();
        without.overlap = false;

        let mut a = SimServer::new(with).unwrap();
        let mut b = SimServer::new(without).unwrap();
        a.run(w.clone()).unwrap();
        b.run(w).unwrap();
        assert!(
            a.metrics.reconfig_exposed.mean() < b.metrics.reconfig_exposed.mean(),
            "overlap {:.1}ms vs sequential {:.1}ms",
            a.metrics.reconfig_exposed.mean() * 1e3,
            b.metrics.reconfig_exposed.mean() * 1e3
        );
        // TTFT improves accordingly.
        assert!(a.metrics.ttft.mean() <= b.metrics.ttft.mean() + 1e-9);
    }

    #[test]
    fn batched_policy_amortizes_swaps() {
        // Same 6 near-simultaneous requests; batched mode pays fewer swaps.
        let mut w = workload(6);
        for r in &mut w {
            r.arrival = 0.0;
        }
        let mut per_req = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        per_req.policy = Policy::SwapPerRequest;
        let mut batched = per_req.clone();
        batched.policy = Policy::BatchedPhases { max_batch: 8 };

        let mut a = SimServer::new(per_req).unwrap();
        let mut b = SimServer::new(batched).unwrap();
        a.run(w.clone()).unwrap();
        b.run(w).unwrap();
        assert!(
            b.metrics.reconfigurations.get() < a.metrics.reconfigurations.get(),
            "batched {} swaps vs per-request {}",
            b.metrics.reconfigurations.get(),
            a.metrics.reconfigurations.get()
        );
        // And the batch finishes sooner overall.
        assert!(b.clock() <= a.clock() + 1e-9);
    }

    #[test]
    fn batched_decode_rounds_amortize_the_weight_stream() {
        // Four simultaneous residents in one phase-batch: grouping their
        // decode rounds shares the packed weight stream, so the same work
        // finishes sooner — and the pool still balances.
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 64, 0.0)).collect();
        let mut base = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        base.policy = Policy::BatchedPhases { max_batch: 8 };
        let mut b4_cfg = base.clone();
        b4_cfg.decode_batch = 4;
        let mut b1 = SimServer::new(base).unwrap();
        b1.run(w.clone()).unwrap();
        let mut b4 = SimServer::new(b4_cfg).unwrap();
        b4.run(w).unwrap();
        assert_eq!(
            b1.metrics.tokens_generated.get(),
            b4.metrics.tokens_generated.get(),
            "same work either way"
        );
        assert!(
            b4.clock() < b1.clock(),
            "grouped rounds {:.2}s vs single {:.2}s",
            b4.clock(),
            b1.clock()
        );
        b4.pool().check_invariants().unwrap();
        assert_eq!(b4.pool().resident_count(), 0);
    }

    #[test]
    fn decode_batch_cap_is_inert_with_one_resident() {
        // A single request can only ever form groups of one, so
        // decode_batch = 4 must reproduce the decode_batch = 1 timeline
        // bit for bit (the batch-1 closed form is bit-identical to the
        // single-step form) — the paper-figure guarantee for the
        // batch-synchronous engine.
        let w = vec![Request::synthetic(0, 256, 32, 0.0)];
        let mut cfg1 = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        cfg1.decode_batch = 1;
        let mut cfg4 = cfg1.clone();
        cfg4.decode_batch = 4;
        let mut a = SimServer::new(cfg1).unwrap();
        a.run(w.clone()).unwrap();
        let mut b = SimServer::new(cfg4).unwrap();
        b.run(w).unwrap();
        assert_eq!(a.clock().to_bits(), b.clock().to_bits());
        assert_eq!(
            a.metrics.tpot.mean().to_bits(),
            b.metrics.tpot.mean().to_bits()
        );
        assert_eq!(
            a.metrics.e2e.mean().to_bits(),
            b.metrics.e2e.mean().to_bits()
        );
    }

    #[test]
    fn batched_rounds_under_pool_pressure_complete_everyone() {
        // Optimistic admission + a small pool at decode_batch 4: eviction
        // happens mid-group assembly; every request still completes
        // exactly once and the accounting balances.
        let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        cfg.policy = Policy::BatchedPhases { max_batch: 8 };
        cfg.decode_batch = 4;
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(40)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut s = SimServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
        s.run(w).unwrap();
        assert_eq!(s.metrics.requests_completed.get(), 4);
        assert!(s.metrics.kv_evictions.get() >= 1, "pool pressure must evict");
        let pool = s.pool();
        pool.check_invariants().unwrap();
        assert_eq!(pool.resident_count(), 0);
        assert_eq!(pool.stats.admitted, pool.stats.completed + pool.stats.evicted);
    }

    #[test]
    fn cache_capacity_caps_generation() {
        let mut s =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        // One request whose generation would overflow max_seq.
        let r = Request::synthetic(0, BITNET_0_73B.max_seq - 4, 100, 0.0);
        s.run(vec![r]).unwrap();
        assert_eq!(s.metrics.tokens_generated.get(), 4);
    }

    #[test]
    fn pool_drains_and_reports_high_water() {
        let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        cfg.policy = Policy::BatchedPhases { max_batch: 8 };
        let mut s = SimServer::new(cfg).unwrap();
        let mut w = workload(6);
        for r in &mut w {
            r.arrival = 0.0;
        }
        s.run(w).unwrap();
        let pool = s.pool();
        pool.check_invariants().unwrap();
        assert_eq!(pool.resident_count(), 0, "pool must drain");
        assert_eq!(pool.used_pages(), 0);
        assert!(pool.stats.high_water_pages > 0);
        assert_eq!(
            s.metrics.kv_pool_high_water.get(),
            pool.stats.high_water_pages as u64
        );
        assert_eq!(pool.stats.completed, 6);
    }

    #[test]
    fn oversubscribed_worst_case_splits_batches() {
        // Pool sized for ~2.5 full-length requests; 6 requests whose
        // aggregate worst case (~6×64 pages) exceeds it. WorstCase
        // admission must split the batch, never panic, and still finish
        // everything.
        let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        cfg.policy = Policy::BatchedPhases { max_batch: 8 };
        cfg.pool = cfg.pool.clone().with_total_pages(160);
        let mut s = SimServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..6).map(|i| Request::synthetic(i, 1800, 64, 0.0)).collect();
        s.run(w).unwrap();
        assert_eq!(s.metrics.requests_completed.get(), 6);
        assert_eq!(s.metrics.kv_evictions.get(), 0, "worst-case never evicts");
        let pool = s.pool();
        pool.check_invariants().unwrap();
        assert_eq!(pool.resident_count(), 0);
        assert!(pool.stats.high_water_pages <= 160);
        // 1800-prompt requests need 59 pages each: at most 2 fit at once.
        assert!(s.metrics.reconfigurations.get() >= 6, "≥3 batches → ≥3 swap pairs");
    }

    #[test]
    fn optimistic_overload_evicts_and_recomputes() {
        let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        cfg.policy = Policy::BatchedPhases { max_batch: 8 };
        // Prompts of 256 → 8 pages each; all 4 admit optimistically
        // (32 of 40 pages), but growing each to 256+96 tokens needs 12
        // more pages than the 8 free — someone gets evicted.
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(40)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut s = SimServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
        s.run(w).unwrap();
        assert_eq!(s.metrics.requests_completed.get(), 4, "evicted requests finish later");
        assert!(s.metrics.kv_evictions.get() >= 1, "pool pressure must evict");
        assert!(
            s.metrics.recompute_overhead.count() >= 1,
            "evicted request re-prefills"
        );
        let pool = s.pool();
        pool.check_invariants().unwrap();
        assert_eq!(pool.resident_count(), 0);
        assert_eq!(pool.stats.evicted, s.metrics.kv_evictions.get());
        assert_eq!(
            pool.stats.admitted,
            pool.stats.completed + pool.stats.evicted
        );
    }

    #[test]
    fn keep_resident_overload_caps_instead_of_evicting() {
        let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        cfg.policy = Policy::BatchedPhases { max_batch: 8 };
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(40)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::KeepResident);
        let mut s = SimServer::new(cfg).unwrap();
        let w: Vec<Request> =
            (0..4).map(|i| Request::synthetic(i, 256, 96, 0.0)).collect();
        s.run(w).unwrap();
        assert_eq!(s.metrics.requests_completed.get(), 4);
        assert_eq!(s.metrics.kv_evictions.get(), 0);
        // Under pressure some generations were truncated.
        assert!(s.metrics.tokens_generated.get() < 4 * 96);
        s.pool().check_invariants().unwrap();
    }

    #[test]
    fn tracing_is_bitwise_inert_and_traces_validate() {
        let w = workload(6);
        let mut off =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        off.run(w.clone()).unwrap();
        let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        cfg.trace = true;
        let mut on = SimServer::new(cfg).unwrap();
        on.run(w).unwrap();
        assert_eq!(off.clock().to_bits(), on.clock().to_bits());
        assert_eq!(
            off.metrics.ttft.mean().to_bits(),
            on.metrics.ttft.mean().to_bits()
        );
        assert_eq!(
            off.metrics.tpot.mean().to_bits(),
            on.metrics.tpot.mean().to_bits()
        );
        assert_eq!(off.outcomes.len(), on.outcomes.len());
        for (a, b) in off.outcomes.iter().zip(&on.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
        }
        assert!(off.recorder.is_empty());
        let names: std::collections::HashSet<&'static str> =
            on.recorder.events().iter().map(|e| e.name).collect();
        for n in ["queued", "prefill", "layer", "trigger", "decode-step", "pcap-to-decode"] {
            assert!(names.contains(n), "missing {n}");
        }
        crate::telemetry::validate_chrome_trace(&on.recorder.to_chrome_json()).unwrap();
        // Byte-identical across a repeated run.
        let rerun = || {
            let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
            cfg.trace = true;
            let mut s = SimServer::new(cfg).unwrap();
            s.run(workload(6)).unwrap();
            s.recorder.to_chrome_json().to_string()
        };
        assert_eq!(rerun(), rerun());
    }

    #[test]
    fn sim_server_splits_swap_directions() {
        let mut s =
            SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
        let m = s.run(workload(4)).unwrap();
        assert_eq!(
            m.reconfigurations.get(),
            m.swaps_to_prefill.get() + m.swaps_to_decode.get()
        );
        assert!(m.swaps_to_decode.get() >= 4, "one decode swap per phase-batch");
        assert!(m.reconfig_hidden_fraction() > 0.0, "§3.4 overlap hides some PCAP time");
    }

    #[test]
    fn single_oversized_request_is_capped_not_stuck() {
        let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
        // Pool smaller than one request's prompt.
        cfg.pool = cfg.pool.clone().with_total_pages(8);
        let mut s = SimServer::new(cfg).unwrap();
        s.run(vec![Request::synthetic(0, 1024, 64, 0.0)]).unwrap();
        assert_eq!(s.metrics.requests_completed.get(), 1);
        assert_eq!(s.metrics.kv_admissions_capped.get(), 1);
        assert_eq!(s.metrics.tokens_generated.get(), 0, "no page left to grow into");
        s.pool().check_invariants().unwrap();
    }
}
