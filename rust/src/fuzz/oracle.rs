//! The differential oracle: run every applicable engine pair on one
//! [`FuzzCase`] and assert the documented equivalences.
//!
//! The oracle matrix (see `docs/ARCHITECTURE.md` extension #9):
//!
//! | pair | promise |
//! |------|---------|
//! | fast-forward vs stepped | bitwise [`semantic_fingerprint`] + fold event accounting |
//! | surface vs direct phase model | bitwise fingerprint |
//! | streamed vs materialized | bitwise fingerprint + event/arrival counts |
//! | telemetry on vs off | bitwise fingerprint (inert recorder) + valid Chrome trace |
//! | `EventServer` vs `SimServer` | invariant-only (different time semantics) |
//!
//! Every `EventServer` run additionally passes always-on well-formedness
//! checks: monotone diagnostic log, finite non-negative clock, drained
//! pool with intact conservation invariants, exact [`OutcomeSink`] drop
//! accounting, eviction-counter agreement, token conservation, and
//! shed-path conservation (`completed + shed == arrivals`).
//!
//! Cases additionally draw a fault axis (extension #10): a
//! [`crate::faults::FaultSpec`] kind and seed realized identically for
//! every `EventServer` leg, so the bitwise pairs are exercised under
//! injected PCAP swap failures, DDR brownouts, and SLO deadline sheds
//! as well as fault-free.

use crate::coordinator::{
    requests_from_stream, requests_from_trace, semantic_fingerprint, EventServer,
    EventServerConfig, OutcomeSink, Policy, Request, SimServer, SimServerConfig,
};
use crate::engines::AcceleratorDesign;
use crate::fpga::KV260;
use crate::telemetry::validate_chrome_trace;

use super::generator::{fuzz_shape, FuzzCase};

/// A failed oracle check: which engine pair disagreed, where, and how.
#[derive(Debug, Clone)]
pub struct Divergence {
    /// Which pair (or which well-formedness check) failed.
    pub pair: &'static str,
    /// First [`semantic_fingerprint`] line at which the runs part ways —
    /// the fingerprint is ordered by the event timeline (clock, counters,
    /// histograms, then per-request outcomes in completion order), so
    /// this is the event-index analog a reproducer should start from.
    /// Zero for invariant violations with no line structure.
    pub line: usize,
    pub detail: String,
}

/// Oracle knobs. The only knob is test-only fault injection: a token
/// ceiling that makes the oracle report a synthetic divergence whenever
/// the reference run generates at least that many tokens. It exists to
/// prove the shrink → fixture → replay loop end-to-end (an injected
/// "bug" shrinks to the floor case and replays from disk) and is never
/// set by the CLI.
#[derive(Debug, Clone, Copy, Default)]
pub struct OracleOptions {
    pub inject_token_ceiling: Option<u64>,
}

/// What a clean case contributes to the run summary.
#[derive(Debug, Clone)]
pub struct CaseReport {
    /// Reference fingerprint (fast-forward + surface, materialized).
    pub fingerprint: String,
    pub requests: usize,
    pub pairs_checked: usize,
    pub events_reference: u64,
    pub events_stepped: u64,
}

fn div(pair: &'static str, detail: String) -> Divergence {
    Divergence { pair, line: 0, detail }
}

/// Compare two fingerprints; on mismatch report the first divergent line.
fn bitwise(pair: &'static str, reference: &str, candidate: &str) -> Result<(), Divergence> {
    if reference == candidate {
        return Ok(());
    }
    let line = reference
        .lines()
        .zip(candidate.lines())
        .position(|(a, b)| a != b)
        .unwrap_or_else(|| reference.lines().count().min(candidate.lines().count()));
    let a = reference.lines().nth(line).unwrap_or("<end>");
    let b = candidate.lines().nth(line).unwrap_or("<end>");
    Err(Divergence {
        pair,
        line,
        detail: format!("fingerprint line {line}: reference `{a}` vs candidate `{b}`"),
    })
}

/// The reference `EventServer` configuration for a case: fast-forward
/// on, cached surface backend, telemetry off.
fn event_cfg(case: &FuzzCase, design: &AcceleratorDesign, batch: usize) -> EventServerConfig {
    let mut cfg = EventServerConfig::pd_swap(fuzz_shape(), KV260.clone(), case.swap_policy());
    cfg.design = design.clone();
    cfg.pool = case.pool_config();
    cfg.decode_batch = batch;
    cfg.max_residents = case.max_residents;
    // The fault axis (extension #10): every EventServer leg realizes its
    // own fresh plan from the same (kind, seed), so their failure-draw
    // streams start aligned and the bitwise pairs stay bitwise under
    // faults. The retry policy stays at the default (retry + degraded
    // fallback); the SimServer leg stays fault-free by construction.
    cfg.faults = case.fault_plan();
    cfg
}

fn run_event(
    cfg: EventServerConfig,
    reqs: &[Request],
    pair: &'static str,
) -> Result<EventServer, Divergence> {
    let mut srv =
        EventServer::new(cfg).map_err(|e| div(pair, format!("EventServer::new failed: {e}")))?;
    srv.run(reqs.to_vec()).map_err(|e| div(pair, format!("run failed: {e}")))?;
    Ok(srv)
}

fn check_outcomes(
    outcomes: &OutcomeSink,
    completed: u64,
    pair: &'static str,
) -> Result<(), Divergence> {
    if outcomes.len() as u64 + outcomes.dropped() != completed {
        return Err(div(
            pair,
            format!(
                "OutcomeSink drop accounting: {} kept + {} dropped != {completed} completed",
                outcomes.len(),
                outcomes.dropped()
            ),
        ));
    }
    let mut ids: Vec<u64> = outcomes.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    ids.dedup();
    if ids.len() != outcomes.len() {
        return Err(div(pair, "duplicate request id in outcomes".into()));
    }
    for o in outcomes.iter() {
        if !(o.ttft >= 0.0 && o.e2e >= o.ttft - 1e-9) {
            return Err(div(
                pair,
                format!("outcome {} latency ordering: ttft {} e2e {}", o.id, o.ttft, o.e2e),
            ));
        }
    }
    Ok(())
}

/// Always-on well-formedness for one completed `EventServer` run.
fn well_formed(s: &EventServer, n: usize, sum_max_new: u64, pair: &'static str) -> Result<(), Divergence> {
    if !s.clock().is_finite() || s.clock() < 0.0 {
        return Err(div(pair, format!("virtual clock not finite/non-negative: {}", s.clock())));
    }
    let log = s.event_log();
    for w in log.windows(2) {
        if w[1].at < w[0].at {
            return Err(div(
                pair,
                format!("diagnostic log not monotone: {} after {}", w[1].at, w[0].at),
            ));
        }
    }
    s.pool()
        .check_invariants()
        .map_err(|e| div(pair, format!("pool conservation: {e}")))?;
    if s.pool().resident_count() != 0 || s.pool().used_pages() != 0 {
        return Err(div(
            pair,
            format!(
                "pool not drained: {} residents, {} pages",
                s.pool().resident_count(),
                s.pool().used_pages()
            ),
        ));
    }
    // Shed-path conservation: every arrival either completes or is shed
    // with an explicit outcome — nothing vanishes. Fault-free plans
    // never shed, so this is the old `completed == n` check there.
    let completed = s.metrics.requests_completed.get();
    let shed = s.metrics.requests_shed.get();
    if completed + shed != n as u64 {
        return Err(div(
            pair,
            format!("conservation: {completed} completed + {shed} shed != {n} arrivals"),
        ));
    }
    if s.metrics.tokens_generated.get() > sum_max_new {
        return Err(div(
            pair,
            format!(
                "token conservation: generated {} > requested {sum_max_new}",
                s.metrics.tokens_generated.get()
            ),
        ));
    }
    if s.metrics.kv_evictions.get() != s.pool().stats.evicted {
        return Err(div(
            pair,
            format!(
                "eviction counters disagree: metrics {} vs pool {}",
                s.metrics.kv_evictions.get(),
                s.pool().stats.evicted
            ),
        ));
    }
    check_outcomes(&s.outcomes, completed + shed, pair)
}

/// The invariant-only `SimServer` leg: the phase-batch engine has
/// different time semantics (round-synchronous, no mid-decode arrivals)
/// so nothing bitwise is promised — but conservation must hold on the
/// same workload, design, and pool.
fn check_sim(
    case: &FuzzCase,
    design: &AcceleratorDesign,
    reqs: &[Request],
    batch: usize,
    sum_max_new: u64,
) -> Result<(), Divergence> {
    const PAIR: &str = "sim-server-conservation";
    let cfg = SimServerConfig {
        design: design.clone(),
        device: KV260.clone(),
        shape: fuzz_shape(),
        policy: Policy::BatchedPhases { max_batch: case.max_residents.max(1) },
        overlap: true,
        pool: case.pool_config(),
        decode_batch: batch,
        trace: false,
    };
    let mut srv =
        SimServer::new(cfg).map_err(|e| div(PAIR, format!("SimServer::new failed: {e}")))?;
    srv.run(reqs.to_vec()).map_err(|e| div(PAIR, format!("run failed: {e}")))?;
    if !srv.clock().is_finite() || srv.clock() < 0.0 {
        return Err(div(PAIR, format!("clock not finite/non-negative: {}", srv.clock())));
    }
    srv.pool()
        .check_invariants()
        .map_err(|e| div(PAIR, format!("pool conservation: {e}")))?;
    if srv.pool().resident_count() != 0 || srv.pool().used_pages() != 0 {
        return Err(div(PAIR, "pool not drained at end of run".into()));
    }
    if srv.metrics.requests_completed.get() != reqs.len() as u64 {
        return Err(div(
            PAIR,
            format!(
                "completed {} of {} requests",
                srv.metrics.requests_completed.get(),
                reqs.len()
            ),
        ));
    }
    if srv.metrics.tokens_generated.get() > sum_max_new {
        return Err(div(
            PAIR,
            format!(
                "token conservation: generated {} > requested {sum_max_new}",
                srv.metrics.tokens_generated.get()
            ),
        ));
    }
    if srv.metrics.kv_evictions.get() != srv.pool().stats.evicted {
        return Err(div(
            PAIR,
            format!(
                "eviction counters disagree: metrics {} vs pool {}",
                srv.metrics.kv_evictions.get(),
                srv.pool().stats.evicted
            ),
        ));
    }
    check_outcomes(&srv.outcomes, srv.metrics.requests_completed.get(), PAIR)
}

/// Run the whole oracle on one case: reference run, then every
/// applicable pair. Returns the first divergence found (the driver
/// shrinks it), or a [`CaseReport`] for the summary digest.
pub fn run_case(case: &FuzzCase, opts: OracleOptions) -> Result<CaseReport, Divergence> {
    let spec = case.trace_spec();
    let reqs = requests_from_trace(&spec.generate());
    let design = case.design();
    let batch = case
        .decode_batch
        .min(design.max_decode_batch(&KV260, &fuzz_shape()))
        .max(1);
    let sum_max_new: u64 = reqs.iter().map(|r| r.max_new_tokens as u64).sum();
    let n = reqs.len();

    // A — reference: fast-forward + surface backend, materialized.
    let reference = run_event(event_cfg(case, &design, batch), &reqs, "reference")?;
    well_formed(&reference, n, sum_max_new, "reference")?;
    let fp = semantic_fingerprint(&reference);
    let mut pairs_checked = 0usize;

    // B — stepped: fast-forward off must be bitwise identical, and the
    // fold accounting must balance (every skipped token-step stands in
    // for exactly one stepped queue event).
    let stepped = {
        let mut cfg = event_cfg(case, &design, batch);
        cfg.fast_forward = false;
        run_event(cfg, &reqs, "fast-forward-vs-stepped")?
    };
    well_formed(&stepped, n, sum_max_new, "fast-forward-vs-stepped")?;
    bitwise("fast-forward-vs-stepped", &fp, &semantic_fingerprint(&stepped))?;
    let equiv = reference
        .fast_forward_stats()
        .stepped_equivalent(reference.events_processed());
    if equiv != stepped.events_processed() {
        return Err(div(
            "fast-forward-vs-stepped",
            format!(
                "fold event accounting drifted: {equiv} folded-equivalent vs {} stepped",
                stepped.events_processed()
            ),
        ));
    }
    if stepped.fast_forward_stats().steps != 0 {
        return Err(div("fast-forward-vs-stepped", "the stepped run must never fold".into()));
    }
    pairs_checked += 1;

    // C — direct backend: the cached surface is a restatement of the
    // phase model, so disabling it must not move a bit.
    let direct = {
        let mut cfg = event_cfg(case, &design, batch);
        cfg.use_surface = false;
        run_event(cfg, &reqs, "surface-vs-direct")?
    };
    well_formed(&direct, n, sum_max_new, "surface-vs-direct")?;
    bitwise("surface-vs-direct", &fp, &semantic_fingerprint(&direct))?;
    pairs_checked += 1;

    // D — streamed: lazy arrivals through a bounded window reproduce the
    // materialized run bitwise, including event and arrival counts.
    let streamed = {
        let cfg = event_cfg(case, &design, batch);
        let mut srv = EventServer::new(cfg)
            .map_err(|e| div("streamed-vs-materialized", format!("EventServer::new failed: {e}")))?;
        srv.run_streamed(requests_from_stream(spec.stream()), case.window)
            .map_err(|e| div("streamed-vs-materialized", format!("run_streamed failed: {e}")))?;
        srv
    };
    well_formed(&streamed, n, sum_max_new, "streamed-vs-materialized")?;
    bitwise("streamed-vs-materialized", &fp, &semantic_fingerprint(&streamed))?;
    if streamed.events_processed() != reference.events_processed()
        || streamed.arrivals_total() != reference.arrivals_total()
    {
        return Err(div(
            "streamed-vs-materialized",
            format!(
                "event accounting drifted: streamed {}/{} vs materialized {}/{}",
                streamed.events_processed(),
                streamed.arrivals_total(),
                reference.events_processed(),
                reference.arrivals_total()
            ),
        ));
    }
    pairs_checked += 1;

    // E — telemetry (when drawn): the recorder must be bitwise inert and
    // the Chrome export structurally valid.
    if case.telemetry {
        let traced = {
            let mut cfg = event_cfg(case, &design, batch);
            cfg.trace = true;
            run_event(cfg, &reqs, "telemetry-inert")?
        };
        well_formed(&traced, n, sum_max_new, "telemetry-inert")?;
        bitwise("telemetry-inert", &fp, &semantic_fingerprint(&traced))?;
        validate_chrome_trace(&traced.recorder.to_chrome_json())
            .map_err(|e| div("chrome-trace", e))?;
        pairs_checked += 1;
    }

    // F — the phase-batch reference engine, invariant-only.
    check_sim(case, &design, &reqs, batch, sum_max_new)?;
    pairs_checked += 1;

    if let Some(ceiling) = opts.inject_token_ceiling {
        let got = reference.metrics.tokens_generated.get();
        if got >= ceiling {
            return Err(div(
                "injected-token-ceiling",
                format!("injected fault: {got} tokens generated >= ceiling {ceiling}"),
            ));
        }
    }

    Ok(CaseReport {
        fingerprint: fp,
        requests: n,
        pairs_checked,
        events_reference: reference.events_processed(),
        events_stepped: stepped.events_processed(),
    })
}
