//! Seeded case generation: one draw from the codesign cross-product.
//!
//! A [`FuzzCase`] is plain data — every field is an integer, bool, or
//! seed — so a failing draw serializes losslessly into a replayable
//! fixture ([`super::shrink::Fixture`]) and shrinks by editing fields,
//! not by re-rolling RNG state. The realization methods
//! ([`FuzzCase::trace_spec`], [`FuzzCase::design`],
//! [`FuzzCase::pool_config`], ...) turn the data back into live
//! configuration deterministically, reusing the same constructors the
//! codesign sweep and the CLI use.

use crate::dse::{evaluate_grid_point, DseConfig};
use crate::engines::{AcceleratorDesign, AttentionHosting};
use crate::faults::{FaultPlan, FaultSpec};
use crate::fpga::KV260;
use crate::kvpool::{AdmissionControl, EvictionPolicy, KvPoolConfig};
use crate::model::{ModelShape, TraceSpec, BITNET_0_73B};
use crate::reconfig::SwapPolicy;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// The model/device the fuzzer runs on. The crate ships exactly one
/// calibrated shape (the paper's BitNet-class 0.73B on the KV260), so
/// the shape axis is fixed; the design axis below still varies the
/// fabric partition under it.
pub fn fuzz_shape() -> ModelShape {
    BITNET_0_73B
}

/// One point in the serving cross-product: trace family × request
/// count × accelerator design (paper or a random feasible DSE grid
/// point) × swap policy × decode batch × residency cap × KV-pool
/// sizing/policies × streaming window × telemetry. Seeds are stored
/// explicitly so realization is deterministic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FuzzCase {
    /// Trace preset family: 0 interactive, 1 mixed-long-context,
    /// 2 bursty, 3 long-decode, 4 million (decode-heavy streaming).
    pub trace_kind: usize,
    pub n_requests: usize,
    /// Seed for the trace preset's own RNG (arrival + length draws).
    pub trace_seed: u64,
    /// Poisson arrival rate in milli-requests/s (integer so the JSON
    /// round-trip is exact); presets that fix their own rate ignore it.
    pub rate_milli: usize,
    /// Long-context knob for the `mixed_long_context` preset.
    pub long_ctx: usize,
    /// DSE grid point realized via [`evaluate_grid_point`]; `tlmm_pe ==
    /// 0` is the sentinel for the paper design (and infeasible draws
    /// fall back to it, so every case runs).
    pub tlmm_pe: usize,
    pub prefill_dsp: usize,
    pub decode_dsp: usize,
    /// 0 eager, 1 hysteresis (defaults), 2 lookahead (defaults).
    pub policy_kind: usize,
    /// Requested decode batch; clamped at realization by the design's
    /// activation-buffer headroom ([`AcceleratorDesign::max_decode_batch`]).
    pub decode_batch: usize,
    pub max_residents: usize,
    pub total_pages: usize,
    pub page_tokens: usize,
    /// Admission: optimistic (grow-on-demand) vs worst-case reservation.
    pub optimistic: bool,
    /// Eviction: evict-and-recompute vs keep-resident (cap in place).
    pub evict: bool,
    /// Arrival-window size for the streamed↔materialized pair.
    pub window: usize,
    /// Run the telemetry pair (recorder on must be bitwise inert and the
    /// Chrome export structurally valid).
    pub telemetry: bool,
    /// Fault axis (extension #10): [`FaultSpec::from_kind`] index. 0 is
    /// fault-free; the draw is biased so half the corpus keeps
    /// exercising the pure zero-fault contracts.
    pub fault_kind: usize,
    /// Seed the fault plan is realized from (swap-failure draws, DDR
    /// window placement).
    pub fault_seed: u64,
}

impl FuzzCase {
    /// Draw a case at the given prop-style `size` (1..=64): size scales
    /// the request-count ceiling so early cases are tiny and later ones
    /// approach `max_requests`.
    pub fn draw(rng: &mut Rng, size: usize, max_requests: usize) -> Self {
        let cap = (2 + size / 6).min(max_requests.max(2));
        let trace_kind = rng.below(5);
        // The long-generation families step thousands of events per
        // request on the stepped side of the oracle; keep their counts
        // smaller so a case stays milliseconds-bounded.
        let n_hi = if trace_kind >= 3 { cap.min(5) } else { cap };
        let (tlmm_pe, prefill_dsp, decode_dsp) = if rng.chance(0.5) {
            (0, 0, 0)
        } else {
            (
                *rng.choose(&[160usize, 240, 320, 400]),
                rng.range(2, 25) * 25,
                rng.range(1, 25) * 25,
            )
        };
        Self {
            trace_kind,
            n_requests: rng.range(1, n_hi),
            trace_seed: rng.next_u64(),
            rate_milli: rng.range(100, 700),
            long_ctx: rng.range(1024, fuzz_shape().max_seq),
            tlmm_pe,
            prefill_dsp,
            decode_dsp,
            policy_kind: rng.below(3),
            decode_batch: *rng.choose(&[1usize, 2, 4]),
            max_residents: *rng.choose(&[1usize, 2, 8]),
            total_pages: rng.range(16, 512),
            page_tokens: *rng.choose(&[16usize, 32, 64]),
            optimistic: rng.chance(0.5),
            evict: rng.chance(0.5),
            window: *rng.choose(&[1usize, 3, 1024]),
            telemetry: rng.chance(0.25),
            fault_kind: if rng.chance(0.5) { 0 } else { rng.below(5) },
            fault_seed: rng.next_u64(),
        }
    }

    /// The trace preset this case serves (deterministic in `trace_seed`).
    pub fn trace_spec(&self) -> TraceSpec {
        let rate = self.rate_milli as f64 / 1000.0;
        match self.trace_kind {
            0 => TraceSpec::interactive(self.n_requests, rate, self.trace_seed),
            1 => TraceSpec::mixed_long_context(
                self.n_requests,
                rate,
                self.long_ctx,
                self.trace_seed,
            ),
            2 => TraceSpec::bursty(self.n_requests, self.trace_seed),
            3 => TraceSpec::long_decode(self.n_requests, self.trace_seed),
            _ => TraceSpec::million(self.n_requests, self.trace_seed),
        }
    }

    /// The accelerator design: the paper floorplan for the `tlmm_pe ==
    /// 0` sentinel, otherwise the DSE grid point — falling back to the
    /// paper design when the drawn point is infeasible on the KV260.
    pub fn design(&self) -> AcceleratorDesign {
        if self.tlmm_pe == 0 {
            return AcceleratorDesign::pd_swap();
        }
        let dse = DseConfig::paper_default(
            fuzz_shape(),
            KV260.clone(),
            AttentionHosting::Reconfigurable,
        );
        let p = evaluate_grid_point(&dse, self.tlmm_pe, self.prefill_dsp, self.decode_dsp);
        if p.feasible {
            p.design
        } else {
            AcceleratorDesign::pd_swap()
        }
    }

    /// The fault plan this case injects (extension #10), realized from
    /// the fault axis for `fault_seed` and the trace family (the family
    /// picks the deadline preset). Each engine leg realizes its own
    /// fresh plan, so the Bernoulli draw streams start aligned and two
    /// legs that issue the same swap sequence see identical failures.
    pub fn fault_plan(&self) -> FaultPlan {
        let family = match self.trace_kind {
            0 => "interactive",
            1 => "mixed",
            2 => "bursty",
            3 => "long",
            _ => "million",
        };
        FaultPlan::from_spec(FaultSpec::from_kind(self.fault_kind), self.fault_seed, family)
    }

    pub fn swap_policy(&self) -> SwapPolicy {
        match self.policy_kind {
            0 => SwapPolicy::Eager,
            1 => SwapPolicy::hysteresis_default(),
            _ => SwapPolicy::lookahead_default(),
        }
    }

    /// The KV pool under test. `with_page_tokens` re-derives the page
    /// count from the byte budget, so it must precede the explicit
    /// `with_total_pages` override.
    pub fn pool_config(&self) -> KvPoolConfig {
        KvPoolConfig::for_device(&fuzz_shape(), &KV260)
            .with_page_tokens(self.page_tokens)
            .with_total_pages(self.total_pages)
            .with_policies(
                if self.optimistic {
                    AdmissionControl::Optimistic
                } else {
                    AdmissionControl::WorstCase
                },
                if self.evict {
                    EvictionPolicy::EvictAndRecompute
                } else {
                    EvictionPolicy::KeepResident
                },
            )
    }

    /// Serialize to JSON. Seeds travel as hex *strings*: the crate's
    /// JSON numbers are f64, which silently rounds u64 values above
    /// 2^53 — exactly the range `next_u64` seeds live in.
    pub fn to_json(&self) -> Value {
        Value::from_pairs(vec![
            ("trace_kind", Value::num(self.trace_kind as f64)),
            ("n_requests", Value::num(self.n_requests as f64)),
            ("trace_seed", Value::str(format!("{:#018x}", self.trace_seed))),
            ("rate_milli", Value::num(self.rate_milli as f64)),
            ("long_ctx", Value::num(self.long_ctx as f64)),
            ("tlmm_pe", Value::num(self.tlmm_pe as f64)),
            ("prefill_dsp", Value::num(self.prefill_dsp as f64)),
            ("decode_dsp", Value::num(self.decode_dsp as f64)),
            ("policy_kind", Value::num(self.policy_kind as f64)),
            ("decode_batch", Value::num(self.decode_batch as f64)),
            ("max_residents", Value::num(self.max_residents as f64)),
            ("total_pages", Value::num(self.total_pages as f64)),
            ("page_tokens", Value::num(self.page_tokens as f64)),
            ("optimistic", Value::Bool(self.optimistic)),
            ("evict", Value::Bool(self.evict)),
            ("window", Value::num(self.window as f64)),
            ("telemetry", Value::Bool(self.telemetry)),
            ("fault_kind", Value::num(self.fault_kind as f64)),
            ("fault_seed", Value::str(format!("{:#018x}", self.fault_seed))),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self, String> {
        let us = |k: &str| -> Result<usize, String> {
            v.get(k)
                .and_then(Value::as_usize)
                .ok_or_else(|| format!("fixture case: missing usize field '{k}'"))
        };
        let fb = |k: &str| -> Result<bool, String> {
            v.get(k)
                .and_then(Value::as_bool)
                .ok_or_else(|| format!("fixture case: missing bool field '{k}'"))
        };
        Ok(Self {
            trace_kind: us("trace_kind")?,
            n_requests: us("n_requests")?,
            trace_seed: parse_hex_seed(
                v.get("trace_seed")
                    .and_then(Value::as_str)
                    .ok_or("fixture case: missing 'trace_seed'")?,
            )?,
            rate_milli: us("rate_milli")?,
            long_ctx: us("long_ctx")?,
            tlmm_pe: us("tlmm_pe")?,
            prefill_dsp: us("prefill_dsp")?,
            decode_dsp: us("decode_dsp")?,
            policy_kind: us("policy_kind")?,
            decode_batch: us("decode_batch")?,
            max_residents: us("max_residents")?,
            total_pages: us("total_pages")?,
            page_tokens: us("page_tokens")?,
            optimistic: fb("optimistic")?,
            evict: fb("evict")?,
            window: us("window")?,
            telemetry: fb("telemetry")?,
            // The fault axis postdates the first corpus fixtures; absent
            // keys mean the fault-free plan, so old fixtures replay
            // byte-for-byte as drawn.
            fault_kind: v.get("fault_kind").and_then(Value::as_usize).unwrap_or(0),
            fault_seed: match v.get("fault_seed").and_then(Value::as_str) {
                Some(s) => parse_hex_seed(s)?,
                None => 0,
            },
        })
    }
}

/// Parse a `0x`-prefixed (or bare-hex) u64 seed string.
pub fn parse_hex_seed(s: &str) -> Result<u64, String> {
    let h = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")).unwrap_or(s);
    u64::from_str_radix(h, 16).map_err(|e| format!("bad hex seed '{s}': {e}"))
}
