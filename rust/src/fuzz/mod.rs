//! Differential serving fuzzer: one seeded oracle over every engine
//! pair (beyond-paper infrastructure; see `docs/ARCHITECTURE.md`
//! extension #9).
//!
//! The repo's four semantics contracts (README §"Semantics contracts")
//! are each pinned by a hand-written property test that fixes most of
//! the configuration space. This module is the cheap insurance for the
//! rest of the cross-product: [`generator`] draws a random
//! `(trace, design, policy, batch, pool, window, telemetry, faults)`
//! tuple from a seed, [`oracle`] runs every applicable engine pair on
//! it and
//! asserts the documented equivalences (bitwise
//! [`crate::coordinator::semantic_fingerprint`] where the contract
//! promises bitwise, conservation invariants everywhere), and
//! [`shrink`] minimizes any failing tuple into a replayable JSON
//! fixture. The CLI entry is `pd-swap fuzz --seed S --cases N`; the
//! committed corpus under `rust/tests/fuzz_corpus/` replays through
//! `rust/tests/fuzz_replay.rs`.
//!
//! Everything is deterministic: same seed → same cases → same summary,
//! byte for byte (pinned by `fuzz_is_deterministic_and_clean` below and
//! the CI `fuzz-smoke` step).

pub mod generator;
pub mod oracle;
pub mod shrink;

pub use generator::{parse_hex_seed, FuzzCase};
pub use oracle::{run_case, CaseReport, Divergence, OracleOptions};
pub use shrink::{replay_file, shrink_case, Fixture, FixtureDivergence, FIXTURE_SCHEMA};

use std::fmt::Write as _;

use crate::util::rng::Rng;

/// Driver configuration for one fuzz run.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    pub cases: usize,
    pub seed: u64,
    /// Ceiling on requests per case (the generator's size ramp tops out
    /// here).
    pub max_requests: usize,
    /// Where to write the shrunk fixture on divergence; `None` skips
    /// writing (tests that only need the summary).
    pub out_dir: Option<std::path::PathBuf>,
}

impl Default for FuzzConfig {
    fn default() -> Self {
        Self { cases: 64, seed: 0x5EED, max_requests: 10, out_dir: None }
    }
}

/// Outcome of a fuzz run. `report` is deliberately free of anything
/// non-deterministic (no wall time, no absolute paths), so re-running
/// the same seed must reproduce it byte for byte.
#[derive(Debug, Clone)]
pub struct FuzzSummary {
    pub cases_run: usize,
    pub divergences: usize,
    pub fixture_path: Option<std::path::PathBuf>,
    pub report: String,
}

/// Size ramp matching [`crate::util::prop::Config`]'s default
/// `max_size`: case `i` of `N` runs at `1 + i*64/N`.
const FUZZ_MAX_SIZE: usize = 64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Run the seeded fuzz loop: draw cases, run the oracle on each, and on
/// the first divergence shrink it and (optionally) write the fixture.
/// Errors are reserved for I/O problems; a divergence is a normal
/// summary outcome (`divergences > 0`) so the CLI can exit nonzero with
/// the full report printed.
pub fn run_fuzz(cfg: &FuzzConfig, opts: OracleOptions) -> Result<FuzzSummary, String> {
    let mut meta = Rng::new(cfg.seed);
    let mut digest = FNV_OFFSET;
    let mut total_requests = 0usize;
    let mut total_pairs = 0usize;
    let mut events_reference = 0u64;
    let mut events_stepped = 0u64;
    for case_index in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let size = 1 + (case_index * FUZZ_MAX_SIZE) / cfg.cases.max(1);
        let mut rng = Rng::new(case_seed);
        let case = FuzzCase::draw(&mut rng, size, cfg.max_requests);
        match run_case(&case, opts) {
            Ok(rep) => {
                digest = fnv1a(digest, rep.fingerprint.as_bytes());
                total_requests += rep.requests;
                total_pairs += rep.pairs_checked;
                events_reference += rep.events_reference;
                events_stepped += rep.events_stepped;
            }
            Err(d) => {
                let (min_case, min_div, attempts) = shrink_case(case, d, opts);
                let fixture = Fixture {
                    master_seed: cfg.seed,
                    case_index,
                    case_seed,
                    case: min_case,
                    divergence: Some(FixtureDivergence {
                        pair: min_div.pair.to_string(),
                        fingerprint_line: min_div.line,
                        detail: min_div.detail.clone(),
                    }),
                };
                let mut fixture_path = None;
                if let Some(dir) = &cfg.out_dir {
                    std::fs::create_dir_all(dir)
                        .map_err(|e| format!("create {}: {e}", dir.display()))?;
                    let path = dir
                        .join(format!("fuzz-repro-{:016x}-{case_index}.json", cfg.seed));
                    fixture.write(&path)?;
                    fixture_path = Some(path);
                }
                let mut report = String::new();
                let _ = writeln!(
                    report,
                    "fuzz: DIVERGENCE at case {case_index}/{} (seed {:#x}, case seed {:#018x})",
                    cfg.cases, cfg.seed, case_seed
                );
                let _ = writeln!(
                    report,
                    "  pair: {} (first divergent fingerprint line {})",
                    min_div.pair, min_div.line
                );
                let _ = writeln!(report, "  detail: {}", min_div.detail);
                let _ = writeln!(
                    report,
                    "  shrunk in {attempts} oracle re-runs to: {:?}",
                    fixture.case
                );
                let _ = writeln!(
                    report,
                    "  replay: pd-swap fuzz --replay <fixture.json>"
                );
                return Ok(FuzzSummary {
                    cases_run: case_index + 1,
                    divergences: 1,
                    fixture_path,
                    report,
                });
            }
        }
    }
    let mut report = String::new();
    let _ = writeln!(
        report,
        "fuzz: {} cases at seed {:#x} (≤ {} requests/case) — no divergence",
        cfg.cases, cfg.seed, cfg.max_requests
    );
    let _ = writeln!(
        report,
        "  {} engine-pair checks over {} generated requests",
        total_pairs, total_requests
    );
    let _ = writeln!(
        report,
        "  events: {} on the fast-forward reference vs {} stepped",
        events_reference, events_stepped
    );
    let _ = writeln!(
        report,
        "  oracle: ff≡stepped, surface≡direct, streamed≡materialized, telemetry-inert \
         (bitwise, incl. the fault axis: swap failures / DDR brownouts / deadline \
         sheds); SimServer + pool/outcome/shed/token conservation (invariants)"
    );
    let _ = writeln!(report, "  corpus digest: {:#018x}", digest);
    Ok(FuzzSummary {
        cases_run: cfg.cases,
        divergences: 0,
        fixture_path: None,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FuzzConfig {
        FuzzConfig { cases: 4, seed: 0x5EED, max_requests: 3, out_dir: None }
    }

    #[test]
    fn fuzz_is_deterministic_and_clean() {
        // The acceptance pin in miniature: the smoke seed finds nothing,
        // and re-running it reproduces the summary byte for byte.
        let a = run_fuzz(&small_cfg(), OracleOptions::default()).unwrap();
        assert_eq!(a.divergences, 0, "{}", a.report);
        assert_eq!(a.cases_run, 4);
        let b = run_fuzz(&small_cfg(), OracleOptions::default()).unwrap();
        assert_eq!(a.report, b.report, "summary must be byte-identical across reruns");
    }

    #[test]
    fn case_json_round_trips() {
        let mut rng = Rng::new(7);
        for size in [1usize, 8, 32, 64] {
            let case = FuzzCase::draw(&mut rng, size, 10);
            let text = case.to_json().to_pretty();
            let doc = crate::util::json::parse(&text).unwrap();
            assert_eq!(FuzzCase::from_json(&doc).unwrap(), case, "{text}");
        }
    }

    #[test]
    fn injected_divergence_shrinks_to_replayable_fixture() {
        // Break the oracle on purpose (a 1-token ceiling fails every
        // case) and prove the whole loop: divergence → shrink → fixture
        // on disk → replay reproduces it → the un-broken oracle clears
        // the same fixture.
        let opts = OracleOptions { inject_token_ceiling: Some(1) };
        let dir = std::env::temp_dir().join(format!("pd-swap-fuzz-{}", std::process::id()));
        let cfg = FuzzConfig {
            cases: 4,
            seed: 1,
            max_requests: 6,
            out_dir: Some(dir.clone()),
        };
        let summary = run_fuzz(&cfg, opts).unwrap();
        assert_eq!(summary.divergences, 1);
        assert_eq!(summary.cases_run, 1, "the first case already trips a 1-token ceiling");
        let path = summary.fixture_path.expect("a fixture must be written");

        let (fx, diverged) = replay_file(&path, opts).unwrap();
        assert_eq!(fx.case.n_requests, 1, "shrink should reach the 1-request floor");
        assert_eq!(fx.master_seed, 1);
        let d = diverged.expect("replay with the injected fault must reproduce");
        assert_eq!(d.pair, "injected-token-ceiling");
        let recorded = fx.divergence.expect("fixture records what failed");
        assert_eq!(recorded.pair, "injected-token-ceiling");

        let (_, clean) = replay_file(&path, OracleOptions::default()).unwrap();
        assert!(clean.is_none(), "without the injected fault the engines agree");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
