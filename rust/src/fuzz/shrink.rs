//! Shrinking and replayable fixtures.
//!
//! On divergence the driver minimizes the failing [`FuzzCase`] by
//! re-running the oracle on deterministic candidate edits — fewer
//! requests, shorter contexts, smaller batch/residency/pool, narrower
//! window, telemetry off, the paper design instead of a grid point —
//! keeping the first candidate that still fails and looping until no
//! edit fails (greedy first-improvement descent, attempt-bounded). The
//! minimized case plus its provenance (master seed, case index, case
//! seed) and the divergence (pair + fingerprint line) serialize to a
//! JSON [`Fixture`] that `pd-swap fuzz --replay` and the committed
//! `rust/tests/fuzz_corpus/` both re-run end-to-end.

use crate::util::json::{self, Value};

use super::generator::{parse_hex_seed, FuzzCase};
use super::oracle::{run_case, Divergence, OracleOptions};

/// Schema tag for serialized fixtures.
pub const FIXTURE_SCHEMA: &str = "pd-swap-fuzz-fixture-v1";

/// Upper bound on oracle re-runs during one shrink (each candidate edit
/// costs a full oracle pass; greedy descent converges long before this).
const MAX_SHRINK_ATTEMPTS: usize = 128;

/// Candidate one-step reductions of a case, most-aggressive first.
fn candidates(c: &FuzzCase) -> Vec<FuzzCase> {
    let mut out = Vec::new();
    // Dropping the fault axis first: if the divergence survives without
    // injected faults it was never a fault-layer bug, and the fixture
    // should say so.
    if c.fault_kind != 0 {
        out.push(FuzzCase { fault_kind: 0, fault_seed: 0, ..c.clone() });
    }
    if c.n_requests > 1 {
        out.push(FuzzCase { n_requests: c.n_requests / 2, ..c.clone() });
        out.push(FuzzCase { n_requests: c.n_requests - 1, ..c.clone() });
    }
    if c.trace_kind == 1 && c.long_ctx > 1024 {
        out.push(FuzzCase { long_ctx: (c.long_ctx / 2).max(1024), ..c.clone() });
    }
    if c.tlmm_pe != 0 {
        out.push(FuzzCase { tlmm_pe: 0, prefill_dsp: 0, decode_dsp: 0, ..c.clone() });
    }
    if c.decode_batch > 1 {
        out.push(FuzzCase { decode_batch: 1, ..c.clone() });
    }
    if c.max_residents > 1 {
        out.push(FuzzCase { max_residents: c.max_residents / 2, ..c.clone() });
    }
    if c.total_pages > 16 {
        out.push(FuzzCase { total_pages: (c.total_pages / 2).max(16), ..c.clone() });
    }
    if c.window > 1 {
        out.push(FuzzCase { window: 1, ..c.clone() });
    }
    if c.telemetry {
        out.push(FuzzCase { telemetry: false, ..c.clone() });
    }
    out
}

/// Greedy first-improvement shrink: returns the minimized still-failing
/// case, its divergence, and how many oracle re-runs it took. Any
/// divergence counts as "still failing" — the minimal case may fail a
/// different pair than the original, which is standard shrinker
/// behavior and still pins the bug.
pub fn shrink_case(
    initial: FuzzCase,
    initial_divergence: Divergence,
    opts: OracleOptions,
) -> (FuzzCase, Divergence, usize) {
    let mut best = initial;
    let mut best_div = initial_divergence;
    let mut attempts = 0usize;
    'outer: loop {
        for cand in candidates(&best) {
            if attempts >= MAX_SHRINK_ATTEMPTS {
                break 'outer;
            }
            attempts += 1;
            if let Err(d) = run_case(&cand, opts) {
                best = cand;
                best_div = d;
                continue 'outer;
            }
        }
        break;
    }
    (best, best_div, attempts)
}

/// The divergence record a fixture carries.
#[derive(Debug, Clone)]
pub struct FixtureDivergence {
    pub pair: String,
    /// First divergent [`crate::coordinator::semantic_fingerprint`]
    /// line (the timeline-ordered event index analog); 0 for invariant
    /// violations.
    pub fingerprint_line: usize,
    pub detail: String,
}

/// A replayable, shrunk failing case with its provenance.
#[derive(Debug, Clone)]
pub struct Fixture {
    /// The `--seed` of the run that found it.
    pub master_seed: u64,
    /// Which case index of that run diverged.
    pub case_index: usize,
    /// The per-case RNG seed (derived from `master_seed` by the driver).
    pub case_seed: u64,
    /// The minimized case.
    pub case: FuzzCase,
    /// What failed when it was recorded. Corpus entries that pin
    /// already-fixed or never-failing corner cases carry `None`.
    pub divergence: Option<FixtureDivergence>,
}

impl Fixture {
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("schema", Value::str(FIXTURE_SCHEMA)),
            ("master_seed", Value::str(format!("{:#018x}", self.master_seed))),
            ("case_index", Value::num(self.case_index as f64)),
            ("case_seed", Value::str(format!("{:#018x}", self.case_seed))),
            ("case", self.case.to_json()),
        ];
        if let Some(d) = &self.divergence {
            pairs.push((
                "divergence",
                Value::from_pairs(vec![
                    ("pair", Value::str(d.pair.clone())),
                    ("fingerprint_line", Value::num(d.fingerprint_line as f64)),
                    ("detail", Value::str(d.detail.clone())),
                ]),
            ));
        }
        Value::from_pairs(pairs)
    }

    pub fn from_json(v: &Value) -> Result<Self, String> {
        match v.get("schema").and_then(Value::as_str) {
            Some(FIXTURE_SCHEMA) => {}
            other => return Err(format!("unknown fixture schema {other:?}")),
        }
        let seed = |k: &str| -> Result<u64, String> {
            parse_hex_seed(
                v.get(k)
                    .and_then(Value::as_str)
                    .ok_or_else(|| format!("fixture: missing seed field '{k}'"))?,
            )
        };
        let divergence = match v.get("divergence") {
            None => None,
            Some(d) => Some(FixtureDivergence {
                pair: d
                    .get("pair")
                    .and_then(Value::as_str)
                    .ok_or("fixture divergence: missing 'pair'")?
                    .to_string(),
                fingerprint_line: d
                    .get("fingerprint_line")
                    .and_then(Value::as_usize)
                    .ok_or("fixture divergence: missing 'fingerprint_line'")?,
                detail: d
                    .get("detail")
                    .and_then(Value::as_str)
                    .ok_or("fixture divergence: missing 'detail'")?
                    .to_string(),
            }),
        };
        Ok(Self {
            master_seed: seed("master_seed")?,
            case_index: v
                .get("case_index")
                .and_then(Value::as_usize)
                .ok_or("fixture: missing 'case_index'")?,
            case_seed: seed("case_seed")?,
            case: FuzzCase::from_json(v.req("case").map_err(|e| e.to_string())?)?,
            divergence,
        })
    }

    pub fn write(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_pretty() + "\n")
            .map_err(|e| format!("write {}: {e}", path.display()))
    }

    pub fn read(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let doc = json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_json(&doc)
    }
}

/// Re-run the oracle on a serialized fixture: `Ok((fx, None))` means the
/// fixture no longer diverges; `Ok((fx, Some(d)))` means it reproduced.
pub fn replay_file(
    path: &std::path::Path,
    opts: OracleOptions,
) -> Result<(Fixture, Option<Divergence>), String> {
    let fx = Fixture::read(path)?;
    match run_case(&fx.case, opts) {
        Ok(_) => Ok((fx, None)),
        Err(d) => Ok((fx, Some(d))),
    }
}
