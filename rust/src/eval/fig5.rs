//! Fig. 5: the latency-overlapped runtime reconfiguration timeline.

use crate::engines::{AcceleratorDesign, PhaseModel};
use crate::fpga::KV260;
use crate::model::BITNET_0_73B;
use crate::reconfig::OverlapScheduler;
use crate::util::table::{ftime, Table};

/// Timeline report for a set of prompt lengths.
#[derive(Debug, Clone)]
pub struct Fig5Report {
    pub l: usize,
    pub reconfig_ms: f64,
    pub tail_ms: f64,
    pub exposed_overlapped_ms: f64,
    pub exposed_sequential_ms: f64,
    pub hidden_fraction: f64,
}

/// Compute the overlap analysis (paper shows L=128).
pub fn analyze(lengths: &[usize]) -> Vec<Fig5Report> {
    let design = AcceleratorDesign::pd_swap();
    let device = design.program(&KV260).expect("programs");
    let lat = device.reconfig_latency();
    let sched = OverlapScheduler::new(PhaseModel::new(design, KV260.clone()), lat);
    lengths
        .iter()
        .map(|&l| {
            let o = sched.overlapped(&BITNET_0_73B, l);
            let s = sched.sequential(&BITNET_0_73B, l);
            Fig5Report {
                l,
                reconfig_ms: o.reconfig * 1e3,
                tail_ms: o.tail * 1e3,
                exposed_overlapped_ms: o.exposed * 1e3,
                exposed_sequential_ms: s.exposed * 1e3,
                hidden_fraction: o.hidden_fraction,
            }
        })
        .collect()
}

/// Print the Fig. 5 table; returns the reports.
pub fn run_fig5() -> Vec<Fig5Report> {
    let reports = analyze(&[64, 128, 256, 512, 1024]);
    let mut t = Table::new(vec![
        "L", "reconfig", "prefill tail", "exposed (overlap)", "exposed (naive)", "hidden",
    ])
    .right_align(&[0, 1, 2, 3, 4, 5]);
    for r in &reports {
        t.row(vec![
            r.l.to_string(),
            ftime(r.reconfig_ms / 1e3),
            ftime(r.tail_ms / 1e3),
            ftime(r.exposed_overlapped_ms / 1e3),
            ftime(r.exposed_sequential_ms / 1e3),
            format!("{:.0}%", r.hidden_fraction * 100.0),
        ]);
    }
    println!("\nFig. 5 — latency-overlapped reconfiguration (prefill->decode swap):");
    t.print();
    println!(
        "paper reference @L=128: reconfig ~45 ms, remaining proj+FFN ~31 ms, \
         ~75% of the overhead hidden."
    );
    reports
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l128_matches_paper_shape() {
        let r = &analyze(&[128])[0];
        assert!((35.0..55.0).contains(&r.reconfig_ms), "reconfig {:.1}", r.reconfig_ms);
        assert!((20.0..42.0).contains(&r.tail_ms), "tail {:.1}", r.tail_ms);
        assert!(r.exposed_overlapped_ms < r.exposed_sequential_ms);
        assert!((0.45..0.95).contains(&r.hidden_fraction));
    }

    #[test]
    fn hidden_fraction_grows_with_prompt() {
        let rs = analyze(&[64, 128, 512, 1024]);
        for w in rs.windows(2) {
            assert!(w[1].hidden_fraction >= w[0].hidden_fraction - 1e-9);
        }
        // Long prompts hide everything.
        assert_eq!(rs.last().unwrap().exposed_overlapped_ms, 0.0);
    }
}
