//! Evaluation harnesses: one module per table/figure in the paper's §4.
//!
//! Each harness *computes* its rows from the simulator (never transcribes
//! our own results), prints them next to the paper's published values,
//! and returns structured data so the benches and EXPERIMENTS.md capture
//! identical numbers. Run via `pd-swap eval <table1|table2|fig4a|fig5|fig6|all>`
//! or the corresponding `cargo bench` target.

pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;
pub mod table2;

pub use fig4::run_fig4a;
pub use fig5::run_fig5;
pub use fig6::{run_fig6, Fig6Point};
pub use table1::run_table1;
pub use table2::run_table2;
