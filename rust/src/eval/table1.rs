//! Table 1: unified cross-platform and FPGA-based comparison.

use crate::baselines::{pd_swap_row, tellme_row, PlatformRow, TABLE1_ROWS};
use crate::util::table::{fnum, Table};

/// Compute all rows (literature + simulated PD-Swap/TeLLMe).
pub fn rows() -> Vec<PlatformRow> {
    let mut rows: Vec<PlatformRow> = TABLE1_ROWS.to_vec();
    rows.push(tellme_row());
    rows.push(pd_swap_row());
    rows
}

/// Print the table; returns the rows for downstream use.
pub fn run_table1() -> Vec<PlatformRow> {
    let rows = rows();
    let mut t = Table::new(vec![
        "Work", "Platform", "Model", "Bits", "Power(W)", "WT-2 PPL",
        "Prefill TK/s", "Decode TK/s", "Prefill TK/J", "Decode TK/J",
    ])
    .right_align(&[4, 5, 6, 7, 8, 9]);
    for r in &rows {
        t.row(vec![
            r.work.to_string(),
            r.platform.to_string(),
            r.model.to_string(),
            r.bitwidth.to_string(),
            fnum(r.power_w),
            fnum(r.wt2_ppl),
            fnum(r.prefill_tks),
            fnum(r.decode_tks),
            fnum(r.prefill_tkj()),
            fnum(r.decode_tkj()),
        ]);
    }
    println!("\nTable 1 — cross-platform comparison (PD-Swap/TeLLMe rows computed from the simulator; others are published numbers):");
    t.print();
    println!(
        "paper reference: PD-Swap 4.9 W / 148 prefill / 27.8 decode TK/s / 5.67 decode TK/J; \
         TeLLMe 4.8 W / 143 / 25 / 5.2"
    );
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_all_platforms() {
        let rows = rows();
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().any(|r| r.work.contains("PD-Swap")));
        assert!(rows.iter().any(|r| r.work.contains("TeLLMe")));
    }

    #[test]
    fn pd_swap_decode_efficiency_leads_fpga_rows() {
        // Table 1's bottom-line: PD-Swap has the best decode TK/J of the
        // FPGA designs (5.67 in the paper).
        let rows = rows();
        let pd = rows.iter().find(|r| r.work.contains("PD-Swap")).unwrap();
        assert!((4.8..7.0).contains(&pd.decode_tkj()), "TK/J {:.2}", pd.decode_tkj());
        for r in &rows {
            if !r.work.contains("PD-Swap") {
                assert!(pd.decode_tkj() > r.decode_tkj(), "vs {}", r.work);
            }
        }
    }
}
