//! Fig. 4a: roofline placement of the major kernels.

use crate::engines::AcceleratorDesign;
use crate::fpga::KV260;
use crate::model::BITNET_0_73B;
use crate::roofline::{Bound, RooflineModel, RooflinePoint};
use crate::util::table::{fnum, Table};

/// Compute the roofline points at a set of context lengths (the shape's
/// ceilings are resolved once and reused across lengths).
pub fn analyze(lengths: &[usize]) -> Vec<(usize, Vec<RooflinePoint>)> {
    let model = RooflineModel::new(AcceleratorDesign::pd_swap(), KV260.clone());
    let roofs = model.roofs_for(&BITNET_0_73B);
    lengths
        .iter()
        .map(|&l| (l, roofs.analyze_at(l)))
        .collect()
}

/// Print the Fig. 4a analysis; returns the points.
pub fn run_fig4a() -> Vec<(usize, Vec<RooflinePoint>)> {
    let results = analyze(&[128, 512, 2048]);
    let mut t = Table::new(vec![
        "L", "kernel", "AI (MAC/B)", "compute roof", "memory roof", "bound", "roof frac",
    ])
    .right_align(&[0, 2, 3, 4, 6]);
    for (l, points) in &results {
        for p in points {
            t.row(vec![
                l.to_string(),
                p.kernel.clone(),
                fnum(p.arithmetic_intensity),
                format!("{} GMAC/s", fnum(p.compute_roof / 1e9)),
                format!("{} GB/s", fnum(p.memory_roof_bytes / 1e9)),
                match p.bound {
                    Bound::Compute => "compute".to_string(),
                    Bound::Memory => "memory".to_string(),
                },
                format!("{:.2}", p.roof_fraction),
            ]);
        }
    }
    println!("\nFig. 4a — roofline placement of the major kernels (PD-Swap design):");
    t.print();
    println!(
        "paper reference (qualitative): decode attention memory-bound, prefill attention \
         compute-bound, decode linear close to its (streaming) roofline."
    );
    results
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regimes_stable_across_lengths() {
        for (l, points) in analyze(&[128, 512, 2048]) {
            let dec = points.iter().find(|p| p.kernel == "decode-attention").unwrap();
            let pre = points.iter().find(|p| p.kernel == "prefill-attention").unwrap();
            assert_eq!(dec.bound, Bound::Memory, "L={l}");
            assert_eq!(pre.bound, Bound::Compute, "L={l}");
        }
    }
}
