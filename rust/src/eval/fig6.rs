//! Fig. 6: (a) decoding throughput and (b) prefill time (TTFT) vs context
//! length, PD-Swap vs TeLLMe.

use crate::engines::{AcceleratorDesign, PhaseModel};
use crate::fpga::KV260;
use crate::model::BITNET_0_73B;
use crate::util::table::{fnum, Table};

/// One context-length sample.
#[derive(Debug, Clone, Copy)]
pub struct Fig6Point {
    pub l: usize,
    pub pd_decode_tks: f64,
    pub te_decode_tks: f64,
    pub decode_speedup: f64,
    pub pd_ttft: f64,
    pub te_ttft: f64,
    pub ttft_saving: f64,
}

/// The paper's anchor values for the series (speedup at 64 and 2048;
/// TTFT pair at 768).
pub const PAPER_SPEEDUP_64: f64 = 1.11;
pub const PAPER_SPEEDUP_2048: f64 = 2.02;
pub const PAPER_TTFT_768: (f64, f64) = (11.10, 8.80); // (TeLLMe, PD-Swap)

/// Default context sweep (the paper's x-axis).
pub const LENGTHS: &[usize] = &[64, 128, 256, 512, 768, 1024, 1536, 2048];

/// Compute the Fig. 6 series.
pub fn series(lengths: &[usize]) -> Vec<Fig6Point> {
    let pd = PhaseModel::new(AcceleratorDesign::pd_swap(), KV260.clone());
    let te = PhaseModel::new(AcceleratorDesign::tellme_static(), KV260.clone());
    let s = BITNET_0_73B;
    lengths
        .iter()
        .map(|&l| {
            let pd_dec = pd.decode_throughput(&s, l);
            let te_dec = te.decode_throughput(&s, l);
            let pd_ttft = pd.prefill(&s, l).total;
            let te_ttft = te.prefill(&s, l).total;
            Fig6Point {
                l,
                pd_decode_tks: pd_dec,
                te_decode_tks: te_dec,
                decode_speedup: pd_dec / te_dec,
                pd_ttft,
                te_ttft,
                ttft_saving: 1.0 - pd_ttft / te_ttft,
            }
        })
        .collect()
}

/// Print both panels; returns the series.
pub fn run_fig6(lengths: &[usize]) -> Vec<Fig6Point> {
    let pts = series(lengths);
    let mut t = Table::new(vec![
        "L", "PD dec TK/s", "TeLLMe dec TK/s", "speedup",
        "PD TTFT (s)", "TeLLMe TTFT (s)", "TTFT saving",
    ])
    .right_align(&[0, 1, 2, 3, 4, 5, 6]);
    for p in &pts {
        t.row(vec![
            p.l.to_string(),
            fnum(p.pd_decode_tks),
            fnum(p.te_decode_tks),
            format!("{:.2}x", p.decode_speedup),
            fnum(p.pd_ttft),
            fnum(p.te_ttft),
            format!("{:.0}%", p.ttft_saving * 100.0),
        ]);
    }
    println!("\nFig. 6 — decoding throughput (a) and prefill time / TTFT (b) vs context length:");
    t.print();
    println!(
        "paper reference: speedup 1.11x @64 -> 2.02x @2048; TTFT @768: 11.10 s -> 8.80 s; \
         PD-Swap holds >10 TK/s at 2048 while TeLLMe drops to ~5."
    );
    pts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(pts: &[Fig6Point], l: usize) -> Fig6Point {
        *pts.iter().find(|p| p.l == l).unwrap()
    }

    #[test]
    fn speedup_endpoints_match_paper() {
        let pts = series(LENGTHS);
        let s64 = at(&pts, 64).decode_speedup;
        let s2048 = at(&pts, 2048).decode_speedup;
        assert!((PAPER_SPEEDUP_64 - 0.09..=PAPER_SPEEDUP_64 + 0.14).contains(&s64), "{s64:.2}");
        assert!(
            (PAPER_SPEEDUP_2048 - 0.27..=PAPER_SPEEDUP_2048 + 0.33).contains(&s2048),
            "{s2048:.2}"
        );
    }

    #[test]
    fn speedup_grows_monotonically_with_context() {
        // The paper's core claim: "larger gains at longer context lengths".
        let pts = series(LENGTHS);
        for w in pts.windows(2) {
            assert!(
                w[1].decode_speedup >= w[0].decode_speedup - 1e-9,
                "speedup dipped between L={} and L={}",
                w[0].l,
                w[1].l
            );
        }
    }

    #[test]
    fn ttft_at_768_matches_paper() {
        let pts = series(LENGTHS);
        let p = at(&pts, 768);
        assert!((PAPER_TTFT_768.0 * 0.9..=PAPER_TTFT_768.0 * 1.1).contains(&p.te_ttft),
            "TeLLMe {:.2}", p.te_ttft);
        assert!((PAPER_TTFT_768.1 * 0.9..=PAPER_TTFT_768.1 * 1.1).contains(&p.pd_ttft),
            "PD {:.2}", p.pd_ttft);
        assert!((0.15..0.30).contains(&p.ttft_saving), "saving {:.2}", p.ttft_saving);
    }

    #[test]
    fn long_context_floor() {
        let pts = series(LENGTHS);
        let p = at(&pts, 2048);
        assert!(p.pd_decode_tks > 9.5, "PD {:.1}", p.pd_decode_tks);
        assert!(p.te_decode_tks < 6.5, "TeLLMe {:.1}", p.te_decode_tks);
    }
}
