//! Table 2: FPGA resource consumption breakdown.

use crate::engines::AcceleratorDesign;
use crate::fpga::{ResourceVec, KV260};
use crate::util::table::Table;

/// Paper's published Table 2 (for side-by-side comparison in the output).
pub const PAPER_TABLE2: &[(&str, ResourceVec)] = &[
    ("Table Lookup Linear Unit",
     ResourceVec { lut: 42_854.0, ff: 50_752.0, bram36: 5.5, uram: 0.0, dsp: 320.0 }),
    ("RMSNorm & Find Max Unit",
     ResourceVec { lut: 6_210.0, ff: 11_206.0, bram36: 4.0, uram: 4.0, dsp: 47.0 }),
    ("Other",
     ResourceVec { lut: 21_432.0, ff: 22_402.0, bram36: 34.0, uram: 48.0, dsp: 5.0 }),
    ("Dynamic Region",
     ResourceVec { lut: 32_140.0, ff: 92_080.0, bram36: 81.0, uram: 10.0, dsp: 378.0 }),
    ("Prefill Attention",
     ResourceVec { lut: 28_400.0, ff: 42_053.0, bram36: 140.0, uram: 8.0, dsp: 303.0 }),
    ("Decoding Attention",
     ResourceVec { lut: 26_418.0, ff: 27_236.0, bram36: 16.0, uram: 8.0, dsp: 278.0 }),
];

/// One computed row.
#[derive(Debug, Clone)]
pub struct Row {
    pub module: String,
    pub ours: ResourceVec,
    pub paper: Option<ResourceVec>,
}

/// Compute the breakdown from the shipped design's engine models.
pub fn rows() -> (Vec<Row>, ResourceVec, ResourceVec) {
    let d = AcceleratorDesign::pd_swap();
    let plan = d.region_plan().expect("pd-swap floorplans");
    let paper = |name: &str| {
        PAPER_TABLE2
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, r)| *r)
    };

    let mut rows = Vec::new();
    for (name, r) in &plan.static_region.components {
        rows.push(Row { module: name.clone(), ours: *r, paper: paper(name) });
    }
    rows.push(Row {
        module: "Dynamic Region".into(),
        ours: plan.rp.pblock,
        paper: paper("Dynamic Region"),
    });
    for m in &plan.rp.modules {
        let label = match m.name.as_str() {
            "attn-prefill" => "Prefill Attention",
            "attn-decode" => "Decoding Attention",
            other => other,
        };
        rows.push(Row { module: label.into(), ours: m.resources, paper: paper(label) });
    }

    // Total = static + dynamic pblock (what the chip actually holds).
    let total = plan.static_region.total() + plan.rp.pblock;
    // Equivalent total = static + both RMs (the >100% headline).
    let equivalent = d.equivalent_total();
    (rows, total, equivalent)
}

fn fmt_res(r: &ResourceVec) -> Vec<String> {
    vec![
        format!("{:.0}", r.lut),
        format!("{:.0}", r.ff),
        format!("{:.1}", r.bram36),
        format!("{:.0}", r.uram),
        format!("{:.0}", r.dsp),
    ]
}

/// Print the table; returns (rows, total, equivalent_total).
pub fn run_table2() -> (Vec<Row>, ResourceVec, ResourceVec) {
    let (rows, total, equivalent) = rows();
    let mut t = Table::new(vec!["Module", "LUT", "FF", "BRAM", "URAM", "DSP", "src"])
        .right_align(&[1, 2, 3, 4, 5]);
    for r in &rows {
        let mut cells = vec![r.module.clone()];
        cells.extend(fmt_res(&r.ours));
        cells.push("model".into());
        t.row(cells);
        if let Some(p) = &r.paper {
            let mut cells = vec![format!("  (paper)")];
            cells.extend(fmt_res(p));
            cells.push("paper".into());
            t.row(cells);
        }
    }
    let budget = KV260.resources;
    for (label, r) in [("Total", &total), ("Equivalent Total", &equivalent)] {
        let mut cells = vec![label.to_string()];
        cells.extend(fmt_res(r));
        cells.push("model".into());
        t.row(cells);
        let u = r.utilization(&budget);
        t.row(vec![
            format!("  utilization"),
            format!("{:.0}%", u.lut * 100.0),
            format!("{:.0}%", u.ff * 100.0),
            format!("{:.0}%", u.bram36 * 100.0),
            format!("{:.0}%", u.uram * 100.0),
            format!("{:.0}%", u.dsp * 100.0),
            "".into(),
        ]);
    }
    println!("\nTable 2 — KV260 resource breakdown (model vs paper):");
    t.print();
    println!(
        "paper reference: Total 102,102 LUT (87%) / 124.5 BRAM (85%) / 62 URAM (96%) / 750 DSP (60%); \
         Equivalent Total 124,780 LUT (106%).\n\
         NB: the paper reports FF at 36%; against the XCK26's 234,240 FFs the same\n\
         absolute count is 75% — we report the arithmetic and flag the discrepancy."
    );
    (rows, total, equivalent)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_rows_track_paper_rows() {
        let (rows, _, _) = rows();
        for r in &rows {
            let Some(p) = &r.paper else { continue };
            if r.module == "Dynamic Region" {
                // pblock sizing differs from the paper's pblock draw; only
                // the order of magnitude is pinned here.
                assert!((r.ours.lut / p.lut - 1.0).abs() < 0.25, "{}", r.module);
                continue;
            }
            if p.lut > 0.0 {
                assert!(
                    (r.ours.lut / p.lut - 1.0).abs() < 0.05,
                    "{}: ours {} paper {}",
                    r.module,
                    r.ours.lut,
                    p.lut
                );
            }
            assert!(
                (r.ours.dsp - p.dsp).abs() <= 2.0,
                "{}: dsp ours {} paper {}",
                r.module,
                r.ours.dsp,
                p.dsp
            );
        }
    }

    #[test]
    fn equivalent_total_exceeds_chip_lut() {
        let (_, total, equivalent) = rows();
        assert!(total.lut <= KV260.resources.lut);
        assert!(equivalent.lut > KV260.resources.lut, "the 106% headline");
        // Paper: equivalent 124,780 LUT. Ours within 5%.
        assert!(
            (equivalent.lut / 124_780.0 - 1.0).abs() < 0.05,
            "equivalent {:.0}",
            equivalent.lut
        );
    }

    #[test]
    fn total_utilization_near_87pct() {
        let (_, total, _) = rows();
        let u = total.lut / KV260.resources.lut;
        assert!((0.80..=0.90).contains(&u), "LUT util {:.3}", u);
    }
}
