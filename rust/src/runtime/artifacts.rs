//! Artifact directory parsing: `manifest.json`, `weights.bin`,
//! `golden.json`.
//!
//! The formats are defined by `python/compile/aot.py` / `weights.py`; this
//! module is the Rust half of that contract and is exercised end-to-end by
//! `rust/tests/runtime_golden.rs` against bytes the Python side produced.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// Magic prefix of `weights.bin`.
pub const WEIGHTS_MAGIC: &[u8; 8] = b"PDSWAP01";

/// Model hyper-parameters as recorded by `configs.py` in the manifest.
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub prefill_buckets: Vec<usize>,
    pub attn_block: usize,
    pub tlmm_block_m: usize,
    pub tlmm_block_n: usize,
    pub rope_base: f64,
}

impl ManifestConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    fn from_json(v: &Value) -> Result<Self> {
        let u = |k: &str| -> Result<usize> {
            v.req(k)?
                .as_usize()
                .with_context(|| format!("config.{k}: expected unsigned int"))
        };
        Ok(Self {
            name: v.req("name")?.as_str().context("config.name")?.to_string(),
            n_layers: u("n_layers")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            vocab: u("vocab")?,
            max_seq: u("max_seq")?,
            prefill_buckets: v
                .req("prefill_buckets")?
                .to_usize_vec()
                .context("config.prefill_buckets")?,
            attn_block: u("attn_block")?,
            tlmm_block_m: u("tlmm_block_m").unwrap_or(128),
            tlmm_block_n: u("tlmm_block_n").unwrap_or(128),
            rope_base: v
                .get("rope_base")
                .and_then(Value::as_f64)
                .unwrap_or(10_000.0),
        })
    }
}

/// One weight tensor's metadata (shape/dtype/position in `weights.bin`).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub offset: usize,
    pub nbytes: usize,
}

impl TensorMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str().context("tensor.name")?.to_string(),
            shape: v.req("shape")?.to_usize_vec().context("tensor.shape")?,
            dtype: v.req("dtype")?.as_str().context("tensor.dtype")?.to_string(),
            offset: v.get("offset").and_then(Value::as_usize).unwrap_or(0),
            nbytes: v.get("nbytes").and_then(Value::as_usize).unwrap_or(0),
        })
    }
}

#[derive(Debug, Clone)]
pub struct PrefillEntry {
    pub bucket: usize,
    pub file: String,
}

#[derive(Debug, Clone)]
pub struct Entrypoints {
    pub prefill: Vec<PrefillEntry>,
    pub decode: String,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub cache_shape: Vec<usize>,
    pub vocab: usize,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format_version: u32,
    pub config: ManifestConfig,
    pub head_dim: usize,
    pub n_params: u64,
    pub weights_file: String,
    pub weight_order: Vec<TensorMeta>,
    pub entrypoints: Entrypoints,
    pub io: IoSpec,
    pub golden: Option<String>,
}

impl Manifest {
    pub fn from_json_str(s: &str) -> Result<Self> {
        let v = json::parse(s).context("manifest.json")?;
        let config = ManifestConfig::from_json(v.req("config")?)?;
        let weight_order = v
            .req("weight_order")?
            .as_arr()
            .context("weight_order: expected array")?
            .iter()
            .map(TensorMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let ep = v.req("entrypoints")?;
        let prefill = ep
            .req("prefill")?
            .as_arr()
            .context("entrypoints.prefill")?
            .iter()
            .map(|e| {
                Ok(PrefillEntry {
                    bucket: e.req("bucket")?.as_usize().context("bucket")?,
                    file: e.req("file")?.as_str().context("file")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let io = v.req("io")?;
        Ok(Self {
            format_version: v
                .req("format_version")?
                .as_usize()
                .context("format_version")? as u32,
            config,
            head_dim: v.req("head_dim")?.as_usize().context("head_dim")?,
            n_params: v.req("n_params")?.as_i64().context("n_params")? as u64,
            weights_file: v
                .req("weights_file")?
                .as_str()
                .context("weights_file")?
                .to_string(),
            weight_order,
            entrypoints: Entrypoints {
                prefill,
                decode: ep.req("decode")?.as_str().context("decode")?.to_string(),
            },
            io: IoSpec {
                cache_shape: io
                    .req("cache_shape")?
                    .to_usize_vec()
                    .context("cache_shape")?,
                vocab: io.req("vocab")?.as_usize().context("vocab")?,
            },
            golden: v
                .get("golden")
                .filter(|g| !g.is_null())
                .and_then(Value::as_str)
                .map(String::from),
        })
    }
}

/// The greedy-generation trace emitted by `aot.py --golden`, used by the
/// cross-layer integration test (Rust execution must reproduce it).
#[derive(Debug, Clone)]
pub struct GoldenTrace {
    pub prompt: Vec<i32>,
    pub bucket: usize,
    pub generated: Vec<i32>,
    pub first_logits_prefix: Vec<f32>,
    pub n_gen: usize,
}

impl GoldenTrace {
    pub fn from_json_str(s: &str) -> Result<Self> {
        let v = json::parse(s).context("golden.json")?;
        Ok(Self {
            prompt: v.req("prompt")?.to_i32_vec().context("prompt")?,
            bucket: v.req("bucket")?.as_usize().context("bucket")?,
            generated: v.req("generated")?.to_i32_vec().context("generated")?,
            first_logits_prefix: v
                .req("first_logits_prefix")?
                .to_f32_vec()
                .context("first_logits_prefix")?,
            n_gen: v.req("n_gen")?.as_usize().context("n_gen")?,
        })
    }
}

/// A raw weight tensor sliced out of `weights.bin`.
#[derive(Debug, Clone)]
pub struct RawTensor {
    pub meta: TensorMeta,
    pub data: Vec<u8>,
}

/// All weights of one config, keyed by name, in manifest order.
#[derive(Debug)]
pub struct WeightStore {
    pub tensors: Vec<RawTensor>,
    by_name: HashMap<String, usize>,
}

impl WeightStore {
    /// Parse a `weights.bin` (format documented in
    /// `python/compile/weights.py`).
    pub fn parse(bytes: &[u8], expected: &[TensorMeta]) -> Result<Self> {
        if bytes.len() < 16 || &bytes[..8] != WEIGHTS_MAGIC {
            bail!("weights.bin: bad magic");
        }
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let header_end = 16usize
            .checked_add(header_len)
            .filter(|&e| e <= bytes.len())
            .context("weights.bin: truncated header")?;
        let header = json::parse_bytes(&bytes[16..header_end])
            .context("weights.bin: header json")?;
        let metas = header
            .req("tensors")?
            .as_arr()
            .context("weights.bin: tensors")?
            .iter()
            .map(TensorMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let data = &bytes[header_end..];

        let mut tensors = Vec::with_capacity(metas.len());
        let mut by_name = HashMap::new();
        for (i, meta) in metas.into_iter().enumerate() {
            let end = meta
                .offset
                .checked_add(meta.nbytes)
                .filter(|&e| e <= data.len())
                .with_context(|| format!("weights.bin: tensor {} out of bounds", meta.name))?;
            // Cross-check against the manifest's declared order/shapes.
            if let Some(exp) = expected.get(i) {
                if exp.name != meta.name || exp.shape != meta.shape || exp.dtype != meta.dtype {
                    bail!(
                        "weights.bin/manifest mismatch at #{i}: {} {:?} {} vs {} {:?} {}",
                        meta.name, meta.shape, meta.dtype, exp.name, exp.shape, exp.dtype
                    );
                }
            }
            by_name.insert(meta.name.clone(), i);
            tensors.push(RawTensor { data: data[meta.offset..end].to_vec(), meta });
        }
        if !expected.is_empty() && tensors.len() != expected.len() {
            bail!(
                "weights.bin has {} tensors, manifest expects {}",
                tensors.len(),
                expected.len()
            );
        }
        Ok(Self { tensors, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&RawTensor> {
        self.by_name.get(name).map(|&i| &self.tensors[i])
    }

    /// Total weight bytes (the paper's on-chip URAM residency figure).
    pub fn total_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }
}

/// An artifact directory (`artifacts/<config>/`) with its parsed manifest.
#[derive(Debug)]
pub struct ArtifactDir {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactDir {
    /// Open and validate `<dir>/manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::from_json_str(&text)?;
        if manifest.format_version != 1 {
            bail!("unsupported manifest format_version {}", manifest.format_version);
        }
        Ok(Self { dir, manifest })
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load and parse `weights.bin`.
    pub fn load_weights(&self) -> Result<WeightStore> {
        let bytes = fs::read(self.path(&self.manifest.weights_file))?;
        WeightStore::parse(&bytes, &self.manifest.weight_order)
    }

    /// Load `golden.json` if the manifest declares one.
    pub fn load_golden(&self) -> Result<Option<GoldenTrace>> {
        match &self.manifest.golden {
            None => Ok(None),
            Some(file) => {
                let text = fs::read_to_string(self.path(file))?;
                Ok(Some(GoldenTrace::from_json_str(&text)?))
            }
        }
    }

    /// Smallest prefill bucket that fits `prompt_len`, if any.
    pub fn bucket_for(&self, prompt_len: usize) -> Option<usize> {
        self.manifest
            .config
            .prefill_buckets
            .iter()
            .copied()
            .find(|&b| b >= prompt_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(name: &str, len: usize, offset: usize) -> TensorMeta {
        TensorMeta {
            name: name.into(),
            shape: vec![len],
            dtype: "u8".into(),
            offset,
            nbytes: len,
        }
    }

    fn build_weights_bin(tensors: &[(&str, Vec<u8>)]) -> (Vec<u8>, Vec<TensorMeta>) {
        let mut metas = Vec::new();
        let mut offset = 0usize;
        for (name, data) in tensors {
            offset = (offset + 63) / 64 * 64;
            metas.push(meta(name, data.len(), offset));
            offset += data.len();
        }
        let tensor_objs: Vec<String> = metas
            .iter()
            .map(|m| {
                format!(
                    r#"{{"name":"{}","shape":[{}],"dtype":"u8","offset":{},"nbytes":{}}}"#,
                    m.name, m.shape[0], m.offset, m.nbytes
                )
            })
            .collect();
        let header = format!(r#"{{"tensors":[{}]}}"#, tensor_objs.join(","));
        let mut out = Vec::new();
        out.extend_from_slice(WEIGHTS_MAGIC);
        out.extend_from_slice(&(header.len() as u64).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        let data_start = out.len();
        for (m, (_, data)) in metas.iter().zip(tensors) {
            out.resize(data_start + m.offset, 0);
            out.extend_from_slice(data);
        }
        (out, metas)
    }

    #[test]
    fn parse_round_trip() {
        let (bytes, metas) = build_weights_bin(&[("a", vec![1, 2, 3]), ("b", vec![9; 100])]);
        let store = WeightStore::parse(&bytes, &metas).unwrap();
        assert_eq!(store.tensors.len(), 2);
        assert_eq!(store.get("a").unwrap().data, vec![1, 2, 3]);
        assert_eq!(store.get("b").unwrap().data.len(), 100);
        assert_eq!(store.total_bytes(), 103);
        assert!(store.get("zzz").is_none());
    }

    #[test]
    fn rejects_bad_magic() {
        let err = WeightStore::parse(b"NOTMAGIC00000000", &[]).unwrap_err();
        assert!(err.to_string().contains("magic"));
    }

    #[test]
    fn rejects_shape_mismatch() {
        let (bytes, mut metas) = build_weights_bin(&[("a", vec![1, 2, 3])]);
        metas[0].shape = vec![4];
        assert!(WeightStore::parse(&bytes, &metas).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let (bytes, metas) = build_weights_bin(&[("a", vec![7; 64])]);
        assert!(WeightStore::parse(&bytes[..bytes.len() - 8], &metas).is_err());
    }

    #[test]
    fn manifest_parses() {
        let text = r#"{
          "format_version": 1,
          "config": {"name":"test","n_layers":2,"d_model":128,"n_heads":4,
                     "d_ff":384,"vocab":256,"max_seq":32,
                     "prefill_buckets":[8,16],"attn_block":8,
                     "tlmm_block_m":8,"tlmm_block_n":64,"rope_base":10000.0},
          "head_dim": 32,
          "n_params": 600000,
          "weights_file": "weights.bin",
          "weight_order": [{"name":"tok_emb","shape":[256,128],"dtype":"f32"}],
          "entrypoints": {"prefill":[{"bucket":8,"file":"prefill_L8.hlo.txt"}],
                          "decode":"decode.hlo.txt"},
          "io": {"cache_shape":[2,4,32,32],"vocab":256},
          "golden": "golden.json"
        }"#;
        let m = Manifest::from_json_str(text).unwrap();
        assert_eq!(m.config.head_dim(), 32);
        assert_eq!(m.entrypoints.prefill[0].bucket, 8);
        assert_eq!(m.golden.as_deref(), Some("golden.json"));
        assert_eq!(m.weight_order[0].element_count(), 256 * 128);
    }

    #[test]
    fn manifest_null_golden() {
        let text = r#"{
          "format_version": 1,
          "config": {"name":"x","n_layers":1,"d_model":4,"n_heads":1,
                     "d_ff":4,"vocab":8,"max_seq":8,
                     "prefill_buckets":[8],"attn_block":8},
          "head_dim": 4, "n_params": 10, "weights_file": "weights.bin",
          "weight_order": [],
          "entrypoints": {"prefill":[],"decode":"decode.hlo.txt"},
          "io": {"cache_shape":[1,1,8,4],"vocab":8},
          "golden": null
        }"#;
        let m = Manifest::from_json_str(text).unwrap();
        assert!(m.golden.is_none());
        // defaulted blocks
        assert_eq!(m.config.tlmm_block_m, 128);
    }

    #[test]
    fn golden_parses() {
        let g = GoldenTrace::from_json_str(
            r#"{"prompt":[1,2],"bucket":8,"generated":[3,4],
                "first_logits_prefix":[0.5,-1.25],"n_gen":2}"#,
        )
        .unwrap();
        assert_eq!(g.prompt, vec![1, 2]);
        assert_eq!(g.first_logits_prefix[1], -1.25);
    }
}
