//! PJRT runtime: loads the AOT HLO artifacts and executes them on the
//! request path.
//!
//! Python is never imported here — `make artifacts` ran once at build time
//! and produced, per model config:
//!
//! * `prefill_L{bucket}.hlo.txt` — shape-specialized prefill executables,
//! * `decode.hlo.txt` — the single-token autoregressive step,
//! * `weights.bin` + `manifest.json` — weights and the IO contract.
//!
//! `InferenceEngine` (behind the `pjrt` feature, so not linkable from a
//! default build's docs) compiles each HLO module once with the PJRT CPU
//! client and keeps the weight tensors uploaded as device buffers so the
//! per-call cost is just the small dynamic inputs (tokens, positions) plus
//! the KV cache round-trip (see `kv_cache` for why the cache currently
//! crosses the host boundary each step, and EXPERIMENTS.md §Perf for the
//! multi-step mitigation).
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids that this XLA build (xla_extension 0.5.1) rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

//! ## Feature gating
//!
//! Everything that touches PJRT/XLA lives behind the `pjrt` cargo feature
//! (default **off**): the simulator, DSE, and eval paths — and `cargo
//! test` — build without an XLA installation. The artifact parsing,
//! sampling, and the paged cache arithmetic ([`PagedKvView`]) are plain
//! Rust and stay available either way.

mod artifacts;
#[cfg(feature = "pjrt")]
mod engine;
mod kv_cache;
mod sampler;

pub use artifacts::{ArtifactDir, GoldenTrace, Manifest, ManifestConfig, TensorMeta, WeightStore};
#[cfg(feature = "pjrt")]
pub use engine::{InferenceEngine, PrefillResult, RuntimeStats};
#[cfg(feature = "pjrt")]
pub use kv_cache::KvCache;
pub use kv_cache::PagedKvView;
pub use sampler::{argmax, SamplerConfig, SamplingMode, sample};
