//! Token sampling — the coordinator-side half of generation.
//!
//! The AOT graphs return raw logits; sampling policy lives here in Rust so
//! one compiled artifact serves greedy, temperature, and top-k decoding.

use crate::util::rng::Rng;

/// How to turn logits into a token id.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplingMode {
    /// Always the argmax (deterministic; matches the golden traces).
    Greedy,
    /// Softmax with temperature.
    Temperature(f32),
    /// Keep the k most likely logits, then temperature-softmax over them.
    TopK { k: usize, temperature: f32 },
}

/// Sampler configuration carried per request.
#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    pub mode: SamplingMode,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self { mode: SamplingMode::Greedy }
    }
}

/// Index of the maximum logit (ties -> lowest index, matching jnp.argmax).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Sample a token id from `logits` according to `cfg`.
pub fn sample(logits: &[f32], cfg: &SamplerConfig, rng: &mut Rng) -> i32 {
    match cfg.mode {
        SamplingMode::Greedy => argmax(logits),
        SamplingMode::Temperature(t) => sample_softmax(logits, t, usize::MAX, rng),
        SamplingMode::TopK { k, temperature } => sample_softmax(logits, temperature, k, rng),
    }
}

fn sample_softmax(logits: &[f32], temperature: f32, top_k: usize, rng: &mut Rng) -> i32 {
    let t = temperature.max(1e-4);
    // Rank indices by logit (descending), truncate to top_k.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        logits[b]
            .partial_cmp(&logits[a])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(top_k.max(1).min(logits.len()));

    let max = logits[idx[0]];
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - max) / t) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        u -= w;
        if u <= 0.0 {
            return i as i32;
        }
    }
    idx[idx.len() - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        // Ties resolve to the first index, like jnp.argmax.
        assert_eq!(argmax(&[1.0, 1.0]), 0);
        // NaN never wins (NaN > x is false).
        assert_eq!(argmax(&[f32::NAN, 1.0]), 1);
    }

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 5.0, -1.0];
        assert_eq!(sample(&logits, &SamplerConfig::default(), &mut rng), 1);
    }

    #[test]
    fn top1_equals_greedy_regardless_of_temperature() {
        let mut rng = Rng::new(1);
        let logits = [0.5, 2.0, 1.0, -3.0];
        let cfg = SamplerConfig { mode: SamplingMode::TopK { k: 1, temperature: 10.0 } };
        for _ in 0..32 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(2);
        let logits = [0.0, 3.0, 0.5];
        let cfg = SamplerConfig { mode: SamplingMode::Temperature(0.01) };
        for _ in 0..64 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
        }
    }

    #[test]
    fn samples_stay_in_top_k() {
        let mut rng = Rng::new(3);
        let logits = [10.0, 9.0, 8.0, -50.0, -60.0];
        let cfg = SamplerConfig { mode: SamplingMode::TopK { k: 3, temperature: 1.0 } };
        for _ in 0..128 {
            let s = sample(&logits, &cfg, &mut rng);
            assert!((0..3).contains(&s), "sampled {s} outside top-3");
        }
    }

    #[test]
    fn high_temperature_spreads() {
        let mut rng = Rng::new(4);
        let logits = [1.0, 1.1];
        let cfg = SamplerConfig { mode: SamplingMode::Temperature(100.0) };
        let n0 = (0..256)
            .filter(|_| sample(&logits, &cfg, &mut rng) == 0)
            .count();
        assert!(n0 > 64 && n0 < 192, "expected near-uniform, got {n0}/256");
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let cfg = SamplerConfig { mode: SamplingMode::Temperature(1.0) };
        let run = |seed| {
            let mut rng = Rng::new(seed);
            (0..16).map(|_| sample(&logits, &cfg, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
