//! [`InferenceEngine`]: compile-once / execute-many PJRT wrapper around one
//! artifact directory.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::artifacts::{ArtifactDir, Manifest, RawTensor, WeightStore};
use super::kv_cache::KvCache;
use super::sampler::argmax;

/// Execution counters (monotonic; cheap enough for the hot path).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub prefill_calls: AtomicU64,
    pub decode_calls: AtomicU64,
    pub prefill_micros: AtomicU64,
    pub decode_micros: AtomicU64,
    /// Host<->device bytes moved for KV caches (the round-trip tax).
    pub cache_bytes: AtomicU64,
}

impl RuntimeStats {
    pub fn avg_decode_ms(&self) -> f64 {
        let n = self.decode_calls.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.decode_micros.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }
    pub fn avg_prefill_ms(&self) -> f64 {
        let n = self.prefill_calls.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.prefill_micros.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }
}

/// Result of a prefill call.
pub struct PrefillResult {
    /// Logits for the last valid prompt position, `f32 [vocab]`.
    pub logits: Vec<f32>,
    /// Freshly minted cache containing the prompt's K/V.
    pub cache: KvCache,
    /// The bucket length actually executed.
    pub bucket: usize,
}

/// Compile-once PJRT engine for one model config.
///
/// Weights are uploaded to the device once at load; per call we only ship
/// the small dynamic inputs and the KV cache. All executables share the
/// same positional parameter convention: `weights..., <dynamic inputs>`.
pub struct InferenceEngine {
    client: PjRtClient,
    pub artifacts: ArtifactDir,
    /// Device-resident weights in manifest order.
    weights: Vec<PjRtBuffer>,
    /// Prefill executables keyed by bucket length.
    prefill_exes: BTreeMap<usize, PjRtLoadedExecutable>,
    decode_exe: PjRtLoadedExecutable,
    pub stats: RuntimeStats,
    /// Total weight bytes (reported by examples; the simulator's URAM
    /// residency check uses the analytic count instead).
    pub weight_bytes: usize,
}

fn upload_tensor(client: &PjRtClient, t: &RawTensor) -> Result<PjRtBuffer> {
    let dims = &t.meta.shape;
    let buf = match t.meta.dtype.as_str() {
        "f32" => {
            let data: Vec<f32> = t
                .data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            client.buffer_from_host_buffer(&data, dims, None)?
        }
        "i32" => {
            let data: Vec<i32> = t
                .data
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            client.buffer_from_host_buffer(&data, dims, None)?
        }
        "u8" => client.buffer_from_host_buffer(&t.data, dims, None)?,
        other => bail!("unsupported dtype {other} for tensor {}", t.meta.name),
    };
    Ok(buf)
}

fn compile_hlo(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

impl InferenceEngine {
    /// Load every artifact of `dir`, compile all executables, upload
    /// weights. This is the (one-time) analogue of the paper's full
    /// bitstream programming + weight preload.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let artifacts = ArtifactDir::open(dir)?;
        let client = PjRtClient::cpu()?;

        let store: WeightStore = artifacts.load_weights()?;
        let weight_bytes = store.total_bytes();
        let weights = store
            .tensors
            .iter()
            .map(|t| upload_tensor(&client, t))
            .collect::<Result<Vec<_>>>()?;

        let mut prefill_exes = BTreeMap::new();
        for entry in &artifacts.manifest.entrypoints.prefill {
            let exe = compile_hlo(&client, &artifacts.path(&entry.file))?;
            prefill_exes.insert(entry.bucket, exe);
        }
        let decode_exe = compile_hlo(
            &client,
            &artifacts.path(&artifacts.manifest.entrypoints.decode),
        )?;

        Ok(Self {
            client,
            artifacts,
            weights,
            prefill_exes,
            decode_exe,
            stats: RuntimeStats::default(),
            weight_bytes,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.artifacts.manifest
    }

    pub fn vocab(&self) -> usize {
        self.manifest().io.vocab
    }

    pub fn max_seq(&self) -> usize {
        self.manifest().config.max_seq
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.prefill_exes.keys().copied().collect()
    }

    fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        // 0-d i32 buffer.
        Ok(self.client.buffer_from_host_buffer::<i32>(&[v], &[], None)?)
    }

    /// Run prefill for `prompt` (unpadded token ids). Picks the smallest
    /// compiled bucket that fits, right-pads with 0, returns the logits of
    /// the last valid position plus the populated KV cache.
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillResult> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let bucket = self
            .artifacts
            .bucket_for(prompt.len())
            .with_context(|| {
                format!(
                    "prompt of {} tokens exceeds largest bucket {:?}",
                    prompt.len(),
                    self.manifest().config.prefill_buckets
                )
            })?;
        let exe = &self.prefill_exes[&bucket];

        let mut padded = vec![0i32; bucket];
        padded[..prompt.len()].copy_from_slice(prompt);

        let t0 = Instant::now();
        let tokens = self
            .client
            .buffer_from_host_buffer(&padded, &[bucket], None)?;
        let plen = self.scalar_i32(prompt.len() as i32)?;

        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&tokens);
        args.push(&plen);

        let result = exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let (logits_l, k, v) = match <[Literal; 3]>::try_from(parts) {
            Ok([a, b, c]) => (a, b, c),
            Err(p) => bail!("prefill returned {} outputs, expected 3", p.len()),
        };
        let logits = logits_l.to_vec::<f32>()?;
        let cache = KvCache::new(k, v, prompt.len(), self.max_seq());

        self.stats.prefill_calls.fetch_add(1, Ordering::Relaxed);
        self.stats
            .prefill_micros
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        self.stats
            .cache_bytes
            .fetch_add(cache.nbytes() as u64, Ordering::Relaxed);

        Ok(PrefillResult { logits, cache, bucket })
    }

    /// One autoregressive step: feed `token` at position `cache.len`,
    /// return the next-token logits and the updated cache.
    pub fn decode(&self, token: i32, cache: KvCache) -> Result<(Vec<f32>, KvCache)> {
        if !cache.has_room() {
            bail!(
                "KV cache full ({} / {}): cannot decode further",
                cache.len,
                cache.capacity
            );
        }
        let pos = cache.len;
        let t0 = Instant::now();

        let tok_buf = self.scalar_i32(token)?;
        let pos_buf = self.scalar_i32(pos as i32)?;
        let k_buf = self.client.buffer_from_host_literal(None, &cache.k)?;
        let v_buf = self.client.buffer_from_host_literal(None, &cache.v)?;

        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&k_buf);
        args.push(&v_buf);

        let result = self.decode_exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let (logits_l, k, v) = match <[Literal; 3]>::try_from(parts) {
            Ok([a, b, c]) => (a, b, c),
            Err(p) => bail!("decode returned {} outputs, expected 3", p.len()),
        };
        let logits = logits_l.to_vec::<f32>()?;
        let new_cache = KvCache::new(k, v, pos + 1, cache.capacity);

        self.stats.decode_calls.fetch_add(1, Ordering::Relaxed);
        self.stats
            .decode_micros
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        self.stats
            .cache_bytes
            .fetch_add(2 * new_cache.nbytes() as u64, Ordering::Relaxed);

        Ok((logits, new_cache))
    }

    /// Convenience: greedy-generate `n` tokens after `prompt`. Returns the
    /// generated ids (stops early when the cache fills).
    pub fn generate_greedy(&self, prompt: &[i32], n: usize) -> Result<Vec<i32>> {
        let pre = self.prefill(prompt)?;
        let mut cache = pre.cache;
        let mut tok = argmax(&pre.logits);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(tok);
            if !cache.has_room() {
                break;
            }
            let (logits, c) = self.decode(tok, cache)?;
            cache = c;
            tok = argmax(&logits);
        }
        Ok(out)
    }

    /// ElementType helper for the manifest's dtype strings (exposed for
    /// integration tests).
    pub fn element_type(dtype: &str) -> Result<ElementType> {
        Ok(match dtype {
            "f32" => ElementType::F32,
            "u8" => ElementType::U8,
            "i32" => ElementType::S32,
            other => bail!("unsupported dtype {other}"),
        })
    }
}

// `argmax` lives in `super::sampler` (available without the `pjrt`
// feature); re-exported from `runtime` for backwards compatibility.
