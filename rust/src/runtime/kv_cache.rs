//! Host-side KV cache handles.
//!
//! The decode executable carries the cache as explicit inputs/outputs
//! (`k_cache`/`v_cache` f32 `[n_layers, n_heads, max_seq, head_dim]`).
//! PJRT (through the `xla` crate's default `ExecuteOptions`) returns the
//! whole result tuple as a *single* buffer, so tuple elements can only be
//! reached by materializing a host literal — which means the cache crosses
//! the host boundary once per decode step. That is correct and, at the
//! model sizes CPU-PJRT can execute, cheap relative to the compute; the
//! multi-step decode graph that amortizes it is tracked in EXPERIMENTS.md
//! §Perf.
//!
//! On the *simulated* KV260 the cache lives in DDR and its streaming cost
//! is modeled by [`crate::memory`]; this module is only the functional
//! path. [`PagedKvView`] is the bridge between the two: the page-granular
//! occupancy arithmetic the simulator's [`crate::kvpool::KvPool`] uses,
//! computed over a live cache's `len`/`capacity` so both sides agree on
//! how many pages a request holds.

#[cfg(feature = "pjrt")]
use xla::Literal;

/// Page-granular view of one request's KV occupancy — the host-side
/// mirror of a [`crate::kvpool::KvPool`] reservation. Pure arithmetic:
/// available with or without the `pjrt` feature so the simulator and the
/// live PJRT path share one definition of "pages used".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PagedKvView {
    /// Tokens per page (must match the pool's `page_tokens`).
    pub page_tokens: usize,
    /// Valid positions (prompt + generated so far).
    pub len: usize,
    /// Capacity in tokens (`max_seq` of the compiled graph).
    pub capacity: usize,
}

impl PagedKvView {
    pub fn new(page_tokens: usize, len: usize, capacity: usize) -> Self {
        Self { page_tokens: page_tokens.max(1), len, capacity }
    }

    /// Pages backing the valid prefix.
    pub fn pages_used(&self) -> usize {
        self.len.div_ceil(self.page_tokens)
    }

    /// Pages a full cache would occupy.
    pub fn pages_capacity(&self) -> usize {
        self.capacity.div_ceil(self.page_tokens)
    }

    /// Valid fraction of the paged allocation (≥ the token-level
    /// occupancy because the last page is partially filled).
    pub fn page_occupancy(&self) -> f64 {
        self.pages_used() as f64 / self.pages_capacity().max(1) as f64
    }

    /// Unused tokens in the trailing page (internal fragmentation).
    pub fn last_page_slack(&self) -> usize {
        let rem = self.len % self.page_tokens;
        if self.len == 0 || rem == 0 { 0 } else { self.page_tokens - rem }
    }
}

/// One request's KV cache (both tensors padded to `max_seq`).
#[cfg(feature = "pjrt")]
pub struct KvCache {
    /// `f32 [n_layers, n_heads, max_seq, head_dim]`, RoPE already applied.
    pub k: Literal,
    /// Same shape as `k`.
    pub v: Literal,
    /// Number of valid positions (prompt + generated so far).
    pub len: usize,
    /// Capacity (`max_seq` of the compiled graph).
    pub capacity: usize,
}

#[cfg(feature = "pjrt")]
impl KvCache {
    pub fn new(k: Literal, v: Literal, len: usize, capacity: usize) -> Self {
        Self { k, v, len, capacity }
    }

    /// True if one more token can be decoded into the cache.
    pub fn has_room(&self) -> bool {
        self.len < self.capacity
    }

    /// Host bytes held by this cache (both tensors).
    pub fn nbytes(&self) -> usize {
        self.k.size_bytes() + self.v.size_bytes()
    }

    /// Valid fraction of the padded cache (decode bandwidth utilization in
    /// the simulator maps 1:1 onto this).
    pub fn occupancy(&self) -> f64 {
        self.len as f64 / self.capacity.max(1) as f64
    }

    /// The page-granular occupancy view the KV pool accounts in.
    pub fn paged_view(&self, page_tokens: usize) -> PagedKvView {
        PagedKvView::new(page_tokens, self.len, self.capacity)
    }
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCache")
            .field("len", &self.len)
            .field("capacity", &self.capacity)
            .field("nbytes", &self.nbytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::PagedKvView;

    #[test]
    fn page_math() {
        let v = PagedKvView::new(32, 100, 2048);
        assert_eq!(v.pages_used(), 4);
        assert_eq!(v.pages_capacity(), 64);
        assert_eq!(v.last_page_slack(), 28);
        assert!((v.page_occupancy() - 4.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn exact_page_boundaries() {
        let v = PagedKvView::new(32, 128, 256);
        assert_eq!(v.pages_used(), 4);
        assert_eq!(v.last_page_slack(), 0);
        let empty = PagedKvView::new(32, 0, 256);
        assert_eq!(empty.pages_used(), 0);
        assert_eq!(empty.last_page_slack(), 0);
    }

    #[test]
    fn agrees_with_pool_page_accounting() {
        // The simulator's pool and the host-side view must count pages
        // identically for the same (len, page_tokens).
        use crate::fpga::KV260;
        use crate::kvpool::KvPoolConfig;
        use crate::model::BITNET_0_73B;
        let cfg = KvPoolConfig::for_device(&BITNET_0_73B, &KV260);
        for len in [1, 31, 32, 33, 100, 2048] {
            let view = PagedKvView::new(cfg.page_tokens, len, BITNET_0_73B.max_seq);
            assert_eq!(view.pages_used(), cfg.pages_for_tokens(len), "len={len}");
        }
    }
}
