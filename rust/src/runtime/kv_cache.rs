//! Host-side KV cache handles.
//!
//! The decode executable carries the cache as explicit inputs/outputs
//! (`k_cache`/`v_cache` f32 `[n_layers, n_heads, max_seq, head_dim]`).
//! PJRT (through the `xla` crate's default `ExecuteOptions`) returns the
//! whole result tuple as a *single* buffer, so tuple elements can only be
//! reached by materializing a host literal — which means the cache crosses
//! the host boundary once per decode step. That is correct and, at the
//! model sizes CPU-PJRT can execute, cheap relative to the compute; the
//! multi-step decode graph that amortizes it is tracked in EXPERIMENTS.md
//! §Perf.
//!
//! On the *simulated* KV260 the cache lives in DDR and its streaming cost
//! is modeled by [`crate::memory`]; this module is only the functional
//! path.

use xla::Literal;

/// One request's KV cache (both tensors padded to `max_seq`).
pub struct KvCache {
    /// `f32 [n_layers, n_heads, max_seq, head_dim]`, RoPE already applied.
    pub k: Literal,
    /// Same shape as `k`.
    pub v: Literal,
    /// Number of valid positions (prompt + generated so far).
    pub len: usize,
    /// Capacity (`max_seq` of the compiled graph).
    pub capacity: usize,
}

impl KvCache {
    pub fn new(k: Literal, v: Literal, len: usize, capacity: usize) -> Self {
        Self { k, v, len, capacity }
    }

    /// True if one more token can be decoded into the cache.
    pub fn has_room(&self) -> bool {
        self.len < self.capacity
    }

    /// Host bytes held by this cache (both tensors).
    pub fn nbytes(&self) -> usize {
        self.k.size_bytes() + self.v.size_bytes()
    }

    /// Valid fraction of the padded cache (decode bandwidth utilization in
    /// the simulator maps 1:1 onto this).
    pub fn occupancy(&self) -> f64 {
        self.len as f64 / self.capacity.max(1) as f64
    }
}

impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCache")
            .field("len", &self.len)
            .field("capacity", &self.capacity)
            .field("nbytes", &self.nbytes())
            .finish()
    }
}
