//! `pd-swap` — the PD-Swap coordinator CLI.
//!
//! ```text
//! pd-swap info                         # device, design, floorplan report
//! pd-swap eval <table1|table2|fig4a|fig5|fig6|all>
//! pd-swap dse [--static] [--l-long N] [--alpha F]
//! pd-swap codesign [--traces mixed,bursty] [--policies eager,hysteresis,lookahead]
//!                  [--decode-batch 1,4]
//! pd-swap generate --artifacts DIR --prompt 1,2,3 [--n N] [--temperature F]
//! pd-swap serve --artifacts DIR [--requests N] [--seed S]
//! pd-swap simulate [--requests N] [--policy batched] [--no-overlap]
//!                  [--pool-pages N] [--optimistic] [--evict]
//! ```

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use pd_swap::coordinator::{
    generate_workload, requests_from_stream, requests_from_trace, EventServer,
    EventServerConfig, Policy, SimServer, SimServerConfig, WorkloadConfig,
};
#[cfg(feature = "pjrt")]
use pd_swap::coordinator::{LiveServer, LiveServerConfig};
use pd_swap::dse::{
    explore_with, run_codesign, trace_winners, CodesignConfig, DseConfig, PoolVariant,
    TracePreset, DSE_PAGE_TOKENS,
};
use pd_swap::engines::{AcceleratorDesign, AttentionHosting, SurfaceCache, SurfaceFactory};
use pd_swap::eval;
use pd_swap::faults::{FaultPlan, FaultSpec};
use pd_swap::fpga::KV260;
use pd_swap::fuzz::{parse_hex_seed, replay_file, run_fuzz, FuzzConfig, OracleOptions};
use pd_swap::kvpool::{AdmissionControl, EvictionPolicy, KvPoolConfig};
use pd_swap::model::{TraceSpec, BITNET_0_73B};
use pd_swap::reconfig::{SwapPolicy, SwapRetryPolicy};
#[cfg(feature = "pjrt")]
use pd_swap::runtime::{SamplerConfig, SamplingMode};
use pd_swap::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("info") => info(),
        Some("eval") => run_eval(&args),
        Some("dse") => run_dse(&args),
        Some("codesign") => run_codesign_cmd(&args),
        Some("generate") => generate(&args),
        Some("serve") => serve(&args),
        Some("simulate") => simulate(&args),
        Some("fuzz") => run_fuzz_cmd(&args),
        _ => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
pd-swap — prefill-decode logic swapping for LLM inference on edge FPGAs (simulated)

USAGE:
  pd-swap info                          device + design + floorplan report
  pd-swap eval <table1|table2|fig4a|fig5|fig6|all>
  pd-swap dse [--static] [--l-long N] [--l-short N] [--alpha F]
  pd-swap codesign [--requests 24] [--rate 0.05] [--seed 0] [--designs N] [--threads N]
                   [--traces mixed,bursty] [--policies eager,hysteresis,lookahead]
                   [--decode-batch 1,4] [--admission worst-case,optimistic]
                   [--eviction keep,evict] [--page-size 32,64]
                   [--long-ctx N] [--l-long N] [--l-short N]
                   [--alpha F] [--cold] [--out FILE] [--trace-winners DIR]
                   joint (DSE grid x swap policy x decode batch x KV pool x
                   trace) sweep through the event-driven simulator; prints
                   the winning design+policy per traffic mix and whether
                   multi-stream decode or the pool axis flips it
                   (deterministic across runs; decode batches are clamped
                   per design by activation-buffer headroom)
  pd-swap generate --artifacts DIR --prompt 1,2,3 [--n 16] [--temperature F] [--top-k K]
  pd-swap serve --artifacts DIR [--requests 8] [--gen 32] [--seed 0]
  pd-swap simulate [--requests 16] [--policy batched] [--no-overlap] [--static]
                   [--pool-pages N] [--optimistic] [--evict] [--decode-batch B]
                   [--trace-out FILE]
  pd-swap fuzz [--cases 64] [--seed 0x5EED] [--max-requests 10] [--out fuzz-failures]
               [--replay FILE]
                   seeded differential fuzzer: random (trace x design x
                   policy x batch x pool x window) tuples through every
                   engine pair, asserting the documented bitwise contracts
                   and conservation invariants; a divergence is shrunk to a
                   minimal case, written as a replayable JSON fixture under
                   --out, and fails the command. --replay re-runs one
                   fixture. Deterministic: same seed, same summary.
  pd-swap simulate --policy <eager|hysteresis|lookahead>   (event-driven core)
                   [--trace interactive|mixed|bursty|long|million] [--rate R]
                   [--long-ctx N] [--requests N] [--seed S] [--max-residents N]
                   [--decode-batch B] [--no-fast-forward] [--no-layer-events]
                   [--streamed] [--window N] [--log-tail N]
                   [--faults none|swap-storm|ddr-brownout|deadlines|chaos]
                   [--fault-seed S] [--fail-stop]
                   [--trace-out FILE] [--log]
                   `long` is the sparse long-generation preset where the
                   analytic decode fast-forward (default on; bit-identical
                   to stepping) folds thousands of token-step events into
                   a handful — the run prints the event-count reduction;
                   --no-fast-forward steps every token for comparison.
                   `million` is the decode-heavy sparse preset sized for
                   million-request runs: combine --streamed (lazy arrivals,
                   --window N queue bound, bit-identical to materialized),
                   --no-layer-events (skip per-layer prefill markers), and
                   --log-tail N (keep the last N diagnostic records) for
                   O(window + residents) memory at any request count.
                   --faults realizes a deterministic fault preset for
                   --fault-seed: PCAP swap failures retry with capped
                   exponential backoff, then fall back to a degraded
                   static-unified mode until a repair swap lands
                   (--fail-stop sheds everything instead); DDR brownout
                   windows scale bandwidth-bound latencies; SLO deadlines
                   shed late requests (KV pages freed, `shed` outcome).
                   Same --fault-seed => byte-identical report and trace;
                   --faults none is bitwise-inert

  --trace-out FILE writes a deterministic Chrome trace-event JSON (load in
  Perfetto / chrome://tracing) with per-request lifecycle spans, DPR swap
  spans, KV-pool instants, and swap-policy decision records, plus a
  per-request TTFT/TPOT breakdown table; codesign --trace-winners DIR
  writes one such trace per per-trace winning cell.";

fn info() -> Result<()> {
    let design = AcceleratorDesign::pd_swap();
    let plan = design.region_plan()?;
    let report = plan.validate(&KV260).map_err(|e| anyhow::anyhow!(e))?;
    println!("device: {}", KV260.name);
    println!("  fabric: {}", KV260.resources);
    println!(
        "  clock: {} MHz, PCAP {:.0} MB/s, DDR {:.1} GB/s over {} HP ports",
        KV260.clock_mhz,
        KV260.pcap_bytes_per_sec / 1e6,
        KV260.ddr_aggregate_peak / 1e9,
        KV260.n_hp_ports
    );
    println!("design: {}", design.name);
    println!("  static region: {}", report.static_total);
    println!("  RP pblock:     {}", plan.rp.pblock);
    for m in &plan.rp.modules {
        println!("    RM {:14} {}", m.name, m.resources);
    }
    println!(
        "  total: {} (peak LUT/FF util {:.1}%)",
        report.total,
        report.peak_utilization * 100.0
    );
    println!("  equivalent total (both RMs resident): {}", plan.equivalent_total());
    let device = design.program(&KV260)?;
    println!(
        "  partial reconfiguration latency: {:.1} ms",
        device.reconfig_latency() * 1e3
    );
    println!("model: {} ({} params)", BITNET_0_73B.name, BITNET_0_73B.total_params());
    Ok(())
}

fn run_eval(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    match which {
        "table1" => {
            eval::run_table1();
        }
        "table2" => {
            eval::run_table2();
        }
        "fig4a" => {
            eval::run_fig4a();
        }
        "fig5" => {
            eval::run_fig5();
        }
        "fig6" => {
            eval::run_fig6(pd_swap::eval::fig6::LENGTHS);
        }
        "all" => {
            eval::run_table1();
            eval::run_table2();
            eval::run_fig4a();
            eval::run_fig5();
            eval::run_fig6(pd_swap::eval::fig6::LENGTHS);
        }
        other => bail!("unknown eval target '{other}' (try table1|table2|fig4a|fig5|fig6|all)"),
    }
    Ok(())
}

fn run_dse(args: &Args) -> Result<()> {
    let hosting = if args.flag("static") {
        AttentionHosting::StaticBoth
    } else {
        AttentionHosting::Reconfigurable
    };
    let mut cfg = DseConfig::paper_default(BITNET_0_73B, KV260.clone(), hosting);
    cfg.l_long = args.get_usize("l-long", cfg.l_long);
    cfg.l_short = args.get_usize("l-short", cfg.l_short);
    cfg.alpha = args.get_f64("alpha", cfg.alpha);

    println!(
        "exploring {} hosting: {} x {} x {} grid ...",
        if hosting == AttentionHosting::Reconfigurable { "DPR" } else { "static" },
        cfg.tlmm_grid.len(),
        cfg.prefill_grid.len(),
        cfg.decode_grid.len()
    );
    // One SurfaceFactory + shared SurfaceCache per CLI invocation — the
    // codesign warm-start applied to the plain dse path.
    let factory = SurfaceFactory::new(&cfg.device, &cfg.shape, DSE_PAGE_TOKENS);
    let surfaces = Arc::new(Mutex::new(SurfaceCache::new()));
    let res = explore_with(&cfg, &factory, &surfaces, 0)?;
    println!("explored {} candidates, {} feasible", res.explored, res.feasible);
    println!("best: {}", res.best.design.name);
    println!(
        "  T_pre(L={}) = {:.2} s | T_dec(L={}) = {:.1} ms ({:.1} tok/s) | T_dec(L={}) = {:.1} ms ({:.1} tok/s)",
        cfg.l_prefill,
        res.best.t_pre,
        cfg.l_long,
        res.best.t_dec_long * 1e3,
        1.0 / res.best.t_dec_long,
        cfg.l_short,
        res.best.t_dec_short * 1e3,
        1.0 / res.best.t_dec_short,
    );
    println!("  objective (Eq. 6): {:.3}", res.best.objective);
    println!("runner-ups:");
    for p in res.top.iter().take(5) {
        println!("  {:40} obj {:.3}", p.design.name, p.objective);
    }
    Ok(())
}

/// Joint (design × swap policy × trace) co-exploration — feasible only
/// because the surface kernel makes grid evaluation and per-token
/// simulation O(1) in the analytic model.
fn run_codesign_cmd(args: &Args) -> Result<()> {
    let mut sweep = CodesignConfig::paper_default(BITNET_0_73B, KV260.clone());
    sweep.dse.l_long = args.get_usize("l-long", sweep.dse.l_long);
    sweep.dse.l_short = args.get_usize("l-short", sweep.dse.l_short);
    sweep.dse.alpha = args.get_f64("alpha", sweep.dse.alpha);
    sweep.max_designs = args.get_usize("designs", 0);
    sweep.threads = args.get_usize("threads", 0);
    let n = args.get_usize("requests", 24);
    let rate = args.get_f64("rate", 0.05);
    let seed = args.get_u64("seed", 0);
    let long_ctx = args.get_usize("long-ctx", BITNET_0_73B.max_seq);
    if let Some(list) = args.get("traces") {
        let mut traces = Vec::new();
        for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match TracePreset::by_name(name, n, rate, long_ctx, seed) {
                Some(t) => traces.push(t),
                None => bail!("unknown trace '{name}' (try interactive|mixed|bursty|long|million)"),
            }
        }
        sweep.traces = traces;
    } else {
        sweep.traces = TracePreset::defaults(n, rate, long_ctx, seed);
    }
    if let Some(list) = args.get("policies") {
        let mut policies = Vec::new();
        for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match SwapPolicy::from_name(name) {
                Some(p) => policies.push(p),
                None => bail!("unknown policy '{name}' (try eager|hysteresis|lookahead)"),
            }
        }
        sweep.policies = policies;
    }
    sweep.decode_batches = args.get_usize_list("decode-batch", &[1]);
    // KV-pool axis: admission x eviction x page size (cross product).
    let default_pool = PoolVariant::paper_default();
    let mut admissions = Vec::new();
    for name in args
        .get_or("admission", default_pool.admission.name())
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
    {
        match AdmissionControl::from_name(name) {
            Some(a) => admissions.push(a),
            None => bail!("unknown admission '{name}' (try worst-case|optimistic)"),
        }
    }
    let mut evictions = Vec::new();
    for name in args
        .get_or("eviction", default_pool.eviction.name())
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
    {
        match EvictionPolicy::from_name(name) {
            Some(e) => evictions.push(e),
            None => bail!("unknown eviction '{name}' (try keep|evict)"),
        }
    }
    let pages = args.get_usize_list("page-size", &[default_pool.page_tokens]);
    let mut pools = Vec::new();
    for &admission in &admissions {
        for &eviction in &evictions {
            for &page_tokens in &pages {
                pools.push(PoolVariant { admission, eviction, page_tokens });
            }
        }
    }
    sweep.pools = pools;
    sweep.warm_start = !args.flag("cold");

    println!(
        "codesign: {} x {} x {} DSE grid x {} policies x {} decode batches x {} pools x {} traces ({} requests each, seed {seed})",
        sweep.dse.tlmm_grid.len(),
        sweep.dse.prefill_grid.len(),
        sweep.dse.decode_grid.len(),
        sweep.policies.len(),
        sweep.decode_batches.len(),
        sweep.pools.len(),
        sweep.traces.len(),
        n,
    );
    let report = run_codesign(&sweep)?;
    println!(
        "explored {} grid points, {} feasible; swept {} designs end-to-end ({} simulations)",
        report.explored, report.feasible, report.designs_swept, report.sims_run,
    );
    for t in &report.traces {
        println!(
            "\n--- trace '{}' (offered {:.1} tok/s) ---",
            t.trace, t.offered_tokens_per_sec
        );
        println!(
            "{:<40} {:<11} {:>6} {:<26} {:>9} {:>9} {:>12} {:>6} {:>11} {:>11}",
            "design", "policy", "B", "pool", "dec t/s", "e2e t/s", "slo-good t/s", "swaps",
            "exposed s", "ttft p95 s"
        );
        for c in t.ranked.iter().take(5) {
            // A trailing '*' marks a batch clamped by the design's
            // activation-buffer headroom (requested > effective).
            let b = if c.batch_capped {
                format!("{}*", c.decode_batch)
            } else {
                c.decode_batch.to_string()
            };
            println!(
                "{:<40} {:<11} {:>6} {:<26} {:>9.2} {:>9.2} {:>12.2} {:>6} {:>11.2} {:>11.1}",
                c.design, c.policy, b, c.pool, c.decode_tps, c.makespan_tps, c.slo_goodput_tps,
                c.swaps, c.exposed_s, c.ttft_p95_s,
            );
        }
        let capped = t.ranked.iter().filter(|c| c.batch_capped).count();
        if capped > 0 {
            println!(
                "({capped} cells decode-batch-capped by activation-buffer headroom, marked '*')"
            );
        }
        let w = t.winner();
        println!(
            "winner: {} + {} @ decode-batch {} / {} — {:.2} tok/s decode (wall TPOT), makespan {:.1} s",
            w.design, w.policy, w.decode_batch, w.pool, w.decode_tps, w.makespan_s
        );
    }
    // Decode-batch flip verdicts: does multi-stream decode change what
    // should ship? (Printed only when the axis was actually swept.)
    if report.decode_batches.len() > 1 {
        println!();
        for f in report.batch_flips() {
            if f.flips {
                let list = f
                    .winners
                    .iter()
                    .map(|(b, d, p)| format!("B={b} -> {d} + {p}"))
                    .collect::<Vec<_>>()
                    .join("; ");
                println!("trace '{}': decode batch FLIPS the winner: {list}", f.trace);
            } else if let Some((_, d, p)) = f.winners.first() {
                println!(
                    "trace '{}': no flip — {d} + {p} wins at every decode batch \
                     (the shared weight stream amortizes equally across these \
                     designs/policies at this traffic)",
                    f.trace
                );
            }
        }
    }
    // KV-pool flip verdicts (printed only when the pool axis was swept).
    if report.pools.len() > 1 {
        println!();
        for f in report.pool_flips() {
            if f.flips {
                let list = f
                    .winners
                    .iter()
                    .map(|(pool, d, p)| format!("{pool} -> {d} + {p}"))
                    .collect::<Vec<_>>()
                    .join("; ");
                println!("trace '{}': KV-pool axis FLIPS the winner: {list}", f.trace);
            } else if let Some((_, d, p)) = f.winners.first() {
                println!(
                    "trace '{}': no flip — {d} + {p} wins under every \
                     admission/eviction/page-size variant at this traffic",
                    f.trace
                );
            }
        }
    }
    if let Some(out) = args.get("out") {
        let path = pd_swap::util::bench::write_json_report(out, &report.to_json(10))?;
        println!("\nwrote {path}");
    }
    if let Some(dir) = args.get("trace-winners") {
        std::fs::create_dir_all(dir)?;
        for (trace, rec) in trace_winners(&sweep, &report)? {
            let path = format!("{dir}/trace-{trace}.json");
            rec.write(&path)?;
            println!(
                "wrote winner trace for '{trace}': {path} ({} events, {} policy decisions)",
                rec.len(),
                rec.decision_count()
            );
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn sampler_from(args: &Args) -> SamplerConfig {
    let temp = args.get_f64("temperature", 0.0) as f32;
    let top_k = args.get_usize("top-k", 0);
    let mode = if top_k > 0 {
        SamplingMode::TopK { k: top_k, temperature: if temp > 0.0 { temp } else { 1.0 } }
    } else if temp > 0.0 {
        SamplingMode::Temperature(temp)
    } else {
        SamplingMode::Greedy
    };
    SamplerConfig { mode }
}

#[cfg(not(feature = "pjrt"))]
fn generate(_args: &Args) -> Result<()> {
    bail!("`generate` needs the PJRT runtime: rebuild with `--features pjrt` (requires XLA)")
}

#[cfg(not(feature = "pjrt"))]
fn serve(_args: &Args) -> Result<()> {
    bail!("`serve` needs the PJRT runtime: rebuild with `--features pjrt` (requires XLA)")
}

#[cfg(feature = "pjrt")]
fn generate(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts/test");
    let prompt: Vec<i32> = args
        .get("prompt")
        .unwrap_or("1,2,3,4,5")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse().expect("prompt must be comma-separated ints"))
        .collect();
    let n = args.get_usize("n", 16);

    let mut server = LiveServer::new(LiveServerConfig {
        artifacts_dir: dir.into(),
        sampler: sampler_from(args),
        seed: args.get_u64("seed", 0),
        simulate_fpga: true,
    })?;
    let req = pd_swap::coordinator::Request::with_tokens(0, prompt.clone(), n, 0.0);
    let out = server.serve(&req)?;
    println!("prompt:    {prompt:?}");
    println!("generated: {:?}", out.outcome.generated);
    println!(
        "host: ttft {:.1} ms, {:.2} tok/s decode",
        out.outcome.ttft * 1e3,
        1.0 / out.outcome.mean_tpot.max(1e-9)
    );
    if let (Some(st), Some(se)) = (out.sim_ttft, out.sim_e2e) {
        println!("simulated KV260 (PD-Swap): ttft {st:.2} s, e2e {se:.2} s");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn serve(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts/tiny");
    let mut server = LiveServer::new(LiveServerConfig {
        artifacts_dir: dir.into(),
        sampler: sampler_from(args),
        seed: args.get_u64("seed", 0),
        simulate_fpga: true,
    })?;
    let m = server.engine.manifest().config.clone();
    let wl = generate_workload(&WorkloadConfig {
        n_requests: args.get_usize("requests", 8),
        prompt_len: (4, *m.prefill_buckets.last().unwrap()),
        gen_len: (4, args.get_usize("gen", 32)),
        seed: args.get_u64("seed", 0),
        vocab: m.vocab,
        ..Default::default()
    });
    println!(
        "serving {} requests against {} ({} params) ...",
        wl.len(),
        m.name,
        server.engine.manifest().n_params
    );
    let outcomes = server.run(&wl)?;
    for o in &outcomes {
        println!(
            "  req {:2} prompt {:4} -> {:3} tokens, host ttft {:7.1} ms, tpot {:6.1} ms",
            o.outcome.id,
            o.outcome.prompt_len,
            o.outcome.generated.len(),
            o.outcome.ttft * 1e3,
            o.outcome.mean_tpot * 1e3
        );
    }
    println!("\nhost (PJRT CPU) metrics:\n{}", server.metrics.report());
    println!(
        "\nsimulated KV260 (PD-Swap) metrics for the same traces:\n{}",
        server.sim_metrics.report()
    );
    Ok(())
}

/// Continuous event-driven serving with a swap-scheduling policy
/// (`--policy eager|hysteresis|lookahead`).
fn simulate_events(args: &Args, policy: SwapPolicy) -> Result<()> {
    let trace_out = args.get("trace-out");
    let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
    cfg.trace = trace_out.is_some();
    if args.flag("no-overlap") {
        cfg.overlap = false;
    }
    cfg.max_residents = args.get_usize("max-residents", cfg.max_residents);
    cfg.decode_batch = args.get_usize("decode-batch", cfg.decode_batch);
    if cfg.decode_batch == 0 {
        bail!("--decode-batch must be >= 1 (1 = the paper's single-stream decode)");
    }
    if args.flag("no-fast-forward") {
        cfg.fast_forward = false;
    }
    if args.flag("no-layer-events") {
        cfg.prefill_layer_events = false;
    }
    if args.get("log-tail").is_some() {
        cfg.log_tail = Some(args.get_usize("log-tail", 0).max(1));
    }
    let pool = cfg.pool.clone();
    let pool = pool.with_total_pages(args.get_usize("pool-pages", pool.total_pages));
    let admission = if args.flag("optimistic") {
        AdmissionControl::Optimistic
    } else {
        AdmissionControl::WorstCase
    };
    let eviction = if args.flag("evict") {
        EvictionPolicy::EvictAndRecompute
    } else {
        EvictionPolicy::KeepResident
    };
    cfg.pool = pool.with_policies(admission, eviction);

    let n = args.get_usize("requests", 16);
    let seed = args.get_u64("seed", 0);
    let rate = args.get_f64("rate", 0.05);
    let trace_name = args.get_or("trace", "interactive");
    let spec = match trace_name {
        "interactive" => TraceSpec::interactive(n, rate, seed),
        "mixed" => TraceSpec::mixed_long_context(
            n,
            rate,
            args.get_usize("long-ctx", BITNET_0_73B.max_seq),
            seed,
        ),
        "bursty" => TraceSpec::bursty(n, seed),
        "long" => TraceSpec::long_decode(n, seed),
        "million" => TraceSpec::million(n, seed),
        other => bail!("unknown trace '{other}' (try interactive|mixed|bursty|long|million)"),
    };
    // Fault injection (docs/ARCHITECTURE.md extension #10): realize a
    // named preset for --fault-seed and the trace family. 'none' keeps
    // the plan inert — bitwise-identical to the pre-fault engine.
    let fault_name = args.get_or("faults", "none");
    let fault_spec = FaultSpec::from_name(fault_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown --faults '{fault_name}' (try none|swap-storm|ddr-brownout|deadlines|chaos)"
        )
    })?;
    let fault_seed = args.get_u64("fault-seed", 1);
    cfg.faults = FaultPlan::from_spec(fault_spec, fault_seed, trace_name);
    if args.flag("fail-stop") {
        cfg.retry = SwapRetryPolicy::fail_stop();
    }
    let mut server = EventServer::new(cfg.clone())?;
    if args.flag("streamed") {
        // Lazy arrivals, bounded queue window: bit-identical to the
        // materialized path (pinned by prop_streamed_matches_materialized)
        // at O(window + residents) memory instead of O(total requests).
        let window = args.get_usize("window", 1024).max(1);
        println!(
            "simulating {} requests on the event-driven core (streamed, window {window}): {} trace (seed {seed}), {} policy, decode batch {}",
            spec.n_requests,
            trace_name,
            policy.name(),
            cfg.decode_batch,
        );
        print_fault_header(&cfg.faults, fault_name, fault_seed, &cfg.retry);
        server.run_streamed(requests_from_stream(spec.stream()), window)?;
    } else {
        let entries = spec.generate();
        println!(
            "simulating {} requests on the event-driven core: {} trace (seed {seed}, {:.1} offered tok/s), {} policy, decode batch {}",
            entries.len(),
            trace_name,
            TraceSpec::offered_tokens_per_sec(&entries),
            policy.name(),
            cfg.decode_batch,
        );
        print_fault_header(&cfg.faults, fault_name, fault_seed, &cfg.retry);
        server.run(requests_from_trace(&entries))?;
    }
    println!("{}", server.metrics.report());
    println!(
        "makespan {:.1} s -> {:.2} tok/s end-to-end, decode throughput {:.2} tok/s (wall TPOT)",
        server.clock(),
        server.metrics.tokens_generated.get() as f64 / server.clock().max(1e-9),
        server.metrics.decode_throughput(),
    );
    if cfg.faults.is_active() {
        println!(
            "SLO attainment {:.1}% ({} shed) -> goodput {:.2} tok/s over the makespan",
            100.0 * server.metrics.slo_attainment(),
            server.metrics.requests_shed.get(),
            server.metrics.slo_goodput_tps(server.clock()),
        );
    }
    // Event-count reduction from the analytic decode fast-forward
    // (bit-identical clocks/metrics either way; compare with
    // --no-fast-forward).
    let processed = server.events_processed();
    let ff = server.fast_forward_stats();
    let stepped_equiv = ff.stepped_equivalent(processed);
    println!(
        "events processed {processed} (stepped-equivalent {stepped_equiv}): \
         {} fast-forward folds skipped {} token-step events ({:.1}x fewer events)",
        ff.folds,
        ff.steps,
        stepped_equiv as f64 / processed.max(1) as f64,
    );
    if ff.absorbed_arrivals > 0 {
        println!(
            "  {} dormant arrivals absorbed mid-fold (handled without breaking a fold)",
            ff.absorbed_arrivals
        );
    }
    if server.outcomes.dropped() > 0 {
        println!(
            "outcome records: first {} retained verbatim, {} beyond the cap folded into the aggregate histograms",
            server.outcomes.len(),
            server.outcomes.dropped()
        );
    }
    if let Some(path) = trace_out {
        server.recorder.write(path)?;
        println!(
            "\nper-request TTFT/TPOT breakdown:\n{}",
            server.recorder.breakdown_table()
        );
        println!(
            "wrote Chrome trace ({} events, {} policy decisions) to {path} — load in Perfetto (ui.perfetto.dev) or chrome://tracing",
            server.recorder.len(),
            server.recorder.decision_count()
        );
    }
    if args.flag("log") {
        let log = server.event_log();
        let dropped = server.event_log_dropped();
        match (dropped, cfg.log_tail) {
            (0, _) => println!("\nevent timeline ({} records):", log.len()),
            (d, Some(_)) => println!(
                "\nevent timeline (last {} records; {d} earlier dropped by the ring):",
                log.len()
            ),
            (d, None) => println!(
                "\nevent timeline (first {} records; {d} later dropped — use --log-tail N for the tail):",
                log.len()
            ),
        }
        for r in log {
            println!("  {:>12.6}s  {:<18} #{}", r.at, r.kind, r.subject);
        }
    }
    Ok(())
}

/// One-line fault-plan banner under the run header (silent when inert),
/// so a faulted run's provenance — preset, seed, retry policy — is in
/// the captured output next to the trace seed.
fn print_fault_header(
    faults: &FaultPlan,
    name: &str,
    fault_seed: u64,
    retry: &SwapRetryPolicy,
) {
    if !faults.is_active() {
        return;
    }
    let deadlines = match faults.deadlines() {
        Some(d) => format!("ttft {:.0} s / e2e {:.0} s", d.ttft_s, d.e2e_s),
        None => "none".to_string(),
    };
    println!(
        "fault injection: preset '{name}' (fault seed {fault_seed}) — swap-fail prob {:.2}, {} DDR brownout window(s), deadlines {}, {}",
        faults.swap_fail_prob(),
        faults.windows().len(),
        deadlines,
        if retry.fail_stop {
            "fail-stop (no degraded fallback)".to_string()
        } else {
            format!("retry x{} then degraded fallback", retry.max_attempts)
        },
    );
}

fn simulate(args: &Args) -> Result<()> {
    let policy_name = args.get_or("policy", "per-request");
    if let Some(policy) = SwapPolicy::from_name(policy_name) {
        return simulate_events(args, policy);
    }
    if !matches!(policy_name, "per-request" | "batched") {
        bail!(
            "unknown --policy '{policy_name}' \
             (try per-request|batched for the phase-batch engine, \
             eager|hysteresis|lookahead for the event-driven core)"
        );
    }
    let trace_out = args.get("trace-out");
    let mut cfg = if args.flag("static") {
        SimServerConfig::tellme_static(BITNET_0_73B, KV260.clone())
    } else {
        SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())
    };
    cfg.trace = trace_out.is_some();
    if args.get_or("policy", "per-request") == "batched" {
        cfg.policy = Policy::BatchedPhases { max_batch: args.get_usize("max-batch", 8) };
    }
    if args.flag("no-overlap") {
        cfg.overlap = false;
    }
    cfg.decode_batch = args.get_usize("decode-batch", cfg.decode_batch);
    if cfg.decode_batch == 0 {
        bail!("--decode-batch must be >= 1 (1 = the paper's one-stream-at-a-time rounds)");
    }
    // KV-pool knobs: size override + admission/eviction policy selection.
    let pool: KvPoolConfig = cfg.pool.clone();
    let pool_pages = args.get_usize("pool-pages", pool.total_pages);
    let pool = pool.with_total_pages(pool_pages);
    let admission = if args.flag("optimistic") {
        AdmissionControl::Optimistic
    } else {
        AdmissionControl::WorstCase
    };
    let eviction = if args.flag("evict") {
        EvictionPolicy::EvictAndRecompute
    } else {
        EvictionPolicy::KeepResident
    };
    cfg.pool = pool.with_policies(admission, eviction);

    let n_requests = args.get_usize("requests", 16);
    let wl_seed = args.get_u64("seed", 0);
    let wl = generate_workload(&WorkloadConfig {
        n_requests,
        seed: wl_seed,
        ..Default::default()
    });
    let mut server = SimServer::new(cfg)?;
    println!(
        "simulating {n_requests} requests on the phase-batch engine ({}), workload seed {wl_seed}",
        if args.flag("static") { "TeLLMe static" } else { "PD-Swap" },
    );
    server.run(wl)?;
    println!(
        "simulated KV260 serving metrics ({}):\n{}",
        if args.flag("static") { "TeLLMe static" } else { "PD-Swap" },
        server.metrics.report()
    );
    let pool = server.pool();
    println!(
        "kv pool: {} pages total ({:.2} GB budget), high-water {} ({:.0}%), admitted {}, evicted {}, completed {}",
        pool.total_pages(),
        pool.config().budget_bytes() / 1e9,
        pool.stats.high_water_pages,
        100.0 * pool.stats.high_water_pages as f64 / pool.total_pages().max(1) as f64,
        pool.stats.admitted,
        pool.stats.evicted,
        pool.stats.completed,
    );
    if let Some(path) = trace_out {
        server.recorder.write(path)?;
        println!(
            "\nper-request TTFT/TPOT breakdown:\n{}",
            server.recorder.breakdown_table()
        );
        println!(
            "wrote Chrome trace ({} events) to {path} — load in Perfetto (ui.perfetto.dev) or chrome://tracing",
            server.recorder.len()
        );
    }
    Ok(())
}

/// `pd-swap fuzz` — seeded differential fuzzing over the engine pairs,
/// or `--replay FILE` to re-run one serialized fixture.
fn run_fuzz_cmd(args: &Args) -> Result<()> {
    if let Some(path) = args.get("replay") {
        let path = std::path::Path::new(path);
        let (fx, diverged) =
            replay_file(path, OracleOptions::default()).map_err(|e| anyhow::anyhow!(e))?;
        println!("replaying fixture {}", path.display());
        println!(
            "  provenance: seed {:#018x}, case {} (case seed {:#018x})",
            fx.master_seed, fx.case_index, fx.case_seed
        );
        println!("  case: {:?}", fx.case);
        if let Some(d) = &fx.divergence {
            println!(
                "  recorded divergence: {} (fingerprint line {}): {}",
                d.pair, d.fingerprint_line, d.detail
            );
        }
        return match diverged {
            None => {
                println!("  verdict: clean — the fixture no longer diverges");
                Ok(())
            }
            Some(d) => bail!(
                "fixture still diverges: {} (fingerprint line {}): {}",
                d.pair,
                d.line,
                d.detail
            ),
        };
    }
    let seed_str = args.get_or("seed", "0x5EED");
    let seed = if seed_str.starts_with("0x") || seed_str.starts_with("0X") {
        parse_hex_seed(seed_str).map_err(|e| anyhow::anyhow!(e))?
    } else {
        seed_str.parse::<u64>().map_err(|_| {
            anyhow::anyhow!("--seed expects a u64 (decimal or 0x-hex), got '{seed_str}'")
        })?
    };
    let cfg = FuzzConfig {
        cases: args.get_usize("cases", 64),
        seed,
        max_requests: args.get_usize("max-requests", 10),
        out_dir: Some(std::path::PathBuf::from(args.get_or("out", "fuzz-failures"))),
    };
    let summary = run_fuzz(&cfg, OracleOptions::default()).map_err(|e| anyhow::anyhow!(e))?;
    print!("{}", summary.report);
    if summary.divergences > 0 {
        bail!(
            "fuzz found a divergence (fixture: {})",
            summary
                .fixture_path
                .as_deref()
                .map(|p| p.display().to_string())
                .unwrap_or_else(|| "not written".into())
        );
    }
    Ok(())
}
