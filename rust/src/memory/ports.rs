//! HP-port arbitration and effective-bandwidth computation.

use std::collections::BTreeMap;

use crate::fpga::DeviceConfig;

/// Logical memory streams an engine issues.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stream {
    /// Query activations (tiny in decode: one token).
    Q,
    /// Key cache reads.
    K,
    /// Value cache reads.
    V,
    /// Output token writes.
    O,
    /// Model weight streaming (TLMM weight reload between layers).
    Weights,
    /// Intermediate activations (prefill tile spill/fill).
    Activations,
}

impl Stream {
    pub const ALL: [Stream; 6] =
        [Stream::Q, Stream::K, Stream::V, Stream::O, Stream::Weights, Stream::Activations];
}

/// AXI burst efficiency: fraction of a port's theoretical peak that a
/// stream with a given burst length actually sustains. Long sequential
/// bursts (KV cache, weights) run near peak; short scattered beats
/// (single-token Q/O) are dominated by protocol overhead.
#[derive(Debug, Clone, Copy)]
pub struct AxiBurst {
    pub beats: usize,
}

impl AxiBurst {
    pub fn efficiency(&self) -> f64 {
        // Saturating curve: eff = beats / (beats + overhead_beats).
        // 16-beat bursts reach ~0.67, 64-beat ~0.89, 256-beat ~0.97.
        let overhead = 8.0;
        let b = self.beats.max(1) as f64;
        b / (b + overhead)
    }
}

/// One HP port with its tenant streams.
#[derive(Debug, Clone, Default)]
pub struct HpPort {
    pub tenants: Vec<Stream>,
}

/// Assignment of streams to the device's HP ports.
///
/// A stream may appear on several ports (striped: bandwidth adds up); a
/// port may host several streams (shared: they serialize on that port).
#[derive(Debug, Clone)]
pub struct PortMapping {
    pub name: String,
    pub ports: Vec<HpPort>,
}

impl PortMapping {
    /// The static / prefill baseline of [10]: one port per tensor class.
    /// Q and O share port 0 (both single-token in decode), K on 1, V on 2,
    /// weights+activations on 3.
    pub fn qkvo_baseline(n_ports: usize) -> Self {
        assert!(n_ports >= 4);
        let mut ports = vec![HpPort::default(); n_ports];
        ports[0].tenants = vec![Stream::Q, Stream::O];
        ports[1].tenants = vec![Stream::K];
        ports[2].tenants = vec![Stream::V];
        ports[3].tenants = vec![Stream::Weights, Stream::Activations];
        Self { name: "qkvo-baseline".into(), ports }
    }

    /// The paper's decode mapping (§3.2.3): two ports stream K, two stream
    /// V. Q is pre-staged through a briefly-borrowed port before the KV
    /// burst begins and O is written back after it ends, so neither
    /// contends with the KV streams; weights ride the same ports *between*
    /// attention bursts (the controller time-multiplexes phases).
    pub fn decode_kv_optimized(n_ports: usize) -> Self {
        assert!(n_ports >= 4);
        let mut ports = vec![HpPort::default(); n_ports];
        ports[0].tenants = vec![Stream::K];
        ports[1].tenants = vec![Stream::K];
        ports[2].tenants = vec![Stream::V];
        ports[3].tenants = vec![Stream::V];
        Self { name: "decode-2k2v".into(), ports }
    }

    /// Projection sub-phase mapping: the packed-weight stream is striped
    /// across every HP port. Legal because the pipeline time-multiplexes
    /// sub-phases — attention's KV ports are idle while the TLMM engine
    /// drains its weight FIFOs, and vice versa.
    pub fn weights_striped(n_ports: usize) -> Self {
        let ports = (0..n_ports)
            .map(|_| HpPort { tenants: vec![Stream::Weights] })
            .collect();
        Self { name: "weights-striped".into(), ports }
    }

    /// Ports hosting `s`.
    pub fn ports_for(&self, s: Stream) -> usize {
        self.ports.iter().filter(|p| p.tenants.contains(&s)).count()
    }
}

/// A demand: bytes per stream with that stream's burst shape.
#[derive(Debug, Clone, Copy)]
pub struct PortAssignment {
    pub stream: Stream,
    pub bytes: f64,
    pub burst: AxiBurst,
}

/// The DDR subsystem: evaluates transfer times under a mapping.
#[derive(Debug, Clone)]
pub struct MemorySystem {
    pub n_ports: usize,
    pub port_peak: f64,
    pub aggregate_peak: f64,
}

impl MemorySystem {
    pub fn for_device(d: &DeviceConfig) -> Self {
        Self {
            n_ports: d.n_hp_ports,
            port_peak: d.hp_port_peak,
            aggregate_peak: d.ddr_aggregate_peak,
        }
    }

    /// Effective bandwidth a single stream sees under `mapping`:
    /// striped ports add up, co-tenants on each port steal a fair share,
    /// and the DDR controller caps the total.
    pub fn effective_bandwidth(&self, mapping: &PortMapping, s: Stream, burst: AxiBurst) -> f64 {
        let mut bw = 0.0;
        for port in &mapping.ports {
            if port.tenants.contains(&s) {
                let share = 1.0 / port.tenants.len() as f64;
                bw += self.port_peak * share * burst.efficiency();
            }
        }
        bw.min(self.aggregate_peak)
    }

    /// Time to move a set of concurrent stream demands under `mapping`.
    ///
    /// Per-port: tenants serialize (sum of their byte-times at that port's
    /// share). Across ports: parallel (max). Then the aggregate-bandwidth
    /// cap is applied: total bytes cannot move faster than the controller
    /// allows.
    pub fn transfer_time(&self, mapping: &PortMapping, demands: &[PortAssignment]) -> f64 {
        let mut per_stream_bytes: BTreeMap<Stream, (f64, AxiBurst)> = BTreeMap::new();
        for d in demands {
            let e = per_stream_bytes
                .entry(d.stream)
                .or_insert((0.0, d.burst));
            e.0 += d.bytes;
        }

        // Split each stream's bytes evenly over its ports; compute each
        // port's busy time as the sum of its tenants' shares.
        let mut port_busy = vec![0.0f64; mapping.ports.len()];
        let mut total_bytes = 0.0;
        for (&s, &(bytes, burst)) in &per_stream_bytes {
            total_bytes += bytes;
            let n = mapping.ports_for(s);
            if n == 0 {
                // Unmapped stream: serialized through a borrowed port at
                // baseline efficiency (the paper's Q pre-stage does this).
                port_busy
                    .iter_mut()
                    .take(1)
                    .for_each(|t| *t += bytes / (self.port_peak * burst.efficiency()));
                continue;
            }
            let per_port = bytes / n as f64;
            for (i, port) in mapping.ports.iter().enumerate() {
                if port.tenants.contains(&s) {
                    let share = 1.0 / port.tenants.len() as f64;
                    port_busy[i] +=
                        per_port / (self.port_peak * share * burst.efficiency());
                }
            }
        }

        let parallel_time = port_busy.iter().cloned().fold(0.0, f64::max);
        let aggregate_floor = total_bytes / self.aggregate_peak;
        parallel_time.max(aggregate_floor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;

    fn mem() -> MemorySystem {
        MemorySystem::for_device(&KV260)
    }

    const LONG: AxiBurst = AxiBurst { beats: 64 };
    const SHORT: AxiBurst = AxiBurst { beats: 4 };

    #[test]
    fn burst_efficiency_monotone() {
        assert!(SHORT.efficiency() < LONG.efficiency());
        assert!(LONG.efficiency() < AxiBurst { beats: 1024 }.efficiency());
        assert!(AxiBurst { beats: 1024 }.efficiency() < 1.0);
    }

    #[test]
    fn kv_remap_doubles_kv_bandwidth() {
        // The §3.2.3 claim: 2K+2V vs 1K+1V gives ~2x effective decode BW.
        let m = mem();
        let base = PortMapping::qkvo_baseline(4);
        let opt = PortMapping::decode_kv_optimized(4);
        let bw_base = m.effective_bandwidth(&base, Stream::K, LONG)
            + m.effective_bandwidth(&base, Stream::V, LONG);
        let bw_opt = m.effective_bandwidth(&opt, Stream::K, LONG)
            + m.effective_bandwidth(&opt, Stream::V, LONG);
        let ratio = bw_opt / bw_base;
        assert!((1.9..=2.1).contains(&ratio), "ratio {ratio:.2}");
    }

    #[test]
    fn transfer_respects_aggregate_cap() {
        let m = mem();
        let opt = PortMapping::decode_kv_optimized(4);
        // Enormous demand on all four ports cannot beat the controller cap.
        let bytes = 1e9;
        let t = m.transfer_time(
            &opt,
            &[
                PortAssignment { stream: Stream::K, bytes, burst: AxiBurst { beats: 4096 } },
                PortAssignment { stream: Stream::V, bytes, burst: AxiBurst { beats: 4096 } },
            ],
        );
        assert!(t >= 2.0 * bytes / m.aggregate_peak - 1e-12);
    }

    #[test]
    fn co_tenants_serialize() {
        let m = mem();
        let base = PortMapping::qkvo_baseline(4);
        // Weights and activations share port 3: their times add.
        let t_w = m.transfer_time(
            &base,
            &[PortAssignment { stream: Stream::Weights, bytes: 1e6, burst: LONG }],
        );
        let t_both = m.transfer_time(
            &base,
            &[
                PortAssignment { stream: Stream::Weights, bytes: 1e6, burst: LONG },
                PortAssignment { stream: Stream::Activations, bytes: 1e6, burst: LONG },
            ],
        );
        assert!(t_both > 1.9 * t_w, "t_w={t_w} t_both={t_both}");
    }

    #[test]
    fn striping_scales_down_time() {
        let m = mem();
        let base = PortMapping::qkvo_baseline(4);
        let opt = PortMapping::decode_kv_optimized(4);
        let demand = [
            PortAssignment { stream: Stream::K, bytes: 8e6, burst: LONG },
            PortAssignment { stream: Stream::V, bytes: 8e6, burst: LONG },
        ];
        let t_base = m.transfer_time(&base, &demand);
        let t_opt = m.transfer_time(&opt, &demand);
        let speedup = t_base / t_opt;
        assert!((1.8..=2.2).contains(&speedup), "speedup {speedup:.2}");
    }

    #[test]
    fn unmapped_stream_borrows_a_port() {
        let m = mem();
        let opt = PortMapping::decode_kv_optimized(4);
        // Q is unmapped in the decode mapping; it must still make progress.
        let t = m.transfer_time(
            &opt,
            &[PortAssignment { stream: Stream::Q, bytes: 1e4, burst: SHORT }],
        );
        assert!(t > 0.0 && t.is_finite());
    }
}
