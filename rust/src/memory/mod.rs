//! DDR + HP-port bandwidth model (the §3.2.3 memory-interface substrate).
//!
//! The KV260's PL reaches DDR4 through four High-Performance (HP) AXI
//! ports. Each port sustains a fraction of its theoretical peak that
//! depends on burst length (short stride-y bursts waste controller cycles);
//! all ports together are capped by the single DDR controller. The
//! paper's decode optimization is purely a *mapping* change: instead of
//! dedicating ports to Q / K / V / O as in the static baseline, the decode
//! RM maps **two ports to K and two to V**, pre-stages the single Q token
//! into on-chip buffers, and holds the output token locally until the KV
//! streams finish — roughly doubling effective KV bandwidth.
//!
//! [`PortMapping`] expresses such assignments, [`MemorySystem::transfer_time`]
//! evaluates them with per-port serialization + aggregate capping, and the
//! unit tests pin the ~2x claim.

pub mod ports;
pub mod traffic;

pub use ports::{AxiBurst, HpPort, MemorySystem, PortAssignment, PortMapping, Stream};
pub use traffic::{paged_kv_burst, PhaseTraffic, TrafficModel};
