//! Phase traffic: turns [`crate::model::workload`] byte counts into
//! per-stream DDR demands evaluated under a [`super::PortMapping`].

use crate::model::{ComponentOps, ModelShape, PhaseWork, PrefillWork};

use super::ports::{AxiBurst, MemorySystem, PortAssignment, PortMapping, Stream};

/// Burst shapes per stream class: KV and weights are long sequential
/// bursts; single-token Q/O are short.
pub fn burst_for(s: Stream) -> AxiBurst {
    match s {
        Stream::K | Stream::V | Stream::Weights => AxiBurst { beats: 64 },
        Stream::Activations => AxiBurst { beats: 16 },
        Stream::Q | Stream::O => AxiBurst { beats: 4 },
    }
}

/// Burst shape of a *paged* KV stream ([`crate::kvpool`]): each page is a
/// contiguous run of `page_tokens · head_dim · precision` bytes per head,
/// but consecutive pages land at arbitrary DDR addresses, so the AXI
/// burst cannot exceed one page-row. Pages of ≥ 8 tokens (head_dim 64,
/// fp16) already reach the 64-beat knee the monolithic model assumes —
/// paging only costs efficiency when pages are made very small.
pub fn paged_kv_burst(shape: &ModelShape, page_tokens: usize) -> AxiBurst {
    // 128-bit HP port: 16 bytes per beat.
    let beat_bytes = 16.0;
    let run_bytes =
        page_tokens.max(1) as f64 * shape.head_dim() as f64 * shape.kv_precision.bytes();
    let beats = (run_bytes / beat_bytes).floor().clamp(1.0, 64.0) as usize;
    AxiBurst { beats }
}

/// DDR demand of one phase, broken down by stream.
#[derive(Debug, Clone)]
pub struct PhaseTraffic {
    pub demands: Vec<PortAssignment>,
}

impl PhaseTraffic {
    /// Decode-step attention traffic: the full KV cache split across the
    /// K and V streams, one token of Q in, one token of O out.
    pub fn decode_attention(shape: &ModelShape, l: usize) -> Self {
        Self::decode_attention_with_burst(shape, l, burst_for(Stream::K))
    }

    /// Decode-step attention traffic against a *paged* KV cache: same
    /// bytes as [`Self::decode_attention`], but each K/V read bursts at
    /// most one page-row before re-addressing.
    pub fn decode_attention_paged(shape: &ModelShape, l: usize, page_tokens: usize) -> Self {
        Self::decode_attention_with_burst(shape, l, paged_kv_burst(shape, page_tokens))
    }

    fn decode_attention_with_burst(shape: &ModelShape, l: usize, kv_burst: AxiBurst) -> Self {
        let kv_total = shape.kv_bytes(l);
        let tok = shape.d_model as f64 * shape.kv_precision.bytes();
        Self {
            demands: vec![
                PortAssignment { stream: Stream::K, bytes: kv_total / 2.0, burst: kv_burst },
                PortAssignment { stream: Stream::V, bytes: kv_total / 2.0, burst: kv_burst },
                PortAssignment { stream: Stream::Q, bytes: tok, burst: burst_for(Stream::Q) },
                PortAssignment { stream: Stream::O, bytes: tok, burst: burst_for(Stream::O) },
            ],
        }
    }

    /// Decode-step projection traffic: the packed ternary weights stream.
    pub fn decode_projection(shape: &ModelShape) -> Self {
        Self {
            demands: vec![PortAssignment {
                stream: Stream::Weights,
                bytes: shape.ternary_weight_bytes(),
                burst: burst_for(Stream::Weights),
            }],
        }
    }

    /// Prefill traffic (per full prompt): weights once + QKV/activations.
    pub fn prefill(shape: &ModelShape, l: usize) -> Self {
        let work = PrefillWork { shape: *shape, l };
        let attn: ComponentOps = work.attention();
        let proj: ComponentOps = work.projection();
        Self {
            demands: vec![
                PortAssignment {
                    stream: Stream::Weights,
                    bytes: shape.ternary_weight_bytes(),
                    burst: burst_for(Stream::Weights),
                },
                PortAssignment {
                    stream: Stream::Activations,
                    bytes: proj.read_bytes - shape.ternary_weight_bytes() + proj.write_bytes,
                    burst: burst_for(Stream::Activations),
                },
                PortAssignment {
                    stream: Stream::K,
                    bytes: attn.read_bytes / 2.0 + attn.write_bytes / 2.0,
                    burst: burst_for(Stream::K),
                },
                PortAssignment {
                    stream: Stream::V,
                    bytes: attn.read_bytes / 2.0 + attn.write_bytes / 2.0,
                    burst: burst_for(Stream::V),
                },
            ],
        }
    }

    pub fn total_bytes(&self) -> f64 {
        self.demands.iter().map(|d| d.bytes).sum()
    }

    /// Evaluate under a mapping.
    pub fn time_under(&self, mem: &MemorySystem, mapping: &PortMapping) -> f64 {
        mem.transfer_time(mapping, &self.demands)
    }
}

/// Convenience bundle: memory system + both mappings, asking the question
/// the paper's §3.2.3 answers.
#[derive(Debug, Clone)]
pub struct TrafficModel {
    pub mem: MemorySystem,
    pub baseline: PortMapping,
    pub optimized: PortMapping,
}

impl TrafficModel {
    pub fn new(mem: MemorySystem) -> Self {
        let n = mem.n_ports;
        Self {
            mem,
            baseline: PortMapping::qkvo_baseline(n),
            optimized: PortMapping::decode_kv_optimized(n),
        }
    }

    /// Effective KV bandwidth under each mapping (B/s).
    pub fn kv_bandwidth(&self, optimized: bool) -> f64 {
        let mapping = if optimized { &self.optimized } else { &self.baseline };
        self.mem.effective_bandwidth(mapping, Stream::K, burst_for(Stream::K))
            + self.mem.effective_bandwidth(mapping, Stream::V, burst_for(Stream::V))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn tm() -> TrafficModel {
        TrafficModel::new(MemorySystem::for_device(&KV260))
    }

    #[test]
    fn decode_attention_time_improves_with_remap() {
        let t = tm();
        let traffic = PhaseTraffic::decode_attention(&BITNET_0_73B, 2048);
        let t_base = traffic.time_under(&t.mem, &t.baseline);
        let t_opt = traffic.time_under(&t.mem, &t.optimized);
        let speedup = t_base / t_opt;
        assert!(
            (1.7..=2.2).contains(&speedup),
            "KV remap speedup {speedup:.2} (base {:.3} ms, opt {:.3} ms)",
            t_base * 1e3,
            t_opt * 1e3
        );
    }

    #[test]
    fn decode_kv_time_scales_with_context() {
        let t = tm();
        let t1 = PhaseTraffic::decode_attention(&BITNET_0_73B, 512)
            .time_under(&t.mem, &t.optimized);
        let t2 = PhaseTraffic::decode_attention(&BITNET_0_73B, 1024)
            .time_under(&t.mem, &t.optimized);
        let r = t2 / t1;
        assert!((1.8..=2.2).contains(&r), "ratio {r:.2}");
    }

    #[test]
    fn weights_stream_dominates_short_context_decode() {
        // At short contexts T_weights >> KV time: decode is projection
        // bound, which is why Fig. 6a starts near-flat.
        let t = tm();
        let w = PhaseTraffic::decode_projection(&BITNET_0_73B)
            .time_under(&t.mem, &t.baseline);
        let kv = PhaseTraffic::decode_attention(&BITNET_0_73B, 64)
            .time_under(&t.mem, &t.optimized);
        assert!(w > 3.0 * kv, "weights {:.3} ms kv {:.3} ms", w * 1e3, kv * 1e3);
    }

    #[test]
    fn paged_burst_saturates_at_monolithic() {
        // ≥ 8-token pages (head_dim 64, fp16) reach the 64-beat cap: the
        // default 32-token page pays no DDR efficiency for paging.
        let full = burst_for(Stream::K).efficiency();
        for pt in [8, 16, 32, 128] {
            let b = paged_kv_burst(&BITNET_0_73B, pt);
            assert_eq!(b.beats, 64, "page_tokens={pt}");
            assert_eq!(b.efficiency(), full);
        }
        // Tiny pages burst shorter and pay for it.
        let tiny = paged_kv_burst(&BITNET_0_73B, 1);
        assert!(tiny.beats < 64);
        assert!(tiny.efficiency() < full);
    }

    #[test]
    fn paged_decode_traffic_matches_monolithic_at_default_page() {
        let t = tm();
        let mono = PhaseTraffic::decode_attention(&BITNET_0_73B, 1024);
        let paged = PhaseTraffic::decode_attention_paged(&BITNET_0_73B, 1024, 32);
        assert_eq!(mono.total_bytes(), paged.total_bytes());
        let tm_ = mono.time_under(&t.mem, &t.optimized);
        let tp = paged.time_under(&t.mem, &t.optimized);
        assert!((tp / tm_ - 1.0).abs() < 1e-12, "paged {tp} vs mono {tm_}");
        // One-token pages are strictly slower.
        let t1 = PhaseTraffic::decode_attention_paged(&BITNET_0_73B, 1024, 1)
            .time_under(&t.mem, &t.optimized);
        assert!(t1 > tp);
    }

    #[test]
    fn kv_bandwidth_ratio_near_two() {
        let t = tm();
        let r = t.kv_bandwidth(true) / t.kv_bandwidth(false);
        assert!((1.9..=2.1).contains(&r), "ratio {r:.2}");
    }
}
