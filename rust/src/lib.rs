//! # PD-Swap
//!
//! Full-system reproduction of *"PD-Swap: Prefill-Decode Logic Swapping for
//! End-to-End LLM Inference on Edge FPGAs via Dynamic Partial
//! Reconfiguration"* (Zhang, Chen, Qiao, Huang — UC Irvine, 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** Pallas kernels (`python/compile/kernels/`) — TLMM ternary
//!   matmul, reverse-scheduled FlashAttention prefill, KV-streaming decode
//!   attention, fused RMSNorm+quant.
//! * **L2** JAX model (`python/compile/model.py`) — BitNet-style ternary
//!   transformer prefill/decode graphs, AOT-lowered to HLO text.
//! * **L3** this crate — loads the HLO artifacts via PJRT ([`runtime`]),
//!   simulates the KV260 FPGA substrate the paper deploys on ([`fpga`],
//!   [`memory`], [`engines`]), performs the paper's roofline-guided design
//!   space exploration ([`roofline`], [`dse`] — parallel, driven by the
//!   O(1) latency surfaces of [`engines::surface`], and joinable with the
//!   serving-policy space via `pd-swap codesign` / [`dse::codesign`]),
//!   manages the DDR KV-cache budget as a page-granular pool with
//!   admission control and eviction ([`kvpool`] — our multi-request
//!   extension), and orchestrates prefill→decode logic swapping with
//!   latency-overlapped dynamic partial reconfiguration ([`reconfig`],
//!   [`coordinator`]). Decode is modeled batch-1 (the paper's engine)
//!   *and* batch-B: multi-stream decode shares one pass over the packed
//!   weight stream per step
//!   ([`engines::PhaseModel::decode_step_batched`]), which the event
//!   server serves through one allocation-free unified scheduler
//!   ([`coordinator::EventServerConfig::decode_batch`]) and
//!   `pd-swap codesign --decode-batch` co-optimizes — alongside the
//!   KV-pool axis (`--admission/--eviction/--page-size`,
//!   [`dse::codesign::PoolVariant`]).
//!
//! The FPGA itself is simulated; the *functional* compute path is real —
//! tokens are produced by executing the AOT artifacts on the PJRT CPU
//! client. The PJRT path is gated behind the `pjrt` cargo feature
//! (default off) so the simulator, DSE, and eval layers build and test
//! without an XLA installation; see `third_party/xla-stub/` for how the
//! binding is satisfied when the feature is enabled without the real
//! library.
//!
//! **Where to start reading:** `docs/ARCHITECTURE.md` maps every paper
//! section/equation to the module implementing it and marks the labeled
//! beyond-paper extensions; the top-level `README.md` has the quickstart
//! and the bench/bless workflow.
//!
//! ## Quick start
//!
//! ```bash
//! cargo run --release -- eval fig6       # regenerate the paper's Fig. 6
//! cargo run --release -- simulate --policy hysteresis --trace mixed
//! cargo run --release -- codesign --decode-batch 1,4
//! make artifacts                         # AOT-compile the HLO artifacts (python)
//! cargo run --release --example quickstart
//! ```

pub mod baselines;
pub mod coordinator;
pub mod dse;
pub mod engines;
pub mod eval;
pub mod faults;
pub mod fpga;
pub mod fuzz;
pub mod kvpool;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod reconfig;
pub mod roofline;
pub mod runtime;
pub mod telemetry;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
