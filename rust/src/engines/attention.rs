//! Attention engine models: the two reconfigurable modules (Fig. 3b/3d)
//! plus their "crammed into a static design" variants for the baseline.

use crate::fpga::ResourceVec;
use crate::memory::{MemorySystem, PortMapping, Stream};
use crate::memory::traffic::burst_for;
use crate::model::ModelShape;

use super::calib;

/// How well the engine's dataflow fits the phase it's running.
///
/// A *tailored* engine exists only because DPR lets each phase get its own
/// logic; a *generic* engine is the compromise dataflow a static design
/// must ship (the paper's §2.1 complaint: "a single static architecture
/// that must compromise between them").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleQuality {
    Tailored,
    Generic,
}

impl ScheduleQuality {
    pub fn efficiency(&self) -> f64 {
        match self {
            ScheduleQuality::Tailored => calib::SCHED_EFF_TAILORED,
            ScheduleQuality::Generic => calib::SCHED_EFF_GENERIC,
        }
    }
}

/// Shared resource-cost shape for both attention engines, anchored to
/// Table 2: `lut = base + k·dsp`.
fn attn_resources(dsp: f64, lut_base: f64, lut_per_dsp: f64, ff_per_dsp: f64, bram: f64) -> ResourceVec {
    ResourceVec {
        lut: lut_base + lut_per_dsp * dsp,
        ff: 2_000.0 + ff_per_dsp * dsp,
        bram36: bram,
        // Stream-buffer URAM scales (coarsely) with engine width; the
        // paper-sized RMs use 8 each (Table 2).
        uram: (dsp / 40.0).clamp(2.0, 8.0).round(),
        dsp,
    }
}

/// Token-parallel blocked FlashAttention engine (prefill RM, Fig. 3b).
#[derive(Debug, Clone, Copy)]
pub struct PrefillAttentionEngine {
    /// DSP budget (MAC array + softmax pipeline).
    pub n_dsp: usize,
    pub schedule: ScheduleQuality,
}

impl PrefillAttentionEngine {
    /// The paper's prefill RM (Table 2: 28,400 LUT / 303 DSP / 140 BRAM).
    pub const PAPER: PrefillAttentionEngine =
        PrefillAttentionEngine { n_dsp: 303, schedule: ScheduleQuality::Tailored };

    /// Anchored to Table 2 row "Prefill Attention".
    pub fn resources(&self) -> ResourceVec {
        attn_resources(self.n_dsp as f64, 4_000.0, 80.5, 132.0, 81.0)
    }

    /// Sustained MAC rate (MACs/s) at `clock_hz`.
    pub fn mac_rate(&self, clock_hz: f64) -> f64 {
        let sched = match self.schedule {
            ScheduleQuality::Tailored => 1.0,
            ScheduleQuality::Generic => calib::PREFILL_GENERIC_EFF,
        };
        self.n_dsp as f64
            * calib::ATTN_MACS_PER_DSP_CYCLE
            * clock_hz
            * calib::PREFILL_ATTN_DERATE
            * sched
    }

    /// Prefill attention time for a prompt of `l` tokens: causal
    /// FlashAttention MACs over all layers. Compute-bound by construction
    /// (Fig. 4a places it far right of the ridge), so no memory term.
    pub fn time(&self, shape: &ModelShape, l: usize, clock_hz: f64) -> f64 {
        let l = l as f64;
        let macs = shape.n_layers as f64 * (l * l / 2.0) * shape.d_model as f64 * 2.0;
        macs / self.mac_rate(clock_hz)
    }
}

/// KV-cache-streaming single-query engine (decode RM, Fig. 3d).
#[derive(Debug, Clone, Copy)]
pub struct DecodeAttentionEngine {
    pub n_dsp: usize,
    pub schedule: ScheduleQuality,
    /// Uses the §3.2.3 2K+2V port remap (true for the dedicated RM; the
    /// static baseline keeps the QKVO mapping).
    pub kv_optimized_ports: bool,
}

impl DecodeAttentionEngine {
    /// The paper's decode RM (Table 2: 26,418 LUT / 278 DSP / 16 BRAM).
    pub const PAPER: DecodeAttentionEngine = DecodeAttentionEngine {
        n_dsp: 278,
        schedule: ScheduleQuality::Tailored,
        kv_optimized_ports: true,
    };

    /// Anchored to Table 2 row "Decoding Attention".
    pub fn resources(&self) -> ResourceVec {
        attn_resources(self.n_dsp as f64, 3_000.0, 84.2, 90.0, 16.0)
    }

    pub fn mac_rate(&self, clock_hz: f64) -> f64 {
        self.n_dsp as f64
            * calib::ATTN_MACS_PER_DSP_CYCLE
            * clock_hz
            * self.schedule.efficiency()
    }

    /// Effective K+V read bandwidth (B/s) under this engine's port plan.
    pub fn kv_bandwidth(&self, mem: &MemorySystem) -> f64 {
        self.kv_bandwidth_with_burst(mem, burst_for(Stream::K))
    }

    /// K+V bandwidth with an explicit burst shape — the paged KV pool
    /// passes [`crate::memory::paged_kv_burst`] here so small pages pay
    /// their shorter-burst DDR tax.
    pub fn kv_bandwidth_with_burst(&self, mem: &MemorySystem, burst: crate::memory::AxiBurst) -> f64 {
        let mapping = if self.kv_optimized_ports {
            PortMapping::decode_kv_optimized(mem.n_ports)
        } else {
            PortMapping::qkvo_baseline(mem.n_ports)
        };
        let bw = mem.effective_bandwidth(&mapping, Stream::K, burst)
            + mem.effective_bandwidth(&mapping, Stream::V, burst);
        bw * calib::KV_CONTROLLER_EFF
    }

    /// One decode step's attention time at context length `l`:
    /// `max(compute roof, memory roof)` — the roofline in code.
    pub fn time(&self, shape: &ModelShape, l: usize, mem: &MemorySystem, clock_hz: f64) -> f64 {
        self.time_with_burst(shape, l, mem, clock_hz, burst_for(Stream::K))
    }

    /// [`Self::time`] against a paged KV cache: identical bytes, but the
    /// K/V streams burst at most one page-row at a time. With the default
    /// 32-token page the burst saturates and this equals [`Self::time`].
    pub fn time_paged(
        &self,
        shape: &ModelShape,
        l: usize,
        mem: &MemorySystem,
        clock_hz: f64,
        page_tokens: usize,
    ) -> f64 {
        let burst = crate::memory::paged_kv_burst(shape, page_tokens);
        self.time_with_burst(shape, l, mem, clock_hz, burst)
    }

    fn time_with_burst(
        &self,
        shape: &ModelShape,
        l: usize,
        mem: &MemorySystem,
        clock_hz: f64,
        burst: crate::memory::AxiBurst,
    ) -> f64 {
        let macs = 2.0 * (l * shape.d_model) as f64 * shape.n_layers as f64;
        let compute = macs / self.mac_rate(clock_hz);
        let memory = shape.kv_bytes(l) / self.kv_bandwidth_with_burst(mem, burst);
        compute.max(memory)
    }

    /// Which roof binds at context `l`? (true = memory-bound, the regime
    /// the paper says decode attention "should ideally operate in".)
    pub fn is_memory_bound(&self, shape: &ModelShape, l: usize, mem: &MemorySystem, clock_hz: f64) -> bool {
        let macs = 2.0 * (l * shape.d_model) as f64 * shape.n_layers as f64;
        let compute = macs / self.mac_rate(clock_hz);
        let memory = shape.kv_bytes(l) / self.kv_bandwidth(mem);
        memory >= compute
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn mem() -> MemorySystem {
        MemorySystem::for_device(&KV260)
    }

    fn clock() -> f64 {
        KV260.clock_hz()
    }

    #[test]
    fn prefill_rm_resources_match_table2() {
        let r = PrefillAttentionEngine::PAPER.resources();
        assert!((r.lut - 28_400.0).abs() < 600.0, "lut {}", r.lut);
        assert_eq!(r.dsp, 303.0);
    }

    #[test]
    fn decode_rm_resources_match_table2() {
        let r = DecodeAttentionEngine::PAPER.resources();
        assert!((r.lut - 26_418.0).abs() < 600.0, "lut {}", r.lut);
        assert_eq!(r.dsp, 278.0);
    }

    #[test]
    fn prefill_attention_quadratic() {
        let e = PrefillAttentionEngine::PAPER;
        let t1 = e.time(&BITNET_0_73B, 512, clock());
        let t2 = e.time(&BITNET_0_73B, 1024, clock());
        assert!((t2 / t1 - 4.0).abs() < 0.01);
    }

    #[test]
    fn paper_prefill_attention_anchor() {
        // Fig. 6b decomposition: PD attention ~3.4 s of the 8.8 s TTFT at
        // L=768.
        let t = PrefillAttentionEngine::PAPER.time(&BITNET_0_73B, 768, clock());
        assert!((2.8..4.0).contains(&t), "t {t:.2} s");
    }

    #[test]
    fn dedicated_decode_rm_is_memory_bound() {
        // The whole point of the swap: with the full RP, decode attention
        // reaches the memory-bound regime at every context length.
        let e = DecodeAttentionEngine::PAPER;
        let m = mem();
        for l in [64, 256, 1024, 2048] {
            assert!(e.is_memory_bound(&BITNET_0_73B, l, &m, clock()), "L={l}");
        }
    }

    #[test]
    fn static_shared_decode_engine_is_compute_bound() {
        // A TeLLMe-like static design: small leftover engine, generic
        // schedule, QKVO ports -> compute-bound (paper §3.3.1: "static
        // designs lack the reusable resources to accelerate it").
        let e = DecodeAttentionEngine {
            n_dsp: 16,
            schedule: ScheduleQuality::Generic,
            kv_optimized_ports: false,
        };
        let m = mem();
        assert!(!e.is_memory_bound(&BITNET_0_73B, 1024, &m, clock()));
    }

    #[test]
    fn kv_port_remap_doubles_bandwidth() {
        let m = mem();
        let opt = DecodeAttentionEngine::PAPER;
        let base = DecodeAttentionEngine { kv_optimized_ports: false, ..opt };
        let r = opt.kv_bandwidth(&m) / base.kv_bandwidth(&m);
        assert!((1.9..2.1).contains(&r), "ratio {r:.2}");
    }

    #[test]
    fn paged_time_matches_monolithic_at_default_page() {
        let e = DecodeAttentionEngine::PAPER;
        let m = mem();
        for l in [64, 512, 2048] {
            let mono = e.time(&BITNET_0_73B, l, &m, clock());
            let paged = e.time_paged(&BITNET_0_73B, l, &m, clock(), 32);
            assert!((paged / mono - 1.0).abs() < 1e-12, "L={l}");
            // Single-token pages are never faster.
            let tiny = e.time_paged(&BITNET_0_73B, l, &m, clock(), 1);
            assert!(tiny >= mono, "L={l}");
        }
    }

    #[test]
    fn paper_decode_attention_anchor() {
        // PD decode attention ~0.032 ms per context token (the Fig. 6a
        // slope): at L=2048 that's ~65 ms.
        let e = DecodeAttentionEngine::PAPER;
        let t = e.time(&BITNET_0_73B, 2048, &mem(), clock());
        assert!((0.050..0.080).contains(&t), "t {:.1} ms", t * 1e3);
    }
}
