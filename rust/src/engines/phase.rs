//! Phase-level latency composition: Eqs. 3 and 5 evaluated over an
//! [`AcceleratorDesign`] — the model every figure harness queries.

use crate::fpga::DeviceConfig;
use crate::memory::MemorySystem;
use crate::model::ModelShape;

use super::design::AcceleratorDesign;

/// Breakdown of one prefill (Eq. 3).
#[derive(Debug, Clone, Copy)]
pub struct PrefillLatency {
    pub projection: f64,
    pub attention: f64,
    pub norm_elementwise: f64,
    pub weights: f64,
    pub total: f64,
}

/// Breakdown of one decode step at a given context length (Eq. 5).
#[derive(Debug, Clone, Copy)]
pub struct DecodeLatency {
    pub projection: f64,
    pub attention: f64,
    pub norm_elementwise: f64,
    pub total: f64,
}

impl DecodeLatency {
    pub fn tokens_per_sec(&self) -> f64 {
        1.0 / self.total
    }
}

/// Breakdown of one *batched* decode step: `batch` resident streams each
/// emit one token, sharing a single pass over the packed weight stream
/// (the projection term amortizes `T_weights`) while every stream pays
/// its own KV-cache attention traffic. Batch-1 is bit-identical to
/// [`DecodeLatency`] from [`PhaseModel::decode_step_paged`].
#[derive(Debug, Clone, Copy)]
pub struct BatchedDecodeLatency {
    /// Streams stepped together (tokens produced this step).
    pub batch: usize,
    /// Shared projection: `max(batch / tps, T_weights)` — one weight
    /// stream feeds every stream's GEMVs.
    pub projection: f64,
    /// Sum of the per-stream attention terms (each stream reads its own
    /// paged KV cache; the single decode engine serves them in turn).
    pub attention: f64,
    /// Element-wise epilogue for all `batch` tokens.
    pub norm_elementwise: f64,
    pub total: f64,
}

impl BatchedDecodeLatency {
    /// Wall time per generated token (the per-stream inter-token gap when
    /// every resident stream is in the batch).
    pub fn per_token(&self) -> f64 {
        if self.batch == 0 {
            0.0
        } else {
            self.total / self.batch as f64
        }
    }

    /// Aggregate tokens/s delivered by the step.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            self.batch as f64 / self.total
        }
    }
}

/// Evaluates a design's phase latencies on a device.
#[derive(Debug, Clone)]
pub struct PhaseModel {
    pub design: AcceleratorDesign,
    pub device: DeviceConfig,
    mem: MemorySystem,
}

impl PhaseModel {
    pub fn new(design: AcceleratorDesign, device: DeviceConfig) -> Self {
        let mem = MemorySystem::for_device(&device);
        Self { design, device, mem }
    }

    pub fn mem(&self) -> &MemorySystem {
        &self.mem
    }

    /// Eq. 3: `T_pre = P_proj·L / f(r_proj) + P_attn·L² / g(r_attn) + T_w`.
    ///
    /// The projection term already folds `T_weights` in (compute and the
    /// weight stream pipeline; the max binds), so `weights` is reported
    /// separately only for diagnostics.
    pub fn prefill(&self, shape: &ModelShape, l: usize) -> PrefillLatency {
        let clock = self.device.clock_hz();
        let projection = self.design.tlmm.projection_time(shape, l, &self.mem);
        let attention = self.design.prefill_attn.time(shape, l, clock);
        let norm = self.design.norm.time(shape, l, clock);
        let weights = self.design.tlmm.weight_stream_time(shape, &self.mem);
        PrefillLatency {
            projection,
            attention,
            norm_elementwise: norm,
            weights,
            total: projection + attention + norm,
        }
    }

    /// Eq. 5: `T_dec = D_proj / f(r_proj) + D_attn·L / g(r_attn) + T_w`.
    pub fn decode_step(&self, shape: &ModelShape, l: usize) -> DecodeLatency {
        let clock = self.device.clock_hz();
        let attention = self.design.decode_attn.time(shape, l, &self.mem, clock);
        self.decode_latency(shape, attention)
    }

    /// Eq. 5 against a paged KV cache ([`crate::kvpool`]): the attention
    /// memory roof is evaluated at the page's burst length. Identical to
    /// [`Self::decode_step`] for pages at or past the AXI burst knee.
    pub fn decode_step_paged(
        &self,
        shape: &ModelShape,
        l: usize,
        page_tokens: usize,
    ) -> DecodeLatency {
        let clock = self.device.clock_hz();
        let attention =
            self.design.decode_attn.time_paged(shape, l, &self.mem, clock, page_tokens);
        self.decode_latency(shape, attention)
    }

    /// Assemble Eq. 5 around a precomputed attention term.
    fn decode_latency(&self, shape: &ModelShape, attention: f64) -> DecodeLatency {
        let clock = self.device.clock_hz();
        let projection = self.design.tlmm.projection_time(shape, 1, &self.mem);
        let norm = self.design.norm.time(shape, 1, clock);
        DecodeLatency {
            projection,
            attention,
            norm_elementwise: norm,
            total: projection + attention + norm,
        }
    }

    /// One batched decode step over `ctxs` resident streams (one token
    /// each, stream *i* attending `ctxs[i]` cached tokens), monolithic
    /// KV bursts. The projection term is shared — the packed weight
    /// stream is read once for the whole batch, which is what makes
    /// multi-stream decode pay off on a bandwidth-bound engine — while
    /// attention and norm are per-stream. Batch-1 equals
    /// [`Self::decode_step`] bit for bit; an empty batch is all zeros.
    pub fn decode_step_batched(
        &self,
        shape: &ModelShape,
        ctxs: &[usize],
    ) -> BatchedDecodeLatency {
        let clock = self.device.clock_hz();
        let attention: f64 = ctxs
            .iter()
            .map(|&l| self.design.decode_attn.time(shape, l, &self.mem, clock))
            .sum();
        self.batched_decode_latency(shape, ctxs.len(), attention)
    }

    /// [`Self::decode_step_batched`] against a paged KV cache: every
    /// stream's attention memory roof is evaluated at the page's burst
    /// length. Batch-1 equals [`Self::decode_step_paged`] bit for bit.
    pub fn decode_step_batched_paged(
        &self,
        shape: &ModelShape,
        ctxs: &[usize],
        page_tokens: usize,
    ) -> BatchedDecodeLatency {
        let clock = self.device.clock_hz();
        let attention: f64 = ctxs
            .iter()
            .map(|&l| {
                self.design.decode_attn.time_paged(shape, l, &self.mem, clock, page_tokens)
            })
            .sum();
        self.batched_decode_latency(shape, ctxs.len(), attention)
    }

    /// Uniform-context batched step: `batch` streams all attending `l`
    /// cached tokens, paged KV. Bit-identical to
    /// [`Self::decode_step_batched_paged`] over `&[l; batch]` — the
    /// per-stream attention term is computed once and accumulated in the
    /// same left-to-right order the slice path's `sum()` uses — but takes
    /// no slice, so callers that only know a representative context (the
    /// swap-policy outlook) never materialize a `vec![l; batch]`.
    pub fn decode_step_uniform_paged(
        &self,
        shape: &ModelShape,
        l: usize,
        batch: usize,
        page_tokens: usize,
    ) -> BatchedDecodeLatency {
        let clock = self.device.clock_hz();
        let per_stream =
            self.design.decode_attn.time_paged(shape, l, &self.mem, clock, page_tokens);
        let mut attention = 0.0;
        for _ in 0..batch {
            attention += per_stream;
        }
        self.batched_decode_latency(shape, batch, attention)
    }

    /// Assemble the batched step around a precomputed attention sum.
    fn batched_decode_latency(
        &self,
        shape: &ModelShape,
        batch: usize,
        attention: f64,
    ) -> BatchedDecodeLatency {
        if batch == 0 {
            return BatchedDecodeLatency {
                batch: 0,
                projection: 0.0,
                attention: 0.0,
                norm_elementwise: 0.0,
                total: 0.0,
            };
        }
        let clock = self.device.clock_hz();
        let projection = self.design.tlmm.projection_time(shape, batch, &self.mem);
        let norm = self.design.norm.time(shape, batch, clock);
        BatchedDecodeLatency {
            batch,
            projection,
            attention,
            norm_elementwise: norm,
            total: projection + attention + norm,
        }
    }

    /// Decode throughput (tokens/s) at context length `l`.
    pub fn decode_throughput(&self, shape: &ModelShape, l: usize) -> f64 {
        self.decode_step(shape, l).tokens_per_sec()
    }

    /// Time to generate `n` tokens starting from context `l0` (the context
    /// grows as tokens are emitted — used by the end-to-end simulations).
    pub fn decode_span(&self, shape: &ModelShape, l0: usize, n: usize) -> f64 {
        (0..n)
            .map(|i| self.decode_step(shape, l0 + i).total)
            .sum()
    }

    /// The prefill *tail* after the final layer's attention completes: the
    /// last layer's output projection + FFN + norms. This is the window
    /// §3.4 overlaps reconfiguration with (~31 ms at L=128 in the paper).
    pub fn prefill_tail_after_last_attention(&self, shape: &ModelShape, l: usize) -> f64 {
        let pre = self.prefill(shape, l);
        // Per-layer share of projection + norm; the FFN block plus the
        // output projection is ~(3·d·dff + d²)/(4·d² + 3·d·dff) of a
        // layer's projection work.
        let proj_per_layer = pre.projection / shape.n_layers as f64;
        let norm_per_layer = pre.norm_elementwise / shape.n_layers as f64;
        let d = shape.d_model as f64;
        let dff = shape.d_ff as f64;
        let tail_frac = (3.0 * d * dff + d * d) / (4.0 * d * d + 3.0 * d * dff);
        proj_per_layer * tail_frac + norm_per_layer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn pd() -> PhaseModel {
        PhaseModel::new(AcceleratorDesign::pd_swap(), KV260.clone())
    }

    fn tellme() -> PhaseModel {
        PhaseModel::new(AcceleratorDesign::tellme_static(), KV260.clone())
    }

    #[test]
    fn paper_decode_endpoints() {
        let pd = pd();
        let te = tellme();
        let s = BITNET_0_73B;

        // PD-Swap @ 64: paper 27.8 tok/s.
        let pd64 = pd.decode_throughput(&s, 64);
        assert!((26.0..30.0).contains(&pd64), "PD@64 {pd64:.1}");
        // TeLLMe @ 64: paper 25 tok/s.
        let te64 = te.decode_throughput(&s, 64);
        assert!((23.0..27.0).contains(&te64), "TeLLMe@64 {te64:.1}");
        // PD-Swap @ 2048: paper ">10 tok/s".
        let pd2048 = pd.decode_throughput(&s, 2048);
        assert!(pd2048 > 9.5, "PD@2048 {pd2048:.1}");
        // TeLLMe @ 2048: paper "~5 tok/s".
        let te2048 = te.decode_throughput(&s, 2048);
        assert!((4.0..6.5).contains(&te2048), "TeLLMe@2048 {te2048:.1}");
    }

    #[test]
    fn paper_speedup_trend() {
        // 1.11x at 64 growing to 2.02x at 2048 (Fig. 6a).
        let pd = pd();
        let te = tellme();
        let s = BITNET_0_73B;
        let r64 = pd.decode_throughput(&s, 64) / te.decode_throughput(&s, 64);
        let r2048 = pd.decode_throughput(&s, 2048) / te.decode_throughput(&s, 2048);
        assert!((1.02..1.25).contains(&r64), "r64 {r64:.2}");
        assert!((1.75..2.35).contains(&r2048), "r2048 {r2048:.2}");
        assert!(r2048 > r64, "gains must grow with context");
    }

    #[test]
    fn paper_prefill_endpoints() {
        // Fig. 6b @ 768: TeLLMe 11.10 s -> PD-Swap 8.80 s (20-25% less).
        let t_pd = pd().prefill(&BITNET_0_73B, 768).total;
        let t_te = tellme().prefill(&BITNET_0_73B, 768).total;
        assert!((7.9..9.7).contains(&t_pd), "PD TTFT {t_pd:.2}");
        assert!((10.0..12.2).contains(&t_te), "TeLLMe TTFT {t_te:.2}");
        let saving = 1.0 - t_pd / t_te;
        assert!((0.15..0.30).contains(&saving), "saving {saving:.2}");
    }

    #[test]
    fn prefill_tail_near_31ms_at_128() {
        // §3.4: remaining projection+FFN after the last attention ~31 ms
        // at L=128.
        let tail = pd().prefill_tail_after_last_attention(&BITNET_0_73B, 128);
        assert!((0.022..0.042).contains(&tail), "tail {:.1} ms", tail * 1e3);
    }

    #[test]
    fn paged_decode_step_matches_monolithic_at_default_page() {
        let pd = pd();
        let s = BITNET_0_73B;
        for l in [64, 512, 2048] {
            let a = pd.decode_step(&s, l).total;
            let b = pd.decode_step_paged(&s, l, 32).total;
            assert!((b / a - 1.0).abs() < 1e-12, "L={l}");
        }
        // A degenerate 1-token page is slower at memory-bound contexts.
        assert!(pd.decode_step_paged(&s, 2048, 1).total > pd.decode_step(&s, 2048).total);
    }

    #[test]
    fn batch1_batched_decode_is_bitwise_identical() {
        let pd = pd();
        let s = BITNET_0_73B;
        for l in [1, 64, 512, 2048] {
            let mono = pd.decode_step_batched(&s, &[l]);
            assert_eq!(mono.total.to_bits(), pd.decode_step(&s, l).total.to_bits(), "L={l}");
            for pt in [1, 8, 32, 128] {
                let paged = pd.decode_step_batched_paged(&s, &[l], pt);
                assert_eq!(
                    paged.total.to_bits(),
                    pd.decode_step_paged(&s, l, pt).total.to_bits(),
                    "L={l} pt={pt}"
                );
            }
        }
    }

    #[test]
    fn uniform_batched_decode_is_bitwise_the_slice_path() {
        // The allocation-free uniform entry point must replay the slice
        // path's arithmetic exactly — including the summation order — so
        // the policy outlook can switch to it without moving a bit.
        let pd = pd();
        let s = BITNET_0_73B;
        for l in [1, 64, 733, 2048] {
            for b in [0usize, 1, 2, 3, 4, 7, 8] {
                for pt in [1, 8, 32, 128] {
                    let uniform = pd.decode_step_uniform_paged(&s, l, b, pt);
                    let slice = pd.decode_step_batched_paged(&s, &vec![l; b], pt);
                    assert_eq!(uniform.batch, slice.batch, "L={l} B={b} pt={pt}");
                    assert_eq!(
                        uniform.attention.to_bits(),
                        slice.attention.to_bits(),
                        "L={l} B={b} pt={pt}"
                    );
                    assert_eq!(
                        uniform.total.to_bits(),
                        slice.total.to_bits(),
                        "L={l} B={b} pt={pt}"
                    );
                }
            }
        }
    }

    #[test]
    fn batched_decode_amortizes_the_weight_stream() {
        // T_weights dominates a batch-1 step; a batch of B streams shares
        // one weight pass, so the per-token latency must fall strictly —
        // and the total must stay below B independent steps.
        let pd = pd();
        let s = BITNET_0_73B;
        for l in [64, 512, 2048] {
            let single = pd.decode_step_paged(&s, l, 32).total;
            let mut last_per_token = f64::INFINITY;
            for b in [1usize, 2, 4, 8] {
                let step = pd.decode_step_batched_paged(&s, &vec![l; b], 32);
                assert_eq!(step.batch, b);
                assert!(step.total <= b as f64 * single + 1e-12, "L={l} B={b}");
                assert!(
                    step.per_token() < last_per_token,
                    "L={l} B={b}: per-token did not fall"
                );
                last_per_token = step.per_token();
            }
        }
    }

    #[test]
    fn batched_decode_handles_mixed_contexts_and_empty() {
        let pd = pd();
        let s = BITNET_0_73B;
        let mixed = pd.decode_step_batched_paged(&s, &[64, 512, 2048], 32);
        let sum_attn: f64 = [64, 512, 2048]
            .iter()
            .map(|&l| pd.decode_step_paged(&s, l, 32).attention)
            .sum();
        assert_eq!(mixed.attention.to_bits(), sum_attn.to_bits());
        assert!(mixed.total > 0.0 && mixed.per_token() > 0.0);
        let empty = pd.decode_step_batched(&s, &[]);
        assert_eq!(empty.total, 0.0);
        assert_eq!(empty.per_token(), 0.0);
        assert_eq!(empty.tokens_per_sec(), 0.0);
    }

    #[test]
    fn decode_span_accumulates_growing_context() {
        let pd = pd();
        let s = BITNET_0_73B;
        let span = pd.decode_span(&s, 64, 10);
        let lo = 10.0 * pd.decode_step(&s, 64).total;
        let hi = 10.0 * pd.decode_step(&s, 74).total;
        assert!(span > lo && span < hi);
    }

    #[test]
    fn throughput_is_monotone_in_context() {
        let pd = pd();
        let s = BITNET_0_73B;
        let mut last = f64::INFINITY;
        for l in [64, 128, 256, 512, 1024, 2048] {
            let t = pd.decode_throughput(&s, l);
            assert!(t < last, "throughput must fall with context");
            last = t;
        }
    }
}
