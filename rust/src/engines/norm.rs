//! RMSNorm & find-max unit + misc element-wise ops (static region).
//!
//! Vector pipeline: one element per lane per cycle. Never a bottleneck in
//! either phase (the paper keeps it static for exactly that reason), but
//! it contributes the constant per-token epilogue visible at short
//! contexts, so it is modeled rather than ignored.

use crate::fpga::ResourceVec;
use crate::model::ModelShape;

/// The fused RMSNorm/find-max/quant + RoPE/SwiGLU element-wise unit.
#[derive(Debug, Clone, Copy)]
pub struct NormEngine {
    /// Parallel vector lanes.
    pub lanes: usize,
}

impl NormEngine {
    /// Paper configuration (Table 2 row 2: 6,210 LUT / 47 DSP).
    pub const PAPER: NormEngine = NormEngine { lanes: 16 };

    pub fn resources(&self) -> ResourceVec {
        let l = self.lanes as f64;
        ResourceVec {
            lut: 2_000.0 + 263.0 * l,
            ff: 3_000.0 + 513.0 * l,
            bram36: 4.0,
            uram: 4.0,
            dsp: 3.0 * l - 1.0,
        }
    }

    /// Element-wise passes per token per layer: 2 norms + RoPE + SwiGLU +
    /// residuals + quant ~ 8 d_model-sized sweeps.
    pub fn time_per_token(&self, shape: &ModelShape, clock_hz: f64) -> f64 {
        let sweeps = 8.0;
        let elems = sweeps * (shape.d_model * shape.n_layers) as f64;
        elems / (self.lanes as f64 * clock_hz)
    }

    pub fn time(&self, shape: &ModelShape, tokens: usize, clock_hz: f64) -> f64 {
        self.time_per_token(shape, clock_hz) * tokens as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    #[test]
    fn resources_match_table2() {
        let r = NormEngine::PAPER.resources();
        assert!((r.lut - 6_210.0).abs() < 100.0, "lut {}", r.lut);
        assert!((r.dsp - 47.0).abs() < 1.0, "dsp {}", r.dsp);
    }

    #[test]
    fn negligible_vs_decode_floor() {
        // Per-token element-wise work must be well under T_weights (~34 ms).
        let t = NormEngine::PAPER.time_per_token(&BITNET_0_73B, KV260.clock_hz());
        assert!(t < 0.002, "norm per-token {:.3} ms", t * 1e3);
    }

    #[test]
    fn linear_in_tokens() {
        let e = NormEngine::PAPER;
        let c = KV260.clock_hz();
        assert!(
            (e.time(&BITNET_0_73B, 100, c) - 100.0 * e.time_per_token(&BITNET_0_73B, c)).abs()
                < 1e-12
        );
    }
}
