//! Engine latency/resource models — the `f(·)` and `g(·)` of Eqs. 3–5.
//!
//! Each accelerator engine (TLMM linear unit, prefill attention RM, decode
//! attention RM, RMSNorm unit) is modeled as:
//!
//! * a **resource cost** function of its parallelism (PE count), anchored
//!   to the paper's Table 2 breakdown, and
//! * a **latency** function combining a compute roof (PEs × clock ×
//!   schedule efficiency) with a memory roof (the [`crate::memory`] port
//!   model), taking whichever binds — exactly the roofline picture of
//!   Fig. 4a.
//!
//! ## Calibration
//!
//! The paper fits its coefficients "empirically measured under a baseline
//! hardware configuration" (§3.3.2); we do the same, anchoring to its
//! published endpoints (calibration table in [`calib`]):
//!
//! | anchor | paper value | model knob |
//! |---|---|---|
//! | PD-Swap decode @ L=64 | 27.8 tok/s | weight-stream controller eff. |
//! | PD-Swap prefill rate | 148 tok/s | TLMM per-PE token rate |
//! | TeLLMe prefill rate | 143 tok/s | (same knob, fewer PEs) |
//! | TeLLMe decode @ L=2048 | ~5 tok/s | static decode engine PE count |
//! | KV remap gain | ~2x | port model (no knob — emergent) |
//! | reconfig latency | ~45 ms | bitstream area model (no knob) |

pub mod attention;
pub mod design;
pub mod norm;
pub mod phase;
pub mod surface;
pub mod tlmm;

pub use attention::{DecodeAttentionEngine, PrefillAttentionEngine, ScheduleQuality};
pub use design::{AcceleratorDesign, AttentionHosting};
pub use norm::NormEngine;
pub use phase::{BatchedDecodeLatency, DecodeLatency, PhaseModel, PrefillLatency};
pub use surface::{LatencySurface, SurfaceCache, SurfaceFactory, SurfaceKey, SurfaceOverlap};
pub use tlmm::TlmmEngine;

/// Calibration constants (see module docs).
pub mod calib {
    /// DDR controller efficiency observed on the strided fp16 KV streams
    /// (head-interleaved 128 B lines defeat row-buffer locality; the PS
    /// and the weight engine share the controller). Both designs see the
    /// same efficiency — PD-Swap's 2x comes purely from the port remap.
    pub const KV_CONTROLLER_EFF: f64 = 0.27;

    /// DDR controller efficiency on the long sequential packed-weight
    /// stream. Anchored so the 0.73B weight set (163 MB packed) streams in
    /// ~34 ms: the decode floor `T_weights` behind the paper's 27.8 tok/s.
    pub const WEIGHT_CONTROLLER_EFF: f64 = 0.28;

    /// Effective tokens/s of one TLMM PE on the BitNet 0.73B projection
    /// stack (all 7 linears). Anchor: 320 PEs -> 148 tok/s (Table 1
    /// prefill). Includes quant/dequant and pipeline bubbles.
    pub const TLMM_TOKENS_PER_PE: f64 = 148.0 / 320.0;

    /// fp16 MACs per DSP per cycle in the attention engines (a MAC uses a
    /// DSP pair; 0.5 MAC/DSP/cycle at ideal scheduling).
    pub const ATTN_MACS_PER_DSP_CYCLE: f64 = 0.5;

    /// Schedule efficiency of the *dedicated* (reconfigured) attention
    /// engines: deep prefetch, no phase compromise.
    pub const SCHED_EFF_TAILORED: f64 = 0.85;

    /// Schedule efficiency of a *static shared* decode attention engine:
    /// a prefill-oriented dataflow reused for single-query streaming loses
    /// most of its PE utilization (the paper's core complaint).
    pub const SCHED_EFF_GENERIC: f64 = 0.25;

    /// Static prefill attention keeps most of its efficiency (the baseline
    /// was designed around prefill; its decode is the afterthought):
    /// calibrated so the TeLLMe TTFT at L=768 lands on Fig. 6b's 11.10 s.
    pub const PREFILL_GENERIC_EFF: f64 = 0.73;

    /// Prefill attention effective per-DSP throughput derate: softmax /
    /// rescale pipelines and causal-block stalls on top of the MAC array.
    /// Anchored so the PD prefill RM (303 DSP) sustains ~6.4 GMAC/s,
    /// reproducing Fig. 6b's 8.8 s TTFT at L=768.
    pub const PREFILL_ATTN_DERATE: f64 = 0.169;
}
