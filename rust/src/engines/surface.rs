//! Latency surfaces: precomputed, O(1) closed forms of the phase model.
//!
//! [`PhaseModel`](super::PhaseModel) re-derives every latency from first
//! principles on each
//! call — including rebuilding [`crate::memory::PortMapping`]s (heap
//! allocations) and re-running the AXI transfer-time arbitration — which
//! makes it the single hottest function of both the §4.3 DSE sweep and
//! the serving simulators (one call per decode token-step event). This
//! module exploits the model's analytic structure to collapse each query
//! to a handful of floating-point operations:
//!
//! * **decode step** — Eq. 5 is *exactly* linear in context length `l`:
//!   the attention term is `max(compute_slope · l, memory_slope · l)` and
//!   projection/norm are constants. The surface caches the two slopes and
//!   the constants.
//! * **batched decode step** — for `B` resident streams the projection
//!   term is `max(B / tps, T_weights)` (one shared weight stream for the
//!   whole batch), attention is the per-stream sum, and norm scales with
//!   `B`. The per-`B` closed form needs no new coefficients — the batch
//!   knee sits at `B* = T_weights · tps`
//!   ([`LatencySurface::decode_batch_breakpoint`]), the same knee the
//!   prefill projection has in `l`.
//! * **prefill** — Eq. 3 is piecewise-linear-plus-quadratic in `l`: the
//!   projection term is `max(l / tps, T_weights)` (one breakpoint at
//!   `l* = T_weights · tps`, where the pipelined weight stream stops
//!   binding), attention is a pure `l²` term, and norm is linear. The
//!   surface caches `tps`, `T_weights`, and the two engine rates.
//!
//! **Everything here is exact, nothing is interpolated.** The cached
//! quantities are the *coefficients* of the closed forms (engine MAC
//! rates, effective KV/weight bandwidths), not sampled latency values, and
//! every evaluation replays the phase model's arithmetic in the same
//! operation order — so a surface query is bit-identical to the
//! corresponding [`PhaseModel`](super::PhaseModel) call, including at the
//! breakpoints and at
//! every decode batch size. The property tests in
//! `rust/tests/prop_invariants.rs` pin this equivalence across the
//! paper's DSE grid, all context breakpoints, batch sizes, and both
//! hosting modes.
//!
//! ```
//! use pd_swap::engines::{AcceleratorDesign, LatencySurface};
//! use pd_swap::fpga::KV260;
//! use pd_swap::model::BITNET_0_73B;
//!
//! // The paper's shipped design on the KV260, 32-token KV pages.
//! let surface = LatencySurface::new(
//!     &AcceleratorDesign::pd_swap(), &KV260, &BITNET_0_73B, 32);
//! let step = surface.decode_step(64);
//! assert!((26.0..30.0).contains(&step.tokens_per_sec())); // paper: 27.8 tok/s
//!
//! // Four resident streams share one weight pass: the per-token wall
//! // latency drops below the batch-1 step.
//! let batched = surface.decode_step_batched_paged(&[64; 4], 32);
//! assert!(batched.per_token() < step.total);
//! ```
//!
//! Three layers of caching, coarse to fine:
//!
//! * [`LatencySurface`] — one (design, device, shape, page size): the
//!   serving engines hold one and query it per token step.
//! * [`SurfaceFactory`] — one (device, shape, page size), amortizing the
//!   design-independent work (memory system, weight-stream time, the four
//!   KV-bandwidth variants) across a whole DSE grid: building a surface
//!   for the next candidate is pure arithmetic.
//! * [`SurfaceCache`] — a memo of finished surfaces keyed by the design's
//!   structural hash ([`SurfaceKey`]), for sweeps that revisit designs
//!   (the `codesign` joint exploration).

use std::collections::HashMap;
use std::sync::Arc;

use crate::fpga::DeviceConfig;
use crate::memory::traffic::burst_for;
use crate::memory::{paged_kv_burst, MemorySystem, Stream};
use crate::model::ModelShape;

use super::attention::DecodeAttentionEngine;
use super::design::{AcceleratorDesign, AttentionHosting};
use super::phase::{BatchedDecodeLatency, DecodeLatency, PrefillLatency};

/// The §3.4 overlap arithmetic evaluated on a surface (mirrors
/// [`crate::reconfig::OverlapScheduler::overlapped`] bit for bit).
#[derive(Debug, Clone, Copy)]
pub struct SurfaceOverlap {
    /// Total prefill latency.
    pub prefill_end: f64,
    /// When the final layer's attention completes (swap trigger point).
    pub trigger: f64,
    /// Prefill tail available to hide the PCAP load.
    pub tail: f64,
    /// When the decode RM is live.
    pub decode_ready: f64,
    /// Reconfiguration latency NOT hidden by the tail.
    pub exposed: f64,
}

/// Precomputed latency surface for one (design, device, shape, page size).
#[derive(Debug, Clone)]
pub struct LatencySurface {
    shape: ModelShape,
    /// TLMM projection throughput (tokens/s) on this shape.
    tlmm_tps: f64,
    /// One full packed-weight stream (the `T_weights` floor of Eqs. 3/5).
    t_weights: f64,
    /// Norm/element-wise time per token.
    norm_per_token: f64,
    /// Prefill attention sustained MAC rate.
    pre_attn_rate: f64,
    /// Decode attention sustained MAC rate.
    dec_attn_rate: f64,
    /// Effective K+V bandwidth at the monolithic (64-beat) burst.
    kv_bw_mono: f64,
    /// Page size the paged bandwidth below was computed for.
    page_tokens: usize,
    /// Effective K+V bandwidth at the paged burst shape.
    kv_bw_paged: f64,
    /// Decode projection constant: `max(1/tps, T_weights)`.
    dec_proj: f64,
    /// Last-layer post-attention fraction of a layer's projection work.
    tail_frac: f64,
    /// Kept for cold queries at page sizes other than `page_tokens`.
    decode_attn: DecodeAttentionEngine,
    mem: MemorySystem,
    /// Structural identity of the configuration this surface was built
    /// for — lets consumers ([`crate::coordinator::EventServer`]) verify
    /// an injected surface actually matches their config.
    key: SurfaceKey,
}

impl LatencySurface {
    /// Build the surface. `page_tokens` selects which paged-burst
    /// bandwidth is precomputed (queries at other page sizes still work,
    /// they just recompute the burst shape).
    pub fn new(
        design: &AcceleratorDesign,
        device: &DeviceConfig,
        shape: &ModelShape,
        page_tokens: usize,
    ) -> Self {
        SurfaceFactory::new(device, shape, page_tokens).surface(design)
    }

    pub fn shape(&self) -> &ModelShape {
        &self.shape
    }

    /// The structural key of the (design, device, shape, page size) this
    /// surface was built for.
    pub fn key(&self) -> &SurfaceKey {
        &self.key
    }

    /// The `T_weights` decode floor (also the prefill stream bound).
    pub fn weight_stream_time(&self) -> f64 {
        self.t_weights
    }

    /// Cached sustained MAC rate of the prefill attention engine.
    pub fn prefill_attn_mac_rate(&self) -> f64 {
        self.pre_attn_rate
    }

    /// Cached sustained MAC rate of the decode attention engine.
    pub fn decode_attn_mac_rate(&self) -> f64 {
        self.dec_attn_rate
    }

    /// Cached effective K+V bandwidth (monolithic burst).
    pub fn kv_bandwidth(&self) -> f64 {
        self.kv_bw_mono
    }

    /// Eq. 3 in closed form — equals `PhaseModel::prefill` exactly.
    pub fn prefill(&self, l: usize) -> PrefillLatency {
        let lf = l as f64;
        let projection = (lf / self.tlmm_tps).max(self.t_weights);
        let macs =
            self.shape.n_layers as f64 * (lf * lf / 2.0) * self.shape.d_model as f64 * 2.0;
        let attention = macs / self.pre_attn_rate;
        let norm = self.norm_per_token * lf;
        PrefillLatency {
            projection,
            attention,
            norm_elementwise: norm,
            weights: self.t_weights,
            total: projection + attention + norm,
        }
    }

    /// Eq. 5 in closed form — equals `PhaseModel::decode_step` exactly.
    pub fn decode_step(&self, l: usize) -> DecodeLatency {
        self.decode_with_bw(l, self.kv_bw_mono)
    }

    /// Paged Eq. 5 — equals `PhaseModel::decode_step_paged` exactly. Hits
    /// the precomputed bandwidth when `page_tokens` matches construction.
    pub fn decode_step_paged(&self, l: usize, page_tokens: usize) -> DecodeLatency {
        self.decode_with_bw(l, self.kv_bw_for_page(page_tokens))
    }

    /// One stream's decode-attention term (Eq. 5 roofline) at an
    /// effective K+V bandwidth — shared by the single and batched steps
    /// so both replay identical arithmetic.
    fn attn_with_bw(&self, l: usize, bw: f64) -> f64 {
        let macs = 2.0 * (l * self.shape.d_model) as f64 * self.shape.n_layers as f64;
        let compute = macs / self.dec_attn_rate;
        let memory = self.shape.kv_bytes(l) / bw;
        compute.max(memory)
    }

    fn decode_with_bw(&self, l: usize, bw: f64) -> DecodeLatency {
        let attention = self.attn_with_bw(l, bw);
        DecodeLatency {
            projection: self.dec_proj,
            attention,
            norm_elementwise: self.norm_per_token,
            total: self.dec_proj + attention + self.norm_per_token,
        }
    }

    /// Resolve the effective K+V bandwidth for a page size (cached when
    /// it matches construction, recomputed otherwise).
    fn kv_bw_for_page(&self, page_tokens: usize) -> f64 {
        if page_tokens == self.page_tokens {
            self.kv_bw_paged
        } else {
            self.decode_attn
                .kv_bandwidth_with_burst(&self.mem, paged_kv_burst(&self.shape, page_tokens))
        }
    }

    /// One batched decode step over `ctxs` resident streams, monolithic
    /// KV bursts — equals [`PhaseModel::decode_step_batched`](super::PhaseModel::decode_step_batched)
    /// exactly. The projection term `max(B / tps, T_weights)` shares one
    /// weight-stream pass across the batch; attention sums per stream.
    pub fn decode_step_batched(&self, ctxs: &[usize]) -> BatchedDecodeLatency {
        self.batched_with_bw(ctxs, self.kv_bw_mono)
    }

    /// Paged batched step — equals
    /// [`PhaseModel::decode_step_batched_paged`](super::PhaseModel::decode_step_batched_paged)
    /// exactly, and is bit-identical to [`Self::decode_step_paged`] at
    /// batch 1 (the serving engines' regression anchor).
    pub fn decode_step_batched_paged(
        &self,
        ctxs: &[usize],
        page_tokens: usize,
    ) -> BatchedDecodeLatency {
        self.batched_with_bw(ctxs, self.kv_bw_for_page(page_tokens))
    }

    /// Uniform-context batched step: `batch` streams all at context `l`,
    /// paged KV. Bit-identical to [`Self::decode_step_batched_paged`]
    /// over `&[l; batch]` (the per-stream attention term is computed once
    /// and accumulated in the slice path's left-to-right order) but takes
    /// no slice — the swap-policy outlook's per-decision estimate stays
    /// allocation-free.
    pub fn decode_step_uniform_paged(
        &self,
        l: usize,
        batch: usize,
        page_tokens: usize,
    ) -> BatchedDecodeLatency {
        self.uniform_with_bw(l, batch, self.kv_bw_for_page(page_tokens))
    }

    fn uniform_with_bw(&self, l: usize, batch: usize, bw: f64) -> BatchedDecodeLatency {
        // Replays `batched_with_bw`'s zero-seeded left fold (the same
        // per-stream value added `batch` times) so the result is
        // bit-identical at every batch size.
        let mut attention = 0.0;
        if batch > 0 {
            let per_stream = self.attn_with_bw(l, bw);
            for _ in 0..batch {
                attention += per_stream;
            }
        }
        self.assemble_batched(batch, attention)
    }

    fn batched_with_bw(&self, ctxs: &[usize], bw: f64) -> BatchedDecodeLatency {
        let attention: f64 = ctxs.iter().map(|&l| self.attn_with_bw(l, bw)).sum();
        self.assemble_batched(ctxs.len(), attention)
    }

    /// Shared tail of the slice and uniform batched paths: the projection
    /// / norm / total assembly exists exactly once, so the two entry
    /// points cannot drift apart.
    fn assemble_batched(&self, batch: usize, attention: f64) -> BatchedDecodeLatency {
        if batch == 0 {
            return BatchedDecodeLatency {
                batch: 0,
                projection: 0.0,
                attention: 0.0,
                norm_elementwise: 0.0,
                total: 0.0,
            };
        }
        let projection = (batch as f64 / self.tlmm_tps).max(self.t_weights);
        let norm = self.norm_per_token * batch as f64;
        BatchedDecodeLatency {
            batch,
            projection,
            attention,
            norm_elementwise: norm,
            total: projection + attention + norm,
        }
    }

    /// The batch knee `B* = T_weights · tps`: below it the shared weight
    /// stream binds the batched projection (every extra stream is almost
    /// free), above it TLMM compute binds (per-token projection cost goes
    /// flat). Numerically the same knee as
    /// [`Self::prefill_projection_breakpoint`] — decode at batch `B` does
    /// exactly a `B`-token projection pass.
    pub fn decode_batch_breakpoint(&self) -> f64 {
        self.t_weights * self.tlmm_tps
    }

    /// Decode throughput (tokens/s) at context `l`.
    pub fn decode_throughput(&self, l: usize) -> f64 {
        self.decode_step(l).tokens_per_sec()
    }

    /// The §3.4 prefill tail after the final layer's attention — equals
    /// `PhaseModel::prefill_tail_after_last_attention` exactly.
    pub fn prefill_tail(&self, l: usize) -> f64 {
        let pre = self.prefill(l);
        let proj_per_layer = pre.projection / self.shape.n_layers as f64;
        let norm_per_layer = pre.norm_elementwise / self.shape.n_layers as f64;
        proj_per_layer * self.tail_frac + norm_per_layer
    }

    /// The §3.4 early-trigger timeline for a given PCAP load latency —
    /// mirrors `OverlapScheduler::overlapped` bit for bit.
    pub fn overlapped(&self, l: usize, reconfig_latency: f64) -> SurfaceOverlap {
        let prefill_end = self.prefill(l).total;
        let tail = self.prefill_tail(l);
        let trigger = prefill_end - tail;
        let decode_ready = (trigger + reconfig_latency).max(prefill_end);
        let exposed = decode_ready - prefill_end;
        SurfaceOverlap { prefill_end, trigger, tail, decode_ready, exposed }
    }

    /// Exposed cost of a decode→prefill→decode round trip — mirrors
    /// [`crate::reconfig::round_trip_exposed`] on the surface.
    pub fn round_trip_exposed(
        &self,
        representative_prompt: usize,
        reconfig_latency: f64,
    ) -> f64 {
        let back = self.overlapped(representative_prompt.max(1), reconfig_latency).exposed;
        reconfig_latency + back
    }

    /// Prefill-projection breakpoint `l* = T_weights · tps`: below it the
    /// weight stream binds, above it PE compute does. Exposed so tests
    /// can probe the exact knee.
    pub fn prefill_projection_breakpoint(&self) -> f64 {
        self.t_weights * self.tlmm_tps
    }
}

/// Design-independent precomputation for one (device, shape, page size):
/// turning a DSE candidate into a [`LatencySurface`] becomes pure
/// arithmetic (no allocation, no port-model evaluation).
#[derive(Debug, Clone)]
pub struct SurfaceFactory {
    shape: ModelShape,
    device: DeviceConfig,
    clock_hz: f64,
    mem: MemorySystem,
    /// `weight_stream_time` is engine-size independent (the stream is
    /// striped over all ports regardless of PE count).
    t_weights: f64,
    page_tokens: usize,
    /// K+V bandwidth by (kv_optimized_ports, paged): engine-size
    /// independent — only the port mapping and burst shape matter.
    kv_bw_opt_mono: f64,
    kv_bw_base_mono: f64,
    kv_bw_opt_paged: f64,
    kv_bw_base_paged: f64,
    tail_frac: f64,
}

impl SurfaceFactory {
    pub fn new(device: &DeviceConfig, shape: &ModelShape, page_tokens: usize) -> Self {
        let mem = MemorySystem::for_device(device);
        // Any PE count serves: weight_stream_time ignores it.
        let t_weights = super::TlmmEngine { n_pe: 1 }.weight_stream_time(shape, &mem);
        let probe = |kv_opt: bool, burst| {
            DecodeAttentionEngine {
                n_dsp: 1,
                schedule: super::ScheduleQuality::Tailored,
                kv_optimized_ports: kv_opt,
            }
            .kv_bandwidth_with_burst(&mem, burst)
        };
        let mono = burst_for(Stream::K);
        let paged = paged_kv_burst(shape, page_tokens);
        let d = shape.d_model as f64;
        let dff = shape.d_ff as f64;
        Self {
            shape: *shape,
            device: device.clone(),
            clock_hz: device.clock_hz(),
            t_weights,
            page_tokens,
            kv_bw_opt_mono: probe(true, mono),
            kv_bw_base_mono: probe(false, mono),
            kv_bw_opt_paged: probe(true, paged),
            kv_bw_base_paged: probe(false, paged),
            tail_frac: (3.0 * d * dff + d * d) / (4.0 * d * d + 3.0 * d * dff),
            mem,
        }
    }

    /// Build the surface for one design: pure arithmetic.
    pub fn surface(&self, design: &AcceleratorDesign) -> LatencySurface {
        let tlmm_tps = design.tlmm.tokens_per_sec(&self.shape);
        let (kv_mono, kv_paged) = if design.decode_attn.kv_optimized_ports {
            (self.kv_bw_opt_mono, self.kv_bw_opt_paged)
        } else {
            (self.kv_bw_base_mono, self.kv_bw_base_paged)
        };
        LatencySurface {
            shape: self.shape,
            tlmm_tps,
            t_weights: self.t_weights,
            norm_per_token: design.norm.time_per_token(&self.shape, self.clock_hz),
            pre_attn_rate: design.prefill_attn.mac_rate(self.clock_hz),
            dec_attn_rate: design.decode_attn.mac_rate(self.clock_hz),
            kv_bw_mono: kv_mono,
            page_tokens: self.page_tokens,
            kv_bw_paged: kv_paged,
            dec_proj: (1.0 / tlmm_tps).max(self.t_weights),
            tail_frac: self.tail_frac,
            decode_attn: design.decode_attn,
            mem: self.mem.clone(),
            key: self.key_for(design),
        }
    }

    /// The [`SurfaceKey`] a surface built by this factory for `design`
    /// will carry.
    pub fn key_for(&self, design: &AcceleratorDesign) -> SurfaceKey {
        SurfaceKey::new(design, &self.device, &self.shape, self.page_tokens)
    }
}

/// Structural identity of a (design, device, shape, page size) tuple —
/// the memo key for [`SurfaceCache`]. Floats enter as bit patterns, so
/// two configurations collide only if they are numerically identical.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SurfaceKey {
    tlmm_pe: usize,
    norm_lanes: usize,
    pre_dsp: usize,
    pre_tailored: bool,
    dec_dsp: usize,
    dec_tailored: bool,
    kv_opt: bool,
    dpr: bool,
    shape: (usize, usize, usize, usize, usize, usize, u64),
    device: (u64, u64, u64, usize, u64, u64),
    page_tokens: usize,
}

impl SurfaceKey {
    pub fn new(
        design: &AcceleratorDesign,
        device: &DeviceConfig,
        shape: &ModelShape,
        page_tokens: usize,
    ) -> Self {
        use super::ScheduleQuality;
        Self {
            tlmm_pe: design.tlmm.n_pe,
            norm_lanes: design.norm.lanes,
            pre_dsp: design.prefill_attn.n_dsp,
            pre_tailored: design.prefill_attn.schedule == ScheduleQuality::Tailored,
            dec_dsp: design.decode_attn.n_dsp,
            dec_tailored: design.decode_attn.schedule == ScheduleQuality::Tailored,
            kv_opt: design.decode_attn.kv_optimized_ports,
            dpr: design.hosting == AttentionHosting::Reconfigurable,
            shape: (
                shape.n_layers,
                shape.d_model,
                shape.n_heads,
                shape.d_ff,
                shape.vocab,
                shape.max_seq,
                shape.kv_precision.bytes().to_bits(),
            ),
            device: (
                device.clock_mhz.to_bits(),
                device.hp_port_peak.to_bits(),
                device.ddr_aggregate_peak.to_bits(),
                device.n_hp_ports,
                device.ddr_bytes.to_bits(),
                device.pcap_bytes_per_sec.to_bits(),
            ),
            page_tokens,
        }
    }
}

/// Memoized surface construction keyed by [`SurfaceKey`] — for sweeps
/// that evaluate the same design repeatedly (policy × trace joints).
#[derive(Debug, Default)]
pub struct SurfaceCache {
    map: HashMap<SurfaceKey, Arc<LatencySurface>>,
}

impl SurfaceCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch (or build and memoize) the surface for a configuration.
    /// Cold misses pay a full [`SurfaceFactory`] construction; sweeps
    /// that hold a factory should prefer [`Self::get_with`].
    pub fn get(
        &mut self,
        design: &AcceleratorDesign,
        device: &DeviceConfig,
        shape: &ModelShape,
        page_tokens: usize,
    ) -> Arc<LatencySurface> {
        let key = SurfaceKey::new(design, device, shape, page_tokens);
        self.map
            .entry(key)
            .or_insert_with(|| Arc::new(LatencySurface::new(design, device, shape, page_tokens)))
            .clone()
    }

    /// Fetch (or build and memoize) through an existing factory: a miss
    /// is pure arithmetic, so this stays cheap even under a shared lock
    /// (the `codesign` sweep's pattern).
    pub fn get_with(
        &mut self,
        factory: &SurfaceFactory,
        design: &AcceleratorDesign,
    ) -> Arc<LatencySurface> {
        self.map
            .entry(factory.key_for(design))
            .or_insert_with(|| Arc::new(factory.surface(design)))
            .clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::PhaseModel;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn surface() -> LatencySurface {
        LatencySurface::new(&AcceleratorDesign::pd_swap(), &KV260, &BITNET_0_73B, 32)
    }

    fn model() -> PhaseModel {
        PhaseModel::new(AcceleratorDesign::pd_swap(), KV260.clone())
    }

    #[test]
    fn prefill_matches_phase_model_bitwise() {
        let s = surface();
        let m = model();
        for l in [0, 1, 63, 64, 128, 767, 768, 2047, 2048] {
            let a = m.prefill(&BITNET_0_73B, l);
            let b = s.prefill(l);
            assert_eq!(a.projection.to_bits(), b.projection.to_bits(), "L={l}");
            assert_eq!(a.attention.to_bits(), b.attention.to_bits(), "L={l}");
            assert_eq!(a.total.to_bits(), b.total.to_bits(), "L={l}");
        }
    }

    #[test]
    fn decode_matches_phase_model_bitwise() {
        let s = surface();
        let m = model();
        for l in [1, 2, 64, 512, 1024, 2048] {
            assert_eq!(
                m.decode_step(&BITNET_0_73B, l).total.to_bits(),
                s.decode_step(l).total.to_bits(),
                "L={l}"
            );
            for pt in [1, 2, 8, 32, 128] {
                assert_eq!(
                    m.decode_step_paged(&BITNET_0_73B, l, pt).total.to_bits(),
                    s.decode_step_paged(l, pt).total.to_bits(),
                    "L={l} pt={pt}"
                );
            }
        }
    }

    #[test]
    fn batched_decode_matches_phase_model_bitwise() {
        let s = surface();
        let m = model();
        for l in [1, 64, 512, 2048] {
            for b in [1usize, 2, 4, 8] {
                let ctxs = vec![l; b];
                assert_eq!(
                    m.decode_step_batched(&BITNET_0_73B, &ctxs).total.to_bits(),
                    s.decode_step_batched(&ctxs).total.to_bits(),
                    "L={l} B={b}"
                );
                for pt in [1, 8, 32, 128] {
                    let a = m.decode_step_batched_paged(&BITNET_0_73B, &ctxs, pt);
                    let b2 = s.decode_step_batched_paged(&ctxs, pt);
                    assert_eq!(a.projection.to_bits(), b2.projection.to_bits(), "L={l} B={b}");
                    assert_eq!(a.attention.to_bits(), b2.attention.to_bits(), "L={l} B={b}");
                    assert_eq!(a.total.to_bits(), b2.total.to_bits(), "L={l} B={b} pt={pt}");
                }
            }
        }
        // Mixed per-stream contexts too.
        let mixed = [7usize, 64, 1999, 2048];
        assert_eq!(
            m.decode_step_batched_paged(&BITNET_0_73B, &mixed, 32).total.to_bits(),
            s.decode_step_batched_paged(&mixed, 32).total.to_bits()
        );
    }

    #[test]
    fn batch1_batched_equals_single_step_bitwise() {
        let s = surface();
        for l in [1, 64, 733, 2048] {
            assert_eq!(
                s.decode_step_batched(&[l]).total.to_bits(),
                s.decode_step(l).total.to_bits(),
                "L={l}"
            );
            for pt in [1, 8, 32, 128] {
                assert_eq!(
                    s.decode_step_batched_paged(&[l], pt).total.to_bits(),
                    s.decode_step_paged(l, pt).total.to_bits(),
                    "L={l} pt={pt}"
                );
            }
        }
    }

    #[test]
    fn uniform_batched_equals_slice_batched_bitwise() {
        let s = surface();
        for l in [1, 64, 733, 2048] {
            for b in [0usize, 1, 2, 3, 4, 7, 8] {
                for pt in [1, 8, 32, 128] {
                    let uniform = s.decode_step_uniform_paged(l, b, pt);
                    let slice = s.decode_step_batched_paged(&vec![l; b], pt);
                    assert_eq!(uniform.batch, slice.batch, "L={l} B={b} pt={pt}");
                    assert_eq!(
                        uniform.attention.to_bits(),
                        slice.attention.to_bits(),
                        "L={l} B={b} pt={pt}"
                    );
                    assert_eq!(
                        uniform.total.to_bits(),
                        slice.total.to_bits(),
                        "L={l} B={b} pt={pt}"
                    );
                }
            }
        }
    }

    #[test]
    fn batch_breakpoint_is_the_projection_knee() {
        // Below B* the shared weight stream binds (projection flat at
        // T_weights); above it TLMM compute binds and grows with B.
        let s = surface();
        let knee = s.decode_batch_breakpoint();
        assert_eq!(knee, s.prefill_projection_breakpoint());
        let lo = (knee.floor() as usize).saturating_sub(1).max(1);
        let hi = knee.ceil() as usize + 1;
        assert_eq!(
            s.decode_step_batched_paged(&vec![64; lo], 32).projection,
            s.weight_stream_time()
        );
        assert!(
            s.decode_step_batched_paged(&vec![64; hi], 32).projection
                > s.weight_stream_time()
        );
    }

    #[test]
    fn tail_matches_phase_model_bitwise() {
        let s = surface();
        let m = model();
        for l in [1, 128, 768, 2048] {
            assert_eq!(
                m.prefill_tail_after_last_attention(&BITNET_0_73B, l).to_bits(),
                s.prefill_tail(l).to_bits(),
                "L={l}"
            );
        }
    }

    #[test]
    fn projection_breakpoint_is_the_knee() {
        // Just below the breakpoint the weight stream binds (projection is
        // flat at T_weights); just above, compute binds (it grows).
        let s = surface();
        let knee = s.prefill_projection_breakpoint();
        let lo = knee.floor() as usize - 1;
        let hi = knee.ceil() as usize + 1;
        assert_eq!(s.prefill(lo).projection, s.weight_stream_time());
        assert!(s.prefill(hi).projection > s.weight_stream_time());
    }

    #[test]
    fn overlap_matches_scheduler() {
        use crate::reconfig::OverlapScheduler;
        let design = AcceleratorDesign::pd_swap();
        let device = design.program(&KV260).unwrap();
        let lat = device.reconfig_latency();
        let sched = OverlapScheduler::new(model(), lat);
        let s = surface();
        for l in [1, 64, 128, 768, 2048] {
            let a = sched.overlapped(&BITNET_0_73B, l);
            let b = s.overlapped(l, lat);
            assert_eq!(a.trigger.to_bits(), b.trigger.to_bits(), "L={l}");
            assert_eq!(a.exposed.to_bits(), b.exposed.to_bits(), "L={l}");
            assert_eq!(a.decode_ready.to_bits(), b.decode_ready.to_bits(), "L={l}");
        }
    }

    #[test]
    fn factory_surface_equals_direct_surface() {
        let factory = SurfaceFactory::new(&KV260, &BITNET_0_73B, 32);
        let tellme = AcceleratorDesign::tellme_static();
        let a = factory.surface(&tellme);
        let b = LatencySurface::new(&tellme, &KV260, &BITNET_0_73B, 32);
        for l in [1, 64, 2048] {
            assert_eq!(a.prefill(l).total.to_bits(), b.prefill(l).total.to_bits());
            assert_eq!(a.decode_step(l).total.to_bits(), b.decode_step(l).total.to_bits());
        }
    }

    #[test]
    fn cache_hits_on_identical_designs() {
        let mut cache = SurfaceCache::new();
        let d1 = AcceleratorDesign::pd_swap();
        let mut d2 = AcceleratorDesign::pd_swap();
        d2.name = "renamed".into(); // names are labels, not structure
        let a = cache.get(&d1, &KV260, &BITNET_0_73B, 32);
        let b = cache.get(&d2, &KV260, &BITNET_0_73B, 32);
        assert_eq!(cache.len(), 1, "structurally identical designs share a surface");
        assert!(Arc::ptr_eq(&a, &b));
        let mut d3 = AcceleratorDesign::pd_swap();
        d3.decode_attn.n_dsp += 25;
        cache.get(&d3, &KV260, &BITNET_0_73B, 32);
        assert_eq!(cache.len(), 2);
        // The factory-backed path lands in the same entries.
        let factory = SurfaceFactory::new(&KV260, &BITNET_0_73B, 32);
        let c = cache.get_with(&factory, &d1);
        assert!(Arc::ptr_eq(&a, &c), "get and get_with share one entry");
        assert_eq!(cache.len(), 2);
        assert_eq!(c.key(), &factory.key_for(&d1));
    }
}
