//! TLMM linear-unit model (static region, Fig. 3a).
//!
//! The table-lookup matmul engine: per PE, one 4-weight group lookup +
//! accumulate per cycle. Weights are packed base-3 in DDR and streamed
//! through the weight ports (at 0.73B they cannot reside on-chip; URAM
//! holds the per-token partial-sum tables and stream buffers — Table 2
//! charges that to "Other").
//!
//! Latency model: projections are a *batch of GEMVs* (the paper's
//! orchestration), so a phase's projection time is
//! `max(weight_stream_time, tokens x per_token_compute)` — the stream and
//! the PE array are pipelined against each other.

use crate::fpga::ResourceVec;
use crate::memory::{AxiBurst, MemorySystem, PortAssignment, PortMapping, Stream};
use crate::model::ModelShape;

use super::calib;

/// The ternary table-lookup matmul engine.
#[derive(Debug, Clone, Copy)]
pub struct TlmmEngine {
    /// Lookup-accumulate processing elements (DSP-count proxy).
    pub n_pe: usize,
}

impl TlmmEngine {
    /// The paper's shipped configuration (Table 2 row 1: 320 DSP).
    pub const PAPER: TlmmEngine = TlmmEngine { n_pe: 320 };

    /// Fabric cost, anchored to Table 2 (320 PE -> 42,854 LUT / 50,752 FF /
    /// 5.5 BRAM / 0 URAM / 320 DSP).
    pub fn resources(&self) -> ResourceVec {
        let pe = self.n_pe as f64;
        ResourceVec {
            lut: 3_000.0 + 124.5 * pe,
            ff: 6_000.0 + 140.0 * pe,
            bram36: 5.5,
            uram: 0.0,
            dsp: pe,
        }
    }

    /// Sustained projection throughput (tokens/s) on `shape`, scaled from
    /// the 0.73B anchor by relative per-token work.
    pub fn tokens_per_sec(&self, shape: &ModelShape) -> f64 {
        let anchor_work = per_token_macs(&crate::model::BITNET_0_73B);
        let work = per_token_macs(shape);
        self.n_pe as f64 * calib::TLMM_TOKENS_PER_PE * anchor_work / work
    }

    /// Weight-stream time for one full pass over the packed weights.
    ///
    /// The stream is striped over all HP ports (the projection sub-phase
    /// owns the memory system — see [`PortMapping::weights_striped`]) and
    /// derated by the measured controller efficiency.
    pub fn weight_stream_time(&self, shape: &ModelShape, mem: &MemorySystem) -> f64 {
        let bytes = shape.ternary_weight_bytes();
        let mapping = PortMapping::weights_striped(mem.n_ports);
        let raw = mem.transfer_time(
            &mapping,
            &[PortAssignment {
                stream: Stream::Weights,
                bytes,
                burst: AxiBurst { beats: 64 },
            }],
        );
        raw / calib::WEIGHT_CONTROLLER_EFF
    }

    /// Projection time for `tokens` tokens in one phase: compute and the
    /// weight stream are pipelined, the slower one binds. `+ epilogue`
    /// covers drain/fill (small, per phase).
    pub fn projection_time(&self, shape: &ModelShape, tokens: usize, mem: &MemorySystem) -> f64 {
        let compute = tokens as f64 / self.tokens_per_sec(shape);
        let stream = self.weight_stream_time(shape, mem);
        compute.max(stream)
    }
}

/// MACs of all 7 ternary linears for one token.
pub fn per_token_macs(shape: &ModelShape) -> f64 {
    ((4 * shape.d_model * shape.d_model + 3 * shape.d_model * shape.d_ff)
        * shape.n_layers) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::{BITNET_0_73B, E2E_100M};

    fn mem() -> MemorySystem {
        MemorySystem::for_device(&KV260)
    }

    #[test]
    fn resources_match_table2() {
        let r = TlmmEngine::PAPER.resources();
        assert!((r.lut - 42_854.0).abs() < 600.0, "lut {}", r.lut);
        assert!((r.ff - 50_752.0).abs() < 700.0, "ff {}", r.ff);
        assert_eq!(r.dsp, 320.0);
    }

    #[test]
    fn paper_prefill_rate_anchor() {
        let rate = TlmmEngine::PAPER.tokens_per_sec(&BITNET_0_73B);
        assert!((rate - 148.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn weight_stream_is_the_decode_floor() {
        // ~163 MB packed ternary at the calibrated controller efficiency
        // lands near the 34 ms T_weights the decode endpoints imply.
        let m = mem();
        let t = TlmmEngine::PAPER.weight_stream_time(&BITNET_0_73B, &m);
        assert!((0.028..0.042).contains(&t), "T_weights {:.1} ms", t * 1e3);
    }

    #[test]
    fn decode_projection_is_stream_bound_prefill_is_compute_bound() {
        let m = mem();
        let e = TlmmEngine::PAPER;
        let stream = e.weight_stream_time(&BITNET_0_73B, &m);
        // 1 token (decode): the stream dominates.
        let t1 = e.projection_time(&BITNET_0_73B, 1, &m);
        assert_eq!(t1, stream);
        // 768 tokens (prefill): compute dominates.
        let t768 = e.projection_time(&BITNET_0_73B, 768, &m);
        assert!(t768 > 2.0 * stream);
        assert!((t768 - 768.0 / 148.0).abs() / t768 < 0.05, "t768 {t768}");
    }

    #[test]
    fn smaller_model_streams_faster() {
        let m = mem();
        let e = TlmmEngine::PAPER;
        assert!(
            e.weight_stream_time(&E2E_100M, &m)
                < e.weight_stream_time(&BITNET_0_73B, &m) / 5.0
        );
    }

    #[test]
    fn more_pes_more_throughput() {
        let a = TlmmEngine { n_pe: 160 }.tokens_per_sec(&BITNET_0_73B);
        let b = TlmmEngine { n_pe: 320 }.tokens_per_sec(&BITNET_0_73B);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
