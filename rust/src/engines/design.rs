//! [`AcceleratorDesign`]: a complete accelerator configuration — the unit
//! the DSE searches over and the baselines instantiate.

use anyhow::Result;

use crate::fpga::{
    DeviceConfig, FpgaDevice, ReconfigurableModule, ReconfigurablePartition, RegionPlan,
    ResourceVec, StaticRegion,
};
use crate::model::ModelShape;

use super::attention::{DecodeAttentionEngine, PrefillAttentionEngine, ScheduleQuality};
use super::norm::NormEngine;
use super::tlmm::TlmmEngine;

/// Fixed interface id shared by the attention RMs (DFX pin contract).
pub const ATTN_RP_INTERFACE: u64 = 0x9D5;

/// Misc static logic beyond the named engines: AXI interconnect, DMA
/// engines, controllers, URAM stream buffers (Table 2 row "Other").
pub fn other_static() -> ResourceVec {
    ResourceVec { lut: 21_432.0, ff: 22_402.0, bram36: 34.0, uram: 48.0, dsp: 5.0 }
}

/// Where the attention engines live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionHosting {
    /// PD-Swap: one reconfigurable partition time-multiplexes the two
    /// engines via DPR.
    Reconfigurable,
    /// Static baseline (TeLLMe-like): both engines permanently resident,
    /// shrunken to co-fit.
    StaticBoth,
}

/// A complete accelerator configuration.
#[derive(Debug, Clone)]
pub struct AcceleratorDesign {
    pub name: String,
    pub tlmm: TlmmEngine,
    pub norm: NormEngine,
    pub prefill_attn: PrefillAttentionEngine,
    pub decode_attn: DecodeAttentionEngine,
    pub hosting: AttentionHosting,
}

impl AcceleratorDesign {
    /// The paper's shipped PD-Swap configuration (Table 2).
    pub fn pd_swap() -> Self {
        Self {
            name: "PD-Swap".into(),
            tlmm: TlmmEngine::PAPER,
            norm: NormEngine::PAPER,
            prefill_attn: PrefillAttentionEngine::PAPER,
            decode_attn: DecodeAttentionEngine::PAPER,
            hosting: AttentionHosting::Reconfigurable,
        }
    }

    /// The static baseline: same engine family, both attention engines
    /// resident simultaneously, sized to co-fit the leftover fabric with a
    /// generic shared dataflow and the QKVO port map. This is TeLLMe [10]
    /// as the paper models it.
    pub fn tellme_static() -> Self {
        Self {
            name: "TeLLMe (static)".into(),
            tlmm: TlmmEngine::PAPER,
            norm: NormEngine::PAPER,
            prefill_attn: PrefillAttentionEngine {
                // Most of the leftover area goes to prefill (their prefill
                // is within ~3% of ours — Table 1: 143 vs 148 tok/s).
                n_dsp: 250,
                schedule: ScheduleQuality::Generic,
            },
            decode_attn: DecodeAttentionEngine {
                // The scraps: a small compute-bound decode engine.
                n_dsp: 30,
                schedule: ScheduleQuality::Generic,
                kv_optimized_ports: false,
            },
            hosting: AttentionHosting::StaticBoth,
        }
    }

    /// Static-region inventory shared by every design.
    pub fn static_region(&self) -> StaticRegion {
        let mut sr = StaticRegion::default();
        sr.add("Table Lookup Linear Unit", self.tlmm.resources());
        sr.add("RMSNorm & Find Max Unit", self.norm.resources());
        sr.add("Other", other_static());
        if self.hosting == AttentionHosting::StaticBoth {
            sr.add("Prefill Attention (static)", self.prefill_attn.resources());
            sr.add("Decoding Attention (static)", self.decode_attn.resources());
        }
        sr
    }

    /// Region plan: for PD-Swap the two RMs share one RP; for the static
    /// baseline the RP is a token empty partition (no DPR used).
    pub fn region_plan(&self) -> Result<RegionPlan> {
        let rp = match self.hosting {
            AttentionHosting::Reconfigurable => ReconfigurablePartition::plan(vec![
                ReconfigurableModule::new(
                    "attn-prefill",
                    self.prefill_attn.resources(),
                    ATTN_RP_INTERFACE,
                ),
                ReconfigurableModule::new(
                    "attn-decode",
                    self.decode_attn.resources(),
                    ATTN_RP_INTERFACE,
                ),
            ]),
            AttentionHosting::StaticBoth => ReconfigurablePartition::plan(vec![
                // A minimal dummy RM: static designs still reserve a tiny
                // debug partition in our floorplanner for uniformity.
                ReconfigurableModule::new(
                    "none",
                    ResourceVec::new(64.0, 128.0, 0.0, 0.0, 0.0),
                    ATTN_RP_INTERFACE,
                ),
            ]),
        }
        .map_err(|e| anyhow::anyhow!(e))?;
        Ok(RegionPlan { static_region: self.static_region(), rp })
    }

    /// Program a simulated device with this design.
    pub fn program(&self, device: &DeviceConfig) -> Result<FpgaDevice> {
        FpgaDevice::program(device.clone(), self.region_plan()?)
    }

    /// [`Self::program`] for callers that already validated this design's
    /// floorplan (the DSE/codesign sweeps run the exact
    /// [`crate::fpga::region::validate_budget`] rule on every candidate
    /// before simulating it): the per-device revalidation is skipped, so
    /// the feasibility verdict is paid once per design instead of once
    /// per (policy × trace × batch × pool) cell.
    pub fn program_prevalidated(&self, device: &DeviceConfig) -> Result<FpgaDevice> {
        Ok(FpgaDevice::program_prevalidated(device.clone(), self.region_plan()?))
    }

    /// Activation-buffer cap on multi-stream decode for this design:
    /// every concurrently stepped decode stream needs its own fp16
    /// hidden-state double buffer plus residual (`3 × d_model × 2` bytes)
    /// in on-chip memory. The first stream's buffers are part of the base
    /// design ("Other" static URAM); extra streams must fit the
    /// floorplan's FREE BRAM/URAM headroom on the device — so bigger
    /// attention RMs (a larger pblock) leave room for fewer resident
    /// streams, which is exactly the engine-size ↔ residency trade the
    /// codesign sweep clamps its `--decode-batch` axis with. Designs
    /// whose floorplan does not validate cap at 1, and the result never
    /// exceeds [`Self::DECODE_BATCH_CEILING`].
    pub fn max_decode_batch(&self, device: &DeviceConfig, shape: &ModelShape) -> usize {
        // One BRAM36 block is 36 Kbit; one URAM block is 288 Kbit.
        const BRAM36_BYTES: f64 = 4_608.0;
        const URAM_BYTES: f64 = 36_864.0;
        let Ok(plan) = self.region_plan() else { return 1 };
        let Ok(report) = plan.validate(device) else { return 1 };
        let free = device.resources - report.total;
        let headroom_bytes =
            free.bram36.max(0.0) * BRAM36_BYTES + free.uram.max(0.0) * URAM_BYTES;
        let per_stream_bytes = (3 * shape.d_model) as f64 * 2.0;
        let extra = (headroom_bytes / per_stream_bytes)
            .floor()
            .clamp(0.0, (Self::DECODE_BATCH_CEILING - 1) as f64);
        1 + extra as usize
    }

    /// Hard ceiling on [`Self::max_decode_batch`]: even with unbounded
    /// on-chip headroom (a far larger part than the KV260), the model
    /// refuses more than this many concurrently stepped decode streams —
    /// past it the shared-weight-stream amortization is far beyond its
    /// knee (`B* = T_weights · tps` ≈ single digits on the paper design)
    /// and control/scheduling overheads the resource model does not
    /// capture would dominate.
    pub const DECODE_BATCH_CEILING: usize = 64;

    /// Total resources if everything had to be resident at once (the
    /// Table 2 "Equivalent Total" for PD-Swap; the actual total for the
    /// static baseline).
    pub fn equivalent_total(&self) -> ResourceVec {
        self.static_region().total()
            + match self.hosting {
                AttentionHosting::Reconfigurable => {
                    self.prefill_attn.resources() + self.decode_attn.resources()
                }
                AttentionHosting::StaticBoth => ResourceVec::ZERO,
            }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;

    #[test]
    fn pd_swap_fits_kv260() {
        let d = AcceleratorDesign::pd_swap();
        let plan = d.region_plan().unwrap();
        let report = plan.validate(&KV260).unwrap();
        // Paper Table 2: 87% LUT utilization.
        assert!(
            (0.80..=0.90).contains(&report.peak_utilization),
            "peak {:.3}",
            report.peak_utilization
        );
    }

    #[test]
    fn tellme_static_fits_kv260() {
        let d = AcceleratorDesign::tellme_static();
        d.region_plan().unwrap().validate(&KV260).unwrap();
    }

    #[test]
    fn pd_swap_equivalent_exceeds_chip() {
        // The Table 2 headline: equivalent logic > 100% of the XCK26.
        let d = AcceleratorDesign::pd_swap();
        let eq = d.equivalent_total();
        assert!(
            eq.lut > KV260.resources.lut,
            "equivalent {:.0} LUT should exceed {:.0}",
            eq.lut,
            KV260.resources.lut
        );
    }

    #[test]
    fn paper_sized_rms_do_not_both_fit_statically() {
        // If we try to keep the PAPER-sized engines resident together the
        // floorplan must blow the routability ceiling — this is precisely
        // why the baseline must shrink them (and why DPR wins).
        let mut d = AcceleratorDesign::tellme_static();
        d.prefill_attn = PrefillAttentionEngine::PAPER;
        d.decode_attn = DecodeAttentionEngine::PAPER;
        let plan = d.region_plan().unwrap();
        assert!(plan.validate(&KV260).is_err());
    }

    #[test]
    fn programs_a_device() {
        let dev = AcceleratorDesign::pd_swap().program(&KV260).unwrap();
        let ms = dev.reconfig_latency() * 1e3;
        assert!((35.0..55.0).contains(&ms), "reconfig {ms:.1} ms");
    }

    #[test]
    fn prevalidated_programming_matches_validated() {
        let d = AcceleratorDesign::pd_swap();
        let a = d.program(&KV260).unwrap();
        let b = d.program_prevalidated(&KV260).unwrap();
        assert_eq!(
            a.reconfig_latency().to_bits(),
            b.reconfig_latency().to_bits(),
            "skipping revalidation must not change the programmed device"
        );
    }

    #[test]
    fn decode_batch_cap_tracks_floorplan_headroom() {
        use crate::model::BITNET_0_73B;
        let paper = AcceleratorDesign::pd_swap();
        let cap = paper.max_decode_batch(&KV260, &BITNET_0_73B);
        // The shipped design leaves a few BRAM/URAM blocks free: several
        // streams fit, but nothing unbounded.
        assert!((4..=64).contains(&cap), "paper cap {cap}");
        // A smaller decode RM shrinks the pblock and frees on-chip
        // memory: the cap can only grow.
        let mut small = AcceleratorDesign::pd_swap();
        small.prefill_attn.n_dsp = 250;
        small.decode_attn.n_dsp = 150;
        let cap_small = small.max_decode_batch(&KV260, &BITNET_0_73B);
        assert!(cap_small >= cap, "small RMs {cap_small} vs paper {cap}");
        // An infeasible floorplan caps at the paper's single stream.
        let mut broken = AcceleratorDesign::pd_swap();
        broken.prefill_attn.n_dsp = 800;
        assert_eq!(broken.max_decode_batch(&KV260, &BITNET_0_73B), 1);
    }
}
