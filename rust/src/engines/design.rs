//! [`AcceleratorDesign`]: a complete accelerator configuration — the unit
//! the DSE searches over and the baselines instantiate.

use anyhow::Result;

use crate::fpga::{
    DeviceConfig, FpgaDevice, ReconfigurableModule, ReconfigurablePartition, RegionPlan,
    ResourceVec, StaticRegion,
};

use super::attention::{DecodeAttentionEngine, PrefillAttentionEngine, ScheduleQuality};
use super::norm::NormEngine;
use super::tlmm::TlmmEngine;

/// Fixed interface id shared by the attention RMs (DFX pin contract).
pub const ATTN_RP_INTERFACE: u64 = 0x9D5;

/// Misc static logic beyond the named engines: AXI interconnect, DMA
/// engines, controllers, URAM stream buffers (Table 2 row "Other").
pub fn other_static() -> ResourceVec {
    ResourceVec { lut: 21_432.0, ff: 22_402.0, bram36: 34.0, uram: 48.0, dsp: 5.0 }
}

/// Where the attention engines live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionHosting {
    /// PD-Swap: one reconfigurable partition time-multiplexes the two
    /// engines via DPR.
    Reconfigurable,
    /// Static baseline (TeLLMe-like): both engines permanently resident,
    /// shrunken to co-fit.
    StaticBoth,
}

/// A complete accelerator configuration.
#[derive(Debug, Clone)]
pub struct AcceleratorDesign {
    pub name: String,
    pub tlmm: TlmmEngine,
    pub norm: NormEngine,
    pub prefill_attn: PrefillAttentionEngine,
    pub decode_attn: DecodeAttentionEngine,
    pub hosting: AttentionHosting,
}

impl AcceleratorDesign {
    /// The paper's shipped PD-Swap configuration (Table 2).
    pub fn pd_swap() -> Self {
        Self {
            name: "PD-Swap".into(),
            tlmm: TlmmEngine::PAPER,
            norm: NormEngine::PAPER,
            prefill_attn: PrefillAttentionEngine::PAPER,
            decode_attn: DecodeAttentionEngine::PAPER,
            hosting: AttentionHosting::Reconfigurable,
        }
    }

    /// The static baseline: same engine family, both attention engines
    /// resident simultaneously, sized to co-fit the leftover fabric with a
    /// generic shared dataflow and the QKVO port map. This is TeLLMe [10]
    /// as the paper models it.
    pub fn tellme_static() -> Self {
        Self {
            name: "TeLLMe (static)".into(),
            tlmm: TlmmEngine::PAPER,
            norm: NormEngine::PAPER,
            prefill_attn: PrefillAttentionEngine {
                // Most of the leftover area goes to prefill (their prefill
                // is within ~3% of ours — Table 1: 143 vs 148 tok/s).
                n_dsp: 250,
                schedule: ScheduleQuality::Generic,
            },
            decode_attn: DecodeAttentionEngine {
                // The scraps: a small compute-bound decode engine.
                n_dsp: 30,
                schedule: ScheduleQuality::Generic,
                kv_optimized_ports: false,
            },
            hosting: AttentionHosting::StaticBoth,
        }
    }

    /// Static-region inventory shared by every design.
    pub fn static_region(&self) -> StaticRegion {
        let mut sr = StaticRegion::default();
        sr.add("Table Lookup Linear Unit", self.tlmm.resources());
        sr.add("RMSNorm & Find Max Unit", self.norm.resources());
        sr.add("Other", other_static());
        if self.hosting == AttentionHosting::StaticBoth {
            sr.add("Prefill Attention (static)", self.prefill_attn.resources());
            sr.add("Decoding Attention (static)", self.decode_attn.resources());
        }
        sr
    }

    /// Region plan: for PD-Swap the two RMs share one RP; for the static
    /// baseline the RP is a token empty partition (no DPR used).
    pub fn region_plan(&self) -> Result<RegionPlan> {
        let rp = match self.hosting {
            AttentionHosting::Reconfigurable => ReconfigurablePartition::plan(vec![
                ReconfigurableModule::new(
                    "attn-prefill",
                    self.prefill_attn.resources(),
                    ATTN_RP_INTERFACE,
                ),
                ReconfigurableModule::new(
                    "attn-decode",
                    self.decode_attn.resources(),
                    ATTN_RP_INTERFACE,
                ),
            ]),
            AttentionHosting::StaticBoth => ReconfigurablePartition::plan(vec![
                // A minimal dummy RM: static designs still reserve a tiny
                // debug partition in our floorplanner for uniformity.
                ReconfigurableModule::new(
                    "none",
                    ResourceVec::new(64.0, 128.0, 0.0, 0.0, 0.0),
                    ATTN_RP_INTERFACE,
                ),
            ]),
        }
        .map_err(|e| anyhow::anyhow!(e))?;
        Ok(RegionPlan { static_region: self.static_region(), rp })
    }

    /// Program a simulated device with this design.
    pub fn program(&self, device: &DeviceConfig) -> Result<FpgaDevice> {
        FpgaDevice::program(device.clone(), self.region_plan()?)
    }

    /// Total resources if everything had to be resident at once (the
    /// Table 2 "Equivalent Total" for PD-Swap; the actual total for the
    /// static baseline).
    pub fn equivalent_total(&self) -> ResourceVec {
        self.static_region().total()
            + match self.hosting {
                AttentionHosting::Reconfigurable => {
                    self.prefill_attn.resources() + self.decode_attn.resources()
                }
                AttentionHosting::StaticBoth => ResourceVec::ZERO,
            }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;

    #[test]
    fn pd_swap_fits_kv260() {
        let d = AcceleratorDesign::pd_swap();
        let plan = d.region_plan().unwrap();
        let report = plan.validate(&KV260).unwrap();
        // Paper Table 2: 87% LUT utilization.
        assert!(
            (0.80..=0.90).contains(&report.peak_utilization),
            "peak {:.3}",
            report.peak_utilization
        );
    }

    #[test]
    fn tellme_static_fits_kv260() {
        let d = AcceleratorDesign::tellme_static();
        d.region_plan().unwrap().validate(&KV260).unwrap();
    }

    #[test]
    fn pd_swap_equivalent_exceeds_chip() {
        // The Table 2 headline: equivalent logic > 100% of the XCK26.
        let d = AcceleratorDesign::pd_swap();
        let eq = d.equivalent_total();
        assert!(
            eq.lut > KV260.resources.lut,
            "equivalent {:.0} LUT should exceed {:.0}",
            eq.lut,
            KV260.resources.lut
        );
    }

    #[test]
    fn paper_sized_rms_do_not_both_fit_statically() {
        // If we try to keep the PAPER-sized engines resident together the
        // floorplan must blow the routability ceiling — this is precisely
        // why the baseline must shrink them (and why DPR wins).
        let mut d = AcceleratorDesign::tellme_static();
        d.prefill_attn = PrefillAttentionEngine::PAPER;
        d.decode_attn = DecodeAttentionEngine::PAPER;
        let plan = d.region_plan().unwrap();
        assert!(plan.validate(&KV260).is_err());
    }

    #[test]
    fn programs_a_device() {
        let dev = AcceleratorDesign::pd_swap().program(&KV260).unwrap();
        let ms = dev.reconfig_latency() * 1e3;
        assert!((35.0..55.0).contains(&ms), "reconfig {ms:.1} ms");
    }
}
