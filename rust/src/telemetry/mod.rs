//! Deterministic virtual-clock telemetry: phase-span tracing,
//! swap-decision attribution, and Chrome-trace export.
//!
//! Both serving engines ([`crate::coordinator::events::EventServer`] and
//! [`crate::coordinator::sim_server::SimServer`]) drive a
//! [`TraceRecorder`] keyed to their deterministic virtual clock. The
//! recorder captures four families of telemetry:
//!
//! * **Request lifecycle spans** (`cat = "request"`, one track per
//!   request): `queued` (arrival → admission), `prefill` / `re-prefill`
//!   (with per-layer `layer` instants and the §3.4 `trigger` instant),
//!   and one `decode-step` span per generated token — batched steps are
//!   attributed to *every* member stream, so a track reads as that
//!   stream's own timeline.
//! * **DPR swap spans** (`cat = "swap"`, the RP-region track): one span
//!   per PCAP load, carrying the derived `hidden_fraction` — how much of
//!   the reconfiguration latency was overlapped with concurrent compute,
//!   the paper's §3.4 mechanism (`hidden_fraction(latency, exposed)`).
//! * **KV-pool instants** (`cat = "kv"`): admit / reject / evict /
//!   release with pool occupancy at that virtual instant.
//! * **Swap-policy decision records** (`cat = "policy"`): at every
//!   Eager/Hysteresis/Lookahead decision point, the full
//!   [`SwapOutlook`] snapshot, the chosen action, and the policy's own
//!   cost operands ([`SwapPolicy::decision_costs`]).
//!
//! **Determinism invariant:** every timestamp comes from the virtual
//! clock and every record call sits on a deterministic engine code path,
//! so the exported trace is *byte-identical* across runs and across
//! `util::par` thread counts (pinned by tests). **Zero-overhead off
//! path:** a disabled recorder ([`TraceRecorder::disabled`]) holds an
//! empty `Vec` (no allocation) and every record method returns before
//! touching it; the recorder only ever *reads* clock values, never feeds
//! simulation arithmetic, so a disabled-recorder run is bitwise
//! identical to a pre-telemetry run — the `hotpath_kernel`
//! counting-allocator bench gates the off path at ~0 allocs/token.
//!
//! Export is Chrome trace-event JSON ([`TraceRecorder::to_chrome_json`],
//! the `{"traceEvents": [...]}` format): load the file in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`. One process groups
//! the request tracks, a second groups the engine tracks (fabric slot,
//! RP region, KV pool, policy decisions).

use std::fmt::Write as _;

use crate::reconfig::{DecisionPoint, SwapOutlook, SwapPolicy};
use crate::util::json::Value;

/// Process id grouping the per-request tracks (`tid` = request id).
pub const PID_REQUESTS: u32 = 1;
/// Process id grouping the engine tracks below.
pub const PID_ENGINE: u32 = 2;
/// Engine track: the compute fabric slot (prefill/decode occupancy).
pub const TID_FABRIC: u64 = 1;
/// Engine track: the reconfigurable partition (PCAP swap spans).
pub const TID_RP: u64 = 2;
/// Engine track: KV-pool admit/reject/evict/release instants.
pub const TID_KV_POOL: u64 = 3;
/// Engine track: swap-policy decision records.
pub const TID_POLICY: u64 = 4;
/// Engine track: fault injection (extension #10) — DDR brownout window
/// spans, PCAP failure/retry instants, degraded-mode enter/exit, shed
/// records.
pub const TID_FAULT: u64 = 5;

/// One recorded event. Names and categories are `&'static str` and args
/// are numbers or static strings, so recording never allocates per-field
/// (only the containing `Vec`s grow, and only while enabled).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub cat: &'static str,
    /// Chrome phase: `'X'` complete span, `'i'` instant.
    pub ph: char,
    /// Start (or instant) time, virtual seconds.
    pub ts_s: f64,
    /// Span duration, virtual seconds (`0.0` for instants).
    pub dur_s: f64,
    pub pid: u32,
    pub tid: u64,
    pub args: Vec<(&'static str, Arg)>,
}

/// Argument payload of a [`TraceEvent`].
#[derive(Debug, Clone, Copy)]
pub enum Arg {
    Num(f64),
    Str(&'static str),
}

impl Arg {
    fn to_json(self) -> Value {
        match self {
            Arg::Num(n) => Value::Num(n),
            Arg::Str(s) => Value::Str(s.to_string()),
        }
    }
}

/// The fraction of a PCAP load hidden behind concurrent compute — the
/// paper's §3.4 overlap metric, derived from the *exposed* (stall) part
/// the engines already account: `(latency − exposed) / latency`, clamped
/// to `[0, 1]`. A zero/negative latency yields `0.0` (nothing to hide).
pub fn hidden_fraction(reconfig_latency: f64, exposed: f64) -> f64 {
    if reconfig_latency <= 0.0 {
        return 0.0;
    }
    ((reconfig_latency - exposed).max(0.0) / reconfig_latency).min(1.0)
}

/// Span/instant recorder keyed to a serving engine's virtual clock.
///
/// Disabled by default everywhere: the engines construct one from their
/// config's `trace` flag and every record method is a no-op branch when
/// disabled. See the module docs for the span taxonomy and the
/// determinism invariant.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    enabled: bool,
    events: Vec<TraceEvent>,
}

impl TraceRecorder {
    /// The inert recorder: no allocation, every record call is a single
    /// predictable branch.
    pub fn disabled() -> Self {
        Self { enabled: false, events: Vec::new() }
    }

    pub fn enabled() -> Self {
        Self { enabled: true, events: Vec::new() }
    }

    pub fn from_flag(trace: bool) -> Self {
        if trace {
            Self::enabled()
        } else {
            Self::disabled()
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Recorded policy decision records (`cat = "policy"`).
    pub fn decision_count(&self) -> usize {
        self.events.iter().filter(|e| e.cat == "policy").count()
    }

    // -- low-level records --------------------------------------------------

    /// Record a complete span (`ph = 'X'`). Engines call this at the
    /// moment the span's start AND duration are both known on the
    /// virtual timeline (at scheduling, since event durations are
    /// analytic), which keeps every track's emission order monotone in
    /// `ts` — the well-formedness property `trace_check` validates.
    pub fn span(
        &mut self,
        name: &'static str,
        cat: &'static str,
        pid: u32,
        tid: u64,
        start_s: f64,
        dur_s: f64,
        args: &[(&'static str, Arg)],
    ) {
        if !self.enabled {
            return;
        }
        self.events.push(TraceEvent {
            name,
            cat,
            ph: 'X',
            ts_s: start_s,
            dur_s: dur_s.max(0.0),
            pid,
            tid,
            args: args.to_vec(),
        });
    }

    /// Record an instant (`ph = 'i'`, thread scope).
    pub fn instant(
        &mut self,
        name: &'static str,
        cat: &'static str,
        pid: u32,
        tid: u64,
        ts_s: f64,
        args: &[(&'static str, Arg)],
    ) {
        if !self.enabled {
            return;
        }
        self.events.push(TraceEvent {
            name,
            cat,
            ph: 'i',
            ts_s,
            dur_s: 0.0,
            pid,
            tid,
            args: args.to_vec(),
        });
    }

    // -- request lifecycle --------------------------------------------------

    /// Queue wait: arrival → admission into prefill.
    pub fn request_queued(&mut self, id: u64, arrival_s: f64, admitted_s: f64) {
        self.span("queued", "request", PID_REQUESTS, id, arrival_s, admitted_s - arrival_s, &[]);
    }

    /// One prefill pass (`re-prefill` = post-eviction recompute).
    pub fn prefill_span(
        &mut self,
        id: u64,
        start_s: f64,
        dur_s: f64,
        prompt_tokens: usize,
        recompute: bool,
    ) {
        let name = if recompute { "re-prefill" } else { "prefill" };
        self.span(
            name,
            "request",
            PID_REQUESTS,
            id,
            start_s,
            dur_s,
            &[("prompt_tokens", Arg::Num(prompt_tokens as f64))],
        );
    }

    /// Per-layer prefill completion instant.
    pub fn prefill_layer(&mut self, id: u64, ts_s: f64, layer: usize) {
        self.instant(
            "layer",
            "request",
            PID_REQUESTS,
            id,
            ts_s,
            &[("layer", Arg::Num(layer as f64))],
        );
    }

    /// The §3.4 final-layer-attention trigger instant.
    pub fn trigger(&mut self, id: u64, ts_s: f64) {
        self.instant("trigger", "request", PID_REQUESTS, id, ts_s, &[]);
    }

    /// One decode token-step, attributed to member stream `id` of a
    /// batch of `batch` streams at context `ctx`.
    pub fn decode_step(&mut self, id: u64, start_s: f64, dur_s: f64, batch: usize, ctx: usize) {
        self.span(
            "decode-step",
            "request",
            PID_REQUESTS,
            id,
            start_s,
            dur_s,
            &[("batch", Arg::Num(batch as f64)), ("ctx", Arg::Num(ctx as f64))],
        );
    }

    /// A fast-forwarded decode stretch: `k` token-steps of member stream
    /// `id` (of a batch of `batch` streams, entering at context `ctx`)
    /// folded analytically into one span — the coalesced form of `k`
    /// consecutive [`Self::decode_step`] spans. `step0_s` is the exact
    /// duration of the first folded step (the TTFT-relevant one;
    /// per-step durations grow with context, so `step_s` in the args is
    /// the mean `dur/k`). Emitted per member at the fold's end with the
    /// fold's entry timestamp — per-track `ts` order stays monotone
    /// because the fold emits nothing else in between.
    pub fn decode_fast_forward(
        &mut self,
        id: u64,
        start_s: f64,
        dur_s: f64,
        k: usize,
        batch: usize,
        ctx: usize,
        step0_s: f64,
    ) {
        if k == 0 {
            return;
        }
        self.span(
            "decode-ff",
            "request",
            PID_REQUESTS,
            id,
            start_s,
            dur_s,
            &[
                ("k", Arg::Num(k as f64)),
                ("step_s", Arg::Num(dur_s / k as f64)),
                ("step0_s", Arg::Num(step0_s)),
                ("batch", Arg::Num(batch as f64)),
                ("ctx", Arg::Num(ctx as f64)),
            ],
        );
    }

    // -- DPR swaps ----------------------------------------------------------

    /// One PCAP load on the RP-region track, `start → ready`, with the
    /// derived §3.4 overlap attribution: `exposed_s` is the part that
    /// stalled serving, the rest was hidden behind concurrent compute.
    pub fn swap_span(
        &mut self,
        start_s: f64,
        ready_s: f64,
        to_decode: bool,
        reconfig_latency_s: f64,
        exposed_s: f64,
    ) {
        let name = if to_decode { "pcap-to-decode" } else { "pcap-to-prefill" };
        let hidden = hidden_fraction(reconfig_latency_s, exposed_s);
        self.span(
            name,
            "swap",
            PID_ENGINE,
            TID_RP,
            start_s,
            ready_s - start_s,
            &[
                ("reconfig_latency_s", Arg::Num(reconfig_latency_s)),
                ("exposed_s", Arg::Num(exposed_s)),
                ("hidden_fraction", Arg::Num(hidden)),
            ],
        );
    }

    // -- KV pool ------------------------------------------------------------

    /// KV-pool instant (`kv-admit` / `kv-reject` / `kv-evict` /
    /// `kv-release`) with the pool occupancy at that virtual instant.
    pub fn kv_instant(
        &mut self,
        name: &'static str,
        ts_s: f64,
        id: u64,
        used_pages: usize,
        total_pages: usize,
    ) {
        self.instant(
            name,
            "kv",
            PID_ENGINE,
            TID_KV_POOL,
            ts_s,
            &[
                ("id", Arg::Num(id as f64)),
                ("used_pages", Arg::Num(used_pages as f64)),
                ("total_pages", Arg::Num(total_pages as f64)),
            ],
        );
    }

    // -- fault injection (extension #10) -------------------------------------

    /// One DDR brownout window as a span on the fault track. Emitted
    /// lazily when the window *opens* (its open/close times are both
    /// known from the plan), which keeps the track's emission order
    /// monotone in `ts`.
    pub fn fault_window(&mut self, start_s: f64, dur_s: f64, bw_scale: f64) {
        self.span(
            "ddr-brownout",
            "fault",
            PID_ENGINE,
            TID_FAULT,
            start_s,
            dur_s,
            &[("bw_scale", Arg::Num(bw_scale))],
        );
    }

    /// A PCAP load attempt failed (`streak` = consecutive failures of
    /// the current logical swap chain).
    pub fn swap_failed(&mut self, ts_s: f64, streak: u32, to_decode: bool) {
        self.instant(
            "pcap-fail",
            "fault",
            PID_ENGINE,
            TID_FAULT,
            ts_s,
            &[
                ("streak", Arg::Num(streak as f64)),
                ("target", Arg::Str(if to_decode { "decode" } else { "prefill" })),
            ],
        );
    }

    /// A post-backoff PCAP load re-issue (retry or degraded-mode
    /// repair); `load_s` is the load latency being re-paid.
    pub fn swap_retry(&mut self, ts_s: f64, attempt: u32, load_s: f64) {
        self.instant(
            "pcap-retry",
            "fault",
            PID_ENGINE,
            TID_FAULT,
            ts_s,
            &[("attempt", Arg::Num(attempt as f64)), ("load_s", Arg::Num(load_s))],
        );
    }

    /// Degraded-mode entry (swap retries exhausted; serving falls back
    /// to the static-unified pricing). Instants, not a span: the exit
    /// time is unknown at entry, and spans must be emitted with both
    /// endpoints known to keep per-track `ts` monotone.
    pub fn degraded_enter(&mut self, ts_s: f64) {
        self.instant("degraded-enter", "fault", PID_ENGINE, TID_FAULT, ts_s, &[]);
    }

    /// Degraded-mode exit (a background repair load landed).
    pub fn degraded_exit(&mut self, ts_s: f64) {
        self.instant("degraded-exit", "fault", PID_ENGINE, TID_FAULT, ts_s, &[]);
    }

    /// A request shed (`reason` = `"deadline"` / `"fail-stop"`).
    pub fn request_shed(&mut self, id: u64, ts_s: f64, reason: &'static str) {
        self.instant(
            "shed",
            "fault",
            PID_ENGINE,
            TID_FAULT,
            ts_s,
            &[("id", Arg::Num(id as f64)), ("reason", Arg::Str(reason))],
        );
    }

    // -- policy decisions ---------------------------------------------------

    /// One swap-policy consultation: the full [`SwapOutlook`] snapshot,
    /// the cost operands the policy compared
    /// ([`SwapPolicy::decision_costs`]: swap ⟺ `in_favor >= threshold`),
    /// and the action taken.
    pub fn decision(
        &mut self,
        ts_s: f64,
        policy: &SwapPolicy,
        point: DecisionPoint,
        o: &SwapOutlook,
        swapped: bool,
    ) {
        if !self.enabled {
            return;
        }
        let (in_favor, threshold) = policy.decision_costs(point, o);
        self.instant(
            point.name(),
            "policy",
            PID_ENGINE,
            TID_POLICY,
            ts_s,
            &[
                ("policy", Arg::Str(policy.name())),
                ("action", Arg::Str(if swapped { "swap" } else { "stay" })),
                ("in_favor", Arg::Num(in_favor)),
                ("threshold", Arg::Num(threshold)),
                ("pending_prefill", Arg::Num(o.pending_prefill as f64)),
                ("pending_prefill_tokens", Arg::Num(o.pending_prefill_tokens as f64)),
                ("est_prefill_time", Arg::Num(o.est_prefill_time)),
                ("decode_ready", Arg::Num(o.decode_ready as f64)),
                ("decode_pending_tokens", Arg::Num(o.decode_pending_tokens as f64)),
                ("est_decode_step", Arg::Num(o.est_decode_step)),
                ("reconfig_latency", Arg::Num(o.reconfig_latency)),
                ("est_round_trip_exposed", Arg::Num(o.est_round_trip_exposed)),
            ],
        );
    }

    // -- export -------------------------------------------------------------

    /// The Chrome trace-event document: `{"traceEvents": [...]}` with
    /// metadata (process/thread names) leading, then every recorded
    /// event in emission order, timestamps in microseconds. Serialization
    /// is fully deterministic (insertion-ordered objects, deterministic
    /// float formatting), so equal recordings produce equal bytes.
    pub fn to_chrome_json(&self) -> Value {
        let mut out: Vec<Value> = Vec::with_capacity(self.events.len() + 16);

        // Metadata: name each process once and each track on first
        // appearance (emission order, hence deterministic).
        let mut seen: Vec<(u32, u64)> = Vec::new();
        for e in &self.events {
            if !seen.contains(&(e.pid, e.tid)) {
                seen.push((e.pid, e.tid));
            }
        }
        let mut seen_pids: Vec<u32> = Vec::new();
        for &(pid, _) in &seen {
            if !seen_pids.contains(&pid) {
                seen_pids.push(pid);
                let pname = match pid {
                    PID_REQUESTS => "requests".to_string(),
                    PID_ENGINE => "engine".to_string(),
                    other => format!("process {other}"),
                };
                out.push(Value::Obj(vec![
                    ("name".into(), Value::Str("process_name".into())),
                    ("ph".into(), Value::Str("M".into())),
                    ("pid".into(), Value::Num(pid as f64)),
                    ("tid".into(), Value::Num(0.0)),
                    (
                        "args".into(),
                        Value::Obj(vec![("name".into(), Value::Str(pname))]),
                    ),
                ]));
            }
        }
        for &(pid, tid) in &seen {
            let tname = match (pid, tid) {
                (PID_REQUESTS, id) => format!("req {id}"),
                (PID_ENGINE, TID_FABRIC) => "fabric".to_string(),
                (PID_ENGINE, TID_RP) => "rp-region".to_string(),
                (PID_ENGINE, TID_KV_POOL) => "kv-pool".to_string(),
                (PID_ENGINE, TID_POLICY) => "swap-policy".to_string(),
                (PID_ENGINE, TID_FAULT) => "faults".to_string(),
                (_, t) => format!("track {t}"),
            };
            out.push(Value::Obj(vec![
                ("name".into(), Value::Str("thread_name".into())),
                ("ph".into(), Value::Str("M".into())),
                ("pid".into(), Value::Num(pid as f64)),
                ("tid".into(), Value::Num(tid as f64)),
                (
                    "args".into(),
                    Value::Obj(vec![("name".into(), Value::Str(tname))]),
                ),
            ]));
        }

        for e in &self.events {
            let mut pairs: Vec<(String, Value)> = vec![
                ("name".into(), Value::Str(e.name.into())),
                ("cat".into(), Value::Str(e.cat.into())),
                ("ph".into(), Value::Str(e.ph.to_string())),
                ("ts".into(), Value::Num(e.ts_s * 1e6)),
            ];
            if e.ph == 'X' {
                pairs.push(("dur".into(), Value::Num(e.dur_s * 1e6)));
            }
            pairs.push(("pid".into(), Value::Num(e.pid as f64)));
            pairs.push(("tid".into(), Value::Num(e.tid as f64)));
            if e.ph == 'i' {
                pairs.push(("s".into(), Value::Str("t".into())));
            }
            if !e.args.is_empty() {
                pairs.push((
                    "args".into(),
                    Value::Obj(
                        e.args.iter().map(|(k, v)| ((*k).to_string(), v.to_json())).collect(),
                    ),
                ));
            }
            out.push(Value::Obj(pairs));
        }

        Value::Obj(vec![
            ("traceEvents".into(), Value::Arr(out)),
            ("displayTimeUnit".into(), Value::Str("ms".into())),
        ])
    }

    /// Write the Chrome trace document to `path` (compact JSON — the
    /// file is *not* wrapped in the bench `ReportEnvelope`; Perfetto
    /// requires the trace object at top level, and byte-identity across
    /// runs is part of the determinism contract).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_json().to_string())
    }

    /// Per-request TTFT/TPOT breakdown derived from the recorded spans:
    /// one row per request track in first-appearance order, splitting
    /// time-to-first-token into queue wait, prefill compute, and swap
    /// wait. Deterministic text (fixed-width, fixed precision).
    pub fn breakdown_table(&self) -> String {
        struct Row {
            id: u64,
            arrival: f64,
            queued: f64,
            prefill: f64,
            prefill_end: f64,
            first_decode_start: Option<f64>,
            first_decode_end: Option<f64>,
            decode_total: f64,
            tokens: usize,
        }
        let mut rows: Vec<Row> = Vec::new();
        for e in self.events.iter().filter(|e| e.pid == PID_REQUESTS) {
            let idx = match rows.iter().position(|r| r.id == e.tid) {
                Some(i) => i,
                None => {
                    rows.push(Row {
                        id: e.tid,
                        arrival: e.ts_s,
                        queued: 0.0,
                        prefill: 0.0,
                        prefill_end: e.ts_s,
                        first_decode_start: None,
                        first_decode_end: None,
                        decode_total: 0.0,
                        tokens: 0,
                    });
                    rows.len() - 1
                }
            };
            let r = &mut rows[idx];
            r.arrival = r.arrival.min(e.ts_s);
            match e.name {
                "queued" => r.queued += e.dur_s,
                "prefill" | "re-prefill" => {
                    r.prefill += e.dur_s;
                    r.prefill_end = r.prefill_end.max(e.ts_s + e.dur_s);
                }
                "decode-step" => {
                    if r.first_decode_start.is_none() {
                        r.first_decode_start = Some(e.ts_s);
                        r.first_decode_end = Some(e.ts_s + e.dur_s);
                    }
                    r.decode_total += e.dur_s;
                    r.tokens += 1;
                }
                // Coalesced fast-forward stretch: k tokens in one span.
                // The first folded step's exact duration rides in
                // `step0_s`, so the TTFT split stays step-accurate.
                "decode-ff" => {
                    let k = e
                        .args
                        .iter()
                        .find(|(n, _)| *n == "k")
                        .and_then(|(_, a)| match a {
                            Arg::Num(v) => Some(*v as usize),
                            _ => None,
                        })
                        .unwrap_or(1);
                    let step0 = e
                        .args
                        .iter()
                        .find(|(n, _)| *n == "step0_s")
                        .and_then(|(_, a)| match a {
                            Arg::Num(v) => Some(*v),
                            _ => None,
                        })
                        .unwrap_or(e.dur_s / k.max(1) as f64);
                    if r.first_decode_start.is_none() {
                        r.first_decode_start = Some(e.ts_s);
                        r.first_decode_end = Some(e.ts_s + step0);
                    }
                    r.decode_total += e.dur_s;
                    r.tokens += k;
                }
                _ => {}
            }
        }

        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:>5} {:>10} {:>9} {:>10} {:>10} {:>10} {:>7} {:>9}",
            "req", "arrival_s", "queue_s", "prefill_s", "swapwait_s", "ttft_s", "tokens", "tpot_ms"
        );
        for r in &rows {
            let swap_wait = r
                .first_decode_start
                .map(|t| (t - r.prefill_end).max(0.0))
                .unwrap_or(0.0);
            let ttft = r.first_decode_end.unwrap_or(r.prefill_end) - r.arrival;
            let tpot_ms = if r.tokens > 0 {
                r.decode_total / r.tokens as f64 * 1e3
            } else {
                0.0
            };
            let _ = writeln!(
                out,
                "{:>5} {:>10.3} {:>9.3} {:>10.3} {:>10.3} {:>10.3} {:>7} {:>9.3}",
                r.id, r.arrival, r.queued, r.prefill, swap_wait, ttft, r.tokens, tpot_ms
            );
        }
        out
    }
}

/// Validate a parsed Chrome trace-event document: the structural
/// well-formedness `trace_check` (and CI) gates — a `traceEvents` array
/// whose entries carry the required fields, every duration non-negative,
/// every `'B'` matched by an `'E'` on its track, and timestamps monotone
/// non-decreasing per `(pid, tid)` track in array order (metadata
/// exempt). Coalesced fast-forward spans (`decode-ff`) additionally
/// must carry numeric `args.k ≥ 1` and `args.step_s ≥ 0` — the token
/// count and mean step a fold stands in for. Shared by
/// `examples/trace_check.rs` and the telemetry tests.
pub fn validate_chrome_trace(doc: &Value) -> Result<usize, String> {
    let Some(events) = doc.get("traceEvents").and_then(Value::as_arr) else {
        return Err("missing traceEvents array".into());
    };
    // (pid, tid) → (last ts, open B-span depth)
    let mut tracks: Vec<((f64, f64), (f64, usize))> = Vec::new();
    let mut checked = 0usize;
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        let name = e
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("event {i}: missing name"))?;
        let pid = e
            .get("pid")
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("event {i}: missing pid"))?;
        let tid = e
            .get("tid")
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("event {i}: missing tid"))?;
        if ph == "M" {
            continue; // metadata carries no timestamp
        }
        let ts = e
            .get("ts")
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("event {i}: missing ts"))?;
        let entry = match tracks.iter_mut().find(|(k, _)| *k == (pid, tid)) {
            Some((_, state)) => state,
            None => {
                tracks.push(((pid, tid), (f64::NEG_INFINITY, 0)));
                &mut tracks.last_mut().unwrap().1
            }
        };
        if ts < entry.0 {
            return Err(format!(
                "event {i}: ts {ts} moves backwards on track ({pid}, {tid}) (last {})",
                entry.0
            ));
        }
        entry.0 = ts;
        match ph {
            "X" => {
                let dur = e
                    .get("dur")
                    .and_then(Value::as_f64)
                    .ok_or_else(|| format!("event {i}: X without dur"))?;
                if dur < 0.0 {
                    return Err(format!("event {i}: negative dur {dur}"));
                }
                if name == "decode-ff" {
                    // A coalesced fold must say what it stands in for.
                    let args = e.get("args");
                    let k = args
                        .and_then(|a| a.get("k"))
                        .and_then(Value::as_f64)
                        .ok_or_else(|| {
                            format!("event {i}: decode-ff without numeric args.k")
                        })?;
                    let step_s = args
                        .and_then(|a| a.get("step_s"))
                        .and_then(Value::as_f64)
                        .ok_or_else(|| {
                            format!("event {i}: decode-ff without numeric args.step_s")
                        })?;
                    if k < 1.0 {
                        return Err(format!("event {i}: decode-ff with k {k} < 1"));
                    }
                    if step_s < 0.0 {
                        return Err(format!(
                            "event {i}: decode-ff with negative step_s {step_s}"
                        ));
                    }
                }
            }
            "B" => entry.1 += 1,
            "E" => {
                if entry.1 == 0 {
                    return Err(format!("event {i}: E without open B on ({pid}, {tid})"));
                }
                entry.1 -= 1;
            }
            "i" | "I" => {}
            other => return Err(format!("event {i}: unsupported ph '{other}'")),
        }
        checked += 1;
    }
    for ((pid, tid), (_, depth)) in &tracks {
        if *depth != 0 {
            return Err(format!("track ({pid}, {tid}): {depth} unclosed B span(s)"));
        }
    }
    Ok(checked)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outlook() -> SwapOutlook {
        SwapOutlook {
            pending_prefill: 2,
            pending_prefill_tokens: 512,
            est_prefill_time: 3.0,
            decode_ready: 1,
            decode_pending_tokens: 64,
            est_decode_step: 0.05,
            reconfig_latency: 0.045,
            est_round_trip_exposed: 0.06,
        }
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut r = TraceRecorder::disabled();
        r.request_queued(1, 0.0, 1.0);
        r.prefill_span(1, 1.0, 2.0, 64, false);
        r.decode_step(1, 3.0, 0.04, 1, 65);
        r.swap_span(2.9, 3.0, true, 0.045, 0.01);
        r.kv_instant("kv-admit", 1.0, 1, 4, 100);
        r.decision(3.0, &SwapPolicy::Eager, DecisionPoint::MidDecode, &outlook(), true);
        assert!(r.is_empty());
        assert_eq!(r.decision_count(), 0);
        // The off path must not even have grown a buffer.
        assert_eq!(r.events.capacity(), 0);
    }

    #[test]
    fn hidden_fraction_clamps() {
        assert_eq!(hidden_fraction(0.045, 0.0), 1.0);
        assert_eq!(hidden_fraction(0.045, 0.045), 0.0);
        assert!((hidden_fraction(0.045, 0.015) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(hidden_fraction(0.045, 0.09), 0.0); // over-exposed clamps
        assert_eq!(hidden_fraction(0.0, 0.0), 0.0); // degenerate latency
    }

    #[test]
    fn export_is_valid_and_deterministic() {
        let mut r = TraceRecorder::enabled();
        r.request_queued(3, 0.0, 0.5);
        r.prefill_span(3, 0.5, 2.0, 128, false);
        r.prefill_layer(3, 1.0, 1);
        r.trigger(3, 2.3);
        r.swap_span(2.3, 2.345, true, 0.045, 0.0);
        r.decode_step(3, 2.5, 0.04, 2, 129);
        r.kv_instant("kv-admit", 0.5, 3, 8, 100);
        r.decision(2.3, &SwapPolicy::lookahead_default(), DecisionPoint::AtTrigger, &outlook(), true);
        let doc = r.to_chrome_json();
        let checked = validate_chrome_trace(&doc).expect("well-formed");
        assert_eq!(checked, r.len());
        assert_eq!(r.decision_count(), 1);
        // Serialization is byte-deterministic.
        assert_eq!(doc.to_string(), r.to_chrome_json().to_string());
        // Round-trips through the parser.
        let back = crate::util::json::parse(&doc.to_string()).unwrap();
        assert!(validate_chrome_trace(&back).is_ok());
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        let bad = crate::util::json::parse(r#"{"traceEvents": 3}"#).unwrap();
        assert!(validate_chrome_trace(&bad).is_err());
        let backwards = crate::util::json::parse(
            r#"{"traceEvents": [
                {"name":"a","ph":"i","ts":5,"pid":1,"tid":1,"s":"t"},
                {"name":"b","ph":"i","ts":4,"pid":1,"tid":1,"s":"t"}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&backwards).unwrap_err().contains("backwards"));
        let unclosed = crate::util::json::parse(
            r#"{"traceEvents": [{"name":"a","ph":"B","ts":1,"pid":1,"tid":1}]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&unclosed).unwrap_err().contains("unclosed"));
        let negdur = crate::util::json::parse(
            r#"{"traceEvents": [{"name":"a","ph":"X","ts":1,"dur":-2,"pid":1,"tid":1}]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&negdur).unwrap_err().contains("negative"));
    }

    #[test]
    fn coalesced_fast_forward_span_validates() {
        let mut r = TraceRecorder::enabled();
        r.request_queued(4, 0.0, 0.5);
        r.prefill_span(4, 0.5, 2.0, 128, false);
        // 99 folded steps in one span, then the completing step.
        r.decode_fast_forward(4, 2.5, 4.95, 99, 1, 129, 0.05);
        r.decode_step(4, 7.45, 0.05, 1, 228);
        let doc = r.to_chrome_json();
        let checked = validate_chrome_trace(&doc).expect("well-formed");
        assert_eq!(checked, r.len());
        // Round-trips through the parser with args intact.
        let back = crate::util::json::parse(&doc.to_string()).unwrap();
        assert!(validate_chrome_trace(&back).is_ok());
        // A zero-step fold records nothing at all.
        let before = r.len();
        r.decode_fast_forward(4, 7.5, 0.0, 0, 1, 228, 0.0);
        assert_eq!(r.len(), before);
    }

    #[test]
    fn validator_rejects_malformed_fast_forward_spans() {
        // decode-ff without args.k
        let no_k = crate::util::json::parse(
            r#"{"traceEvents": [
                {"name":"decode-ff","ph":"X","ts":1,"dur":2,"pid":1,"tid":1,
                 "args":{"step_s":0.05}}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&no_k).unwrap_err().contains("args.k"));
        // decode-ff without args.step_s
        let no_step = crate::util::json::parse(
            r#"{"traceEvents": [
                {"name":"decode-ff","ph":"X","ts":1,"dur":2,"pid":1,"tid":1,
                 "args":{"k":40}}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&no_step).unwrap_err().contains("args.step_s"));
        // k < 1 is meaningless for a coalesced span
        let zero_k = crate::util::json::parse(
            r#"{"traceEvents": [
                {"name":"decode-ff","ph":"X","ts":1,"dur":2,"pid":1,"tid":1,
                 "args":{"k":0,"step_s":0.05}}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&zero_k).unwrap_err().contains("k 0 < 1"));
        // negative mean step
        let neg_step = crate::util::json::parse(
            r#"{"traceEvents": [
                {"name":"decode-ff","ph":"X","ts":1,"dur":2,"pid":1,"tid":1,
                 "args":{"k":4,"step_s":-0.05}}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&neg_step).unwrap_err().contains("negative step_s"));
    }

    #[test]
    fn breakdown_table_counts_coalesced_folds() {
        // The same timeline once stepped, once coalesced: the breakdown
        // must agree on every column (the fold carries the first step's
        // exact duration, so even the TTFT split is step-accurate).
        let mut stepped = TraceRecorder::enabled();
        stepped.request_queued(9, 1.0, 2.0);
        stepped.prefill_span(9, 2.0, 3.0, 256, false);
        stepped.decode_step(9, 5.25, 0.05, 1, 257);
        stepped.decode_step(9, 5.30, 0.05, 1, 258);
        stepped.decode_step(9, 5.35, 0.05, 1, 259);
        stepped.decode_step(9, 5.40, 0.05, 1, 260);
        let mut folded = TraceRecorder::enabled();
        folded.request_queued(9, 1.0, 2.0);
        folded.prefill_span(9, 2.0, 3.0, 256, false);
        // Three folded steps in one span + the completing stepped one.
        folded.decode_fast_forward(9, 5.25, 0.15, 3, 1, 257, 0.05);
        folded.decode_step(9, 5.40, 0.05, 1, 260);
        assert_eq!(stepped.breakdown_table(), folded.breakdown_table());
        assert!(folded.len() < stepped.len());
    }

    #[test]
    fn breakdown_table_splits_ttft() {
        let mut r = TraceRecorder::enabled();
        r.request_queued(7, 1.0, 2.0); // 1 s queued
        r.prefill_span(7, 2.0, 3.0, 256, false); // prefill ends at 5.0
        r.decode_step(7, 5.25, 0.05, 1, 257); // 0.25 s swap wait
        r.decode_step(7, 5.30, 0.05, 1, 258);
        let table = r.breakdown_table();
        let row = table.lines().nth(1).expect("one data row");
        let cols: Vec<&str> = row.split_whitespace().collect();
        assert_eq!(cols[0], "7");
        assert_eq!(cols[1], "1.000"); // arrival
        assert_eq!(cols[2], "1.000"); // queue
        assert_eq!(cols[3], "3.000"); // prefill
        assert_eq!(cols[4], "0.250"); // swap wait
        assert_eq!(cols[5], "4.300"); // ttft = first token end 5.3 − arrival 1.0
        assert_eq!(cols[6], "2"); // tokens
        assert_eq!(cols[7], "50.000"); // tpot ms
    }
}
