//! Serving telemetry: counters and log-bucketed latency histograms.
//!
//! Shared by the simulated coordinator and the live (PJRT) server; the
//! serving example prints these as its latency/throughput report.

use std::fmt;

/// Monotonic counter.
#[derive(Debug, Default, Clone)]
pub struct Counter {
    value: u64,
}

impl Counter {
    pub fn inc(&mut self) {
        self.value += 1;
    }
    pub fn add(&mut self, n: u64) {
        self.value += n;
    }
    pub fn get(&self) -> u64 {
        self.value
    }
}

/// High-water-mark gauge (e.g. peak KV-pool pages committed).
#[derive(Debug, Default, Clone)]
pub struct Peak {
    value: u64,
}

impl Peak {
    /// Record an observation; keeps the maximum seen.
    pub fn observe(&mut self, v: u64) {
        if v > self.value {
            self.value = v;
        }
    }
    pub fn get(&self) -> u64 {
        self.value
    }
}

/// Latency histogram: log-bucketed (microseconds, factor-of-2 buckets
/// from 1 µs to ~1.2 hours) with exact min/max/mean tracking, plus an
/// exact-sample reservoir so p50/p95/p99 are exact for runs up to
/// [`SAMPLE_CAP`] observations (every simulation in this crate) and
/// bucket-approximate beyond that.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    /// First `SAMPLE_CAP` raw observations (exact quantiles).
    samples: Vec<f64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const N_BUCKETS: usize = 32;

/// Exact-quantile reservoir bound (512 KiB of f64 at the cap).
pub const SAMPLE_CAP: usize = 1 << 16;

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: vec![0; N_BUCKETS],
            samples: Vec::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    fn bucket_of(seconds: f64) -> usize {
        let micros = (seconds * 1e6).max(1.0);
        (micros.log2() as usize).min(N_BUCKETS - 1)
    }

    /// Upper edge (seconds) of bucket `i`.
    fn bucket_edge(i: usize) -> f64 {
        (1u64 << (i + 1)) as f64 * 1e-6
    }

    pub fn record(&mut self, seconds: f64) {
        self.buckets[Self::bucket_of(seconds)] += 1;
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(seconds);
        }
        self.count += 1;
        self.sum += seconds;
        self.min = self.min.min(seconds);
        self.max = self.max.max(seconds);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Quantile of the recorded distribution: exact while every
    /// observation is in the sample reservoir, otherwise approximate
    /// from bucket edges. For several quantiles at once use
    /// [`Self::quantiles`], which sorts the reservoir a single time.
    pub fn quantile(&self, q: f64) -> f64 {
        self.quantiles(&[q])[0]
    }

    /// Batch quantiles with one sort of the sample reservoir.
    pub fn quantiles(&self, qs: &[f64]) -> Vec<f64> {
        if self.count == 0 {
            return vec![0.0; qs.len()];
        }
        if self.count as usize <= self.samples.len() {
            let mut xs = self.samples.clone();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            return qs
                .iter()
                .map(|q| {
                    let rank = (q.clamp(0.0, 1.0) * xs.len() as f64).ceil() as usize;
                    xs[rank.max(1).min(xs.len()) - 1]
                })
                .collect();
        }
        qs.iter().map(|&q| self.bucket_quantile(q)).collect()
    }

    /// Bucket-edge estimate (upper bound of the bucket containing the
    /// q-th sample) — the over-reservoir fallback.
    fn bucket_quantile(&self, q: f64) -> f64 {
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target.max(1) {
                return Self::bucket_edge(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    /// `{count, mean, p50, p95, p99, max}` as a JSON object — the shape
    /// the `BENCH_*.json` regression reports use for latency series.
    pub fn summary_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let ps = self.quantiles(&[0.50, 0.95, 0.99]);
        Value::Obj(vec![
            ("count".into(), Value::Num(self.count as f64)),
            ("mean_s".into(), Value::Num(self.mean())),
            ("p50_s".into(), Value::Num(ps[0])),
            ("p95_s".into(), Value::Num(ps[1])),
            ("p99_s".into(), Value::Num(ps[2])),
            ("max_s".into(), Value::Num(self.max())),
        ])
    }
}

impl fmt::Display for Histogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ps = self.quantiles(&[0.50, 0.95, 0.99]);
        write!(
            f,
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.count,
            self.mean() * 1e3,
            ps[0] * 1e3,
            ps[1] * 1e3,
            ps[2] * 1e3,
            self.max() * 1e3
        )
    }
}

/// The serving metric bundle.
#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub requests_completed: Counter,
    pub tokens_generated: Counter,
    pub reconfigurations: Counter,
    /// Reconfigurations loading the prefill RM (continuous serving only;
    /// `reconfigurations` is the sum of both directions).
    pub swaps_to_prefill: Counter,
    /// Reconfigurations loading the decode RM.
    pub swaps_to_decode: Counter,
    /// Time-to-first-token per request.
    pub ttft: Histogram,
    /// Per-token decode latency.
    pub tpot: Histogram,
    /// End-to-end request latency.
    pub e2e: Histogram,
    /// Exposed (non-hidden) reconfiguration latency per swap.
    pub reconfig_exposed: Histogram,
    /// Hidden (overlapped-with-compute) reconfiguration latency per swap
    /// — the complement of [`Self::reconfig_exposed`] within each PCAP
    /// load, the paper's §3.4 mechanism made visible as a metric.
    pub reconfig_hidden: Histogram,
    /// Peak pages committed in the paged KV pool ([`crate::kvpool`]).
    pub kv_pool_high_water: Peak,
    /// Requests evicted from the KV pool (pages reclaimed, KV discarded).
    pub kv_evictions: Counter,
    /// Admissions whose reservation had to be clamped to the pool size.
    pub kv_admissions_capped: Counter,
    /// Time spent re-running prefill for evicted requests (the
    /// evict-and-recompute tax).
    pub recompute_overhead: Histogram,
    /// PCAP partial-reconfiguration attempts that failed (fault
    /// injection, extension #10). Zero on every fault-free run.
    pub swap_failures: Counter,
    /// Failed swaps re-attempted under the retry/backoff policy (the
    /// terminal failure of an exhausted swap is counted in
    /// [`Self::swap_failures`] but not here).
    pub swap_retries: Counter,
    /// Requests shed (SLO deadline exceeded or fail-stop fallback)
    /// instead of completed; `requests_completed + requests_shed` equals
    /// total arrivals.
    pub requests_shed: Counter,
    /// Virtual seconds spent serving in the degraded (static-unified
    /// fallback) engine while the reconfigurable partition was down.
    pub degraded_seconds: f64,
}

impl ServerMetrics {
    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} swaps={} (to-prefill {}, to-decode {})\n  TTFT: {}\n  TPOT: {}\n  E2E:  {}\n  exposed-reconfig: {} (hidden fraction {:.0}%)\n  kv-pool: high-water {} pages, evictions {}, capped admissions {}, recompute {:.1} ms total\n  faults: shed {}, swap failures {} (retries {}), degraded {:.2} s, SLO attainment {:.1}%",
            self.requests_completed.get(),
            self.tokens_generated.get(),
            self.reconfigurations.get(),
            self.swaps_to_prefill.get(),
            self.swaps_to_decode.get(),
            self.ttft,
            self.tpot,
            self.e2e,
            self.reconfig_exposed,
            self.reconfig_hidden_fraction() * 100.0,
            self.kv_pool_high_water.get(),
            self.kv_evictions.get(),
            self.kv_admissions_capped.get(),
            self.recompute_overhead.mean() * self.recompute_overhead.count() as f64 * 1e3,
            self.requests_shed.get(),
            self.swap_failures.get(),
            self.swap_retries.get(),
            self.degraded_seconds,
            self.slo_attainment() * 100.0,
        )
    }

    /// Aggregate decode throughput (tokens/s) implied by TPOT.
    pub fn decode_throughput(&self) -> f64 {
        let m = self.tpot.mean();
        if m == 0.0 { 0.0 } else { 1.0 / m }
    }

    /// Fraction of finished requests that completed within their SLO
    /// (`completed / (completed + shed)`); 1.0 when nothing finished —
    /// an idle node hasn't violated anything.
    pub fn slo_attainment(&self) -> f64 {
        let done = self.requests_completed.get();
        let total = done + self.requests_shed.get();
        if total == 0 { 1.0 } else { done as f64 / total as f64 }
    }

    /// SLO goodput over a run of `makespan` seconds: tokens that reached
    /// *completed* requests per second of wall (virtual) time. Shed
    /// requests' partial tokens are excluded — `tokens_generated` only
    /// counts completions — which is exactly what a fleet router should
    /// price a degraded node by.
    pub fn slo_goodput_tps(&self, makespan: f64) -> f64 {
        if makespan <= 0.0 { 0.0 } else { self.tokens_generated.get() as f64 / makespan }
    }

    /// Record one exposure-accounted PCAP load: `exposed` seconds
    /// stalled serving, the remainder of `reconfig_latency` was hidden
    /// behind concurrent compute (§3.4). Feeds both histograms so
    /// [`Self::reconfig_hidden_fraction`] is a pure aggregate.
    pub fn record_reconfig_exposure(&mut self, reconfig_latency: f64, exposed: f64) {
        self.reconfig_exposed.record(exposed);
        self.reconfig_hidden
            .record((reconfig_latency - exposed).max(0.0).min(reconfig_latency.max(0.0)));
    }

    /// Aggregate fraction of exposure-accounted reconfiguration time
    /// hidden behind compute: `hidden / (hidden + exposed)` over every
    /// swap recorded via [`Self::record_reconfig_exposure`]; `0.0` when
    /// no swap has been accounted yet.
    pub fn reconfig_hidden_fraction(&self) -> f64 {
        let hidden = self.reconfig_hidden.mean() * self.reconfig_hidden.count() as f64;
        let exposed = self.reconfig_exposed.mean() * self.reconfig_exposed.count() as f64;
        let total = hidden + exposed;
        if total <= 0.0 { 0.0 } else { hidden / total }
    }

    /// The registry view: every metric under a stable name.
    pub fn registry(&self) -> MetricsRegistry<'_> {
        MetricsRegistry {
            counters: vec![
                ("requests_completed", &self.requests_completed),
                ("tokens_generated", &self.tokens_generated),
                ("reconfigurations", &self.reconfigurations),
                ("swaps_to_prefill", &self.swaps_to_prefill),
                ("swaps_to_decode", &self.swaps_to_decode),
                ("kv_evictions", &self.kv_evictions),
                ("kv_admissions_capped", &self.kv_admissions_capped),
                ("swap_failures", &self.swap_failures),
                ("swap_retries", &self.swap_retries),
                ("requests_shed", &self.requests_shed),
            ],
            gauges: vec![
                ("kv_pool_high_water_pages", self.kv_pool_high_water.get() as f64),
                ("decode_throughput_tps", self.decode_throughput()),
                ("reconfig_hidden_fraction", self.reconfig_hidden_fraction()),
                ("degraded_seconds", self.degraded_seconds),
                ("slo_attainment", self.slo_attainment()),
            ],
            histograms: vec![
                ("ttft", &self.ttft),
                ("tpot", &self.tpot),
                ("e2e", &self.e2e),
                ("reconfig_exposed", &self.reconfig_exposed),
                ("reconfig_hidden", &self.reconfig_hidden),
                ("recompute_overhead", &self.recompute_overhead),
            ],
        }
    }

    /// JSON snapshot of the whole bundle — the per-cell metrics payload
    /// `codesign --out` embeds. Shorthand for `registry().to_json()`.
    pub fn summary_json(&self) -> crate::util::json::Value {
        self.registry().to_json()
    }
}

/// A named, uniform view over a metric bundle: counters, gauges, and
/// histograms addressable by stable string names, with a deterministic
/// JSON snapshot. Borrowing (not owning) keeps the hot path free of any
/// registry bookkeeping — engines mutate plain [`ServerMetrics`] fields
/// and the registry is materialized only at report time.
#[derive(Debug)]
pub struct MetricsRegistry<'m> {
    pub counters: Vec<(&'static str, &'m Counter)>,
    pub gauges: Vec<(&'static str, f64)>,
    pub histograms: Vec<(&'static str, &'m Histogram)>,
}

impl MetricsRegistry<'_> {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| *n == name).map(|(_, c)| c.get())
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.iter().find(|(n, _)| *n == name).map(|(_, h)| *h)
    }

    /// `{counters: {..}, gauges: {..}, histograms: {name: summary}}`,
    /// insertion-ordered (hence byte-deterministic).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::Obj(vec![
            (
                "counters".into(),
                Value::Obj(
                    self.counters
                        .iter()
                        .map(|(n, c)| ((*n).to_string(), Value::Num(c.get() as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges".into(),
                Value::Obj(
                    self.gauges
                        .iter()
                        .map(|(n, v)| ((*n).to_string(), Value::Num(*v)))
                        .collect(),
                ),
            ),
            (
                "histograms".into(),
                Value::Obj(
                    self.histograms
                        .iter()
                        .map(|(n, h)| ((*n).to_string(), h.summary_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let mut c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for ms in [1.0, 2.0, 4.0, 8.0, 100.0] {
            h.record(ms / 1e3);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 0.023).abs() < 1e-3);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.1);
        // p50 within a factor-2 bucket of the true median (4 ms).
        let p50 = h.quantile(0.5);
        assert!((0.002..=0.008).contains(&p50), "p50 {p50}");
        assert!(h.quantile(1.0) >= h.quantile(0.5));
    }

    #[test]
    fn histogram_empty_is_safe() {
        let h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.min(), 0.0);
    }

    #[test]
    fn quantiles_monotone() {
        let mut h = Histogram::default();
        let mut x = 0.0001;
        for _ in 0..100 {
            h.record(x);
            x *= 1.1;
        }
        let mut last = 0.0;
        for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let v = h.quantile(q);
            assert!(v >= last, "q={q}");
            last = v;
        }
    }

    #[test]
    fn quantiles_are_exact_within_reservoir() {
        let mut h = Histogram::default();
        // 100 samples 1..=100 ms: exact p50 = 50 ms, p95 = 95 ms,
        // p99 = 99 ms — a log-bucketed estimate could only answer with a
        // power-of-two edge.
        for ms in 1..=100 {
            h.record(ms as f64 / 1e3);
        }
        assert_eq!(h.quantile(0.50), 0.050);
        assert_eq!(h.quantile(0.95), 0.095);
        assert_eq!(h.quantile(0.99), 0.099);
        assert_eq!(h.quantile(1.0), 0.100);
        assert_eq!(h.quantile(0.0), 0.001);
    }

    #[test]
    fn summary_json_has_percentile_keys() {
        let mut h = Histogram::default();
        h.record(0.004);
        h.record(0.008);
        let v = h.summary_json();
        assert_eq!(v.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("p50_s").unwrap().as_f64(), Some(0.004));
        assert_eq!(v.get("p99_s").unwrap().as_f64(), Some(0.008));
        assert!(v.get("mean_s").is_some() && v.get("max_s").is_some());
    }

    #[test]
    fn report_includes_swap_directions() {
        let mut m = ServerMetrics::default();
        m.swaps_to_prefill.add(3);
        m.swaps_to_decode.add(4);
        m.reconfigurations.add(7);
        assert!(m.report().contains("(to-prefill 3, to-decode 4)"));
    }

    #[test]
    fn hidden_fraction_aggregates_per_swap_exposure() {
        let mut m = ServerMetrics::default();
        assert_eq!(m.reconfig_hidden_fraction(), 0.0);
        // One fully hidden swap, one fully exposed, one 50/50.
        m.record_reconfig_exposure(0.040, 0.0);
        m.record_reconfig_exposure(0.040, 0.040);
        m.record_reconfig_exposure(0.040, 0.020);
        assert!((m.reconfig_hidden_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(m.reconfig_hidden.count(), 3);
        assert!(m.report().contains("hidden fraction 50%"));
    }

    #[test]
    fn record_reconfig_exposure_clamps_over_exposure() {
        // A swap that waited behind an earlier PCAP load can report
        // exposed > latency; hidden must clamp at zero, not go negative.
        let mut m = ServerMetrics::default();
        m.record_reconfig_exposure(0.040, 0.100);
        assert_eq!(m.reconfig_hidden.max(), 0.0);
        assert_eq!(m.reconfig_hidden_fraction(), 0.0);
    }

    #[test]
    fn registry_names_every_metric() {
        let mut m = ServerMetrics::default();
        m.requests_completed.add(3);
        m.tokens_generated.add(99);
        m.ttft.record(0.5);
        m.kv_pool_high_water.observe(17);
        let r = m.registry();
        assert_eq!(r.counter("requests_completed"), Some(3));
        assert_eq!(r.counter("tokens_generated"), Some(99));
        assert_eq!(r.counter("nonexistent"), None);
        assert_eq!(r.gauge("kv_pool_high_water_pages"), Some(17.0));
        assert_eq!(r.histogram("ttft").unwrap().count(), 1);
        let v = m.summary_json();
        assert_eq!(
            v.get("counters").unwrap().get("tokens_generated").unwrap().as_f64(),
            Some(99.0)
        );
        assert_eq!(
            v.get("gauges").unwrap().get("reconfig_hidden_fraction").unwrap().as_f64(),
            Some(0.0)
        );
        assert!(v.get("histograms").unwrap().get("tpot").is_some());
        // Deterministic serialization.
        assert_eq!(v.to_string(), m.summary_json().to_string());
    }

    #[test]
    fn slo_attainment_counts_shed_against_completed() {
        let mut m = ServerMetrics::default();
        assert_eq!(m.slo_attainment(), 1.0, "idle node violates nothing");
        m.requests_completed.add(3);
        m.requests_shed.inc();
        assert!((m.slo_attainment() - 0.75).abs() < 1e-12);
        m.tokens_generated.add(150);
        assert!((m.slo_goodput_tps(10.0) - 15.0).abs() < 1e-12);
        assert_eq!(m.slo_goodput_tps(0.0), 0.0);
        assert!(m.report().contains("shed 1"));
        assert!(m.report().contains("SLO attainment 75.0%"));
        let r = m.registry();
        assert_eq!(r.counter("requests_shed"), Some(1));
        assert_eq!(r.counter("swap_failures"), Some(0));
        assert_eq!(r.gauge("slo_attainment"), Some(0.75));
        assert_eq!(r.gauge("degraded_seconds"), Some(0.0));
    }

    #[test]
    fn peak_keeps_maximum() {
        let mut p = Peak::default();
        assert_eq!(p.get(), 0);
        p.observe(5);
        p.observe(3);
        assert_eq!(p.get(), 5);
        p.observe(9);
        assert_eq!(p.get(), 9);
    }

    #[test]
    fn report_includes_pool_line() {
        let mut m = ServerMetrics::default();
        m.kv_pool_high_water.observe(42);
        m.kv_evictions.inc();
        assert!(m.report().contains("high-water 42 pages"));
        assert!(m.report().contains("evictions 1"));
    }

    #[test]
    fn throughput_from_tpot() {
        let mut m = ServerMetrics::default();
        m.tpot.record(0.040);
        m.tpot.record(0.040);
        assert!((m.decode_throughput() - 25.0).abs() < 0.1);
    }
}
