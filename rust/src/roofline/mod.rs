//! Roofline analysis (Fig. 4a): arithmetic intensity vs attainable
//! performance for the major kernels in each phase.
//!
//! The paper uses a *qualitative* roofline to argue where resources should
//! go; this module computes the actual numbers from the workload model and
//! device ceilings so the argument can be checked: decode attention sits
//! deep in the memory-bound region, prefill attention far into the
//! compute-bound region, and the decode-stage linears close to their
//! (streaming) roof.

use crate::engines::{AcceleratorDesign, calib};
use crate::fpga::DeviceConfig;
use crate::memory::MemorySystem;
use crate::model::{ComponentOps, DecodeStepWork, ModelShape, PhaseWork, PrefillWork};

/// Which ceiling binds a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
}

/// One kernel's position on the roofline.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub kernel: String,
    /// MACs per DDR byte.
    pub arithmetic_intensity: f64,
    /// MAC/s the kernel would need to be compute-limited at this AI.
    pub attainable_rate: f64,
    /// MAC/s ceiling of the engine assigned to this kernel.
    pub compute_roof: f64,
    /// B/s ceiling of the memory system for this kernel's streams.
    pub memory_roof_bytes: f64,
    pub bound: Bound,
    /// attainable / compute_roof — how close the kernel runs to its roof.
    pub roof_fraction: f64,
}

/// The device-level roofline: compute ceilings per engine + memory ceiling.
#[derive(Debug, Clone)]
pub struct RooflineModel {
    pub design: AcceleratorDesign,
    pub device: DeviceConfig,
    mem: MemorySystem,
}

/// The ridge point (MACs/byte) where a kernel transitions between regimes
/// for a given compute roof and memory roof.
pub fn ridge_point(compute_roof: f64, memory_roof: f64) -> f64 {
    compute_roof / memory_roof
}

impl RooflineModel {
    pub fn new(design: AcceleratorDesign, device: DeviceConfig) -> Self {
        let mem = MemorySystem::for_device(&device);
        Self { design, device, mem }
    }

    fn point(
        &self,
        kernel: &str,
        ops: ComponentOps,
        compute_roof: f64,
        memory_roof: f64,
    ) -> RooflinePoint {
        let ai = ops.arithmetic_intensity();
        let attainable = compute_roof.min(ai * memory_roof);
        let bound = if ai * memory_roof < compute_roof {
            Bound::Memory
        } else {
            Bound::Compute
        };
        RooflinePoint {
            kernel: kernel.to_string(),
            arithmetic_intensity: ai,
            attainable_rate: attainable,
            compute_roof,
            memory_roof_bytes: memory_roof,
            bound,
            roof_fraction: attainable / compute_roof,
        }
    }

    /// The three Fig. 4a panels at context length `l`.
    pub fn analyze(&self, shape: &ModelShape, l: usize) -> Vec<RooflinePoint> {
        let clock = self.device.clock_hz();
        let pre = PrefillWork { shape: *shape, l };
        let dec = DecodeStepWork { shape: *shape, l };

        // Decode attention: engine MAC roof vs its KV bandwidth.
        let dec_attn = self.point(
            "decode-attention",
            dec.attention(),
            self.design.decode_attn.mac_rate(clock),
            self.design.decode_attn.kv_bandwidth(&self.mem),
        );
        // Prefill attention: engine MAC roof vs general DDR streaming.
        let pre_attn = self.point(
            "prefill-attention",
            pre.attention(),
            self.design.prefill_attn.mac_rate(clock),
            self.mem.aggregate_peak * calib::KV_CONTROLLER_EFF,
        );
        // Linear (TLMM): lookup-accumulate roof vs the weight stream.
        let tlmm_roof = self.design.tlmm.n_pe as f64 * 4.0 * clock;
        let weight_bw = shape.ternary_weight_bytes()
            / self.design.tlmm.weight_stream_time(shape, &self.mem);
        let dec_lin = self.point("decode-linear", dec.projection(), tlmm_roof, weight_bw);
        let pre_lin = self.point("prefill-linear", pre.projection(), tlmm_roof, weight_bw);

        vec![dec_attn, pre_attn, dec_lin, pre_lin]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn model() -> RooflineModel {
        RooflineModel::new(AcceleratorDesign::pd_swap(), KV260.clone())
    }

    fn by_name(points: &[RooflinePoint], name: &str) -> RooflinePoint {
        points.iter().find(|p| p.kernel == name).unwrap().clone()
    }

    #[test]
    fn fig4a_regimes() {
        // The paper's qualitative placement, computed: decode attention
        // memory-bound, prefill attention compute-bound.
        let pts = model().analyze(&BITNET_0_73B, 1024);
        assert_eq!(by_name(&pts, "decode-attention").bound, Bound::Memory);
        assert_eq!(by_name(&pts, "prefill-attention").bound, Bound::Compute);
    }

    #[test]
    fn prefill_ai_dwarfs_decode_ai() {
        let pts = model().analyze(&BITNET_0_73B, 1024);
        let pre = by_name(&pts, "prefill-attention").arithmetic_intensity;
        let dec = by_name(&pts, "decode-attention").arithmetic_intensity;
        assert!(pre > 20.0 * dec, "pre {pre:.2} dec {dec:.2}");
    }

    #[test]
    fn decode_linear_runs_near_its_roof() {
        // §3.3.1: "the decode-stage linear modules ... operate close to
        // their roofline limits" — the streaming roof, not the MAC roof.
        let pts = model().analyze(&BITNET_0_73B, 1024);
        let lin = by_name(&pts, "decode-linear");
        assert_eq!(lin.bound, Bound::Memory);
        // Attainable = AI * weight_bw; actual rate achieved = work/time is
        // the same quantity by construction, so roof_fraction < 1 but the
        // memory roof itself is saturated.
        assert!(lin.attainable_rate > 0.0);
    }

    #[test]
    fn ridge_point_math() {
        assert!((ridge_point(10.0, 2.0) - 5.0).abs() < 1e-12);
        // AI above the ridge -> compute bound.
        let m = model();
        let pts = m.analyze(&BITNET_0_73B, 512);
        for p in pts {
            let ridge = ridge_point(p.compute_roof, p.memory_roof_bytes);
            match p.bound {
                Bound::Compute => assert!(p.arithmetic_intensity >= ridge),
                Bound::Memory => assert!(p.arithmetic_intensity < ridge),
            }
        }
    }

    #[test]
    fn decode_attention_ai_constant_in_l() {
        // Both MACs and bytes scale linearly with context: AI ~ constant.
        let m = model();
        let a = by_name(&m.analyze(&BITNET_0_73B, 256), "decode-attention")
            .arithmetic_intensity;
        let b = by_name(&m.analyze(&BITNET_0_73B, 2048), "decode-attention")
            .arithmetic_intensity;
        assert!((a - b).abs() / a < 0.05, "{a} vs {b}");
    }
}
