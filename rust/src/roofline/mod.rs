//! Roofline analysis (Fig. 4a): arithmetic intensity vs attainable
//! performance for the major kernels in each phase.
//!
//! The paper uses a *qualitative* roofline to argue where resources should
//! go; this module computes the actual numbers from the workload model and
//! device ceilings so the argument can be checked: decode attention sits
//! deep in the memory-bound region, prefill attention far into the
//! compute-bound region, and the decode-stage linears close to their
//! (streaming) roof.

use crate::engines::{AcceleratorDesign, LatencySurface, calib};
use crate::fpga::DeviceConfig;
use crate::memory::MemorySystem;
use crate::model::{ComponentOps, DecodeStepWork, ModelShape, PhaseWork, PrefillWork};

/// Which ceiling binds a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
}

/// One kernel's position on the roofline.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub kernel: String,
    /// MACs per DDR byte.
    pub arithmetic_intensity: f64,
    /// MAC/s the kernel would need to be compute-limited at this AI.
    pub attainable_rate: f64,
    /// MAC/s ceiling of the engine assigned to this kernel.
    pub compute_roof: f64,
    /// B/s ceiling of the memory system for this kernel's streams.
    pub memory_roof_bytes: f64,
    pub bound: Bound,
    /// attainable / compute_roof — how close the kernel runs to its roof.
    pub roof_fraction: f64,
}

/// The device-level roofline: compute ceilings per engine + memory ceiling.
#[derive(Debug, Clone)]
pub struct RooflineModel {
    pub design: AcceleratorDesign,
    pub device: DeviceConfig,
    mem: MemorySystem,
}

/// The ridge point (MACs/byte) where a kernel transitions between regimes
/// for a given compute roof and memory roof.
pub fn ridge_point(compute_roof: f64, memory_roof: f64) -> f64 {
    compute_roof / memory_roof
}

/// Per-kernel ceilings resolved for one shape — the expensive half of
/// [`RooflineModel::analyze`] (engine rates, effective bandwidths, the
/// weight-stream evaluation), cached once so the per-`l` queries the
/// Fig. 4a sweeps and benches issue are pure arithmetic. Built through a
/// [`LatencySurface`], so the numbers are bit-identical to the direct
/// derivation.
#[derive(Debug, Clone)]
pub struct ShapeRoofs {
    shape: ModelShape,
    /// (compute MAC/s, memory B/s) per kernel.
    dec_attn: (f64, f64),
    pre_attn: (f64, f64),
    linear: (f64, f64),
}

fn point(kernel: &str, ops: ComponentOps, compute_roof: f64, memory_roof: f64) -> RooflinePoint {
    let ai = ops.arithmetic_intensity();
    let attainable = compute_roof.min(ai * memory_roof);
    let bound = if ai * memory_roof < compute_roof {
        Bound::Memory
    } else {
        Bound::Compute
    };
    RooflinePoint {
        kernel: kernel.to_string(),
        arithmetic_intensity: ai,
        attainable_rate: attainable,
        compute_roof,
        memory_roof_bytes: memory_roof,
        bound,
        roof_fraction: attainable / compute_roof,
    }
}

impl ShapeRoofs {
    /// The three Fig. 4a panels at context length `l`.
    pub fn analyze_at(&self, l: usize) -> Vec<RooflinePoint> {
        let pre = PrefillWork { shape: self.shape, l };
        let dec = DecodeStepWork { shape: self.shape, l };
        vec![
            point("decode-attention", dec.attention(), self.dec_attn.0, self.dec_attn.1),
            point("prefill-attention", pre.attention(), self.pre_attn.0, self.pre_attn.1),
            point("decode-linear", dec.projection(), self.linear.0, self.linear.1),
            point("prefill-linear", pre.projection(), self.linear.0, self.linear.1),
        ]
    }
}

impl RooflineModel {
    pub fn new(design: AcceleratorDesign, device: DeviceConfig) -> Self {
        let mem = MemorySystem::for_device(&device);
        Self { design, device, mem }
    }

    /// Resolve the per-kernel ceilings for `shape` once; reuse the result
    /// across context lengths (the hot pattern of the eval sweeps).
    pub fn roofs_for(&self, shape: &ModelShape) -> ShapeRoofs {
        let clock = self.device.clock_hz();
        let surface = LatencySurface::new(&self.design, &self.device, shape, 32);
        // Linear (TLMM): lookup-accumulate roof vs the weight stream.
        let tlmm_roof = self.design.tlmm.n_pe as f64 * 4.0 * clock;
        let weight_bw = shape.ternary_weight_bytes() / surface.weight_stream_time();
        ShapeRoofs {
            shape: *shape,
            // Decode attention: engine MAC roof vs its KV bandwidth.
            dec_attn: (surface.decode_attn_mac_rate(), surface.kv_bandwidth()),
            // Prefill attention: engine MAC roof vs general DDR streaming.
            pre_attn: (
                surface.prefill_attn_mac_rate(),
                self.mem.aggregate_peak * calib::KV_CONTROLLER_EFF,
            ),
            linear: (tlmm_roof, weight_bw),
        }
    }

    /// The three Fig. 4a panels at context length `l` (one-shot form of
    /// [`Self::roofs_for`] + [`ShapeRoofs::analyze_at`]).
    pub fn analyze(&self, shape: &ModelShape, l: usize) -> Vec<RooflinePoint> {
        self.roofs_for(shape).analyze_at(l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn model() -> RooflineModel {
        RooflineModel::new(AcceleratorDesign::pd_swap(), KV260.clone())
    }

    fn by_name(points: &[RooflinePoint], name: &str) -> RooflinePoint {
        points.iter().find(|p| p.kernel == name).unwrap().clone()
    }

    #[test]
    fn fig4a_regimes() {
        // The paper's qualitative placement, computed: decode attention
        // memory-bound, prefill attention compute-bound.
        let pts = model().analyze(&BITNET_0_73B, 1024);
        assert_eq!(by_name(&pts, "decode-attention").bound, Bound::Memory);
        assert_eq!(by_name(&pts, "prefill-attention").bound, Bound::Compute);
    }

    #[test]
    fn prefill_ai_dwarfs_decode_ai() {
        let pts = model().analyze(&BITNET_0_73B, 1024);
        let pre = by_name(&pts, "prefill-attention").arithmetic_intensity;
        let dec = by_name(&pts, "decode-attention").arithmetic_intensity;
        assert!(pre > 20.0 * dec, "pre {pre:.2} dec {dec:.2}");
    }

    #[test]
    fn decode_linear_runs_near_its_roof() {
        // §3.3.1: "the decode-stage linear modules ... operate close to
        // their roofline limits" — the streaming roof, not the MAC roof.
        let pts = model().analyze(&BITNET_0_73B, 1024);
        let lin = by_name(&pts, "decode-linear");
        assert_eq!(lin.bound, Bound::Memory);
        // Attainable = AI * weight_bw; actual rate achieved = work/time is
        // the same quantity by construction, so roof_fraction < 1 but the
        // memory roof itself is saturated.
        assert!(lin.attainable_rate > 0.0);
    }

    #[test]
    fn ridge_point_math() {
        assert!((ridge_point(10.0, 2.0) - 5.0).abs() < 1e-12);
        // AI above the ridge -> compute bound.
        let m = model();
        let pts = m.analyze(&BITNET_0_73B, 512);
        for p in pts {
            let ridge = ridge_point(p.compute_roof, p.memory_roof_bytes);
            match p.bound {
                Bound::Compute => assert!(p.arithmetic_intensity >= ridge),
                Bound::Memory => assert!(p.arithmetic_intensity < ridge),
            }
        }
    }

    #[test]
    fn decode_attention_ai_constant_in_l() {
        // Both MACs and bytes scale linearly with context: AI ~ constant.
        let m = model();
        let a = by_name(&m.analyze(&BITNET_0_73B, 256), "decode-attention")
            .arithmetic_intensity;
        let b = by_name(&m.analyze(&BITNET_0_73B, 2048), "decode-attention")
            .arithmetic_intensity;
        assert!((a - b).abs() / a < 0.05, "{a} vs {b}");
    }
}
